"""Production mesh factory (DESIGN.md §7).

A function — not a module-level constant — so importing this module never
touches jax device state. The dry-run entrypoint sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
import; smoke tests and benchmarks see the real single CPU device.

Single pod : (16, 16)      axes ("data", "model")   = 256 chips (v5e pod)
Multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

In the AVERY mapping, the "pod" axis doubles as the edge/cloud
disaggregation boundary for split serving (launch/serve.py): pod 0 runs
the head + bottleneck encoder, pod 1 the decoder + tail, and the
inter-pod link carries exactly the compressed boundary payload.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Single-host mesh for tests and sharded serving: uses however many
    devices exist. ``model`` (the tensor-parallel axis size) is clamped
    to the device count — asking for more shards than devices degrades
    to whatever the host has instead of building an empty ``(0, k)``
    mesh — and must divide the remaining device count."""
    n = len(jax.devices())
    if model < 1:
        raise ValueError(f"model axis must be >= 1, got {model}")
    model = min(model, n)
    if n % model:
        raise ValueError(
            f"model={model} does not divide the {n} local devices; pick a "
            f"divisor of {n} (or force more host devices via "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return jax.make_mesh((n // model, model), ("data", "model"))
