"""Training launcher.

Two modes:
  * generic arch training on synthetic LM data (reduced configs run on
    CPU; full configs are for the dry-run only):
      python -m repro.launch.train --arch phi4-mini-3.8b --reduced \
          --steps 50 --batch 8 --seq 128
  * the AVERY offline phase (lisa-mini + fine-tune + bottleneck tiers +
    LUT), producing checkpoints consumed by serve.py / benchmarks:
      python -m repro.launch.train --lisa --steps 300
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro import optim
from repro.checkpoint import save_pytree
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.data import lm
from repro.models import init_params, make_train_step


def train_arch(arch: str, reduced: bool, steps: int, batch: int, seq: int,
               lr: float, out: str) -> None:
    cfg = get_reduced(arch) if reduced else get_config(arch)
    print(f"[train] {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"params={cfg.param_count()/1e6:.1f}M")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.adamw(optim.cosine_with_warmup(lr, max(1, steps // 10), steps))
    state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))
    stream = lm.lm_stream(0, cfg, batch, seq)
    t0 = time.time()
    for i in range(steps):
        batch_np = next(stream)
        params, state, metrics = step_fn(
            params, state, {k: jax.numpy.asarray(v)
                            for k, v in batch_np.items()})
        if i % max(1, steps // 10) == 0 or i == steps - 1:
            print(f"  step {i:5d} loss={float(metrics['loss']):.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if out:
        save_pytree(os.path.join(out, cfg.name), params)
        print(f"[train] saved params to {out}/{cfg.name}")


def train_lisa_system(steps: int, bn_steps: int, ft_steps: int, out: str
                      ) -> None:
    from repro.configs.lisa_mini import CONFIG as pcfg
    from repro.core import profile as prof
    params, params_ft, bns = prof.train_full_system(
        pcfg, steps=steps, bn_steps=bn_steps, ft_steps=ft_steps)
    lut = prof.build_lut(pcfg, params, params_ft, bns)
    os.makedirs(out, exist_ok=True)
    save_pytree(os.path.join(out, "lisa_mini_original"), params)
    save_pytree(os.path.join(out, "lisa_mini_finetuned"), params_ft)
    for r, bp in bns.items():
        save_pytree(os.path.join(out, f"bottleneck_r{r}"), bp)
    lut.save(os.path.join(out, "lut.json"))
    print("[train] LUT:")
    for t in lut.tiers:
        print(f"  {t.name:16s} r={t.ratio:<5} base={t.acc_base:.4f} "
              f"ft={t.acc_finetuned:.4f} payload={t.payload_mb:.2f}MB")
    print(f"[train] artifacts saved under {out}/")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--lisa", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--bn-steps", type=int, default=200)
    ap.add_argument("--ft-steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--out", default="benchmarks/artifacts/checkpoints")
    args = ap.parse_args()
    if args.lisa:
        train_lisa_system(args.steps, args.bn_steps, args.ft_steps, args.out)
    elif args.arch:
        train_arch(args.arch, args.reduced, args.steps, args.batch, args.seq,
                   args.lr, args.out)
    else:
        ap.error("pass --arch <id> or --lisa")


if __name__ == "__main__":
    main()
