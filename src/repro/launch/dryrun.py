import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
combination on the production meshes, and extract the roofline terms.

For each combo this produces:
  * compiled.memory_analysis()  — per-device bytes (does it fit?)
  * compiled.cost_analysis()    — HLO FLOPs / bytes accessed
  * collective bytes parsed from the partitioned HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute)
  * MODEL_FLOPS = 2·N_active·D (x3 for training) and the HLO/model ratio

Results land in benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json
and are consumed by benchmarks/bench_roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import dataclasses
import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import (decode_step, init_cache, init_params, loss_fn,
                          make_train_step, prefill_step)
from repro.models import stack
from repro.models.config import ModelConfig
from repro.sharding import specs as sh

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SLIDING_WINDOW = 8192            # long_500k variant for dense archs


def adapt_config(cfg: ModelConfig, shape: ShapeSpec) -> Optional[ModelConfig]:
    """Apply shape-dependent config adaptation; None => combo skipped."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return None              # encoder-only (hubert): no decode exists
    if shape.name == "long_500k" and not cfg.subquadratic:
        if cfg.arch_type in ("dense", "moe", "vlm"):
            # beyond-paper sliding-window variant (DESIGN.md §3)
            return cfg.with_sliding_window(SLIDING_WINDOW)
        return None
    if shape.name == "long_500k" and cfg.arch_type == "hybrid":
        # shared attention block also windows at 500k context
        return cfg.with_sliding_window(SLIDING_WINDOW)
    return cfg


def cache_width(cfg: ModelConfig, shape: ShapeSpec) -> int:
    w = shape.seq_len
    if cfg.sliding_window:
        w = min(w, cfg.sliding_window)
    return w


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    act = cfg.adtype
    if shape.kind in ("train", "prefill"):
        if cfg.modality == "audio":
            return {"frames": SDS((B, S, cfg.frontend_dim), act),
                    "targets": SDS((B, S), tok),
                    "mask_positions": SDS((B, S), jnp.bool_)}
        if cfg.modality == "vlm":
            return {"tokens": SDS((B, S), tok),
                    "vision_embeds": SDS((B, cfg.num_vision_tokens,
                                          cfg.frontend_dim), act),
                    "positions": SDS((3, B, S), tok)}
        return {"tokens": SDS((B, S), tok)}
    # decode: one token against a seq_len context
    return {"tokens": SDS((B, 1), tok), "pos": SDS((), tok)}


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def abstract_cache(cfg: ModelConfig, shape: ShapeSpec):
    W = cache_width(cfg, shape)
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, W))


# ---------------------------------------------------------------------------
# HLO collective-bytes extraction
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in partitioned HLO.
    (Per-device bytes, since post-SPMD HLO shapes are per-device.)"""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        lhs, rhs = line.split("=", 1)
        rhs = rhs.strip()
        m = re.match(r"^(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z0-9\-]+)",
                     rhs)
        if not m:
            continue
        op = m.group(2)
        # match e.g. all-reduce, all-gather-start (count once, not -done)
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                out[c] += _shape_bytes(m.group(1))
                out["count"] += 1
                break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def build_lowerable(cfg: ModelConfig, shape: ShapeSpec, mesh,
                    fsdp: bool = False):
    """Returns (jitted_fn, example_args) ready for .lower(*args)."""
    aparams = abstract_params(cfg)
    pspecs = sh.param_specs(cfg, aparams, mesh, fsdp=fsdp)
    psh = sh.to_shardings(mesh, pspecs)
    batch = input_specs(cfg, shape)

    if shape.kind == "train":
        opt = optim.adamw(3e-4)
        aopt = jax.eval_shape(opt.init, aparams)
        ospecs = sh.opt_state_specs(cfg, aopt, pspecs, mesh)
        osh = sh.to_shardings(mesh, ospecs)
        bsh = sh.to_shardings(mesh, sh.batch_specs(batch, mesh))
        fn = jax.jit(make_train_step(cfg, opt),
                     in_shardings=(psh, osh, bsh),
                     out_shardings=(psh, osh, None),
                     donate_argnums=(0, 1))
        return fn, (aparams, aopt, batch)

    if shape.kind == "prefill":
        bsh = sh.to_shardings(mesh, sh.batch_specs(batch, mesh))
        fn = jax.jit(lambda p, b: prefill_step(p, cfg, b),
                     in_shardings=(psh, bsh))
        return fn, (aparams, batch)

    # decode
    acache = abstract_cache(cfg, shape)
    cspecs = sh.cache_specs(cfg, acache, mesh)
    csh = sh.to_shardings(mesh, cspecs)
    tok_sh = sh.to_shardings(mesh, sh.batch_specs(
        {"tokens": batch["tokens"]}, mesh))["tokens"]
    pos = shape.seq_len - 1
    fn = jax.jit(
        lambda p, c, t, pos: decode_step(p, cfg, c, t, pos),
        in_shardings=(psh, csh, tok_sh, None),
        out_shardings=(None, csh),
        donate_argnums=(1,))
    return fn, (aparams, acache, batch["tokens"], SDS((), jnp.int32))


# ---------------------------------------------------------------------------
# cost extraction via depth-variant extrapolation
#
# XLA's cost_analysis counts a while (lax.scan) body ONCE regardless of trip
# count, so FLOPs/bytes/collectives from the scanned full-depth lowering are
# wrong. Per-layer costs are exactly linear in group counts, so we lower
# small fully-unrolled depth variants and solve
#     m(counts) = fixed + sum_g counts_g * per_layer_g
# exactly, then evaluate at the real depths. Memory analysis still comes
# from the full scanned lowering (buffers are reused across iterations, so
# scan memory IS the truth).
# ---------------------------------------------------------------------------


def _cfg_with_counts(cfg: ModelConfig, counts) -> ModelConfig:
    if cfg.arch_type == "hybrid":
        return cfg.replace(num_layers=counts[0] * cfg.hybrid.attn_every,
                           scan_unroll=True)
    if cfg.moe is not None and cfg.moe.first_k_dense:
        import dataclasses as dc
        return cfg.replace(
            num_layers=counts[0] + counts[1],
            moe=dc.replace(cfg.moe, first_k_dense=counts[0]),
            scan_unroll=True)
    return cfg.replace(num_layers=counts[0], scan_unroll=True)


def _real_counts(cfg: ModelConfig):
    if cfg.arch_type == "hybrid":
        return (cfg.num_layers // cfg.hybrid.attn_every,)
    if cfg.moe is not None and cfg.moe.first_k_dense:
        return (cfg.moe.first_k_dense, cfg.num_layers - cfg.moe.first_k_dense)
    return (cfg.num_layers,)


def _variant_counts(cfg: ModelConfig):
    if cfg.moe is not None and cfg.moe.first_k_dense:
        return [(1, 2), (2, 4), (1, 4)]
    return [(1,), (2,)]


def _extract_metrics(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0)),
           "coll_total": float(coll["total"])}
    for c in _COLLECTIVES:
        out[f"coll_{c}"] = float(coll[c])
    return out


def measure_costs(cfg: ModelConfig, shape: ShapeSpec, mesh,
                  fsdp: bool = False) -> Dict[str, float]:
    """Extrapolated full-depth per-device costs from unrolled variants."""
    variants = _variant_counts(cfg)
    rows = []
    metrics = []
    for counts in variants:
        vcfg = _cfg_with_counts(cfg, counts)
        fn, args = build_lowerable(vcfg, shape, mesh, fsdp=fsdp)
        with mesh:
            compiled = fn.lower(*args).compile()
        rows.append((1.0,) + tuple(float(c) for c in counts))
        metrics.append(_extract_metrics(compiled))
    A = np.array(rows)                      # (V, 1+G)
    real = np.array((1.0,) + tuple(float(c) for c in _real_counts(cfg)))
    out: Dict[str, float] = {}
    for key in metrics[0]:
        y = np.array([m[key] for m in metrics])
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        out[key] = float(max(0.0, real @ coef))
    return out


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12              # bf16 / chip (v5e)
HBM_BW = 819e9                   # bytes/s / chip
ICI_BW = 50e9                    # bytes/s / link


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    n_active = stack.count_params(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # one token


def run_combo(arch: str, shape_name: str, multi_pod: bool,
              out_dir: str = "benchmarks/artifacts/dryrun",
              cfg_override: Optional[ModelConfig] = None,
              tag: str = "", with_costs: Optional[bool] = None,
              fsdp: bool = False) -> Dict[str, Any]:
    # roofline cost extraction is a single-pod deliverable; the multi-pod
    # pass proves the "pod" axis shards (lower+compile+memory only)
    if with_costs is None:
        with_costs = not multi_pod
    shape = SHAPES[shape_name]
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    cfg = adapt_config(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "tag": tag}
    if cfg is None:
        rec["skipped"] = ("encoder-only: no decode step"
                          if shape.kind == "decode" else "not applicable")
        _save(rec, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    try:
        t0 = time.time()
        fn, args = build_lowerable(cfg, shape, mesh, fsdp=fsdp)
        with mesh:
            lowered = fn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        mem = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                rec[attr] = int(v)
        # raw (scan-body-counted-once) numbers, for reference only
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["raw_scan_flops"] = float(cost.get("flops", 0.0))
        rec["raw_scan_collectives"] = collective_bytes(compiled.as_text())
        if not with_costs:
            rec["n_chips"] = n_chips
            _save(rec, out_dir)
            return rec

        # extrapolated full-depth per-device costs (see header comment)
        t0 = time.time()
        costs = measure_costs(cfg, shape, mesh, fsdp=fsdp)
        rec["cost_extraction_s"] = round(time.time() - t0, 2)
        rec["hlo_flops"] = costs["flops"]
        rec["hlo_bytes"] = costs["bytes"]
        rec["collectives"] = {k[len("coll_"):]: v for k, v in costs.items()
                              if k.startswith("coll_")}
        rec["collectives"]["total"] = costs["coll_total"]

        # roofline terms (seconds). Costs are PER-DEVICE (post-SPMD HLO).
        mf = model_flops(cfg, shape)
        rec["model_flops"] = mf
        rec["compute_term_s"] = rec["hlo_flops"] / PEAK_FLOPS
        rec["memory_term_s"] = rec["hlo_bytes"] / HBM_BW
        rec["collective_term_s"] = rec["collectives"]["total"] / ICI_BW
        rec["useful_flops_ratio"] = (mf / n_chips) / max(rec["hlo_flops"], 1)
        terms = {"compute": rec["compute_term_s"],
                 "memory": rec["memory_term_s"],
                 "collective": rec["collective_term_s"]}
        rec["bottleneck"] = max(terms, key=terms.get)
        rec["n_chips"] = n_chips
    except Exception as e:                                    # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"[:500]
    _save(rec, out_dir)
    return rec


def _save(rec: Dict[str, Any], out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_combo(arch, shape, mp, out_dir=args.out)
                if rec.get("skipped") or rec.get("error"):
                    status = rec.get("skipped") or rec.get("error")
                elif "hlo_flops" in rec:
                    status = (
                        f"ok flops={rec['hlo_flops']:.3g} "
                        f"bytes={rec['hlo_bytes']:.3g} "
                        f"coll={rec['collectives']['total']:.3g} "
                        f"bottleneck={rec['bottleneck']} "
                        f"[lower {rec['lower_s']}s compile {rec['compile_s']}s]")
                else:
                    status = (f"ok (compile-only) "
                              f"[lower {rec['lower_s']}s "
                              f"compile {rec['compile_s']}s]")
                print(f"[dryrun] {arch} x {shape} x "
                      f"{'2x16x16' if mp else '16x16'}: {status}", flush=True)


if __name__ == "__main__":
    main()
