import os
import sys

if "--dryrun" in sys.argv:
    # pod-disaggregated lowering needs the production device count; must be
    # set before jax initialises (same contract as launch/dryrun.py).
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512")

"""Serving launcher.

Local mode (default): closed-loop dual-stream serving of the trained
lisa-mini system over a simulated channel — batched operator requests,
intent gating, Algorithm-1 tier control:

  python -m repro.launch.serve --duration 120

Pod-disaggregated dry-run (DESIGN.md §4.1): lowers a split serve step on
the 2x16x16 multi-pod mesh where pod 0 ("edge") runs the SAM head +
bottleneck encoder and pod 1 ("cloud") decodes + runs the tail; the
boundary codes cross the pod axis via ppermute inside shard_map. Prints
the inter-pod collective bytes with and without the bottleneck:

  python -m repro.launch.serve --dryrun
"""
import argparse
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def serve_local(duration_s: float, seed: int, max_batch: int = 8,
                smoke: bool = False, batching: str = "microbatch") -> None:
    """Closed-loop local serving through the ``AveryEngine`` front door.

    ``smoke=True`` skips the offline training phase (random-init weights,
    paper LUT) so CI can exercise the full engine path — intent gate,
    policy, transport, batched cloud serving — in seconds. ``batching``
    picks the cloud discipline: closed tier-bucketed microbatches or the
    token-level in-flight batch (``"inflight"``)."""
    from repro.configs.lisa_mini import CONFIG as pcfg
    from repro.core import DualStreamExecutor, Intent
    from repro.core.vlm import iou_metrics
    from repro.data import floodseg, requests
    from repro.engine import AdaptivePolicy, AveryEngine, ChannelTransport
    from repro.network import paper_trace

    from repro.core import profile as prof
    if smoke:
        print("[serve] smoke mode: random-init weights, paper LUT")
        params, bns_by_name, lut = prof.random_init_system(pcfg, seed=seed)
    else:
        print("[serve] training lisa-mini system (offline phase, small "
              "budget)")
        params, params_ft, bns = prof.train_full_system(
            pcfg, steps=120, bn_steps=80, ft_steps=60, log=lambda s: None)
        lut = prof.build_lut(pcfg, params, params_ft, bns, eval_batches=2)
        bns_by_name = {lut.tiers[i].name: bns[r]
                       for i, r in enumerate(sorted(bns, reverse=True))}
    execu = DualStreamExecutor(pcfg=pcfg, params=params,
                               bottlenecks=bns_by_name, lut=lut)
    trace = paper_trace(seed=seed, duration_s=int(duration_s))
    engine = AveryEngine(
        lut=lut, executor=execu,
        transport=ChannelTransport.from_trace(trace),
        policy=AdaptivePolicy(), max_batch=max_batch, batching=batching)
    session = engine.session("operator-0")
    rng = np.random.RandomState(seed)

    # edge loop: each operator request goes through the engine — intent
    # gate, tier policy, edge encode, channel, cloud scheduler; full
    # microbatches are served as soon as they form (continuous batching),
    # stragglers at the end of the stream
    truth = {}
    futures = []
    for req in requests.mission_requests(seed, duration_s):
        batch = floodseg.make_batch(rng, 1, req.kind, augment=False,
                                    cls=req.cls)
        fut = session.submit(prompt=req.prompt,
                             images=jnp.asarray(batch["images"]),
                             query=batch["query"], time_s=req.time_s)
        truth[fut.request.request_id] = batch
        futures.append(fut)
    engine.drain()

    ious, ctx_correct = [], []
    for fut in futures:
        res = fut.result()
        if not res.feasible:           # no tier sustained F_I: never served
            continue
        batch = truth[res.request_id]
        if res.intent is Intent.CONTEXT:
            ctx_correct.append(
                float(np.argmax(res.answer_logits[0]) == batch["answer"][0]))
        else:
            m = iou_metrics(jnp.asarray(res.mask_logits),
                            jnp.asarray(batch["mask"]))
            ious.append(float(m["avg_iou"]))
    stats = engine.stats
    detail = (f"{stats['inflight_steps']:.0f} in-flight decode steps (mean "
              f"{stats['mean_live_slots']:.1f} live slots"
              if batching == "inflight" else
              f"{stats['n_microbatches']:.0f} microbatches (mean batch "
              f"{stats['mean_batch_size']:.1f}")
    print(f"[serve] served {len(ctx_correct)} context + {len(ious)} insight "
          f"requests over {duration_s:.0f}s in {detail}, "
          f"{stats['compiled_stages']:.0f} compiled cloud stages)")
    if ctx_correct:
        print(f"[serve] context answer accuracy: {np.mean(ctx_correct):.3f}")
    if ious:
        print(f"[serve] insight Average IoU:     {np.mean(ious):.3f}")
    lat = [r.latency_s for r in engine.transport.records]
    print(f"[serve] mean packet latency: {np.mean(lat):.3f}s "
          f"(p95 {np.percentile(lat, 95):.3f}s)")


# ---------------------------------------------------------------------------
# pod-disaggregated dry-run
# ---------------------------------------------------------------------------


def serve_dryrun() -> None:
    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.configs.lisa7b import CONFIG as pcfg
    from repro.core import bottleneck as bn
    from repro.core import vlm
    from repro.launch.dryrun import collective_bytes
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=True)
    d = pcfg.sam.d_model
    rank = bn.rank_for_ratio(d, 0.25, 2)
    B = 32                                      # images per serve step (2/chip-row)

    aparams = jax.eval_shape(
        lambda: vlm.init_lisa(pcfg, jax.random.PRNGKey(0)))
    abn = jax.eval_shape(
        lambda: bn.init_bottleneck(jax.random.PRNGKey(0),
                                   bn.BottleneckSpec(d, rank, 2)))
    images = jax.ShapeDtypeStruct((B, pcfg.image_size, pcfg.image_size, 3),
                                  jnp.bfloat16)
    query = jax.ShapeDtypeStruct((B, 8), jnp.int32)

    def split_serve(params, bnp, images, query):
        """Edge pod (pod 0) computes the head + compressed codes; ppermute
        moves ONLY the codes across the pod axis; cloud pod (pod 1) decodes
        and finishes. Data-parallel over ("data",) within each pod; model
        dim unsharded here (the encoder fits one chip's slice at B/16)."""
        def inner(imgs, q):
            a = vlm.sam_head(params, pcfg, imgs)                 # edge
            codes, scales = bn.encode(bnp, a)
            codes = jax.lax.ppermute(codes, "pod", [(0, 1)])     # the link
            scales = jax.lax.ppermute(scales, "pod", [(0, 1)])
            a_hat = bn.decode(bnp, codes, scales,
                              out_dtype=pcfg.sam.adtype)         # cloud
            feats = vlm.sam_tail(params, pcfg, a_hat)
            ctx = vlm.clip_encode(params, pcfg, imgs)
            ans, seg = vlm.llm_reason(params, pcfg, ctx, q)
            return vlm.mask_decode(params, pcfg, feats, seg)
        return shard_map(
            inner, mesh=mesh,
            in_specs=(P(("data",)), P(("data",))),
            out_specs=P(("data",)),
            check_rep=False)(images, query)

    with mesh:
        lowered = jax.jit(split_serve).lower(aparams, abn, images, query)
        compiled = lowered.compile()
    coll = collective_bytes(compiled.as_text())
    raw_bytes = B * pcfg.sam_tokens * d * 2      # uncompressed boundary
    comp_bytes = B * pcfg.sam_tokens * (rank + 4)
    print("[serve-dryrun] pod-disaggregated split serve step compiled on "
          f"{mesh.shape}")
    print(f"[serve-dryrun] collective-permute bytes (per device): "
          f"{coll['collective-permute']:.3g}")
    print(f"[serve-dryrun] boundary payload: uncompressed={raw_bytes/1e6:.2f}"
          f"MB vs bottlenecked={comp_bytes/1e6:.2f}MB "
          f"({raw_bytes/comp_bytes:.1f}x reduction on the pod link)")
    print(compiled.memory_analysis())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=8,
                    help="cloud scheduler microbatch / in-flight slot cap")
    ap.add_argument("--smoke", action="store_true",
                    help="skip offline training: random-init weights + "
                         "paper LUT (fast engine smoke for CI)")
    ap.add_argument("--batching", choices=("microbatch", "inflight"),
                    default="microbatch",
                    help="cloud serving discipline: closed microbatches or "
                         "token-level in-flight batching")
    args = ap.parse_args()
    if args.dryrun:
        serve_dryrun()
    else:
        serve_local(args.duration, args.seed, args.max_batch,
                    smoke=args.smoke, batching=args.batching)


if __name__ == "__main__":
    main()
