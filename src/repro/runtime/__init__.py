from repro.runtime.mission import (FidelityOracle, FrameResult, MissionLog,
                                   MissionSpec, edge_insight_flops,
                                   full_edge_flops, run_mission)
from repro.runtime.scheduler import (MicrobatchScheduler, ServeRequest,
                                     ServeResult)

__all__ = ["MissionSpec", "MissionLog", "FrameResult", "FidelityOracle",
           "run_mission", "edge_insight_flops", "full_edge_flops",
           "MicrobatchScheduler", "ServeRequest", "ServeResult"]
