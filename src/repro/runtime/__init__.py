from repro.runtime.mission import (FrameResult, MissionLog, MissionSpec,
                                   edge_insight_flops, full_edge_flops,
                                   run_mission)

__all__ = ["MissionSpec", "MissionLog", "FrameResult", "run_mission",
           "edge_insight_flops", "full_edge_flops"]
