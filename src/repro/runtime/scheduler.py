"""Continuous-batching microbatch scheduler for the cloud serving engine.

The cloud side of the split system is a shared resource — many concurrent
operator requests (and, in the fleet extension, N UAVs' streams) funnel
into one set of model weights. The seed served them one jitted call per
request at batch 1; this scheduler turns the arrival stream into
tier/intent-bucketed microbatches and drives the batched
``DualStreamExecutor`` stages instead:

  arrival queue -> head-of-line key (intent, tier) -> FIFO microbatch of
  up to ``max_batch`` matching requests -> one batched executor call.

Requests of other keys are never reordered within their own key, and
results are handed back per request, so callers see exactly the
semantics of the per-request loop — just fewer, larger device calls.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import packets as pk
from repro.core.intent import Intent


@dataclass
class ServeRequest:
    seq_id: int
    intent: Intent
    packet: pk.Packet
    query: np.ndarray                 # (B, L) or (L,) tokenised query
    arrival_s: float = 0.0


@dataclass
class ServeResult:
    seq_id: int
    intent: Intent
    tier_name: Optional[str]
    answer_logits: np.ndarray         # (B, V)
    mask_logits: Optional[np.ndarray] = None   # (B, H, W), Insight only
    tokens: Optional[np.ndarray] = None        # (B, T), generate mode only
    batch_size: int = 1               # microbatch this request rode in


def _batch_key(req: ServeRequest) -> Tuple[str, Optional[str], int]:
    """Requests are stackable only when kind, tier AND query length agree
    (the executor concatenates query rows along the batch axis)."""
    return (req.packet.kind, req.packet.tier_name,
            int(np.asarray(req.query).shape[-1]))


def _rows(req: ServeRequest) -> int:
    """Content rows this request contributes to a stacked device batch
    (edge calls may pack several frames into one packet)."""
    key = "ctx" if req.packet.kind == "context" else "codes"
    arr = req.packet.content.get(key)
    return int(arr.shape[0]) if arr is not None else 1


@dataclass
class MicrobatchScheduler:
    """Groups queued requests into same-(intent, tier) microbatches and
    executes them on the batched executor. ``generate=True`` serves
    multi-token answers through the prefill + flash-decode path;
    otherwise the single-token ``llm_reason``-equivalent stage runs."""
    executor: object                  # DualStreamExecutor
    max_batch: int = 8
    generate: bool = False
    _queue: Deque[ServeRequest] = field(default_factory=deque)
    n_microbatches: int = 0
    n_requests: int = 0

    def __post_init__(self):
        # the executor stacks packet *content rows*, so both the request
        # count and the summed rows must fit the largest bucket
        self._row_cap = max(self.executor.buckets)
        self.max_batch = max(1, min(self.max_batch, self._row_cap))

    # ---- queueing ----

    def submit(self, req: ServeRequest) -> None:
        if _rows(req) > self._row_cap:
            raise ValueError(
                f"packet carries {_rows(req)} rows, above the largest "
                f"executor bucket {self._row_cap}; split it at the edge")
        self._queue.append(req)

    @property
    def pending(self) -> int:
        return len(self._queue)

    def _take_microbatch(self, key: Optional[Tuple] = None
                         ) -> List[ServeRequest]:
        """Pop requests matching ``key`` (default: the head-of-line key)
        while both the request count and the stacked content rows fit;
        FIFO within the key — once one matching request doesn't fit, later
        ones can't jump past it. Other keys keep their queue order."""
        if not self._queue:
            return []
        if key is None:
            key = _batch_key(self._queue[0])
        taken: List[ServeRequest] = []
        kept: Deque[ServeRequest] = deque()
        rows, closed = 0, False
        for r in self._queue:
            if not closed and _batch_key(r) == key:
                if (len(taken) < self.max_batch
                        and rows + _rows(r) <= self._row_cap):
                    taken.append(r)
                    rows += _rows(r)
                    continue
                closed = True
            kept.append(r)
        self._queue = kept
        return taken

    # ---- execution ----

    def step(self) -> List[ServeResult]:
        """Serve one microbatch from the head-of-line key (no-op on an
        empty queue)."""
        return self._execute(self._take_microbatch())

    def _execute(self, batch: List[ServeRequest]) -> List[ServeResult]:
        if not batch:
            return []
        self.n_microbatches += 1
        self.n_requests += len(batch)
        packets = [r.packet for r in batch]
        queries = [r.query for r in batch]
        kind = batch[0].packet.kind
        results: List[ServeResult] = []
        if self.generate:
            outs = self.executor.cloud_generate_batch(packets, queries)
            for r, out in zip(batch, outs):
                if kind == "insight":
                    mask, logits, tokens = out
                else:
                    mask, (logits, tokens) = None, out
                results.append(ServeResult(
                    r.seq_id, r.intent, r.packet.tier_name, logits,
                    mask_logits=mask, tokens=tokens, batch_size=len(batch)))
        elif kind == "insight":
            outs = self.executor.cloud_insight_batch(packets, queries)
            for r, (mask, logits) in zip(batch, outs):
                results.append(ServeResult(
                    r.seq_id, r.intent, r.packet.tier_name, logits,
                    mask_logits=mask, batch_size=len(batch)))
        else:
            outs = self.executor.cloud_context_batch(packets, queries)
            for r, logits in zip(batch, outs):
                results.append(ServeResult(
                    r.seq_id, r.intent, None, logits,
                    batch_size=len(batch)))
        return results

    def step_ready(self) -> List[ServeResult]:
        """Continuous batching: serve while a *full* microbatch of some key
        is queued, taking exactly that key (called as requests arrive;
        partial batches of other keys stay queued for ``drain``)."""
        results: List[ServeResult] = []
        while (key := self._ready_key()) is not None:
            results.extend(self._execute(self._take_microbatch(key)))
        return results

    def _ready_key(self) -> Optional[Tuple]:
        counts: Dict[Tuple, int] = {}
        rows: Dict[Tuple, int] = {}
        for r in self._queue:
            k = _batch_key(r)
            counts[k] = counts.get(k, 0) + 1
            rows[k] = rows.get(k, 0) + _rows(r)
            if counts[k] >= self.max_batch or rows[k] >= self._row_cap:
                return k
        return None

    def drain(self) -> List[ServeResult]:
        results: List[ServeResult] = []
        while self._queue:
            results.extend(self.step())
        return results

    def serve_all(self, reqs: Sequence[ServeRequest]) -> List[ServeResult]:
        """Submit everything, drain, and return results aligned with the
        input order (the per-request contract callers rely on)."""
        for r in reqs:
            self.submit(r)
        by_id = {res.seq_id: res for res in self.drain()}
        return [by_id[r.seq_id] for r in reqs]

    @property
    def mean_batch_size(self) -> float:
        return self.n_requests / max(1, self.n_microbatches)
