"""Closed-loop mission simulator — the paper's dynamic evaluation (§5.3).

Simulates a UAV streaming the Insight pathway over a fluctuating uplink
for ``duration_s`` (paper: 20 minutes, 8–20 Mbps). The per-frame
pipeline — Sense, tier selection, analytic edge compute (Jetson model at
the DEPLOYMENT geometry), packet transmission, fidelity measurement —
runs inside ``AveryEngine`` (``session.submit_frame``); this module owns
only mission time, the frame log, and the fidelity oracle.

Tier control is a ``ControlPolicy`` on the session: ``AdaptivePolicy``
is AVERY mode, ``StaticTierPolicy`` the §5.3.1 baselines,
``BestEffortPolicy`` the graceful-degradation fleet variant. The old
``mode="avery"|"static"`` / ``fallback=`` knobs still work via
``policy_from_mode`` (deprecation shim).

Frame capture pipelines with transmission (frame k+1 is computed while
packet k is in flight), so steady-state throughput is min(compute rate,
link rate) — matching the paper's PPS accounting.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.configs.lisa7b import LISAPipelineConfig
from repro.core.controller import MissionGoal
from repro.core.intent import DEFAULT_REQUIREMENTS, Intent
from repro.core.lut import SystemLUT, Tier
from repro.data import floodseg
from repro.engine import (AveryEngine, ChannelTransport, ControlPolicy,
                          policy_from_mode)
# re-exported for compatibility (formulas live with the device models now)
from repro.network.energy import edge_insight_flops, full_edge_flops  # noqa: F401
from repro.network.traces import BandwidthTrace


@dataclass(frozen=True)
class MissionSpec:
    duration_s: float = 1200.0
    goal: MissionGoal = MissionGoal.PRIORITIZE_ACCURACY
    # tier control: pass a ControlPolicy; the mode/static_tier/fallback
    # trio below is the pre-engine interface, mapped via policy_from_mode
    policy: Optional[ControlPolicy] = None
    mode: str = "avery"               # deprecated: "avery" | "static"
    static_tier: Optional[str] = None  # deprecated: tier for mode="static"
    finetuned: bool = False
    min_pps: float = 0.5              # F_I for Insight intents
    seed: int = 0
    # deprecated (use policy=BestEffortPolicy()): when no tier satisfies
    # F_I, transmit the lightest tier best-effort instead of idling
    fallback: bool = False

    def resolve_policy(self) -> ControlPolicy:
        if self.policy is not None:
            return self.policy
        return policy_from_mode(self.mode, self.static_tier, self.fallback)


@dataclass
class FrameResult:
    t_capture: float
    t_delivered: float
    tier: str
    payload_mb: float
    iou: Optional[float]
    edge_energy_j: float

    @property
    def latency_s(self) -> float:
        return self.t_delivered - self.t_capture


@dataclass
class MissionLog:
    spec: MissionSpec
    frames: List[FrameResult] = field(default_factory=list)
    infeasible_s: float = 0.0

    @property
    def mean_pps(self) -> float:
        if not self.frames:
            return 0.0
        return len(self.frames) / self.spec.duration_s

    @property
    def mean_iou(self) -> float:
        vals = [f.iou for f in self.frames if f.iou is not None]
        return float(np.mean(vals)) if vals else 0.0

    @property
    def total_edge_energy_j(self) -> float:
        return sum(f.edge_energy_j for f in self.frames)

    def pps_timeline(self, window_s: float = 60.0) -> np.ndarray:
        n = int(np.ceil(self.spec.duration_s / window_s))
        out = np.zeros(n)
        for f in self.frames:
            out[min(n - 1, int(f.t_delivered / window_s))] += 1
        return out / window_s

    def tier_timeline(self, window_s: float = 60.0) -> List[str]:
        n = int(np.ceil(self.spec.duration_s / window_s))
        buckets: List[List[str]] = [[] for _ in range(n)]
        for f in self.frames:
            buckets[min(n - 1, int(f.t_capture / window_s))].append(f.tier)
        return [max(set(b), key=b.count) if b else "-" for b in buckets]


class FidelityOracle:
    """Per-frame fidelity: real lisa-mini inference (executor mode) or the
    LUT expectation plus per-scene variation (fast mode).

    Executor mode pre-generates a small evaluation pool once (scenes,
    device-resident images/queries, and one CLIP context pass per pooled
    frame) and cycles through it, instead of rebuilding and re-transferring
    a fresh batch every frame; per-(tier, scene) IoUs are memoised since
    the pipeline is deterministic."""

    POOL_SIZE = 6

    def __init__(self, lut: SystemLUT, spec: MissionSpec,
                 executor=None, pcfg: Optional[LISAPipelineConfig] = None):
        self.lut = lut
        self.spec = spec
        self.executor = executor
        self.pcfg = pcfg
        self.rng = np.random.RandomState(spec.seed + 77)
        self._pool: Optional[list] = None
        self._pool_i = 0
        self._memo: Dict[tuple, float] = {}

    def _ensure_pool(self) -> None:
        if self._pool is not None:
            return
        import jax.numpy as jnp
        self._pool = []
        for _ in range(self.POOL_SIZE):
            batch = floodseg.make_batch(self.rng, 1, "segment", augment=False)
            images = jnp.asarray(batch["images"])
            _, ctx = self.executor.edge_context(images, 0, 0.0)
            self._pool.append({
                "images": images,
                "query": jnp.asarray(batch["query"]),
                "mask": batch["mask"],
                "ctx": ctx,
            })

    def measure(self, tier: Tier) -> float:
        if self.executor is not None:
            self._ensure_pool()
            i = self._pool_i % len(self._pool)
            self._pool_i += 1
            key = (tier.name, i)
            if key not in self._memo:
                entry = self._pool[i]
                pkt = self.executor.edge_insight(
                    entry["images"], tier, 0, 0.0, ctx=entry["ctx"])
                mask_logits, _ = self.executor.cloud_insight(
                    pkt, entry["query"])
                pred = (mask_logits[0] > 0).astype(np.float64)
                gt = entry["mask"][0].astype(np.float64)
                inter = (pred * gt).sum()
                union = np.maximum(pred, gt).sum()
                self._memo[key] = float(inter / (union + 1e-6))
            return self._memo[key]
        base = tier.acc_finetuned if self.spec.finetuned else tier.acc_base
        return float(np.clip(base + self.rng.randn() * 0.02, 0.0, 1.0))


def mission_session(engine: AveryEngine, trace: BandwidthTrace,
                    spec: MissionSpec, oracle: FidelityOracle):
    """One UAV's ``OperatorSession`` for a profiled mission: its own
    bandwidth share and controller, the shared engine's cloud side."""
    reqs = DEFAULT_REQUIREMENTS[Intent.INSIGHT]
    if spec.min_pps != reqs.min_update_pps:
        reqs = dataclasses.replace(reqs, min_update_pps=spec.min_pps)
    return engine.session(
        f"uav-{spec.seed}",
        transport=ChannelTransport.from_trace(trace),
        policy=spec.resolve_policy(), goal=spec.goal,
        finetuned=spec.finetuned,
        requirements={**DEFAULT_REQUIREMENTS, Intent.INSIGHT: reqs},
        oracle=oracle)


def mission_step(sess, log: MissionLog, lut: SystemLUT, t: float) -> float:
    """One profiled mission frame at capture time ``t``: submit through
    the engine's admission path, account it on ``log``, and return the
    next capture time (pipelined capture — frame k+1 overlaps packet
    k's transmission). Shared by ``run_mission`` and the fleet loop so
    both drive the exact same per-frame semantics."""
    resp = sess.submit_frame(t)
    if not resp.feasible:
        log.infeasible_s += 1.0
        # a strict policy idles the frame; admission control sheds it
        # (``rejected``) — either way no frame transmits this second
        if resp.tier_name is None:
            return t + 1.0
    log.frames.append(FrameResult(
        t_capture=t, t_delivered=resp.t_delivered, tier=resp.tier_name,
        payload_mb=lut.by_name(resp.tier_name).payload_mb,
        iou=resp.iou, edge_energy_j=resp.edge_energy_j))
    return max(t + resp.edge_compute_s,
               resp.t_delivered - resp.edge_compute_s, t + 1e-3)


def run_mission(lut: SystemLUT, trace: BandwidthTrace, spec: MissionSpec,
                executor=None, pcfg: Optional[LISAPipelineConfig] = None,
                deploy: Optional[LISAPipelineConfig] = None,
                oracle: Optional[FidelityOracle] = None,
                engine: Optional[AveryEngine] = None) -> MissionLog:
    """``oracle``: pass a shared FidelityOracle to amortise its evaluation
    pool across missions; ``engine``: pass a shared AveryEngine so N UAV
    sessions report into one executor + telemetry (the fleet path)."""
    if engine is None:
        engine = AveryEngine(lut=lut, executor=executor, deploy=deploy)
    else:
        engine.bind_deploy(deploy)     # shared engine must not silently
        if executor is not None and engine.executor is not executor:
            raise ValueError("shared engine carries a different executor")
    if oracle is None:
        oracle = FidelityOracle(lut, spec, executor=executor, pcfg=pcfg)
    sess = mission_session(engine, trace, spec, oracle)

    log = MissionLog(spec=spec)
    t = 0.0
    seq = 0
    while t < spec.duration_s:
        t = mission_step(sess, log, lut, t)
        seq += 1
        if seq > 100_000:
            break
    return log
