"""Closed-loop mission simulator — the paper's dynamic evaluation (§5.3).

Simulates a UAV streaming the Insight pathway over a fluctuating uplink
for ``duration_s`` (paper: 20 minutes, 8–20 Mbps). Each frame:

  1. Sense: read current bandwidth from the channel;
  2. the controller (Algorithm 1) selects the tier — adaptive AVERY mode —
     or a fixed tier (the static High-Accuracy / Balanced /
     High-Throughput baselines of §5.3.1);
  3. edge compute (analytic Jetson model at the DEPLOYMENT geometry) +
     packet transmission (serialised on the simulated channel);
  4. cloud inference; per-packet fidelity is measured by real lisa-mini
     inference when an executor is provided, else drawn from the LUT
     (fast mode for property tests).

Frame capture pipelines with transmission (frame k+1 is computed while
packet k is in flight), so steady-state throughput is min(compute rate,
link rate) — matching the paper's PPS accounting.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.configs.lisa7b import LISAPipelineConfig
from repro.core import bottleneck as bn
from repro.core.controller import (MissionGoal, NoFeasibleInsightTier,
                                   PowerConfig, select_configuration)
from repro.core.intent import DEFAULT_REQUIREMENTS, Intent
from repro.core.lut import SystemLUT, Tier
from repro.data import floodseg
from repro.network.channel import Channel
from repro.network.energy import EdgeDevice, bottleneck_flops, encoder_flops, \
    patch_embed_flops
from repro.network.traces import BandwidthTrace


@dataclass(frozen=True)
class MissionSpec:
    duration_s: float = 1200.0
    goal: MissionGoal = MissionGoal.PRIORITIZE_ACCURACY
    mode: str = "avery"               # "avery" | "static"
    static_tier: Optional[str] = None  # tier name for mode="static"
    finetuned: bool = False
    min_pps: float = 0.5              # F_I for Insight intents
    seed: int = 0
    # beyond-paper (fleet finding, EXPERIMENTS §Beyond-paper): when no tier
    # satisfies F_I, transmit the lightest tier best-effort instead of
    # idling — Algorithm 1 reports NoFeasible; this is the graceful
    # degradation policy layered on top
    fallback: bool = False


@dataclass
class FrameResult:
    t_capture: float
    t_delivered: float
    tier: str
    payload_mb: float
    iou: Optional[float]
    edge_energy_j: float

    @property
    def latency_s(self) -> float:
        return self.t_delivered - self.t_capture


@dataclass
class MissionLog:
    spec: MissionSpec
    frames: List[FrameResult] = field(default_factory=list)
    infeasible_s: float = 0.0

    @property
    def mean_pps(self) -> float:
        if not self.frames:
            return 0.0
        return len(self.frames) / self.spec.duration_s

    @property
    def mean_iou(self) -> float:
        vals = [f.iou for f in self.frames if f.iou is not None]
        return float(np.mean(vals)) if vals else 0.0

    @property
    def total_edge_energy_j(self) -> float:
        return sum(f.edge_energy_j for f in self.frames)

    def pps_timeline(self, window_s: float = 60.0) -> np.ndarray:
        n = int(np.ceil(self.spec.duration_s / window_s))
        out = np.zeros(n)
        for f in self.frames:
            out[min(n - 1, int(f.t_delivered / window_s))] += 1
        return out / window_s

    def tier_timeline(self, window_s: float = 60.0) -> List[str]:
        n = int(np.ceil(self.spec.duration_s / window_s))
        buckets: List[List[str]] = [[] for _ in range(n)]
        for f in self.frames:
            buckets[min(n - 1, int(f.t_capture / window_s))].append(f.tier)
        return [max(set(b), key=b.count) if b else "-" for b in buckets]


def edge_insight_flops(deploy: LISAPipelineConfig, ratio: float) -> float:
    """Edge-side FLOPs per Insight frame at the deployment geometry:
    patch embed + SAM blocks [0, k) + bottleneck encode + CLIP encoder."""
    d = deploy.sam.d_model
    orig_bytes = 2 if deploy.sam.param_dtype == "bfloat16" else 4
    rank = bn.rank_for_ratio(d, ratio, orig_bytes)
    return (patch_embed_flops(d, deploy.patch_size, deploy.sam_tokens)
            + encoder_flops(deploy.sam, deploy.sam_tokens,
                            deploy.split_layer)
            + bottleneck_flops(d, rank, deploy.sam_tokens)
            + patch_embed_flops(deploy.clip.d_model,
                                deploy.context_patch_size, deploy.clip_tokens)
            + encoder_flops(deploy.clip, deploy.clip_tokens))


def full_edge_flops(deploy: LISAPipelineConfig) -> float:
    """Full onboard execution of the Insight segmentation backbone."""
    d = deploy.sam.d_model
    return (patch_embed_flops(d, deploy.patch_size, deploy.sam_tokens)
            + encoder_flops(deploy.sam, deploy.sam_tokens))


class FidelityOracle:
    """Per-frame fidelity: real lisa-mini inference (executor mode) or the
    LUT expectation plus per-scene variation (fast mode).

    Executor mode pre-generates a small evaluation pool once (scenes,
    device-resident images/queries, and one CLIP context pass per pooled
    frame) and cycles through it, instead of rebuilding and re-transferring
    a fresh batch every frame; per-(tier, scene) IoUs are memoised since
    the pipeline is deterministic."""

    POOL_SIZE = 6

    def __init__(self, lut: SystemLUT, spec: MissionSpec,
                 executor=None, pcfg: Optional[LISAPipelineConfig] = None):
        self.lut = lut
        self.spec = spec
        self.executor = executor
        self.pcfg = pcfg
        self.rng = np.random.RandomState(spec.seed + 77)
        self._pool: Optional[list] = None
        self._pool_i = 0
        self._memo: Dict[tuple, float] = {}

    def _ensure_pool(self) -> None:
        if self._pool is not None:
            return
        import jax.numpy as jnp
        self._pool = []
        for _ in range(self.POOL_SIZE):
            batch = floodseg.make_batch(self.rng, 1, "segment", augment=False)
            images = jnp.asarray(batch["images"])
            _, ctx = self.executor.edge_context(images, 0, 0.0)
            self._pool.append({
                "images": images,
                "query": jnp.asarray(batch["query"]),
                "mask": batch["mask"],
                "ctx": ctx,
            })

    def measure(self, tier: Tier) -> float:
        if self.executor is not None:
            self._ensure_pool()
            i = self._pool_i % len(self._pool)
            self._pool_i += 1
            key = (tier.name, i)
            if key not in self._memo:
                entry = self._pool[i]
                pkt = self.executor.edge_insight(
                    entry["images"], tier, 0, 0.0, ctx=entry["ctx"])
                mask_logits, _ = self.executor.cloud_insight(
                    pkt, entry["query"])
                pred = (mask_logits[0] > 0).astype(np.float64)
                gt = entry["mask"][0].astype(np.float64)
                inter = (pred * gt).sum()
                union = np.maximum(pred, gt).sum()
                self._memo[key] = float(inter / (union + 1e-6))
            return self._memo[key]
        base = tier.acc_finetuned if self.spec.finetuned else tier.acc_base
        return float(np.clip(base + self.rng.randn() * 0.02, 0.0, 1.0))


def run_mission(lut: SystemLUT, trace: BandwidthTrace, spec: MissionSpec,
                executor=None, pcfg: Optional[LISAPipelineConfig] = None,
                deploy: Optional[LISAPipelineConfig] = None,
                oracle: Optional[FidelityOracle] = None) -> MissionLog:
    """``oracle``: pass a shared FidelityOracle to amortise its evaluation
    pool across missions (the fleet path runs N UAVs against one cloud)."""
    if deploy is None:
        from repro.configs.lisa7b import CONFIG as deploy
    from repro.core import packets as pk

    channel = Channel(trace)
    device = EdgeDevice()
    if oracle is None:
        oracle = FidelityOracle(lut, spec, executor=executor, pcfg=pcfg)
    log = MissionLog(spec=spec)
    reqs = DEFAULT_REQUIREMENTS[Intent.INSIGHT]
    if spec.min_pps != reqs.min_update_pps:
        import dataclasses
        reqs = dataclasses.replace(reqs, min_update_pps=spec.min_pps)

    t = 0.0
    seq = 0
    while t < spec.duration_s:
        bw = channel.measure_bandwidth(t)
        if spec.mode == "avery":
            try:
                sel = select_configuration(bw, PowerConfig(), spec.goal,
                                           Intent.INSIGHT, reqs, lut,
                                           finetuned=spec.finetuned)
                tier = sel.tier
            except NoFeasibleInsightTier:
                log.infeasible_s += 1.0
                if spec.fallback:
                    tier = min(lut.tiers, key=lambda x: x.payload_mb)
                else:
                    t += 1.0
                    continue
        else:
            tier = lut.by_name(spec.static_tier)

        flops = edge_insight_flops(deploy, tier.ratio)
        compute_s = device.latency_s(flops)
        energy = device.compute_energy_j(flops) \
            + device.tx_energy_j(tier.payload_mb * 1e6)
        packet = pk.Packet(kind="insight", tier_name=tier.name, seq_id=seq,
                           created_at=t, payload_bytes=int(tier.payload_mb * 1e6))
        rec = channel.transmit(packet, t + compute_s)
        iou = oracle.measure(tier)
        log.frames.append(FrameResult(
            t_capture=t, t_delivered=rec.end_s, tier=tier.name,
            payload_mb=tier.payload_mb, iou=iou, edge_energy_j=energy))
        # pipelined capture: next frame overlaps with this transmission
        t = max(t + compute_s, rec.end_s - compute_s, t + 1e-3)
        seq += 1
        if seq > 100_000:
            break
    return log
