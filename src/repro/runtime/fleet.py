"""Multi-UAV fleet simulation (beyond-paper; the paper's §6 future-work
item: "extending the framework to multi-UAV coordination ... whether
intent-driven semantic adaptation remains beneficial at larger system
scale").

Model: N UAVs share one uplink cell. The scheduler grants each UAV an
equal bandwidth share (B_t / N); each UAV is an ``OperatorSession`` on
one shared ``AveryEngine`` — its own ``ChannelTransport`` over the
share, its own controller policy — while the cloud executor, fidelity
oracle, and telemetry are engine-level and shared. This is the
conservative fair-share model — no cross-UAV coordination — so it
lower-bounds what a coordinating controller could do, and directly
answers the paper's question: adaptive tiering degrades gracefully with
fleet size while static tiers fall off a feasibility cliff."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List

import numpy as np

from repro.core.lut import SystemLUT
from repro.engine import AveryEngine
from repro.network.traces import BandwidthTrace
from repro.runtime.mission import (FidelityOracle, MissionLog, MissionSpec,
                                   run_mission)


@dataclass
class FleetResult:
    n_uavs: int
    logs: List[MissionLog]

    @property
    def aggregate_pps(self) -> float:
        return sum(l.mean_pps for l in self.logs)

    @property
    def mean_iou(self) -> float:
        vals = [l.mean_iou for l in self.logs if l.frames]
        return float(np.mean(vals)) if vals else 0.0

    @property
    def infeasible_frac(self) -> float:
        total = sum(l.spec.duration_s for l in self.logs)
        return sum(l.infeasible_s for l in self.logs) / max(1.0, total)


def run_fleet(lut: SystemLUT, trace: BandwidthTrace, n_uavs: int,
              spec: MissionSpec, executor=None, deploy=None) -> FleetResult:
    """Equal-share scheduler: each UAV sees trace/N.

    All N UAV sessions ride one ``AveryEngine``. With ``executor``
    per-frame fidelity comes from real lisa-mini inference on the shared
    cloud executor: every session reports into one ``FidelityOracle``
    whose evaluation pool and per-(tier, scene) measurements are built
    once and memoised, so fleet cost does not scale with N on the cloud
    side."""
    share = BandwidthTrace(trace.samples / n_uavs,
                           name=f"{trace.name}/share{n_uavs}")
    engine = AveryEngine(lut=lut, executor=executor, deploy=deploy)
    oracle = (FidelityOracle(lut, spec, executor=executor)
              if executor is not None else None)
    logs = []
    for i in range(n_uavs):
        s = dataclasses.replace(spec, seed=spec.seed + 101 * i)
        logs.append(run_mission(lut, share, s, executor=executor,
                                oracle=oracle, engine=engine))
    return FleetResult(n_uavs=n_uavs, logs=logs)
