"""Multi-UAV fleet simulation (beyond-paper; the paper's §6 future-work
item: "extending the framework to multi-UAV coordination ... whether
intent-driven semantic adaptation remains beneficial at larger system
scale").

Model: N UAVs share one uplink cell. The scheduler grants each UAV an
equal bandwidth share (B_t / N); each UAV runs its own Algorithm-1
controller against its share. This is the conservative fair-share model —
no cross-UAV coordination — so it lower-bounds what a coordinating
controller could do, and directly answers the paper's question: adaptive
tiering degrades gracefully with fleet size while static tiers fall off
a feasibility cliff."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

from repro.core.lut import SystemLUT
from repro.network.traces import BandwidthTrace
from repro.runtime.mission import (FidelityOracle, MissionLog, MissionSpec,
                                   run_mission)


@dataclass
class FleetResult:
    n_uavs: int
    logs: List[MissionLog]

    @property
    def aggregate_pps(self) -> float:
        return sum(l.mean_pps for l in self.logs)

    @property
    def mean_iou(self) -> float:
        vals = [l.mean_iou for l in self.logs if l.frames]
        return float(np.mean(vals)) if vals else 0.0

    @property
    def infeasible_frac(self) -> float:
        total = sum(l.spec.duration_s for l in self.logs)
        return sum(l.infeasible_s for l in self.logs) / max(1.0, total)


def run_fleet(lut: SystemLUT, trace: BandwidthTrace, n_uavs: int,
              spec: MissionSpec, executor=None) -> FleetResult:
    """Equal-share scheduler: each UAV sees trace/N.

    With ``executor`` per-frame fidelity comes from real lisa-mini
    inference on the shared cloud executor: all N missions report into one
    ``FidelityOracle`` whose evaluation pool and per-(tier, scene)
    measurements are built once and memoised, so fleet cost does not
    scale with N on the cloud side. (Evals are per-packet calls; they are
    shared, not stacked into one device batch.)"""
    share = BandwidthTrace(trace.samples / n_uavs,
                           name=f"{trace.name}/share{n_uavs}")
    oracle = (FidelityOracle(lut, spec, executor=executor)
              if executor is not None else None)
    logs = []
    for i in range(n_uavs):
        s = MissionSpec(duration_s=spec.duration_s, goal=spec.goal,
                        mode=spec.mode, static_tier=spec.static_tier,
                        finetuned=spec.finetuned, min_pps=spec.min_pps,
                        seed=spec.seed + 101 * i, fallback=spec.fallback)
        logs.append(run_mission(lut, share, s, executor=executor,
                                oracle=oracle))
    return FleetResult(n_uavs=n_uavs, logs=logs)
