"""Multi-UAV fleet simulation (beyond-paper; the paper's §6 future-work
item: "extending the framework to multi-UAV coordination ... whether
intent-driven semantic adaptation remains beneficial at larger system
scale").

Model: N UAVs share one uplink cell. The scheduler grants each UAV an
equal bandwidth share (B_t / N); each UAV is an ``OperatorSession`` on
one shared ``AveryEngine`` — its own ``ChannelTransport`` over the
share, its own controller policy — while the cloud executor, fidelity
oracle, and telemetry are engine-level and shared. This is the
conservative fair-share model — no cross-UAV coordination — so it
lower-bounds what a coordinating controller could do, and directly
answers the paper's question: adaptive tiering degrades gracefully with
fleet size while static tiers fall off a feasibility cliff.

The fleet loop is arrival-ordered: a heap merges the N per-UAV capture
clocks so frames hit the shared engine's admission path (scheduler
admission checks, rate limits, per-operator accounting) in mission-clock
order — the scheduler sees a fleet, not N sequential missions. Each
frame itself goes through ``mission_step``, the same code path
``run_mission`` drives, so fleet numbers and single-mission numbers
share per-frame semantics exactly."""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass, field
from typing import Any, Dict, List

import numpy as np

from repro.core.lut import SystemLUT
from repro.engine import AveryEngine
from repro.network.traces import BandwidthTrace
from repro.runtime.mission import (FidelityOracle, MissionLog, MissionSpec,
                                   mission_session, mission_step)


@dataclass
class FleetResult:
    n_uavs: int
    logs: List[MissionLog]
    # shared-engine telemetry snapshot at drain (scheduler counters,
    # per-operator served counts, rejections) — empty for old callers
    stats: Dict[str, Any] = field(default_factory=dict)
    # the shared engine's Tracer when the fleet ran with trace= (one
    # lifecycle trace per frame; ``tracer.dump(path)`` writes Perfetto
    # JSON) — None for untraced runs
    tracer: Any = None

    @property
    def aggregate_pps(self) -> float:
        return sum(l.mean_pps for l in self.logs)

    @property
    def mean_iou(self) -> float:
        vals = [l.mean_iou for l in self.logs if l.frames]
        return float(np.mean(vals)) if vals else 0.0

    @property
    def infeasible_frac(self) -> float:
        total = sum(l.spec.duration_s for l in self.logs)
        return sum(l.infeasible_s for l in self.logs) / max(1.0, total)


def run_fleet(lut: SystemLUT, trace: BandwidthTrace, n_uavs: int,
              spec: MissionSpec, executor=None, deploy=None,
              scheduler=None, engine_trace: bool = False) -> FleetResult:
    """Equal-share scheduler: each UAV sees trace/N.

    All N UAV sessions ride one ``AveryEngine``; pass ``scheduler=``
    (e.g. a ``QoSScheduler`` with per-operator rate limits) to put the
    fleet behind a non-default admission policy. With ``executor``
    per-frame fidelity comes from real lisa-mini inference on the shared
    cloud executor: every session reports into one ``FidelityOracle``
    whose evaluation pool and per-(tier, scene) measurements are built
    once and memoised, so fleet cost does not scale with N on the cloud
    side. Without one, each UAV keeps its own oracle (per-seed scene
    variation), matching ``run_mission`` run N times."""
    share = BandwidthTrace(trace.samples / n_uavs,
                           name=f"{trace.name}/share{n_uavs}")
    engine = AveryEngine(lut=lut, executor=executor, deploy=deploy,
                         scheduler=scheduler, trace=engine_trace)
    shared_oracle = (FidelityOracle(lut, spec, executor=executor)
                     if executor is not None else None)
    sessions = []
    logs: List[MissionLog] = []
    for i in range(n_uavs):
        s = dataclasses.replace(spec, seed=spec.seed + 101 * i)
        oracle = (shared_oracle if shared_oracle is not None
                  else FidelityOracle(lut, s))
        sessions.append(mission_session(engine, share, s, oracle))
        logs.append(MissionLog(spec=s))
    # arrival-ordered merge: always step the UAV whose next capture is
    # earliest, so the shared admission path sees one interleaved
    # mission-clock stream
    heap = [(0.0, i) for i in range(n_uavs)]
    heapq.heapify(heap)
    steps = [0] * n_uavs
    while heap:
        t, i = heapq.heappop(heap)
        if t >= logs[i].spec.duration_s:
            continue
        t_next = mission_step(sessions[i], logs[i], lut, t)
        steps[i] += 1
        if steps[i] > 100_000:
            continue
        heapq.heappush(heap, (t_next, i))
    return FleetResult(n_uavs=n_uavs, logs=logs, stats=dict(engine.stats),
                       tracer=engine.tracer if engine_trace else None)
