from repro.optim.adamw import Optimizer, adamw, sgd
from repro.optim.schedules import constant, cosine_with_warmup, linear_warmup

__all__ = ["Optimizer", "adamw", "sgd",
           "cosine_with_warmup", "linear_warmup", "constant"]
