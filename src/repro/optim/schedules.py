"""Learning-rate schedules as pure functions of the step counter."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def sched(step):
        return jnp.float32(lr)
    return sched


def linear_warmup(lr: float, warmup_steps: int):
    def sched(step):
        frac = jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))
        return jnp.float32(lr) * frac
    return sched


def cosine_with_warmup(lr: float, warmup_steps: int, total_steps: int,
                       final_frac: float = 0.1):
    def sched(step):
        warm = jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))
        t = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps),
                     0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.float32(lr) * warm * cos
    return sched
