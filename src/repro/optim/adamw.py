"""AdamW with decoupled weight decay and global-norm gradient clipping.

optax is not available offline, so this is a small, self-contained pytree
optimizer. Moments are kept in float32 regardless of parameter dtype
(mixed-precision training: bf16 params / fp32 optimizer state).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    apply: Callable[[Any, Any, Any], Tuple[Any, Any]]


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)) + 1e-12)


def _clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / norm)
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)


def adamw(schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.01, clip_norm: float = 1.0) -> Optimizer:
    if not callable(schedule):
        lr_value = float(schedule)
        schedule = lambda step: jnp.float32(lr_value)  # noqa: E731

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def apply(params, state, grads):
        step = state["step"] + 1
        lr = schedule(step)
        grads = _clip_by_global_norm(grads, clip_norm)
        m = jax.tree.map(lambda mo, g: b1 * mo + (1 - b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda vo, g: b2 * vo + (1 - b2) * g * g,
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, mo, vo):
            mhat = mo / bc1
            vhat = vo / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
                p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        params = jax.tree.map(upd, params, m, v)
        return params, {"step": step, "m": m, "v": v}

    return Optimizer(init=init, apply=apply)


def sgd(schedule, momentum: float = 0.9, clip_norm: float = 1.0) -> Optimizer:
    if not callable(schedule):
        lr_value = float(schedule)
        schedule = lambda step: jnp.float32(lr_value)  # noqa: E731

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def apply(params, state, grads):
        step = state["step"] + 1
        lr = schedule(step)
        grads = _clip_by_global_norm(grads, clip_norm)
        m = jax.tree.map(lambda mo, g: momentum * mo + g, state["m"], grads)
        params = jax.tree.map(
            lambda p, mo: (p.astype(jnp.float32) - lr * mo).astype(p.dtype),
            params, m)
        return params, {"step": step, "m": m}

    return Optimizer(init=init, apply=apply)
