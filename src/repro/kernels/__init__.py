"""Pallas TPU kernels for the framework's compute hot-spots.

  bottleneck      — fused low-rank projection + int8 quantisation at the
                    split boundary (the paper's per-frame edge hot-spot)
  flash_attention — blocked online-softmax causal GQA attention (prefill)
  ssm_scan        — chunked selective-scan recurrence (Mamba prefill)
  decode_attention— flash-decode: one token vs a long KV cache (the
                    Insight-serving decode hot loop; HBM traffic = one
                    cache read, the Pair-2 roofline floor)

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper; interpret=True on CPU), ref.py (pure-jnp oracle used by tests).
"""
