"""Pure-jnp oracle for the chunked selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_ref(decay: jax.Array, drive: jax.Array) -> jax.Array:
    """h_t = decay_t * h_{t-1} + drive_t along axis 1.

    decay/drive: (B, S, C, N) fp32. Returns h (B, S, C, N).
    """
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(
        combine, (decay.astype(jnp.float32), drive.astype(jnp.float32)),
        axis=1)
    return h
