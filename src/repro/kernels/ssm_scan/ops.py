"""Jit'd wrapper for the chunked selective scan: pads channels/sequence to
block multiples and restores the original shape."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import ssm_scan as _k

_INTERPRET = True  # CPU container: interpret mode; flip on real TPU.


@functools.partial(jax.jit, static_argnames=("chunk", "block_c"))
def chunked_scan(decay: jax.Array, drive: jax.Array, chunk: int = 64,
                 block_c: int = 128) -> jax.Array:
    """h_t = decay_t * h_{t-1} + drive_t over axis 1. (B, S, C, N) in,
    (B, S, C, N) f32 out."""
    B, S, C, N = decay.shape
    chunk = min(chunk, S)
    block_c = min(block_c, C)
    pad_s = (-S) % chunk
    pad_c = (-C) % block_c
    if pad_s or pad_c:
        pads = ((0, 0), (0, pad_s), (0, pad_c), (0, 0))
        decay = jnp.pad(decay, pads)
        drive = jnp.pad(drive, pads)
    out = _k.scan_call(decay, drive, chunk=chunk, block_c=block_c,
                       interpret=_INTERPRET)
    return out[:, :S, :C]
