"""Chunked selective-scan kernel (Mamba recurrence) for TPU.

h_t = decay_t * h_{t-1} + drive_t, scanned over the sequence axis.

Grid (B, channel_blocks, seq_chunks) with the sequence dimension innermost
and sequential; the running state h (bc, N) is carried in VMEM scratch
across chunks, so HBM traffic is exactly one read of (decay, drive) and
one write of h — the TPU-native adaptation of Mamba's CUDA scan: instead
of warp-level prefix products, the VPU iterates the small in-chunk
recurrence over lanes of (channels x state) held in vector registers
(DESIGN.md §4.3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(decay_ref, drive_ref, h_ref, state_scr, *, chunk: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    dec = decay_ref[0].astype(jnp.float32)     # (chunk, bc, N)
    drv = drive_ref[0].astype(jnp.float32)

    def step(t, h):
        h = dec[t] * h + drv[t]
        h_ref[0, pl.dslice(t, 1)] = h[None].astype(h_ref.dtype)
        return h

    state_scr[...] = jax.lax.fori_loop(0, chunk, step, state_scr[...])


def scan_call(decay: jax.Array, drive: jax.Array, *, chunk: int = 64,
              block_c: int = 128, interpret: bool = True) -> jax.Array:
    """decay/drive (B, S, C, N); S % chunk == 0, C % block_c == 0."""
    B, S, C, N = decay.shape
    grid = (B, C // block_c, S // chunk)
    spec = pl.BlockSpec((1, chunk, block_c, N),
                        lambda b, ci, si: (b, si, ci, 0))
    return pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, S, C, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_c, N), jnp.float32)],
        interpret=interpret,
    )(decay, drive)
