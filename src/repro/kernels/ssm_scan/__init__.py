from repro.kernels.ssm_scan.ops import chunked_scan

__all__ = ["chunked_scan"]
