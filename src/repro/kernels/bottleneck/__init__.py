from repro.kernels.bottleneck.ops import bottleneck_decode, bottleneck_encode

__all__ = ["bottleneck_encode", "bottleneck_decode"]
