"""Jit'd wrappers for the bottleneck kernels: handle (B, S, d) batching,
token-count padding to the row-tile, and CPU interpret mode."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.bottleneck import bottleneck as _k

_INTERPRET = True  # CPU container: interpret mode; flip on real TPU.


def _flatten(x):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _pad_rows(x, block):
    T = x.shape[0]
    pad = (-T) % block
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, T


@functools.partial(jax.jit, static_argnames=("block_t",))
def bottleneck_encode(x: jax.Array, w_enc: jax.Array,
                      block_t: int = _k.DEFAULT_BLOCK_T
                      ) -> Tuple[jax.Array, jax.Array]:
    """x (..., d) -> (codes int8 (..., r), scales f32 (..., 1))."""
    flat, lead = _flatten(x)
    flat, T = _pad_rows(flat, block_t)
    codes, scales = _k.encode_call(flat, w_enc, block_t=block_t,
                                   interpret=_INTERPRET)
    r = w_enc.shape[1]
    return (codes[:T].reshape(*lead, r),
            scales[:T].reshape(*lead, 1))


@functools.partial(jax.jit, static_argnames=("out_dtype", "block_t"))
def bottleneck_decode(codes: jax.Array, scales: jax.Array, w_dec: jax.Array,
                      out_dtype=jnp.float32,
                      block_t: int = _k.DEFAULT_BLOCK_T) -> jax.Array:
    flat, lead = _flatten(codes)
    sflat = scales.reshape(-1, 1)
    flat, T = _pad_rows(flat, block_t)
    sflat, _ = _pad_rows(sflat, block_t)
    out = _k.decode_call(flat, sflat, w_dec, out_dtype=out_dtype,
                         block_t=block_t, interpret=_INTERPRET)
    return out[:T].reshape(*lead, w_dec.shape[1])
