"""Fused bottleneck kernels: low-rank projection + int8 quantisation.

TPU adaptation of the paper's learned compression (DESIGN.md §4.3): the
projection runs on the MXU with the quantisation fused into the epilogue,
so the full-width boundary activation is consumed tile-by-tile from VMEM
and only int8 codes + fp16-able scales are written back to HBM. The
decode kernel dequantises in VMEM and feeds the MXU directly.

Grid: one program per row-tile of tokens; the projection weight is small
(d x r with r << d) and resident in VMEM for every program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_T = 128


def _encode_kernel(x_ref, w_ref, codes_ref, scales_ref):
    z = jnp.dot(x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    s = jnp.max(jnp.abs(z), axis=-1, keepdims=True) / 127.0 + 1e-8
    codes_ref[...] = jnp.clip(jnp.round(z / s), -127, 127).astype(jnp.int8)
    scales_ref[...] = s


def encode_call(x: jax.Array, w_enc: jax.Array, *, block_t: int = DEFAULT_BLOCK_T,
                interpret: bool = True):
    """x (T, d) [T % block_t == 0], w_enc (d, r)."""
    T, d = x.shape
    r = w_enc.shape[1]
    grid = (T // block_t,)
    return pl.pallas_call(
        _encode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((d, r), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, r), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, r), jnp.int8),
            jax.ShapeDtypeStruct((T, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, w_enc)


def _decode_kernel(codes_ref, scales_ref, w_ref, out_ref, *, out_dtype):
    z = codes_ref[...].astype(jnp.float32) * scales_ref[...]
    out_ref[...] = jnp.dot(z, w_ref[...].astype(jnp.float32),
                           preferred_element_type=jnp.float32).astype(out_dtype)


def decode_call(codes: jax.Array, scales: jax.Array, w_dec: jax.Array,
                out_dtype=jnp.float32, *, block_t: int = DEFAULT_BLOCK_T,
                interpret: bool = True):
    """codes (T, r) int8, scales (T, 1), w_dec (r, d)."""
    T, r = codes.shape
    d = w_dec.shape[1]
    grid = (T // block_t,)
    return pl.pallas_call(
        functools.partial(_decode_kernel, out_dtype=jnp.dtype(out_dtype)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, r), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 1), lambda i: (i, 0)),
            pl.BlockSpec((r, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), jnp.dtype(out_dtype)),
        interpret=interpret,
    )(codes, scales, w_dec)
