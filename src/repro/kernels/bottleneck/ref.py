"""Pure-jnp oracle for the fused bottleneck encode/decode kernels."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def encode_ref(x: jax.Array, w_enc: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """x (T, d), w_enc (d, r) -> (codes int8 (T, r), scales f32 (T, 1))."""
    z = jnp.dot(x.astype(jnp.float32), w_enc.astype(jnp.float32))
    s = jnp.max(jnp.abs(z), axis=-1, keepdims=True) / 127.0 + 1e-8
    codes = jnp.clip(jnp.round(z / s), -127, 127).astype(jnp.int8)
    return codes, s


def decode_ref(codes: jax.Array, scales: jax.Array, w_dec: jax.Array,
               out_dtype=jnp.float32) -> jax.Array:
    """codes (T, r) int8, scales (T, 1) -> (T, d)."""
    z = codes.astype(jnp.float32) * scales
    return jnp.dot(z, w_dec.astype(jnp.float32)).astype(out_dtype)
