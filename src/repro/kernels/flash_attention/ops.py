"""Jit'd wrapper: (B,S,H,hd) layout -> kernel layout, GQA head grouping,
sequence padding, CPU interpret mode."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _k

_INTERPRET = True  # CPU container: interpret mode; flip on real TPU.


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128) -> jax.Array:
    """q (B,S,H,hd), k/v (B,S,K,hd) with H % K == 0. Returns (B,S,H,hd).

    Heads are laid out kv-major (B, K, G, S, hd) so that query row p maps
    to kv row p // G in the kernel's index space.
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    pad = (-S) % max(block_q, block_k)
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    Sp = S + pad
    # (B,S,H,hd) -> (B*H, S, hd) with H = K*G laid out kv-major
    qh = qp.reshape(B, Sp, K, G, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(B * H, Sp, hd)
    kh = kp.transpose(0, 2, 1, 3).reshape(B * K, Sp, hd)
    vh = vp.transpose(0, 2, 1, 3).reshape(B * K, Sp, hd)
    out = _k.flash_call(qh, kh, vh, causal=causal, block_q=block_q,
                        block_k=block_k, valid_len=S, interpret=_INTERPRET)
    out = out.reshape(B, K, G, Sp, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(B, Sp, H, hd)
    return out[:, :S]
