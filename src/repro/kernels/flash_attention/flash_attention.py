"""Blocked online-softmax attention (FlashAttention) for TPU.

Grid (batch*q_heads, q_blocks, kv_blocks) with the kv dimension innermost
and sequential; running (m, l, acc) statistics live in VMEM scratch and
the output tile is written on the last kv step. K/V are streamed
block-by-block HBM->VMEM by the BlockSpec pipeline — the TPU-native
shape of the algorithm (no shared-memory/warp semantics; DESIGN.md §4.3).

GQA is handled in the k/v index_map: query-head program p attends to
kv-head p % H // group. Causal masking uses global block offsets; fully
masked kv blocks are skipped via pl.when on the block index.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  num_kv_blocks: int, valid_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # with causal masking, blocks strictly above the diagonal contribute 0
    live = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0].astype(jnp.float32)              # (bk, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        rows = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        ok = cols < valid_len
        if causal:
            ok &= cols <= rows
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]                           # (bq, 1)
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_call(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
               block_q: int = 128, block_k: int = 128,
               valid_len: int = -1, interpret: bool = True) -> jax.Array:
    """q (BH, S, hd), k/v (BK, S, hd), BH % BK == 0 (grouped heads laid out
    so that query row p maps to kv row p // group)."""
    BH, S, hd = q.shape
    BK = k.shape[0]
    group = BH // BK
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, num_kv_blocks=nk,
        valid_len=S if valid_len < 0 else valid_len)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda h, qi, ki: (h // group, ki, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda h, qi, ki: (h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
