"""Pure-jnp oracle for blocked GQA flash attention."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q (B,S,H,hd), k/v (B,T,K,hd) with H % K == 0. fp32 softmax."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(float(hd))
    i = jnp.arange(S)[:, None]
    j = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= j <= i
    if window is not None:
        ok &= j > i - window
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)
