"""Flash-decode kernel: one query token against a long KV cache.

The decode serving hot loop (Insight-stream token generation on the
cloud/pod side). Grid (B*H, kv_blocks) with the cache dimension innermost
and sequential: k/v blocks stream HBM->VMEM once, the online-softmax
running statistics (m, l, acc) stay in VMEM scratch, and the (1, hd)
output tile is written on the last block. HBM traffic is exactly one read
of the cache — the roofline floor the Pair-2 §Perf hillclimb drove decode
to.

``paged_decode_call`` is the page-table-aware variant for the paged KV
cache: k/v live in a shared page pool and each row's blocks are gathered
through its page table (scalar-prefetched, so the indirection is resolved
in the BlockSpec index maps — same one-pass cache traffic).

``paged_verify_call`` is the multi-query variant for speculative
decoding: a q-block of C chunk tokens (the last accepted token plus the
drafted continuations) scores against the row's paged cache in one
pass, with the per-query bias carrying the causal-within-chunk mask.
The online-softmax running statistics simply grow a leading C axis —
cache traffic stays one read per (row, head), amortised over all C
verify positions (the whole point of multi-token verification).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, num_kv_blocks: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                   # (1, hd)
    k = k_ref[0].astype(jnp.float32)                   # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (1, bk)
    s = s + bias_ref[0].astype(jnp.float32)[None, :]
    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_call(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                      page_table: jax.Array, bias: jax.Array, *, group: int,
                      interpret: bool = True) -> jax.Array:
    """Page-table-aware gather path: the KV cache lives in a shared page
    pool and each batch row addresses it through its page table.

    q (BH, 1, hd) laid out kv-major as in ``decode_call``; k_pool/v_pool
    (K, P, page, hd) — the shared pool, transposed kv-head-major so one
    (page, hd) tile is one block; page_table (B, n_pages) i32 page ids
    (every entry must be valid — unused rows point at the reserved trash
    page); bias (B, n_pages*page) additive over the row's gathered
    virtual sequence.

    The page table rides in as a scalar-prefetch operand, so the k/v
    BlockSpec index maps dereference it *before* the kernel body runs —
    each page streams HBM->VMEM exactly once per (row, head) program,
    the same online-softmax traffic floor as the contiguous kernel; only
    the addressing is indirect.
    """
    BH, _, hd = q.shape
    page = k_pool.shape[2]
    B, n_pages = page_table.shape
    heads_per_batch = BH // B
    scale = 1.0 / (hd ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda h, ki, pt: (h, 0, 0)),
            pl.BlockSpec(
                (1, 1, page, hd),
                lambda h, ki, pt: ((h % heads_per_batch) // group,
                                   pt[h // heads_per_batch, ki], 0, 0)),
            pl.BlockSpec(
                (1, 1, page, hd),
                lambda h, ki, pt: ((h % heads_per_batch) // group,
                                   pt[h // heads_per_batch, ki], 0, 0)),
            pl.BlockSpec((1, page),
                         lambda h, ki, pt: (h // heads_per_batch, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda h, ki, pt: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               num_kv_blocks=n_pages)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, 1, hd), q.dtype),
        interpret=interpret,
    )(page_table, q, k_pool, v_pool, bias)


def _paged_decode_kernel(pt_ref, q_ref, k_ref, v_ref, bias_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale: float,
                         num_kv_blocks: int):
    """Online-softmax body of the paged path. Identical running-statistics
    scheme to ``_decode_kernel``; the only differences are the (consumed
    by the index maps) scalar-prefetch page-table ref and the extra pool
    axis on the k/v blocks."""
    del pt_ref                                         # used by index maps
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                   # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                # (page, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = s + bias_ref[0].astype(jnp.float32)[None, :]
    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_verify_call(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                      page_table: jax.Array, bias: jax.Array, *, group: int,
                      interpret: bool = True) -> jax.Array:
    """Multi-query paged attention for the speculative verify step.

    q (BH, C, hd) — C chunk tokens per (row, head) program, laid out
    kv-major as in ``paged_decode_call``; k_pool/v_pool (K, P, page, hd);
    page_table (B, n_pages) i32 (every entry valid — idle rows park on
    the reserved trash page); bias (B, C, n_pages*page) additive per
    query position over the row's gathered virtual sequence — the caller
    encodes both slot validity and causal-within-chunk there.

    Grid (BH, n_pages), cache-innermost: each page streams HBM->VMEM
    once per (row, head) and all C verify positions score against it
    before the next page loads — the (C, 1)/(C, hd) running statistics
    live in VMEM scratch exactly like the single-query kernel's.
    """
    BH, C, hd = q.shape
    page = k_pool.shape[2]
    B, n_pages = page_table.shape
    heads_per_batch = BH // B
    scale = 1.0 / (hd ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, n_pages),
        in_specs=[
            pl.BlockSpec((1, C, hd), lambda h, ki, pt: (h, 0, 0)),
            pl.BlockSpec(
                (1, 1, page, hd),
                lambda h, ki, pt: ((h % heads_per_batch) // group,
                                   pt[h // heads_per_batch, ki], 0, 0)),
            pl.BlockSpec(
                (1, 1, page, hd),
                lambda h, ki, pt: ((h % heads_per_batch) // group,
                                   pt[h // heads_per_batch, ki], 0, 0)),
            pl.BlockSpec((1, C, page),
                         lambda h, ki, pt: (h // heads_per_batch, 0, ki)),
        ],
        out_specs=pl.BlockSpec((1, C, hd), lambda h, ki, pt: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, 1), jnp.float32),
            pltpu.VMEM((C, 1), jnp.float32),
            pltpu.VMEM((C, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_verify_kernel, scale=scale,
                               num_kv_blocks=n_pages)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, C, hd), q.dtype),
        interpret=interpret,
    )(page_table, q, k_pool, v_pool, bias)


def _paged_verify_kernel(pt_ref, q_ref, k_ref, v_ref, bias_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale: float,
                         num_kv_blocks: int):
    """Online-softmax body of the multi-query verify path: the decode
    kernel's running statistics with a leading C (chunk) axis."""
    del pt_ref                                         # used by index maps
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                   # (C, hd)
    k = k_ref[0, 0].astype(jnp.float32)                # (page, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = s + bias_ref[0].astype(jnp.float32)            # (C, page)
    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_call(q: jax.Array, k: jax.Array, v: jax.Array, bias: jax.Array,
                *, group: int, block_k: int = 512,
                interpret: bool = True) -> jax.Array:
    """q (BH, 1, hd); k/v (BK, W, hd); bias (B, W). BH = B*H laid out
    kv-major so query row p reads kv row p // group and bias row
    p // (H) — H passed implicitly via bias grid math below."""
    BH, _, hd = q.shape
    BK, W, _ = k.shape
    assert W % block_k == 0, (W, block_k)
    nk = W // block_k
    B = bias.shape[0]
    heads_per_batch = BH // B
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(_decode_kernel, scale=scale, num_kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nk),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda h, ki: (h, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda h, ki: (h // group, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda h, ki: (h // group, ki, 0)),
            pl.BlockSpec((1, block_k),
                         lambda h, ki: (h // heads_per_batch, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda h, ki: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, bias)
