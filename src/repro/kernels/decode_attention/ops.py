"""Jit'd wrapper: (B,H,hd) / (B,W,K,hd) layouts, cache-length padding.

Per-shard head counts (sharded serving): under tensor parallelism the
paged pool shards kv-heads over the mesh's "model" axis, so inside a
``shard_map`` each shard calls these wrappers with
``K = n_kv_heads / model_shards`` (and ``H = num_heads / model_shards``)
— the ``group``/``heads_per_batch`` grid math is derived from the
per-shard shapes, so the kernel bodies run unchanged on the smaller K.
On this CPU container the kernels execute in *interpret mode* and
cannot lower inside a GSPMD partition, so ``ShardedServingContext``
serves the jnp reference attention instead (XLA partitions it over the
head-sharded operands); route the kernels through ``shard_map`` with
the per-shard head counts on real TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention as _k

_INTERPRET = True  # CPU container: interpret mode; flip on real TPU.
NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     bias: jax.Array, block_k: int = 512) -> jax.Array:
    """q (B,H,hd); k/v (B,W,K,hd); bias (B,W) additive. -> (B,H,hd)."""
    B, H, hd = q.shape
    W, K = k.shape[1], k.shape[2]
    G = H // K
    block_k = min(block_k, W)
    pad = (-W) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, pad)), constant_values=NEG_INF)
    qh = q.reshape(B, K, G, hd).reshape(B * H, 1, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, W + pad, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, W + pad, hd)
    out = _k.decode_call(qh, kh, vh, bias, group=G, block_k=block_k,
                         interpret=_INTERPRET)
    return out.reshape(B, K, G, hd).reshape(B, H, hd)


@jax.jit
def paged_decode_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           page_table: jax.Array,
                           bias: jax.Array) -> jax.Array:
    """Flash decode against a paged KV cache.

    q (B,H,hd); k_pool/v_pool (P, page, K, hd) — the shared page pool;
    page_table (B, n_pages) i32 page ids (all entries must be valid —
    point unused rows at the reserved trash page); bias
    (B, n_pages*page) additive over the gathered virtual sequence.
    Returns (B,H,hd). One kv block per page, page table resolved via
    scalar prefetch.
    """
    B, H, hd = q.shape
    K = k_pool.shape[2]
    G = H // K
    qh = q.reshape(B, K, G, hd).reshape(B * H, 1, hd)
    kh = k_pool.transpose(2, 0, 1, 3)                  # (K, P, page, hd)
    vh = v_pool.transpose(2, 0, 1, 3)
    out = _k.paged_decode_call(qh, kh, vh,
                               jnp.asarray(page_table, jnp.int32), bias,
                               group=G, interpret=_INTERPRET)
    return out.reshape(B, K, G, hd).reshape(B, H, hd)


@jax.jit
def paged_verify_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, page_table: jax.Array,
                           bias: jax.Array) -> jax.Array:
    """Multi-query flash attention against a paged KV cache — the
    speculative-decode verify step.

    q (B, C, H, hd) — C chunk tokens (last accepted token + drafts) per
    row; k_pool/v_pool (P, page, K, hd); page_table (B, n_pages) i32
    (all entries valid); bias (B, C, n_pages*page) additive per query
    position (slot validity + causal-within-chunk). Returns
    (B, C, H, hd). One kv block per page, page table resolved via scalar
    prefetch; column 0 of a C=1 call matches ``paged_decode_attention``.
    """
    B, C, H, hd = q.shape
    K = k_pool.shape[2]
    G = H // K
    # kv-major head layout: program h reads kv head (h % H) // G
    qh = q.transpose(0, 2, 1, 3).reshape(B, K, G, C, hd) \
          .reshape(B * H, C, hd)
    kh = k_pool.transpose(2, 0, 1, 3)                  # (K, P, page, hd)
    vh = v_pool.transpose(2, 0, 1, 3)
    out = _k.paged_verify_call(qh, kh, vh,
                               jnp.asarray(page_table, jnp.int32), bias,
                               group=G, interpret=_INTERPRET)
    return out.reshape(B, K, G, C, hd).reshape(B, H, C, hd) \
              .transpose(0, 2, 1, 3)
