"""Jit'd wrapper: (B,H,hd) / (B,W,K,hd) layouts, cache-length padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention as _k

_INTERPRET = True  # CPU container: interpret mode; flip on real TPU.
NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     bias: jax.Array, block_k: int = 512) -> jax.Array:
    """q (B,H,hd); k/v (B,W,K,hd); bias (B,W) additive. -> (B,H,hd)."""
    B, H, hd = q.shape
    W, K = k.shape[1], k.shape[2]
    G = H // K
    block_k = min(block_k, W)
    pad = (-W) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, pad)), constant_values=NEG_INF)
    qh = q.reshape(B, K, G, hd).reshape(B * H, 1, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, W + pad, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, W + pad, hd)
    out = _k.decode_call(qh, kh, vh, bias, group=G, block_k=block_k,
                         interpret=_INTERPRET)
    return out.reshape(B, K, G, hd).reshape(B, H, hd)
