"""Pure-jnp oracle for single-token decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         bias: jax.Array) -> jax.Array:
    """q (B,H,hd); k/v (B,W,K,hd); bias (B,W) additive slot mask.
    Returns (B,H,hd). fp32 softmax over the cache axis."""
    B, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,bwkh->bkgw", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(float(hd)) + bias[:, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgw,bwkh->bkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def paged_decode_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, page_table: jax.Array,
                               bias: jax.Array) -> jax.Array:
    """Oracle for the paged path: gather each row's pages into the
    contiguous (B, W, K, hd) layout, then run the dense reference.
    q (B,H,hd); k_pool/v_pool (P, page, K, hd); page_table (B, n) i32;
    bias (B, n*page)."""
    B = q.shape[0]
    n, page = page_table.shape[1], k_pool.shape[1]
    K, hd = k_pool.shape[2], k_pool.shape[3]
    k = k_pool[page_table].reshape(B, n * page, K, hd)
    v = v_pool[page_table].reshape(B, n * page, K, hd)
    return decode_attention_ref(q, k, v, bias)


def paged_verify_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, page_table: jax.Array,
                               bias: jax.Array) -> jax.Array:
    """Oracle for the multi-query (speculative verify) paged path:
    gather each row's pages into the contiguous layout, then dense
    grouped attention with the per-query additive bias. q (B,C,H,hd);
    k_pool/v_pool (P, page, K, hd); page_table (B, n) i32; bias
    (B, C, n*page). Returns (B, C, H, hd)."""
    B, C, H, hd = q.shape
    n, page = page_table.shape[1], k_pool.shape[1]
    K = k_pool.shape[2]
    G = H // K
    k = k_pool[page_table].reshape(B, n * page, K, hd)
    v = v_pool[page_table].reshape(B, n * page, K, hd)
    qg = q.reshape(B, C, K, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bckgh,bwkh->bkgcw", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(float(hd)) + bias[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgcw,bwkh->bckgh", probs, v.astype(jnp.float32))
    return out.reshape(B, C, H, hd).astype(q.dtype)
