"""Pure-jnp oracle for single-token decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         bias: jax.Array) -> jax.Array:
    """q (B,H,hd); k/v (B,W,K,hd); bias (B,W) additive slot mask.
    Returns (B,H,hd). fp32 softmax over the cache axis."""
    B, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,bwkh->bkgw", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(float(hd)) + bias[:, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgw,bwkh->bkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
