"""Depth-wise split of any stacked model into an edge head and cloud tail.

Because every layer group carries its parameters with a leading layer
axis (repro.models.stack), splitting at depth k is a pure pytree slice —
no re-initialisation, no weight copying. This generalises the paper's
split@1 of the SAM backbone to *every* architecture in the zoo
(DESIGN.md §3: parts (ii)+(iii) of the technique are family-agnostic).

GroupSpec metadata stays static (outside the param pytrees) so the head
and tail apply-functions close over it and remain jit-friendly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import jax

from repro.models import stack
from repro.models.config import ModelConfig


def _slice_group(gparams: Any, lo: int, hi: int) -> Any:
    return jax.tree.map(lambda a: a[lo:hi], gparams)


def split_layer_groups(cfg: ModelConfig, k: int
                       ) -> Tuple[List[stack.GroupSpec], List[stack.GroupSpec]]:
    """GroupSpec lists for head (layers [0,k)) and tail (layers [k,L))."""
    head, tail = [], []
    off = 0
    for spec in stack.layer_groups(cfg):
        n = spec.count
        if k <= off:
            tail.append(spec)
        elif k >= off + n:
            head.append(spec)
        else:
            head.append(dataclasses.replace(spec, count=k - off))
            tail.append(dataclasses.replace(spec, count=n - (k - off)))
        off += n
    return head, tail


def split_group_params(cfg: ModelConfig, groups: list,
                       k: int) -> Tuple[list, list]:
    """Split the ``groups`` param list at absolute layer index k (aligned
    with split_layer_groups)."""
    head, tail = [], []
    off = 0
    for spec, gp in zip(stack.layer_groups(cfg), groups):
        n = spec.count
        if k <= off:
            tail.append(gp)
        elif k >= off + n:
            head.append(gp)
        else:
            head.append(_slice_group(gp, 0, k - off))
            tail.append(_slice_group(gp, k - off, n))
        off += n
    return head, tail


@dataclass(frozen=True)
class SplitPlan:
    """Static description of a depth-wise split; apply-methods take the
    (sliced) param pytrees as explicit jit-able arguments."""
    cfg: ModelConfig
    split_layer: int

    def __post_init__(self):
        assert 0 < self.split_layer < self.cfg.num_layers, \
            f"split@{self.split_layer} invalid for {self.cfg.num_layers}L"

    @property
    def head_specs(self):
        return split_layer_groups(self.cfg, self.split_layer)[0]

    @property
    def tail_specs(self):
        return split_layer_groups(self.cfg, self.split_layer)[1]

    def split_params(self, params: dict) -> Tuple[dict, dict]:
        """Full model params -> (edge_params, cloud_params). The edge gets
        embeddings/frontends + head groups; the cloud gets tail groups +
        final norm + output head. Hybrid shared-attention params are
        replicated to both sides (small)."""
        hg, tg = split_group_params(self.cfg, params["groups"],
                                    self.split_layer)
        edge = {"groups": hg}
        cloud = {"groups": tg, "final_norm": params["final_norm"]}
        for key in ("embed", "feat_proj", "vision_proj"):
            if key in params:
                edge[key] = params[key]
        for key in ("head", "mtp"):
            if key in params:
                cloud[key] = params[key]
        if "shared_attn" in params:
            edge["shared_attn"] = params["shared_attn"]
            cloud["shared_attn"] = params["shared_attn"]
        if self.cfg.tie_embeddings:
            cloud["embed"] = params["embed"]
        return edge, cloud

    def head_apply(self, edge_params: dict, x: jax.Array, positions,
                   mask) -> jax.Array:
        """Edge prefix over an already-embedded activation x (B,S,d)."""
        for spec, gp in zip(self.head_specs, edge_params["groups"]):
            x, _, _ = stack.group_forward(
                gp, self.cfg, spec, x, positions, mask,
                shared_attn=edge_params.get("shared_attn"))
        return x

    def tail_apply(self, cloud_params: dict, x: jax.Array, positions,
                   mask) -> jax.Array:
        for spec, gp in zip(self.tail_specs, cloud_params["groups"]):
            x, _, _ = stack.group_forward(
                gp, self.cfg, spec, x, positions, mask,
                shared_attn=cloud_params.get("shared_attn"))
        return x
