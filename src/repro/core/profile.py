"""Offline profiling -> System Configuration LUT (paper §4.4.1).

Accuracies are measured on the *trained* proxy models (original and
fine-tuned); payload sizes are computed for the TARGET DEPLOYMENT
geometry (LISA-7B: 4096 SAM tokens x d=1280 bf16 = 10.49 MB boundary
activation, exactly the paper's figure) so the runtime dynamics — tier
feasibility thresholds vs the 8–20 Mbps trace — match the paper's
operating regime. This mirrors how the paper builds its LUT by offline
profiling of the real system (documented deviation: accuracy column is
proxy-scale; DESIGN.md §6).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.lisa7b import LISAPipelineConfig
from repro.core import bottleneck as bn
from repro.core import packets as pk
from repro.core import training
from repro.core.lut import ContextConfig, SystemLUT, Tier

TIER_NAMES = {0.25: "High Accuracy", 0.10: "Balanced", 0.05: "High Throughput"}


def deployment_payload_mb(deploy: LISAPipelineConfig, ratio: float) -> float:
    """Insight packet size at the deployment geometry (SAM codes + scales
    + CLIP context features)."""
    d = deploy.sam.d_model
    orig_bytes = 2 if deploy.sam.param_dtype == "bfloat16" else 4
    rank = bn.rank_for_ratio(d, ratio, orig_bytes)
    nbytes = pk.insight_payload_bytes(
        deploy.sam_tokens, rank,
        clip_tokens=deploy.clip_tokens, clip_dim=deploy.clip.d_model)
    return nbytes / 1e6


def deployment_context_mb(deploy: LISAPipelineConfig) -> float:
    return pk.context_payload_bytes(deploy.clip_tokens,
                                    deploy.llm.d_model) / 1e6


def build_lut(pcfg: LISAPipelineConfig,
              params_original: dict,
              params_finetuned: dict,
              bottlenecks: Dict[float, dict],
              deploy: Optional[LISAPipelineConfig] = None,
              eval_batches: int = 6) -> SystemLUT:
    """Profile each tier: Average IoU for both model variants + deployment
    payload size. ``bottlenecks`` maps ratio -> trained pair."""
    if deploy is None:
        from repro.configs.lisa7b import CONFIG as deploy
    tiers = []
    for ratio, bp in sorted(bottlenecks.items(), reverse=True):
        acc_base = training.evaluate_insight(
            pcfg, params_original, bn_params=bp, batches=eval_batches)
        acc_ft = training.evaluate_insight(
            pcfg, params_finetuned, bn_params=bp, batches=eval_batches)
        tiers.append(Tier(
            name=TIER_NAMES.get(ratio, f"r={ratio}"),
            ratio=ratio,
            acc_base=acc_base["avg_iou"],
            acc_finetuned=acc_ft["avg_iou"],
            payload_mb=deployment_payload_mb(deploy, ratio),
        ))
    ctx = ContextConfig(payload_mb=deployment_context_mb(deploy))
    return SystemLUT(tiers=tiers, context=ctx)


def train_full_system(pcfg: LISAPipelineConfig,
                      ratios: Sequence[float] = (0.25, 0.10, 0.05),
                      steps: int = 300, bn_steps: int = 200,
                      ft_steps: int = 150, batch_size: int = 16,
                      seed: int = 0, log=print
                      ) -> Tuple[dict, dict, Dict[float, dict]]:
    """End-to-end offline phase: train original model, fine-tune the flood
    variant, distillation-train one bottleneck per ratio (against the
    original model, as the paper trains compression models once)."""
    log("[profile] training original lisa-mini ...")
    params = training.train_lisa(pcfg, steps=steps, batch_size=batch_size,
                                 seed=seed, log=log)
    log("[profile] fine-tuning flood variant ...")
    params_ft = training.finetune_lisa(pcfg, params, steps=ft_steps,
                                       batch_size=batch_size, seed=seed + 1,
                                       log=log)
    bns = {}
    for r in ratios:
        log(f"[profile] training bottleneck r={r} ...")
        bns[r] = training.train_bottleneck(pcfg, params, r, steps=bn_steps,
                                           batch_size=batch_size, seed=seed,
                                           log=log)
    return params, params_ft, bns


def random_init_system(pcfg: LISAPipelineConfig, seed: int = 0,
                       lut: Optional[SystemLUT] = None, params=None):
    """Random-init weights + per-tier bottlenecks over a published LUT —
    the no-offline-phase system used by serving smoke runs, benchmarks,
    and engine tests (serving plumbing and throughput depend only on the
    geometry, not on the weight values). Pass ``params`` (e.g. a cached
    trained checkpoint) to skip the weight init and only build the
    bottlenecks. Returns (params, bottlenecks-by-tier-name, lut)."""
    from repro.core import vlm
    from repro.core.lut import paper_lut
    if lut is None:
        lut = paper_lut()
    if params is None:
        params = vlm.init_lisa(pcfg, jax.random.PRNGKey(seed))
    d = pcfg.sam.d_model
    bns = {t.name: bn.init_bottleneck(
        jax.random.PRNGKey(i),
        bn.BottleneckSpec(d, bn.rank_for_ratio(d, t.ratio, 4), 4))
        for i, t in enumerate(lut.tiers)}
    return params, bns, lut
