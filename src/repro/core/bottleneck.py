"""Learned bottleneck compression for split activations (paper Fig. 5).

An encoder/decoder pair is inserted at the split point: the edge projects
the (B, T, d) boundary activation to a low-rank code and int8-quantises it
(per-token absmax scale); the cloud dequantises and projects back. Each
pre-trained pair is one LUT operating tier.

TPU adaptation (DESIGN.md §4): the projection+quantisation is fused in a
single Pallas kernel (``repro.kernels.bottleneck``) so the full-width
activation never round-trips HBM; this module is the pure-jnp reference
path and the training path (straight-through estimator for the rounding).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import fan_in_init


@dataclass(frozen=True)
class BottleneckSpec:
    d_model: int
    rank: int                    # code channels
    orig_bytes_per_el: int = 2   # boundary activation dtype width (bf16)

    @property
    def ratio(self) -> float:
        """Compression ratio r = compressed bytes / original bytes
        (int8 codes vs full-width activation), per token."""
        return (self.rank * 1 + 2) / (self.d_model * self.orig_bytes_per_el)


def rank_for_ratio(d_model: int, ratio: float,
                   orig_bytes_per_el: int = 2) -> int:
    """Code rank such that int8 payload ≈ ratio * original activation."""
    rank = int(round(ratio * d_model * orig_bytes_per_el)) - 2
    return max(1, min(d_model, rank))


def init_bottleneck(rng: jax.Array, spec: BottleneckSpec,
                    dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(rng)
    return {
        "enc": fan_in_init(k1, (spec.d_model, spec.rank), dtype),
        "dec": fan_in_init(k2, (spec.rank, spec.d_model), dtype),
    }


def _absmax_scale(z: jax.Array) -> jax.Array:
    return jnp.max(jnp.abs(z), axis=-1, keepdims=True) / 127.0 + 1e-8


def encode(params: dict, x: jax.Array,
           use_kernel: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x (..., d) -> (codes int8 (..., rank), scales f32 (..., 1))."""
    if use_kernel:
        from repro.kernels.bottleneck import ops as bops
        return bops.bottleneck_encode(x, params["enc"])
    z = (x @ params["enc"].astype(x.dtype)).astype(jnp.float32)
    s = _absmax_scale(z)
    codes = jnp.clip(jnp.round(z / s), -127, 127).astype(jnp.int8)
    return codes, s


def decode(params: dict, codes: jax.Array, scales: jax.Array,
           out_dtype=jnp.float32, use_kernel: bool = False) -> jax.Array:
    if use_kernel:
        from repro.kernels.bottleneck import ops as bops
        return bops.bottleneck_decode(codes, scales, params["dec"], out_dtype)
    z = codes.astype(jnp.float32) * scales
    return (z @ params["dec"].astype(jnp.float32)).astype(out_dtype)


def roundtrip_st(params: dict, x: jax.Array) -> jax.Array:
    """Differentiable encode→quantise→decode with a straight-through
    estimator on the rounding — the training path."""
    z = (x.astype(jnp.float32) @ params["enc"].astype(jnp.float32))
    s = _absmax_scale(jax.lax.stop_gradient(z))
    zq = z / s
    zq = zq + jax.lax.stop_gradient(jnp.clip(jnp.round(zq), -127, 127) - zq)
    return ((zq * s) @ params["dec"].astype(jnp.float32)).astype(x.dtype)


def payload_bytes(spec: BottleneckSpec, num_tokens: int) -> int:
    from repro.core.packets import HEADER_BYTES
    return HEADER_BYTES + num_tokens * spec.rank + num_tokens * 2


def recon_loss(params: dict, x: jax.Array) -> jax.Array:
    """Normalised reconstruction MSE (distillation regulariser)."""
    xh = roundtrip_st(params, x).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    return jnp.mean(jnp.square(xh - xf)) / (jnp.mean(jnp.square(xf)) + 1e-8)
