"""AVERY core: the paper's contribution as composable JAX modules.

  intent      — operator-intent taxonomy + NL gate (§3.1)
  streams     — dual-stream (Context/Insight) execution modes (§4.1–4.3)
  split       — depth-wise head/tail partition of any stacked model
  bottleneck  — learned low-rank + int8 boundary compression (Fig. 5)
  lut         — pre-profiled System Configuration LUT (Table 3)
  controller  — Algorithm 1 Sense/Gate/Evaluate/Select
  packets     — payload accounting + packetisation
  vlm         — LISA-style grounded VLM pipeline (Fig. 4)
"""
from repro.core.bottleneck import (BottleneckSpec, init_bottleneck,
                                   rank_for_ratio)
from repro.core.controller import (MissionGoal, NoFeasibleInsightTier,
                                   PowerConfig, SelectedConfig,
                                   select_configuration)
from repro.core.intent import (DEFAULT_REQUIREMENTS, Intent,
                               IntentRequirements, classify_intent)
from repro.core.lut import ContextConfig, SystemLUT, Tier, paper_lut
from repro.core.split import SplitPlan
from repro.core.streams import DualStreamExecutor, Stream

__all__ = [
    "Intent", "IntentRequirements", "classify_intent", "DEFAULT_REQUIREMENTS",
    "Stream", "DualStreamExecutor", "SplitPlan",
    "BottleneckSpec", "init_bottleneck", "rank_for_ratio",
    "SystemLUT", "Tier", "ContextConfig", "paper_lut",
    "MissionGoal", "PowerConfig", "SelectedConfig", "select_configuration",
    "NoFeasibleInsightTier",
]
