"""System Configuration LUT (paper Table 3 + §4.4.1).

The LUT is the controller's pre-profiled knowledge base: one row per
Insight operating tier storing (compression ratio r, expected Average IoU
for the base and fine-tuned models, compressed payload size). It is built
offline by ``repro.core.profile.build_lut`` against the trained lisa-mini
bottlenecks, or instantiated from the paper's published values.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import List, Optional


@dataclass(frozen=True)
class Tier:
    name: str
    ratio: float                 # bottleneck compression ratio r
    acc_base: float              # Average IoU, base/original model
    acc_finetuned: float         # Average IoU, flood fine-tuned model
    payload_mb: float            # compressed Insight packet size

    def max_pps(self, bandwidth_mbps: float) -> float:
        """Achievable update throughput f_i,max = (B/8) / data_size
        (Algorithm 1 line 21; bandwidth in Mbit/s, payload in MB)."""
        return (bandwidth_mbps / 8.0) / self.payload_mb


@dataclass(frozen=True)
class ContextConfig:
    """The lightweight Context stream's fixed operating point."""
    name: str = "Context"
    payload_mb: float = 0.002    # pooled CLIP features
    max_pps_cap: float = 30.0    # sensor frame-rate cap

    def max_pps(self, bandwidth_mbps: float) -> float:
        return min(self.max_pps_cap, (bandwidth_mbps / 8.0) / self.payload_mb)


@dataclass(frozen=True)
class SystemLUT:
    tiers: List[Tier]
    context: ContextConfig = field(default_factory=ContextConfig)

    def by_name(self, name: str) -> Tier:
        for t in self.tiers:
            if t.name == name:
                return t
        raise KeyError(name)

    def sorted_by_fidelity(self, finetuned: bool = False) -> List[Tier]:
        key = (lambda t: t.acc_finetuned) if finetuned else (lambda t: t.acc_base)
        return sorted(self.tiers, key=key, reverse=True)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"tiers": [asdict(t) for t in self.tiers],
                       "context": asdict(self.context)}, f, indent=2)

    @staticmethod
    def load(path: str) -> "SystemLUT":
        with open(path) as f:
            raw = json.load(f)
        return SystemLUT(tiers=[Tier(**t) for t in raw["tiers"]],
                         context=ContextConfig(**raw["context"]))


def paper_lut() -> SystemLUT:
    """Paper Table 3, verbatim (LISA-7B on Flood-ReasonSeg)."""
    return SystemLUT(tiers=[
        Tier("High Accuracy", 0.25, 0.8442, 0.8112, 2.92),
        Tier("Balanced", 0.10, 0.8289, 0.7920, 1.35),
        Tier("High Throughput", 0.05, 0.8067, 0.7848, 0.83),
    ])
