"""Paged KV-cache bookkeeping for the cloud serving engine.

The in-flight decode batch no longer owns a contiguous ``(slots, width)``
KV cache. KV lives in a shared **page pool** — fixed-size pages of
``page_size`` token slots per LLM layer — and every request addresses
its virtual sequence through a per-row **page table**. Two properties
fall out of that indirection (the vLLM paged-attention discipline):

  * slot KV memory scales with *tokens actually cached*, not with
    ``slots × max_width`` — freed pages return to the allocator and are
    reused without zeroing (stale KV is masked by the position
    bookkeeping, never attended);
  * the ``[ctx; query]`` prefix of successive frames from one UAV is
    content-addressed in a **prefix store**: the first request pays the
    prefill and pins read-only prefix pages, every repeat maps the same
    pages into its own page table and skips the prefill entirely
    (ROADMAP "paged / shared-prefix KV cache").

This module is the *host-side* bookkeeping: a refcounting free-page
allocator, the per-operator prefix store (optionally LRU-capped via
``max_prefixes`` so long multi-operator missions don't grow the pool
unboundedly), and the telemetry counters the engine reports. The device
arrays themselves (``PagePool.kv``) are written/read by the executor's
jitted page ops (``core.streams``) and the paged decode kernel
(``kernels.decode_attention``).

Speculative decoding allocates decode pages *ahead* of acceptance: a
verify chunk writes drafted tokens past the committed length, and a
rejection truncates back. ``grow_to``/``rollback_to`` manage one row's
private page run under that discipline — pages wholly past the accepted
length free immediately, refcounts intact — and ``kv_pages_peak``
records the transient high-water mark those bursts produce (the number
to size a fixed pool by).

Page id 0 is the reserved **trash page**: idle decode rows park their
page tables on it, so their (discarded) writes can never corrupt a live
request's pages. It is never handed out by the allocator.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

TRASH_PAGE = 0


def pages_for(tokens: int, page_size: int) -> int:
    """Number of pages covering ``tokens`` slots."""
    return -(-int(tokens) // int(page_size))


def prefix_digest(ctx: Any, query: Any) -> str:
    """Content hash of one request's ``[ctx; query]`` LLM prefix. Two
    requests share prefix pages iff their digests (and operator) match,
    so reuse is exact-by-construction: identical bytes in, identical
    prefill out."""
    h = hashlib.sha1()
    for arr in (ctx, query):
        a = np.ascontiguousarray(np.asarray(arr))
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def prefix_positions(prefix_len: int, n_pages: int, page_size: int
                     ) -> np.ndarray:
    """Absolute positions of the prefix region of one row's virtual
    sequence: ``[0, prefix_len)`` then ``-1`` (empty) through the zero-
    padded tail of the last prefix page."""
    out = np.full((n_pages * page_size,), -1, np.int32)
    out[:prefix_len] = np.arange(prefix_len, dtype=np.int32)
    return out


@dataclass
class PrefixEntry:
    """One cached ``[ctx; query]`` prefix: its read-only pages plus the
    prefill products every sharer reuses verbatim."""
    key: Tuple[str, str]               # (operator_id, content digest)
    page_ids: Tuple[int, ...]
    prefix_len: int
    logits0: np.ndarray                # (1, V) first-token logits


class PagePool:
    """Free-page allocator + prefix store over one shared device pool.

    ``kv`` is the device pytree ``{"groups": [leaves (L, P, page, ...)]}``
    — created lazily from the first prefill's page shapes and grown
    (doubling) when the free list runs dry, so allocation never fails and
    admission never deadlocks. Pages are refcounted: prefix pages carry
    one pin from the store plus one per active sharer; private decode
    pages carry exactly their request's reference.
    """

    def __init__(self, page_size: int = 16, share_prefixes: bool = True,
                 initial_pages: Optional[int] = None,
                 max_prefixes: Optional[int] = None,
                 placement: Optional[Any] = None, shards: int = 1):
        """``placement`` (sharded serving): a callable mapping the pool
        pytree to its device placement (``ShardedServingContext.
        place_pool`` — kv-heads sharded over the mesh's "model" axis);
        applied on every ``ensure`` create/growth so the device buffers
        stay mesh-resident and page-table updates never round-trip
        through the host. ``shards`` is the model-axis size, used only
        for the per-shard residency telemetry."""
        self.page_size = int(page_size)
        self.share_prefixes = bool(share_prefixes)
        self.initial_pages = initial_pages
        self.placement = placement
        self.kv_shards = max(1, int(shards))
        if max_prefixes is not None and max_prefixes < 1:
            raise ValueError(f"max_prefixes must be >= 1, got {max_prefixes}")
        self.max_prefixes = max_prefixes
        self.kv: Optional[Dict] = None
        self._refcount: List[int] = []
        self._free: List[int] = []
        # insertion order doubles as recency order: a hit reinserts its
        # key at the back, so the front is always the LRU candidate
        self.prefix: Dict[Tuple[str, str], PrefixEntry] = {}
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.prefix_evictions = 0
        self.kv_pages_peak = 0

    # ---- capacity ----

    @property
    def num_pages(self) -> int:
        return len(self._refcount)

    @property
    def pages_in_use(self) -> int:
        """Live pages, excluding the reserved trash page."""
        return sum(1 for c in self._refcount[1:] if c > 0)

    @property
    def page_bytes(self) -> int:
        """Device bytes of one page across all layers (k + v leaves)."""
        if self.kv is None:
            return 0
        leaves = jax.tree.leaves(self.kv)
        return sum(l.nbytes for l in leaves) // max(1, self.num_pages)

    @property
    def pool_bytes(self) -> int:
        """Total device bytes resident in the pool (all pages, all
        layers — the logical/global size; divide by ``kv_shards`` for
        the per-device footprint under sharded serving)."""
        return self.page_bytes * self.num_pages

    def ensure(self, n_free: int, like: Optional[Dict] = None,
               capacity_hint: int = 0) -> None:
        """Guarantee ``n_free`` allocatable pages. ``like`` (a prefill's
        paged KV, leaves ``(L, n, page, ...)``) is required on the first
        call to shape the pool; later calls grow by doubling."""
        if self.kv is None:
            if like is None:
                raise RuntimeError("page pool is empty and no prefill "
                                   "shapes were provided to create it")
            cap = max(n_free + 1, capacity_hint,
                      self.initial_pages or 0)
            self.kv = jax.tree.map(
                lambda a: jnp.zeros((a.shape[0], cap) + a.shape[2:],
                                    a.dtype), like)
            self._refcount = [1] + [0] * (cap - 1)   # page 0: trash, pinned
            self._free = list(range(1, cap))
            if self.placement is not None:
                self.kv = self.placement(self.kv)
            return
        grown = False
        while len(self._free) < n_free:
            old = self.num_pages
            grow = max(old, n_free)
            self.kv = jax.tree.map(
                lambda a: jnp.concatenate(
                    [a, jnp.zeros((a.shape[0], grow) + a.shape[2:],
                                  a.dtype)], axis=1), self.kv)
            self._refcount.extend([0] * grow)
            self._free.extend(range(old, old + grow))
            grown = True
        if grown and self.placement is not None:
            self.kv = self.placement(self.kv)

    # ---- refcounted page allocation ----

    def alloc(self, n: int) -> List[int]:
        if len(self._free) < n:
            self.ensure(n)
        ids = [self._free.pop() for _ in range(n)]
        for i in ids:
            self._refcount[i] = 1
        self.kv_pages_peak = max(self.kv_pages_peak, self.pages_in_use)
        return ids

    def retain(self, ids: Sequence[int]) -> None:
        for i in ids:
            assert self._refcount[i] > 0, f"retain of free page {i}"
            self._refcount[i] += 1

    def release(self, ids: Sequence[int]) -> None:
        for i in ids:
            self._refcount[i] -= 1
            assert self._refcount[i] >= 0, f"double free of page {i}"
            if self._refcount[i] == 0:
                self._free.append(i)

    # ---- speculative allocation (draft overhang + rollback) ----

    def grow_to(self, ids: List[int], tokens: int) -> List[int]:
        """Extend one row's private page run (in place) to cover
        ``tokens`` slots — the speculative path allocates ahead so a
        verify chunk can write drafted tokens past the committed length.
        Returns the freshly allocated page ids (empty when the run
        already covers ``tokens``)."""
        need = pages_for(tokens, self.page_size)
        if need <= len(ids):
            return []
        fresh = self.alloc(need - len(ids))
        ids.extend(fresh)
        return fresh

    def rollback_to(self, ids: List[int], tokens: int) -> List[int]:
        """Speculative rollback: truncate one row's private page run (in
        place) to the pages covering ``tokens`` accepted slots. Pages
        wholly past the accepted length lose this row's reference and
        free immediately (refcounts intact — a page somehow shared stays
        live for its other holders). Returns the dropped page ids so the
        caller can park its page-table entries back on the trash page."""
        keep = pages_for(tokens, self.page_size)
        if keep >= len(ids):
            return []
        dropped = list(ids[keep:])
        del ids[keep:]
        self.release(dropped)
        return dropped

    # ---- prefix store ----

    def lookup_prefix(self, key: Tuple[str, str]) -> Optional[PrefixEntry]:
        entry = self.prefix.get(key) if self.share_prefixes else None
        if entry is None:
            self.prefix_misses += 1
        else:
            self.prefix_hits += 1
            self.prefix.pop(key)          # refresh recency: move to back
            self.prefix[key] = entry
        return entry

    def put_prefix(self, key: Tuple[str, str], page_ids: Sequence[int],
                   prefix_len: int, logits0: np.ndarray) -> PrefixEntry:
        """Register a freshly prefilled prefix. The caller's ``alloc``
        reference stays the *request's* (released when it finishes); when
        sharing is on, the store takes one pin of its own on top
        (released by ``release_operator``), so the pages outlive the
        request. When sharing is off nothing is stored and the pages
        free with the request."""
        entry = PrefixEntry(key=key, page_ids=tuple(page_ids),
                            prefix_len=int(prefix_len),
                            logits0=np.asarray(logits0))
        if self.share_prefixes:
            self.prefix[key] = entry
            self.retain(entry.page_ids)
            self._evict_lru()
        return entry

    def _evict_lru(self) -> None:
        """Enforce ``max_prefixes``: drop least-recently-hit entries
        (the store's pin only — pages still retained by a live request
        survive until that request finishes, so eviction is always
        refcount-safe)."""
        if self.max_prefixes is None:
            return
        while len(self.prefix) > self.max_prefixes:
            lru = next(iter(self.prefix))
            self.release(self.prefix.pop(lru).page_ids)
            self.prefix_evictions += 1

    def release_operator(self, operator_id: str) -> int:
        """Drop every stored prefix of one operator (their pin; pages
        free once no active request shares them). Returns the number of
        entries released."""
        keys = [k for k in self.prefix if k[0] == operator_id]
        for k in keys:
            self.release(self.prefix.pop(k).page_ids)
        return len(keys)

    # ---- invariant audit ----

    def check_invariants(self) -> Dict[str, int]:
        """Audit the allocator: every page is exactly one of
        {trash, live, free}, the free list carries no duplicates and
        only refcount-zero pages, no refcount is negative, and every
        stored prefix still holds live pages. Raises ``RuntimeError``
        naming the first violations; returns the page accounting on
        success. Cheap (host bookkeeping only), so the engine's
        ``debug_invariants`` knob can run it after every pump/cancel,
        and the chaos bench runs it after every storm."""
        errs = []
        rc = self._refcount
        if any(c < 0 for c in rc):
            errs.append("negative refcount")
        if len(set(self._free)) != len(self._free):
            errs.append("duplicate ids on the free list")
        if rc and TRASH_PAGE in self._free:
            errs.append("trash page on the free list")
        if rc and rc[TRASH_PAGE] < 1:
            errs.append("trash page lost its pin")
        for i in self._free:
            if rc[i] != 0:
                errs.append(f"free page {i} has refcount {rc[i]}")
                break
        if rc and self.pages_in_use + len(self._free) != self.num_pages - 1:
            errs.append(
                f"conservation violated: {self.pages_in_use} in use + "
                f"{len(self._free)} free != {self.num_pages} pages - trash")
        for key, entry in self.prefix.items():
            if any(rc[i] <= 0 for i in entry.page_ids):
                errs.append(f"prefix {key} holds a freed page")
                break
        if errs:
            raise RuntimeError("PagePool invariants violated: "
                               + "; ".join(errs))
        return {"pages_in_use": self.pages_in_use,
                "pages_free": len(self._free),
                "pages_total": self.num_pages}

    # ---- telemetry ----

    @property
    def prefix_hit_rate(self) -> float:
        n = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / n if n else 0.0

    def stats(self) -> Dict[str, float]:
        return {
            "kv_page_size": self.page_size,
            "kv_pages_total": self.num_pages,
            "kv_pages_in_use": self.pages_in_use,
            "kv_pages_peak": self.kv_pages_peak,
            "kv_pool_bytes": self.pool_bytes,
            "kv_pool_bytes_per_shard": self.pool_bytes // self.kv_shards,
            "kv_shards": self.kv_shards,
            "prefix_entries": len(self.prefix),
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "prefix_hit_rate": self.prefix_hit_rate,
            "prefix_evictions": self.prefix_evictions,
        }
