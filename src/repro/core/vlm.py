"""LISA-style grounded VLM pipeline (paper Fig. 4), built from the stack
substrate: SAM vision backbone + CLIP context encoder + multi-modal LLM +
<SEG>-conditioned mask decoder.

Instantiated at two scales (repro.configs.lisa7b / lisa_mini — DESIGN.md
§6). All pipeline stages are pure functions so the split-computing runtime
can place them on either side of the channel:

  EDGE : patchify -> SAM blocks [0,k)        (Insight head, split@k)
         patchify_lowres -> CLIP encoder     (Context stream)
  LINK : bottleneck codes (+ CLIP features)
  CLOUD: bottleneck decode -> SAM blocks [k,L) -> mask features
         LLM([ctx tokens; query]) -> answer logits + <SEG> embedding
         mask decoder(SAM feats, <SEG>) -> segmentation mask logits
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.lisa7b import LISAPipelineConfig
from repro.core import bottleneck as bn
from repro.models import stack
from repro.models.common import (cache_mask, causal_mask, fan_in_init, gelu,
                                 linear, normal_init)
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# patch embedding
# ---------------------------------------------------------------------------


def patchify(images: jax.Array, patch: int) -> jax.Array:
    """(B, H, W, C) -> (B, T, patch*patch*C), row-major patches."""
    B, H, W, C = images.shape
    gh, gw = H // patch, W // patch
    x = images.reshape(B, gh, patch, gw, patch, C)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, gh * gw, patch * patch * C)


def _init_encoder(rng: jax.Array, cfg: ModelConfig, patch: int,
                  num_tokens: int, in_ch: int = 3) -> dict:
    ks = jax.random.split(rng, 3)
    spec = stack.layer_groups(cfg)[0]
    return {
        "patch_w": fan_in_init(ks[0], (patch * patch * in_ch, cfg.d_model),
                               cfg.pdtype),
        "patch_b": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "pos": normal_init(ks[1], (num_tokens, cfg.d_model), 0.02, cfg.pdtype),
        "groups": [stack.init_group(ks[2], cfg, spec)],
        "norm": stack.init_norm(cfg),
    }


def _encoder_embed(p: dict, cfg: ModelConfig, images: jax.Array,
                   patch: int) -> jax.Array:
    x = linear(patchify(images, patch).astype(cfg.adtype),
               p["patch_w"], p["patch_b"])
    return x + p["pos"][None].astype(cfg.adtype)


def _encoder_blocks(p_groups, cfg: ModelConfig, x: jax.Array,
                    lo: int = 0, hi: Optional[int] = None) -> jax.Array:
    """Run encoder blocks [lo, hi) — supports the depth-wise split."""
    import dataclasses
    full = stack.layer_groups(cfg)[0]
    hi = full.count if hi is None else hi
    if lo == hi:
        return x
    gp = jax.tree.map(lambda a: a[lo:hi], p_groups[0])
    spec = dataclasses.replace(full, count=hi - lo)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    mask = jnp.zeros((1, S, S), jnp.float32)
    x, _, _ = stack.group_forward(gp, cfg, spec, x, positions, mask)
    return x


# ---------------------------------------------------------------------------
# LISA model
# ---------------------------------------------------------------------------


def init_lisa(pcfg: LISAPipelineConfig, rng: jax.Array) -> dict:
    ks = jax.random.split(rng, 8)
    llm = pcfg.llm
    d_sam, d_clip, d_llm = pcfg.sam.d_model, pcfg.clip.d_model, llm.d_model
    llm_spec = stack.layer_groups(llm)[0]
    return {
        "sam": _init_encoder(ks[0], pcfg.sam, pcfg.patch_size, pcfg.sam_tokens),
        "clip": _init_encoder(ks[1], pcfg.clip, pcfg.context_patch_size,
                              pcfg.clip_tokens),
        "clip_proj": fan_in_init(ks[2], (d_clip, d_llm), llm.pdtype),
        "llm": {
            "embed": normal_init(ks[3], (llm.vocab_size, d_llm), 0.02,
                                 llm.pdtype),
            "groups": [stack.init_group(ks[4], llm, llm_spec)],
            "norm": stack.init_norm(llm),
            "answer_head": fan_in_init(ks[5], (d_llm, llm.vocab_size),
                                       llm.pdtype),
        },
        "seg_proj": fan_in_init(ks[6], (d_llm, d_sam), llm.pdtype),
        "mask_head": {
            "w1": fan_in_init(ks[7], (d_sam, d_sam), pcfg.sam.pdtype),
            "b1": jnp.zeros((d_sam,), pcfg.sam.pdtype),
            "w2": fan_in_init(jax.random.fold_in(ks[7], 1),
                              (d_sam, max(1, pcfg.mask_pixels_per_patch)),
                              pcfg.sam.pdtype),
        },
    }


# ----- edge-side stages -----


def sam_head(params: dict, pcfg: LISAPipelineConfig, images: jax.Array,
             split_k: Optional[int] = None) -> jax.Array:
    """Edge prefix of the SAM backbone: patchify + blocks [0, k)."""
    k = pcfg.split_layer if split_k is None else split_k
    p = params["sam"]
    x = _encoder_embed(p, pcfg.sam, images, pcfg.patch_size)
    return _encoder_blocks(p["groups"], pcfg.sam, x, 0, k)


def clip_encode(params: dict, pcfg: LISAPipelineConfig,
                images: jax.Array) -> jax.Array:
    """Context stream: low-res CLIP features, projected to LLM width.
    Returns (B, clip_tokens, d_llm). Images are resized down to the
    context resolution first (the low-res pathway, paper §4.1)."""
    p = params["clip"]
    if images.shape[1] != pcfg.context_image_size:
        B = images.shape[0]
        images = jax.image.resize(
            images.astype(jnp.float32),
            (B, pcfg.context_image_size, pcfg.context_image_size, 3),
            method="linear").astype(images.dtype)
    x = _encoder_embed(p, pcfg.clip, images, pcfg.context_patch_size)
    x = _encoder_blocks(p["groups"], pcfg.clip, x)
    x = stack.apply_norm(x, p["norm"], pcfg.clip)
    return linear(x, params["clip_proj"])


# ----- cloud-side stages -----


def sam_tail(params: dict, pcfg: LISAPipelineConfig, x: jax.Array,
             split_k: Optional[int] = None) -> jax.Array:
    """Cloud suffix: blocks [k, L) + final norm -> mask features."""
    k = pcfg.split_layer if split_k is None else split_k
    p = params["sam"]
    x = _encoder_blocks(p["groups"], pcfg.sam, x, k, None)
    return stack.apply_norm(x, p["norm"], pcfg.sam)


def _llm_trunk(params: dict, pcfg: LISAPipelineConfig, ctx_tokens: jax.Array,
               query_tokens: jax.Array, want_cache: bool = False):
    """Shared full-sequence LLM trunk over [ctx; query]: embed, causal
    attention stack, final norm. Returns (x (B,S,d), kv_cache_or_None) —
    the single source of truth for both ``llm_reason`` and
    ``llm_prefill`` so the fast path and the serving prefill can't
    diverge."""
    llm = pcfg.llm
    p = params["llm"]
    x_q = jnp.take(p["embed"], query_tokens, axis=0).astype(llm.adtype)
    x = jnp.concatenate([ctx_tokens.astype(llm.adtype), x_q], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    mask = causal_mask(S)[None]
    spec = stack.layer_groups(llm)[0]
    x, _, kv = stack.group_forward(p["groups"][0], llm, spec, x, positions,
                                   mask, want_cache=want_cache)
    return stack.apply_norm(x, p["norm"], llm), kv


def llm_reason(params: dict, pcfg: LISAPipelineConfig, ctx_tokens: jax.Array,
               query_tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Multi-modal LLM over [ctx; query]. Returns (answer_logits (B,V),
    seg_embedding (B, d_sam)) taken at the final (<SEG>) position."""
    x, _ = _llm_trunk(params, pcfg, ctx_tokens, query_tokens)
    last = x[:, -1]                                   # <SEG> position
    answer_logits = linear(last, params["llm"]["answer_head"])
    seg = linear(last, params["seg_proj"])
    return answer_logits, seg


def _llm_outputs(params: dict, x_last: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Answer logits + <SEG> embedding from the hidden state at one
    position (B, d_llm)."""
    answer_logits = linear(x_last, params["llm"]["answer_head"])
    seg = linear(x_last, params["seg_proj"])
    return answer_logits, seg


def llm_prefill(params: dict, pcfg: LISAPipelineConfig, ctx_tokens: jax.Array,
                query_tokens: jax.Array, width: Optional[int] = None
                ) -> Tuple[jax.Array, jax.Array, Dict]:
    """Full-sequence forward over [ctx; query] that also materialises the
    per-layer KV cache (the serving prefill stage). Returns
    (answer_logits (B,V), seg (B,d_sam), cache).

    The cache is laid out for ``llm_decode_step``: ring-buffer slots of
    ``width`` (>= S; defaults to S) with per-slot absolute positions, the
    same contract as ``models.model.init_cache``. Equivalent to
    ``llm_reason`` at the last position.
    """
    llm = pcfg.llm
    x, kv = _llm_trunk(params, pcfg, ctx_tokens, query_tokens,
                       want_cache=True)
    B, S, _ = x.shape
    answer_logits, seg = _llm_outputs(params, x[:, -1])

    W = S if width is None else width
    assert W >= S, (W, S)
    if W > S:
        kv = jax.tree.map(
            lambda a: jnp.pad(a, [(0, 0), (0, 0), (0, W - S)]
                              + [(0, 0)] * (a.ndim - 3)), kv)
    pos_arr = jnp.concatenate(
        [jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S)),
         jnp.full((B, W - S), -1, jnp.int32)], axis=1)
    cache = {"groups": [kv], "positions": pos_arr}
    return answer_logits, seg, cache


def llm_prefill_paged(params: dict, pcfg: LISAPipelineConfig,
                      ctx_tokens: jax.Array, query_tokens: jax.Array,
                      page_size: int) -> Tuple[jax.Array, jax.Array, Dict]:
    """Prefill over [ctx; query] that emits the KV cache chunked into
    fixed-size pages — the serving path's shared-prefix unit. Returns
    (answer_logits (B,V), seg (B,d_sam), paged_kv) with paged_kv leaves
    (L, B, n_pages, page_size, ...); the zero-padded tail of the last
    page carries no position and is masked by the caller's bookkeeping
    (``paging.prefix_positions``). Equivalent to ``llm_prefill`` with
    ``width = n_pages * page_size`` up to the page reshape."""
    x, kv = _llm_trunk(params, pcfg, ctx_tokens, query_tokens,
                       want_cache=True)
    B, S, _ = x.shape
    answer_logits, seg = _llm_outputs(params, x[:, -1])
    n_pages = -(-S // page_size)
    W = n_pages * page_size
    if W > S:
        kv = jax.tree.map(
            lambda a: jnp.pad(a, [(0, 0), (0, 0), (0, W - S)]
                              + [(0, 0)] * (a.ndim - 3)), kv)
    paged = jax.tree.map(
        lambda a: a.reshape(a.shape[:2] + (n_pages, page_size)
                            + a.shape[3:]), kv)
    return answer_logits, seg, {"groups": [paged]}


def llm_decode_step_paged(params: dict, pcfg: LISAPipelineConfig, pool: Dict,
                          page_table: jax.Array, positions: jax.Array,
                          tokens: jax.Array, pos: jax.Array,
                          write_slot: jax.Array
                          ) -> Tuple[jax.Array, jax.Array, Dict]:
    """One in-flight decode step against the shared KV page pool.

    pool {"groups": [kv]} with leaves (L, P, page, ...) — pages shared
    across every live request; page_table (B, n_pages) i32, every entry
    a valid page id (idle rows park on the reserved trash page);
    positions (B, n_pages*page) i32 absolute position stored in each
    virtual slot (-1 empty — the caller owns this bookkeeping, it is
    append-only and deterministic); tokens (B,1) i32; pos (B,) i32
    absolute positions of the new tokens; write_slot (B,) i32 virtual
    slot receiving each row's token. Returns (answer_logits (B,V),
    seg (B,d_sam), new pool). Token-exact with the contiguous
    ``llm_decode_step``: the gathered virtual sequence preserves
    ascending position order and masked slots contribute exactly zero.
    """
    llm = pcfg.llm
    p = params["llm"]
    B = tokens.shape[0]
    page = pool["groups"][0]["k"].shape[2]
    x = jnp.take(p["embed"], tokens, axis=0).astype(llm.adtype)
    pos = jnp.asarray(pos, jnp.int32)
    write_slot = jnp.asarray(write_slot, jnp.int32)
    rows = jnp.arange(B)
    pos_arr = jnp.asarray(positions, jnp.int32).at[rows, write_slot].set(pos)
    mask = cache_mask(pos_arr, pos[:, None], llm.sliding_window)
    page_table = jnp.asarray(page_table, jnp.int32)
    write_page = page_table[rows, write_slot // page]
    write_off = write_slot % page
    spec = stack.layer_groups(llm)[0]
    x, kv = stack.group_decode_paged(p["groups"][0], llm, spec, x,
                                     pos[:, None], pool["groups"][0],
                                     page_table, write_page, write_off, mask)
    x = stack.apply_norm(x, p["norm"], llm)
    answer_logits, seg = _llm_outputs(params, x[:, -1])
    return answer_logits, seg, {"groups": [kv]}


def llm_verify_step_paged(params: dict, pcfg: LISAPipelineConfig, pool: Dict,
                          page_table: jax.Array, positions: jax.Array,
                          tokens: jax.Array, pos: jax.Array,
                          write_slot: jax.Array, chunk_len: jax.Array
                          ) -> Tuple[jax.Array, jax.Array, Dict]:
    """One speculative *verify* step: a chunk of C tokens per row — the
    row's last accepted token followed by drafted continuations — scored
    through the serving model in a single paged multi-token pass.

    pool/page_table/positions as in ``llm_decode_step_paged``; tokens
    (B, C) i32 chunk tokens occupying consecutive virtual slots
    ``write_slot .. write_slot+C-1`` at absolute positions
    ``pos .. pos+C-1`` (both (B,) i32 starts); chunk_len (B,) i32 marks
    how many leading chunk entries are real — pad entries scatter their
    k/v to the reserved trash page, record no position, and their
    logits are garbage the caller ignores (this is what lets plain
    C=1-style rows ride the same jitted call as speculating rows).

    Causal within the chunk: the chunk's k/v land in the pool before
    attention and the position mask admits slots with position <= the
    query's, so chunk token i attends [cache; chunk tokens <= i] —
    exactly the context C successive ``llm_decode_step_paged`` calls
    would give it. Returns (answer_logits (B, C, V), seg (B, C, d_sam),
    new pool): logits[:, i] is the model's next-token distribution
    after consuming chunk token i (column 0 of a chunk_len=1 call
    matches ``llm_decode_step_paged`` on the same token), and seg[:, i]
    is the <SEG> read at chunk position i (the final accepted position
    supplies ``llm_generate``'s end-of-answer embedding)."""
    from repro.core.paging import TRASH_PAGE
    llm = pcfg.llm
    p = params["llm"]
    B, C = tokens.shape
    page = pool["groups"][0]["k"].shape[2]
    n_slots = positions.shape[1]
    x = jnp.take(p["embed"], tokens, axis=0).astype(llm.adtype)
    rows = jnp.arange(B)[:, None]
    offs = jnp.arange(C, dtype=jnp.int32)[None, :]
    valid = offs < jnp.asarray(chunk_len, jnp.int32)[:, None]
    pos_c = jnp.asarray(pos, jnp.int32)[:, None] + offs          # (B, C)
    ws = jnp.asarray(write_slot, jnp.int32)[:, None] + offs      # (B, C)
    # pad entries scatter out of bounds -> dropped (their positions stay
    # unset, so their trash-page writes can never be attended as valid)
    ws_sc = jnp.where(valid, ws, n_slots)
    pos_arr = jnp.asarray(positions, jnp.int32).at[rows, ws_sc].set(
        pos_c, mode="drop")
    mask = cache_mask(pos_arr[:, None, :], pos_c[:, :, None],
                      llm.sliding_window)                        # (B, C, W)
    page_table = jnp.asarray(page_table, jnp.int32)
    ws_in = jnp.minimum(ws, n_slots - 1)
    write_page = jnp.where(valid, page_table[rows, ws_in // page],
                           TRASH_PAGE)
    write_off = ws_in % page
    spec = stack.layer_groups(llm)[0]
    x, kv = stack.group_verify_paged(p["groups"][0], llm, spec, x, pos_c,
                                     pool["groups"][0], page_table,
                                     write_page, write_off, mask)
    x = stack.apply_norm(x, p["norm"], llm)
    answer_logits, seg = _llm_outputs(params, x)
    return answer_logits, seg, {"groups": [kv]}


def llm_decode_step(params: dict, pcfg: LISAPipelineConfig, cache: Dict,
                    tokens: jax.Array, pos: jax.Array
                    ) -> Tuple[jax.Array, jax.Array, Dict]:
    """One autoregressive decode step against the KV cache. tokens (B,1)
    i32; pos i32 — either a scalar (whole batch at the same absolute
    position) or a (B,) vector of per-row positions (the in-flight
    batching path, where requests join a running decode mid-stream and
    each slot sits at its own depth). Returns (answer_logits (B,V),
    seg (B,d_sam), new_cache). The attention hot loop routes through the
    flash-decode Pallas kernel when ``pcfg.llm.use_flash_decode`` is
    set."""
    llm = pcfg.llm
    p = params["llm"]
    B = tokens.shape[0]
    x = jnp.take(p["embed"], tokens, axis=0).astype(llm.adtype)
    W = cache["positions"].shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    slot = pos % W
    if pos.ndim == 0:
        pos_arr = jax.lax.dynamic_update_slice(
            cache["positions"],
            jnp.broadcast_to(pos, (B, 1)), (0, slot))
        mask = cache_mask(pos_arr, pos, llm.sliding_window)
        positions = jnp.broadcast_to(pos, (B, 1))
    else:                               # per-row ring slots + masks
        pos_arr = cache["positions"].at[jnp.arange(B), slot].set(pos)
        mask = cache_mask(pos_arr, pos[:, None], llm.sliding_window)
        positions = pos[:, None]
    spec = stack.layer_groups(llm)[0]
    x, kv = stack.group_decode(p["groups"][0], llm, spec, x, positions,
                               cache["groups"][0], slot, mask)
    x = stack.apply_norm(x, p["norm"], llm)
    answer_logits, seg = _llm_outputs(params, x[:, -1])
    return answer_logits, seg, {"groups": [kv], "positions": pos_arr}


def llm_generate(params: dict, pcfg: LISAPipelineConfig, ctx_tokens: jax.Array,
                 query_tokens: jax.Array, max_new_tokens: int
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Greedy multi-token answer generation: one prefill over [ctx; query]
    then flash-decode steps (the first answer token comes from the prefill
    logits). Returns (tokens (B, T) i32, first_answer_logits (B, V),
    seg (B, d_sam)). The seg embedding is always read from the hidden
    state of the *final generated* token — the answer's trailing <SEG>
    position — for every T, so mask conditioning doesn't change
    convention between T == 1 and T > 1. jit-able with static
    ``max_new_tokens``."""
    S = ctx_tokens.shape[1] + query_tokens.shape[1]
    W = S + max_new_tokens
    logits0, _, cache = llm_prefill(params, pcfg, ctx_tokens, query_tokens,
                                    width=W)
    tok0 = jnp.argmax(logits0, axis=-1).astype(jnp.int32)
    if max_new_tokens > 1:
        def step(carry, pos):
            tok, c = carry
            logits, _, c2 = llm_decode_step(params, pcfg, c, tok[:, None],
                                            pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return (nxt, c2), nxt

        (last, cache), toks = jax.lax.scan(
            step, (tok0, cache), jnp.arange(S, S + max_new_tokens - 1,
                                            dtype=jnp.int32))
        tokens = jnp.concatenate([tok0[:, None], toks.T], axis=1)
    else:
        last, tokens = tok0, tok0[:, None]
    # one more decode step to read the <SEG> hidden state at the last
    # generated token itself (its logits predict beyond the answer and
    # are discarded)
    _, seg, _ = llm_decode_step(params, pcfg, cache, last[:, None],
                                jnp.int32(S + max_new_tokens - 1))
    return tokens, logits0, seg


def mask_decode(params: dict, pcfg: LISAPipelineConfig, sam_feats: jax.Array,
                seg: jax.Array) -> jax.Array:
    """<SEG>-conditioned mask decoder: (B, T, d_sam) x (B, d_sam) ->
    per-pixel logits (B, H, W)."""
    mh = params["mask_head"]
    fused = sam_feats * seg[:, None, :].astype(sam_feats.dtype)
    h = gelu(linear(fused, mh["w1"], mh["b1"]))
    pix = linear(h, mh["w2"])                         # (B, T, pp)
    B, T, pp = pix.shape
    g = pcfg.image_size // pcfg.patch_size
    if pp == 1:
        return pix.reshape(B, g, g)
    s = int(round(pp ** 0.5))
    pix = pix.reshape(B, g, g, s, s)
    pix = pix.transpose(0, 1, 3, 2, 4)
    return pix.reshape(B, g * s, g * s)


# ----- end-to-end pipelines -----


def insight_forward(params: dict, pcfg: LISAPipelineConfig,
                    images: jax.Array, query_tokens: jax.Array,
                    bn_params: Optional[dict] = None,
                    split_k: Optional[int] = None,
                    ) -> Tuple[jax.Array, jax.Array]:
    """Full Insight pipeline; with ``bn_params`` the boundary activation is
    compressed with the straight-through bottleneck (training/eval path).
    Returns (mask_logits (B,H,W), answer_logits (B,V))."""
    a = sam_head(params, pcfg, images, split_k)
    if bn_params is not None:
        a = bn.roundtrip_st(bn_params, a)
    feats = sam_tail(params, pcfg, a, split_k)
    ctx = clip_encode(params, pcfg, images)
    answer_logits, seg = llm_reason(params, pcfg, ctx, query_tokens)
    mask_logits = mask_decode(params, pcfg, feats, seg)
    return mask_logits, answer_logits


def context_forward(params: dict, pcfg: LISAPipelineConfig,
                    images: jax.Array, query_tokens: jax.Array) -> jax.Array:
    """Context pipeline: CLIP-only features -> LLM -> text answer logits."""
    ctx = clip_encode(params, pcfg, images)
    answer_logits, _ = llm_reason(params, pcfg, ctx, query_tokens)
    return answer_logits


# ----- losses / metrics -----


def insight_loss(params: dict, pcfg: LISAPipelineConfig, batch: Dict,
                 bn_params: Optional[dict] = None,
                 pos_weight: float = 25.0) -> Tuple[jax.Array, Dict]:
    mask_logits, answer_logits = insight_forward(
        params, pcfg, batch["images"], batch["query"], bn_params)
    m = batch["mask"].astype(jnp.float32)
    ml = mask_logits.astype(jnp.float32)
    # positive-class weighting: targets cover ~2% of pixels, so unweighted
    # BCE collapses to the empty-mask optimum
    w = 1.0 + (pos_weight - 1.0) * m
    bce = jnp.mean(w * (jnp.maximum(ml, 0) - ml * m
                        + jnp.log1p(jnp.exp(-jnp.abs(ml)))))
    # dice loss stabilises IoU on small targets
    p = jax.nn.sigmoid(ml)
    inter = jnp.sum(p * m, axis=(1, 2))
    dice = 1 - jnp.mean((2 * inter + 1) /
                        (jnp.sum(p, axis=(1, 2)) + jnp.sum(m, axis=(1, 2)) + 1))
    ans = _answer_ce(answer_logits, batch["answer"])
    loss = bce + dice + 0.5 * ans
    return loss, {"bce": bce, "dice": dice, "answer_ce": ans}


def context_loss(params: dict, pcfg: LISAPipelineConfig,
                 batch: Dict) -> Tuple[jax.Array, Dict]:
    logits = context_forward(params, pcfg, batch["images"], batch["query"])
    ce = _answer_ce(logits, batch["answer"])
    return ce, {"answer_ce": ce}


def _answer_ce(logits: jax.Array, answer: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, answer[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def iou_metrics(mask_logits: jax.Array, gt: jax.Array) -> Dict[str, jax.Array]:
    """gIoU (mean per-image IoU), cIoU (cumulative), and their mean —
    the paper's 'Average IoU' (Table 3 note)."""
    pred = (mask_logits > 0).astype(jnp.float32)
    gt = gt.astype(jnp.float32)
    inter = jnp.sum(pred * gt, axis=(1, 2))
    union = jnp.sum(jnp.maximum(pred, gt), axis=(1, 2))
    giou = jnp.mean(inter / (union + 1e-6))
    ciou = jnp.sum(inter) / (jnp.sum(union) + 1e-6)
    return {"giou": giou, "ciou": ciou, "avg_iou": 0.5 * (giou + ciou)}
