"""Packetisation of transmitted representations (paper Fig. 4 step 5).

Payload accounting is exact: int8 codes + fp16 per-token scales for
bottlenecked Insight activations, fp16 for Context features, plus a fixed
header. These byte counts drive both the network simulator and the
payload_mb column of the LUT.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import numpy as np

HEADER_BYTES = 64
FP16_BYTES = 2
INT8_BYTES = 1


@dataclass
class Packet:
    kind: str                      # "context" | "insight"
    tier_name: Optional[str]       # Insight tier, None for context
    seq_id: int
    created_at: float              # simulation time (s)
    payload_bytes: int
    content: Dict[str, Any] = field(default_factory=dict, repr=False)

    @property
    def payload_mb(self) -> float:
        return self.payload_bytes / 1e6


def insight_payload_bytes(num_tokens: int, rank: int,
                          clip_tokens: int = 0, clip_dim: int = 0) -> int:
    """Compressed SAM activation (int8 codes + fp16 scales) + fp16 CLIP
    context features riding in the same Insight packet (paper §4.1)."""
    codes = num_tokens * rank * INT8_BYTES
    scales = num_tokens * FP16_BYTES
    clip = clip_tokens * clip_dim * FP16_BYTES
    return HEADER_BYTES + codes + scales + clip


def context_payload_bytes(ctx_tokens: int, dim: int) -> int:
    return HEADER_BYTES + ctx_tokens * dim * FP16_BYTES


def make_insight_packet(seq_id: int, now: float, tier_name: str,
                        codes: np.ndarray, scales: np.ndarray,
                        clip_feats: Optional[np.ndarray] = None) -> Packet:
    nbytes = HEADER_BYTES + codes.size * INT8_BYTES + scales.size * FP16_BYTES
    content = {"codes": codes, "scales": scales}
    if clip_feats is not None:
        nbytes += clip_feats.size * FP16_BYTES
        content["clip"] = clip_feats
    return Packet(kind="insight", tier_name=tier_name, seq_id=seq_id,
                  created_at=now, payload_bytes=nbytes, content=content)


def make_context_packet(seq_id: int, now: float,
                        ctx_feats: np.ndarray) -> Packet:
    return Packet(kind="context", tier_name=None, seq_id=seq_id,
                  created_at=now,
                  payload_bytes=HEADER_BYTES + ctx_feats.size * FP16_BYTES,
                  content={"ctx": ctx_feats})
