"""Operator intent taxonomy (paper §3.1).

Two intent levels, mapped 1:1 to admissible streams (§3.2):
  * CONTEXT — coarse semantic awareness / triage; text answer suffices.
  * INSIGHT — fine-grained spatial grounding; a segmentation mask is the
    required semantic product.

``classify_intent`` is the lightweight onboard NL gate: a keyword rule
set over the operator prompt (the paper's controller is likewise
"lightweight and interpretable", §4.4). Each intent induces service
requirements (F_I update-timeliness floor, Q_I fidelity floor).
"""
from __future__ import annotations

import enum
import re
from dataclasses import dataclass
from typing import Optional


class Intent(enum.Enum):
    CONTEXT = "context"
    INSIGHT = "insight"


@dataclass(frozen=True)
class IntentRequirements:
    """Service-level objectives induced by an intent (paper §3.1)."""
    min_update_pps: float         # F_I: minimum update throughput (packets/s)
    min_fidelity: float = 0.0     # Q_I: minimum Average IoU (Insight only)
    # per-request latency SLO: a request not delivered within
    # max_latency_s of its submission is cancelled by the engine
    # (Response.failure == "deadline"); None disables the deadline —
    # matching the paper's listing, where timeliness is a throughput
    # floor (F_I) and hard per-request deadlines are deployment knobs
    max_latency_s: Optional[float] = None


# Deployment defaults (paper §3.3: F_I = 0.5 PPS for Insight-level intents;
# Q_I is deployment-dependent — 0.0 disables the fidelity floor, matching
# Algorithm 1's listing; missions can raise it per-intent).
DEFAULT_REQUIREMENTS = {
    Intent.CONTEXT: IntentRequirements(min_update_pps=2.0),
    Intent.INSIGHT: IntentRequirements(min_update_pps=0.5, min_fidelity=0.0),
}

# Grounding verbs / spatial-output requests => Insight-level.
_INSIGHT_PATTERNS = [
    r"\bhighlight\b", r"\bsegment\b", r"\bmark\b", r"\boutline\b",
    r"\bmask\b", r"\blocal[iz]e\b", r"\bpinpoint\b", r"\bshow exactly\b",
    r"\bwhere exactly\b", r"\bdraw\b", r"\btrace\b",
]
# Triage / existence / counting questions => Context-level.
_CONTEXT_PATTERNS = [
    r"\bwhat is happening\b", r"\bany\b", r"\bis there\b", r"\bare there\b",
    r"\bhow many\b", r"\bdescribe\b", r"\bsummar", r"\bstatus\b",
    r"\bsurvey\b", r"\boverview\b",
]


def classify_intent(prompt: str) -> Intent:
    p = prompt.lower()
    insight = sum(bool(re.search(pat, p)) for pat in _INSIGHT_PATTERNS)
    context = sum(bool(re.search(pat, p)) for pat in _CONTEXT_PATTERNS)
    if insight > context:
        return Intent.INSIGHT
    if context > insight:
        return Intent.CONTEXT
    # tie / no signal: grounding requests usually name a concrete target
    # ("the red car on the roof"); default to CONTEXT (cheap, escalate later)
    return Intent.INSIGHT if insight else Intent.CONTEXT


def admissible_streams(intent: Intent):
    """S(I_t) — paper §3.2: the stream set is a singleton per intent level."""
    from repro.core.streams import Stream
    return (Stream.INSIGHT,) if intent is Intent.INSIGHT else (Stream.CONTEXT,)
