"""Training drivers for the LISA proxy pipeline and its bottleneck tiers.

Two model variants mirror the paper's LUT columns (§5.1):
  * "original"  — trained on the broad mixture (both classes, context +
    insight queries, heavy photometric augmentation) — the stand-in for
    pre-trained LISA;
  * "finetuned" — the original weights further specialised on the
    flood-proxy Insight distribution (the stand-in for LoRA flood
    fine-tuning on Flood-ReasonSeg).

Bottleneck pairs are distillation-trained per compression ratio with the
pipeline frozen (paper Fig. 5: "pre-trained compression models").
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.lisa7b import LISAPipelineConfig
from repro.core import bottleneck as bn
from repro.core import vlm
from repro.data import floodseg
from repro import optim


def _to_jnp(batch: Dict[str, np.ndarray]) -> Dict[str, jnp.ndarray]:
    return {k: jnp.asarray(v) for k, v in batch.items()}


def mixed_loss(params, pcfg, ins_batch, ctx_batch):
    li, mi = vlm.insight_loss(params, pcfg, ins_batch)
    lc, mc = vlm.context_loss(params, pcfg, ctx_batch)
    return li + 0.5 * lc, {**mi, "ctx_ce": mc["answer_ce"]}


def train_lisa(pcfg: LISAPipelineConfig, steps: int = 300, batch_size: int = 16,
               seed: int = 0, lr: float = 3e-4,
               params: Optional[dict] = None,
               insight_only: bool = False,
               log_every: int = 50,
               log: Callable[[str], None] = print) -> dict:
    rng = np.random.RandomState(seed)
    if params is None:
        params = vlm.init_lisa(pcfg, jax.random.PRNGKey(seed))
    opt = optim.adamw(optim.cosine_with_warmup(lr, steps // 10, steps))
    state = opt.init(params)

    def step_fn(p, s, ins, ctx):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: mixed_loss(q, pcfg, ins, ctx), has_aux=True)(p)
        p, s = opt.apply(p, s, grads)
        return p, s, loss, metrics

    step_jit = jax.jit(step_fn)
    for i in range(steps):
        ins = _to_jnp(floodseg.make_batch(rng, batch_size, "segment"))
        kind = "any" if (i % 2 == 0 or insight_only) else "count"
        ctx = _to_jnp(floodseg.make_batch(rng, batch_size, kind))
        params, state, loss, metrics = step_jit(params, state, ins, ctx)
        if log_every and (i % log_every == 0 or i == steps - 1):
            log(f"  step {i:4d} loss={float(loss):.4f} "
                f"bce={float(metrics['bce']):.4f} "
                f"dice={float(metrics['dice']):.4f}")
    return params


def finetune_lisa(pcfg: LISAPipelineConfig, params: dict, steps: int = 150,
                  batch_size: int = 16, seed: int = 1,
                  lr: float = 1e-4, log=print) -> dict:
    """Flood-specialisation pass (stand-in for the paper's LoRA FT)."""
    return train_lisa(pcfg, steps=steps, batch_size=batch_size, seed=seed,
                      lr=lr, params=params, insight_only=True, log=log)


def train_bottleneck(pcfg: LISAPipelineConfig, params: dict, ratio: float,
                     steps: int = 200, batch_size: int = 16, seed: int = 0,
                     lr: float = 1e-3, recon_weight: float = 0.1,
                     log_every: int = 50, log=print) -> dict:
    """Distillation-train one bottleneck pair at ``ratio`` with the
    pipeline frozen (gradients flow only into the encoder/decoder)."""
    d = pcfg.sam.d_model
    orig_bytes = jnp.dtype(pcfg.sam.adtype).itemsize
    spec = bn.BottleneckSpec(d, bn.rank_for_ratio(d, ratio, orig_bytes),
                             orig_bytes)
    rng = np.random.RandomState(seed + int(ratio * 1000))
    bn_params = bn.init_bottleneck(
        jax.random.PRNGKey(seed + int(ratio * 1000)), spec)
    opt = optim.adamw(lr)
    state = opt.init(bn_params)
    frozen = jax.tree.map(jax.lax.stop_gradient, params)

    def loss_fn(bp, ins):
        task, _ = vlm.insight_loss(frozen, pcfg, ins, bn_params=bp)
        a = vlm.sam_head(frozen, pcfg, ins["images"])
        return task + recon_weight * bn.recon_loss(bp, a)

    def step_fn(bp, s, ins):
        loss, grads = jax.value_and_grad(loss_fn)(bp, ins)
        bp, s = opt.apply(bp, s, grads)
        return bp, s, loss

    step_jit = jax.jit(step_fn)
    for i in range(steps):
        ins = _to_jnp(floodseg.make_batch(rng, batch_size, "segment"))
        bn_params, state, loss = step_jit(bn_params, state, ins)
        if log_every and (i % log_every == 0 or i == steps - 1):
            log(f"  bn(r={ratio}) step {i:4d} loss={float(loss):.4f}")
    return bn_params


def evaluate_insight(pcfg: LISAPipelineConfig, params: dict,
                     bn_params: Optional[dict] = None, batches: int = 8,
                     batch_size: int = 32, seed: int = 999) -> Dict[str, float]:
    """Average IoU (mean of gIoU and cIoU, paper Table 3) on held-out
    un-augmented scenes."""
    rng = np.random.RandomState(seed)
    fwd = jax.jit(lambda p, bp, img, q: vlm.insight_forward(
        p, pcfg, img, q, bn_params=bp))
    # built once outside the loop: a fresh jit(lambda) per iteration is
    # a new function identity, i.e. a recompile every batch (AV101)
    fwd_raw = jax.jit(lambda p, img, q: vlm.insight_forward(
        p, pcfg, img, q))
    inters, unions, gious = [], [], []
    for _ in range(batches):
        b = _to_jnp(floodseg.make_batch(rng, batch_size, "segment",
                                        augment=False))
        if bn_params is None:
            ml, _ = fwd_raw(params, b["images"], b["query"])
        else:
            ml, _ = fwd(params, bn_params, b["images"], b["query"])
        pred = (np.asarray(ml) > 0).astype(np.float64)
        gt = np.asarray(b["mask"]).astype(np.float64)
        inter = (pred * gt).sum(axis=(1, 2))
        union = np.maximum(pred, gt).sum(axis=(1, 2))
        inters.append(inter.sum())
        unions.append(union.sum())
        gious.append((inter / (union + 1e-6)).mean())
    giou = float(np.mean(gious))
    ciou = float(sum(inters) / (sum(unions) + 1e-6))
    return {"giou": giou, "ciou": ciou, "avg_iou": 0.5 * (giou + ciou)}


def evaluate_context(pcfg: LISAPipelineConfig, params: dict, batches: int = 8,
                     batch_size: int = 32, seed: int = 999) -> float:
    rng = np.random.RandomState(seed)
    fwd = jax.jit(lambda p, img, q: vlm.context_forward(p, pcfg, img, q))
    accs = []
    for _ in range(batches):
        b = _to_jnp(floodseg.make_batch(rng, batch_size, "any", augment=False))
        logits = fwd(params, b["images"], b["query"])
        accs.append(float(np.mean(np.argmax(np.asarray(logits), -1)
                                  == np.asarray(b["answer"]))))
    return float(np.mean(accs))
