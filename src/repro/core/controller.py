"""AVERY onboard Split Controller — Algorithm 1, verbatim structure.

Four phases: Sense (bandwidth), Gate (intent -> admissible stream),
Evaluate (feasible Insight tiers under the F_I timeliness floor),
Select (mission-goal preference over the feasible set).

Deterministic, LUT-driven, O(|tiers|) — deliberately *not* an online
optimizer (paper §3.3). Runs on the host in the serving runtime; a pure
function so it is also trivially property-testable (hypothesis tests
assert feasibility/monotonicity invariants).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Optional, Tuple, Union

from repro.core.intent import Intent, IntentRequirements
from repro.core.lut import SystemLUT, Tier


class MissionGoal(enum.Enum):
    PRIORITIZE_ACCURACY = "accuracy"
    PRIORITIZE_THROUGHPUT = "throughput"


@dataclass(frozen=True)
class PowerConfig:
    """Onboard compute-power budget P_cfg. In the paper's prototype this is
    the fixed Jetson operating mode (MODE_30W_ALL) — it scales the edge
    compute-latency/energy model, not the tier feasibility check (§4.4.2)."""
    name: str = "MODE_30W_ALL"
    power_watts: float = 30.0
    edge_flops_per_sec: float = 16e12   # Jetson AGX Xavier ~16 TOPS eqv.


@dataclass(frozen=True)
class SelectedConfig:
    stream: str                  # "context" | "insight"
    tier: Optional[Tier]         # None for the Context stream
    throughput_pps: float        # induced f*


class NoFeasibleInsightTier(Exception):
    """Raised when no profiled tier satisfies F_I at current bandwidth
    (Algorithm 1 lines 26-28)."""


def select_configuration(
    bandwidth_mbps: float,
    power_cfg: PowerConfig,
    mission_goal: MissionGoal,
    intent: Intent,
    requirements: IntentRequirements,
    lut: SystemLUT,
    finetuned: bool = False,
) -> SelectedConfig:
    """Algorithm 1 ``SelectConfiguration``. Raises NoFeasibleInsightTier if
    the feasible set is empty."""
    # --- Stage 1: Sense (bandwidth_mbps is the sensed value) ---
    b = float(bandwidth_mbps)

    # --- Stage 2: Gate ---
    if intent is not Intent.INSIGHT:
        ctx = lut.context
        return SelectedConfig(stream="context", tier=None,
                              throughput_pps=ctx.max_pps(b))

    # --- Stage 3: Evaluate feasible Insight tiers ---
    # Feasibility is F_I (timeliness) AND Q_I (fidelity floor): the paper's
    # formal model (§3.3) states Q(S_t, r_t) >= Q_I although Algorithm 1's
    # listing only shows the timeliness check; we enforce both.
    feasible: list[Tuple[Tier, float]] = []
    for tier in lut.tiers:
        f_max = tier.max_pps(b)                       # (B/8) / data_size
        q = tier.acc_finetuned if finetuned else tier.acc_base
        if f_max >= requirements.min_update_pps and \
                q >= requirements.min_fidelity:
            feasible.append((tier, f_max))
    if not feasible:
        raise NoFeasibleInsightTier(
            f"no Insight tier sustains F_I={requirements.min_update_pps} PPS "
            f"with Q_I={requirements.min_fidelity} at {b:.2f} Mbps")

    # --- Stage 4: Select tier by mission goal ---
    acc_key = (lambda tf: tf[0].acc_finetuned) if finetuned \
        else (lambda tf: tf[0].acc_base)
    if mission_goal is MissionGoal.PRIORITIZE_ACCURACY:
        tier, f = max(feasible, key=acc_key)
    else:
        tier, f = max(feasible, key=lambda tf: tf[1])
    return SelectedConfig(stream="insight", tier=tier, throughput_pps=f)


def min_bandwidth_for_tier(tier: Tier, min_pps: float) -> float:
    """Inverse of the feasibility check: the bandwidth (Mbps) below which
    ``tier`` violates F_I. Paper §3.3 quotes 11.68 Mbps for High-Accuracy
    at 0.5 PPS (= 2.92 MB * 8 * 0.5)."""
    return tier.payload_mb * 8.0 * min_pps
