"""Dual-stream execution modes (paper §4.1–§4.3) + the batched cloud
serving engine.

``Stream`` names the two semantically distinct execution modes; the
``DualStreamExecutor`` bundles the jitted edge/cloud stage functions for a
trained LISA pipeline plus the per-tier bottlenecks, and exposes
``run_context`` / ``run_insight`` used by the serving runtime and the
mission simulator.

Cloud serving is batched: ``cloud_context_batch`` / ``cloud_insight_batch``
stack multiple packets of the same tier into one device call, and
``cloud_generate_batch`` serves multi-token answers through the
prefill + flash-decode KV-cache path (``vlm.llm_prefill`` /
``vlm.llm_decode_step``). The in-flight stages serve the paged
shared-prefix cache instead: ``cloud_prefix`` prefills a [ctx; query]
prefix into fixed-size KV pages, ``pool_write`` scatters them into the
shared page pool, and ``cloud_decode_rows`` advances every live slot one
token through per-row page tables (``vlm.llm_decode_step_paged``; the
allocator/prefix-store bookkeeping lives in ``core.paging``). Request
counts are padded up to a small set of bucket sizes and every jitted
stage is held in an explicit compile cache keyed on (stage, tier,
bucket, query_len), so varying request counts never retrigger XLA
compilation.

The executor is deliberately channel-agnostic: it returns the numpy
payloads + packets; the runtime decides what the (simulated or pod-
disaggregated) link does with them.
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.lisa7b import LISAPipelineConfig
from repro.core import bottleneck as bn
from repro.core import packets as pk
from repro.core import vlm
from repro.core.lut import SystemLUT, Tier


class Stream(enum.Enum):
    CONTEXT = "context"   # high-frequency, low-resolution awareness
    INSIGHT = "insight"   # low-frequency, high-fidelity grounding


def _pool_write(dst: Dict, src: Dict, page_ids) -> Dict:
    """Scatter one prefilled prefix's pages (leaves (L, n, page, ...))
    into the shared page pool (leaves (L, P, page, ...)) at
    ``page_ids`` (n,)."""
    return jax.tree.map(lambda d, s: d.at[:, page_ids].set(s), dst, src)


def _pad_rows(arr: np.ndarray, bucket: int) -> np.ndarray:
    """Pad axis 0 up to ``bucket`` by repeating the last row (rows past the
    real count are sliced away after the call)."""
    n = arr.shape[0]
    if n == bucket:
        return arr
    reps = np.repeat(arr[-1:], bucket - n, axis=0)
    return np.concatenate([arr, reps], axis=0)


@dataclass
class DualStreamExecutor:
    pcfg: LISAPipelineConfig
    params: dict
    bottlenecks: Dict[str, dict]          # tier name -> bottleneck params
    lut: SystemLUT
    # batch buckets for the cloud stages: request counts are padded up to
    # the smallest bucket >= n so the jit cache sees a fixed shape set
    buckets: Tuple[int, ...] = (1, 2, 4, 8, 16)
    # answer length for the generate path (continuous-batching serving)
    max_new_tokens: int = 4
    # route decode attention through the flash-decode Pallas kernel
    flash_decode: bool = True
    # KV page size (token slots per page) for the paged in-flight cache
    page_size: int = 16

    def __post_init__(self):
        pcfg = self.pcfg
        self.buckets = tuple(sorted(self.buckets))
        # decode steps run with the flash-decode kernel on the attention
        # hot loop; prefill keeps the full-sequence path
        self._gen_pcfg = dataclasses.replace(
            pcfg, llm=pcfg.llm.replace(use_flash_decode=self.flash_decode))
        self._edge_context = jax.jit(
            lambda p, img: vlm.clip_encode(p, pcfg, img))
        self._edge_insight = jax.jit(
            lambda p, img: vlm.sam_head(p, pcfg, img))
        # one shared jitted bottleneck encode for every tier (tiers differ
        # only in code rank, which the jit cache keys on via shape)
        self._encode = jax.jit(lambda bp, a: bn.encode(bp, a))
        # explicit compile cache: (stage, tier, bucket, query_len) ->
        # jitted callable.
        # Each entry owns exactly one compiled executable (bucket shapes
        # are fixed), so len(self._compiled) == number of XLA compiles.
        self._compiled: Dict[Tuple, Callable] = {}
        # in-flight decode stages (token-level continuous batching): one
        # paged decode step over all live slots with per-row positions and
        # page tables, the prefix-page scatter into the shared pool, and
        # the standalone mask decode
        self._decode_paged = jax.jit(
            lambda p, pool, pt, posarr, tok, pos, ws:
            vlm.llm_decode_step_paged(p, self._gen_pcfg, pool, pt, posarr,
                                      tok, pos, ws))
        # speculative verify: one paged multi-token pass over every live
        # slot's chunk (last accepted token + drafts); the jit cache keys
        # on the chunk width C via the tokens shape
        self._verify_paged = jax.jit(
            lambda p, pool, pt, posarr, tok, pos, ws, cl:
            vlm.llm_verify_step_paged(p, self._gen_pcfg, pool, pt, posarr,
                                      tok, pos, ws, cl))
        self._mask_decode = jax.jit(
            lambda p, feats, seg: vlm.mask_decode(p, pcfg, feats, seg))
        self._pool_write = jax.jit(_pool_write)

    # ---- compile cache ----

    def _stage_fn(self, stage: str, width: Optional[int] = None) -> Callable:
        pcfg, T = self.pcfg, self.max_new_tokens
        gcfg = dataclasses.replace(
            pcfg, llm=pcfg.llm.replace(use_flash_decode=self.flash_decode))

        if stage == "cloud_sam_feats":
            def fn(p, bp, codes, scales):
                a = bn.decode(bp, codes, scales, out_dtype=pcfg.sam.adtype)
                return vlm.sam_tail(p, pcfg, a)
        elif stage == "cloud_prefix":
            page = self.page_size

            def fn(p, ctx, query):
                logits0, _, paged = vlm.llm_prefill_paged(p, pcfg, ctx,
                                                          query, page)
                # one request per pool row: drop the unit batch axis so
                # leaves are (L, n_pages, page, ...), the pool-write unit
                return logits0, jax.tree.map(lambda a: a[:, 0], paged)
        elif stage == "cloud_insight":
            def fn(p, bp, codes, scales, ctx, query):
                a = bn.decode(bp, codes, scales, out_dtype=pcfg.sam.adtype)
                feats = vlm.sam_tail(p, pcfg, a)
                answer_logits, seg = vlm.llm_reason(p, pcfg, ctx, query)
                return vlm.mask_decode(p, pcfg, feats, seg), answer_logits
        elif stage == "cloud_context":
            def fn(p, ctx, query):
                return vlm.llm_reason(p, pcfg, ctx, query)[0]
        elif stage == "cloud_insight_gen":
            def fn(p, bp, codes, scales, ctx, query):
                a = bn.decode(bp, codes, scales, out_dtype=pcfg.sam.adtype)
                feats = vlm.sam_tail(p, pcfg, a)
                tokens, logits0, seg = vlm.llm_generate(p, gcfg, ctx, query, T)
                return vlm.mask_decode(p, pcfg, feats, seg), logits0, tokens
        elif stage == "cloud_context_gen":
            def fn(p, ctx, query):
                tokens, logits0, _ = vlm.llm_generate(p, gcfg, ctx, query, T)
                return logits0, tokens
        else:
            raise ValueError(stage)
        return fn

    def _jitted(self, stage: str, tier_name: Optional[str], bucket: int,
                qlen: int, width: Optional[int] = None) -> Callable:
        # max_new_tokens / flash_decode / page_size are baked into the
        # staged fns, so they are part of the key: mutating them after some
        # buckets have compiled must not serve stale answers from the old
        # entries
        key = (stage, tier_name, bucket, qlen, self.max_new_tokens,
               self.flash_decode, self.page_size, width)
        fn = self._compiled.get(key)
        if fn is None:
            fn = jax.jit(self._stage_fn(stage, width=width))
            self._compiled[key] = fn
        return fn

    @property
    def num_compiled_stages(self) -> int:
        return len(self._compiled)

    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= n; oversized direct calls round up to a
        multiple of the largest bucket instead of failing (the scheduler
        never builds such microbatches, but per-packet callers may pass
        arbitrarily large frame batches, as the seed path allowed)."""
        for b in self.buckets:
            if n <= b:
                return b
        top = self.buckets[-1]
        return ((n + top - 1) // top) * top

    # ---- edge side ----

    def edge_context(self, images, seq_id: int, now: float
                     ) -> Tuple[pk.Packet, np.ndarray]:
        ctx = np.asarray(self._edge_context(self.params, images))
        return pk.make_context_packet(seq_id, now, ctx), ctx

    def edge_insight(self, images, tier: Tier, seq_id: int, now: float,
                     ctx: Optional[np.ndarray] = None) -> pk.Packet:
        """``ctx``: precomputed CLIP context features for this frame (e.g.
        from an ``edge_context`` call on the same image) — passing them
        keeps the edge at one CLIP pass per frame."""
        a = self._edge_insight(self.params, images)
        codes, scales = self._encode(self.bottlenecks[tier.name], a)
        if ctx is None:
            ctx = np.asarray(self._edge_context(self.params, images))
        return pk.make_insight_packet(seq_id, now, tier.name,
                                      np.asarray(codes), np.asarray(scales),
                                      clip_feats=np.asarray(ctx))

    # ---- cloud side (single packet, kept as the thin compat wrappers) ----

    def cloud_context(self, packet: pk.Packet, query) -> np.ndarray:
        return self.cloud_context_batch([packet], [np.asarray(query)])[0]

    def cloud_insight(self, packet: pk.Packet, query
                      ) -> Tuple[np.ndarray, np.ndarray]:
        return self.cloud_insight_batch([packet], [np.asarray(query)])[0]

    # ---- cloud side (batched serving engine) ----

    def _stack(self, packets: Sequence[pk.Packet],
               queries: Sequence[np.ndarray], keys: Sequence[str]
               ) -> Tuple[List[np.ndarray], np.ndarray, List[int], int]:
        """Concatenate per-packet content rows + queries along the batch
        axis and pad to the bucket. Returns (stacked content arrays in
        ``keys`` order, stacked queries, per-packet row counts, bucket)."""
        rows = [np.asarray(q).reshape(-1, np.asarray(q).shape[-1])
                for q in queries]
        counts = [p.content[keys[0]].shape[0] for p in packets]
        if any(r.shape[0] != c for r, c in zip(rows, counts)):
            raise ValueError(
                f"query batch rows {[r.shape[0] for r in rows]} do not match "
                f"packet batch rows {counts}")
        n = sum(counts)
        bucket = self.bucket_for(n)
        content = [_pad_rows(np.concatenate(
            [np.asarray(p.content[k]) for p in packets], axis=0), bucket)
            for k in keys]
        query = _pad_rows(np.concatenate(rows, axis=0), bucket)
        return content, query, counts, bucket

    @staticmethod
    def _split(arrs: Sequence[np.ndarray], counts: Sequence[int]
               ) -> List[Tuple[np.ndarray, ...]]:
        """Slice off the pad rows and split back into per-packet results."""
        out, lo = [], 0
        for c in counts:
            out.append(tuple(np.asarray(a[lo:lo + c]) for a in arrs))
            lo += c
        return out

    def cloud_context_batch(self, packets: Sequence[pk.Packet],
                            queries: Sequence[np.ndarray]
                            ) -> List[np.ndarray]:
        """Batched Context stage: K packets -> K answer-logit arrays."""
        (ctx,), query, counts, bucket = self._stack(packets, queries, ["ctx"])
        fn = self._jitted("cloud_context", None, bucket, query.shape[-1])
        logits = fn(self.params, jnp.asarray(ctx), jnp.asarray(query))
        return [r[0] for r in self._split([logits], counts)]

    def cloud_insight_batch(self, packets: Sequence[pk.Packet],
                            queries: Sequence[np.ndarray]
                            ) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Batched Insight stage: K same-tier packets -> K
        (mask_logits, answer_logits) pairs."""
        tier = self._same_tier(packets)
        content, query, counts, bucket = self._stack(
            packets, queries, ["codes", "scales", "clip"])
        fn = self._jitted("cloud_insight", tier, bucket, query.shape[-1])
        mask, logits = fn(self.params, self.bottlenecks[tier],
                          *map(jnp.asarray, content), jnp.asarray(query))
        return self._split([mask, logits], counts)

    def cloud_generate_batch(self, packets: Sequence[pk.Packet],
                             queries: Sequence[np.ndarray]
                             ) -> List[Tuple[np.ndarray, ...]]:
        """Batched multi-token serving through the KV-cache decode path.
        Context packets -> (answer_logits, tokens); Insight packets ->
        (mask_logits, answer_logits, tokens). ``tokens`` is the greedy
        ``max_new_tokens``-long answer."""
        if packets[0].kind == "context":
            (ctx,), query, counts, bucket = self._stack(packets, queries,
                                                        ["ctx"])
            fn = self._jitted("cloud_context_gen", None, bucket, query.shape[-1])
            logits, tokens = fn(self.params, jnp.asarray(ctx),
                                jnp.asarray(query))
            return self._split([logits, tokens], counts)
        tier = self._same_tier(packets)
        content, query, counts, bucket = self._stack(
            packets, queries, ["codes", "scales", "clip"])
        fn = self._jitted("cloud_insight_gen", tier, bucket, query.shape[-1])
        mask, logits, tokens = fn(self.params, self.bottlenecks[tier],
                                  *map(jnp.asarray, content),
                                  jnp.asarray(query))
        return self._split([mask, logits, tokens], counts)

    # ---- cloud side (in-flight / paged continuous batching) ----
    #
    # The one-shot ``cloud_generate_batch`` serves a closed microbatch end
    # to end. The in-flight stages below split that into page-table ops:
    # the [ctx; query] prefix prefills once into fixed-size KV pages
    # (shared read-only across repeat-prefix requests), per-frame SAM
    # feats compute separately, and each decode step advances every live
    # row against the shared page pool with per-row positions, page
    # tables, and write slots (the engine's ``InflightDecoder`` owns the
    # allocator + prefix-store bookkeeping in ``core.paging``).

    def cloud_sam_feats(self, packet: pk.Packet) -> np.ndarray:
        """Per-frame Insight tail: bottleneck decode + SAM suffix ->
        mask features. Runs on every admission (frames differ even when
        the LLM prefix repeats)."""
        tier = packet.tier_name
        rows = packet.content["codes"].shape[0]
        fn = self._jitted("cloud_sam_feats", tier, rows, 0)
        return fn(self.params, self.bottlenecks[tier],
                  jnp.asarray(packet.content["codes"]),
                  jnp.asarray(packet.content["scales"]))

    def cloud_prefix(self, ctx, query) -> Tuple[np.ndarray, Dict]:
        """Prefill one request's [ctx; query] prefix into KV pages.
        Returns (first-token logits (1, V), paged KV with leaves
        (L, n_pages, page_size, ...)) — the unit the page-pool scatter
        (``pool_write``) consumes. One sequence per call: pool rows are
        per-request."""
        query = np.asarray(query).reshape(-1, np.asarray(query).shape[-1])
        rows, qlen = query.shape
        if rows != 1:
            raise ValueError(
                f"prefix prefill is per-sequence, got {rows} rows")
        fn = self._jitted("cloud_prefix", None, rows, qlen)
        return fn(self.params, jnp.asarray(ctx), jnp.asarray(query))

    def pool_write(self, pool: Dict, paged_kv: Dict, page_ids) -> Dict:
        """Scatter a prefilled prefix's pages into the shared page pool
        at ``page_ids``; returns the new pool value."""
        return self._pool_write(pool, paged_kv,
                                jnp.asarray(page_ids, jnp.int32))

    def cloud_decode_rows(self, pool: Dict, page_table, positions, tokens,
                          pos, write_slot
                          ) -> Tuple[np.ndarray, np.ndarray, Dict]:
        """One paged decode step over all slots. pool {"groups": [kv]}
        with leaves (L, P, page, ...); page_table (slots, n_pages) i32
        (idle rows parked on the trash page); positions
        (slots, n_pages*page) i32 absolute slot positions (-1 empty);
        tokens (slots, 1) i32; pos / write_slot (slots,) i32 — idle rows
        write into the trash page and their outputs are discarded.
        Returns (answer_logits, seg, new pool)."""
        return self._decode_paged(self.params, pool,
                                  jnp.asarray(page_table, jnp.int32),
                                  jnp.asarray(positions, jnp.int32),
                                  jnp.asarray(tokens, jnp.int32),
                                  jnp.asarray(pos, jnp.int32),
                                  jnp.asarray(write_slot, jnp.int32))

    def cloud_verify_rows(self, pool: Dict, page_table, positions, tokens,
                          pos, write_slot, chunk_len
                          ) -> Tuple[np.ndarray, np.ndarray, Dict]:
        """One speculative verify step over all slots: tokens
        (slots, C) i32 chunks (last accepted token + drafts, pad past
        ``chunk_len``); pos / write_slot (slots,) i32 starts; chunk_len
        (slots,) i32 real chunk entries per row (pad entries write to
        the trash page and their logits are discarded). Returns
        (answer_logits (slots, C, V), seg (slots, C, d_sam), new pool)
        — ``vlm.llm_verify_step_paged`` semantics."""
        return self._verify_paged(self.params, pool,
                                  jnp.asarray(page_table, jnp.int32),
                                  jnp.asarray(positions, jnp.int32),
                                  jnp.asarray(tokens, jnp.int32),
                                  jnp.asarray(pos, jnp.int32),
                                  jnp.asarray(write_slot, jnp.int32),
                                  jnp.asarray(chunk_len, jnp.int32))

    def cloud_mask(self, feats, seg) -> np.ndarray:
        """<SEG>-conditioned mask decode from stored sam feats (the final
        in-flight stage for Insight requests)."""
        return self._mask_decode(self.params, jnp.asarray(feats),
                                 jnp.asarray(seg))

    @staticmethod
    def _same_tier(packets: Sequence[pk.Packet]) -> str:
        tiers = {p.tier_name for p in packets}
        if len(tiers) != 1:
            raise ValueError(f"mixed tiers in one microbatch: {tiers} — "
                             "bucket packets by tier before batching")
        return next(iter(tiers))
