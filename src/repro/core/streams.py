"""Dual-stream execution modes (paper §4.1–§4.3).

``Stream`` names the two semantically distinct execution modes; the
``DualStreamExecutor`` bundles the jitted edge/cloud stage functions for a
trained LISA pipeline plus the per-tier bottlenecks, and exposes
``run_context`` / ``run_insight`` used by the serving runtime and the
mission simulator.

The executor is deliberately channel-agnostic: it returns the numpy
payloads + packets; the runtime decides what the (simulated or pod-
disaggregated) link does with them.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.lisa7b import LISAPipelineConfig
from repro.core import bottleneck as bn
from repro.core import packets as pk
from repro.core import vlm
from repro.core.lut import SystemLUT, Tier


class Stream(enum.Enum):
    CONTEXT = "context"   # high-frequency, low-resolution awareness
    INSIGHT = "insight"   # low-frequency, high-fidelity grounding


@dataclass
class DualStreamExecutor:
    pcfg: LISAPipelineConfig
    params: dict
    bottlenecks: Dict[str, dict]          # tier name -> bottleneck params
    lut: SystemLUT

    def __post_init__(self):
        pcfg = self.pcfg
        self._edge_context = jax.jit(
            lambda p, img: vlm.clip_encode(p, pcfg, img))
        self._edge_insight = jax.jit(
            lambda p, img: vlm.sam_head(p, pcfg, img))
        self._encode = {
            name: jax.jit(lambda bp, a: bn.encode(bp, a))
            for name in self.bottlenecks
        }
        def _cloud_insight(p, bp, codes, scales, ctx, query):
            a = bn.decode(bp, codes, scales, out_dtype=pcfg.sam.adtype)
            feats = vlm.sam_tail(p, pcfg, a)
            answer_logits, seg = vlm.llm_reason(p, pcfg, ctx, query)
            return vlm.mask_decode(p, pcfg, feats, seg), answer_logits
        self._cloud_insight = jax.jit(_cloud_insight)
        self._cloud_context = jax.jit(
            lambda p, ctx, query: vlm.llm_reason(p, pcfg, ctx, query)[0])

    # ---- edge side ----

    def edge_context(self, images, seq_id: int, now: float
                     ) -> Tuple[pk.Packet, np.ndarray]:
        ctx = np.asarray(self._edge_context(self.params, images))
        return pk.make_context_packet(seq_id, now, ctx), ctx

    def edge_insight(self, images, tier: Tier, seq_id: int, now: float
                     ) -> pk.Packet:
        a = self._edge_insight(self.params, images)
        codes, scales = self._encode[tier.name](self.bottlenecks[tier.name], a)
        ctx = np.asarray(self._edge_context(self.params, images))
        return pk.make_insight_packet(seq_id, now, tier.name,
                                      np.asarray(codes), np.asarray(scales),
                                      clip_feats=ctx)

    # ---- cloud side ----

    def cloud_context(self, packet: pk.Packet, query) -> np.ndarray:
        return np.asarray(self._cloud_context(
            self.params, jnp.asarray(packet.content["ctx"]), query))

    def cloud_insight(self, packet: pk.Packet, query
                      ) -> Tuple[np.ndarray, np.ndarray]:
        bp = self.bottlenecks[packet.tier_name]
        mask_logits, answer_logits = self._cloud_insight(
            self.params, bp,
            jnp.asarray(packet.content["codes"]),
            jnp.asarray(packet.content["scales"]),
            jnp.asarray(packet.content["clip"]), query)
        return np.asarray(mask_logits), np.asarray(answer_logits)
