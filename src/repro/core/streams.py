"""Dual-stream execution modes (paper §4.1–§4.3) + the batched cloud
serving engine.

``Stream`` names the two semantically distinct execution modes; the
``DualStreamExecutor`` bundles the jitted edge/cloud stage functions for a
trained LISA pipeline plus the per-tier bottlenecks, and exposes
``run_context`` / ``run_insight`` used by the serving runtime and the
mission simulator.

Cloud serving is batched: ``cloud_context_batch`` / ``cloud_insight_batch``
stack multiple packets of the same tier into one device call, and
``cloud_generate_batch`` serves multi-token answers through the
prefill + flash-decode KV-cache path (``vlm.llm_prefill`` /
``vlm.llm_decode_step``). Request counts are padded up to a small set of
bucket sizes and every jitted stage is held in an explicit compile cache
keyed on (stage, tier, bucket, query_len), so varying request counts
never retrigger XLA compilation.

The executor is deliberately channel-agnostic: it returns the numpy
payloads + packets; the runtime decides what the (simulated or pod-
disaggregated) link does with them.
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.lisa7b import LISAPipelineConfig
from repro.core import bottleneck as bn
from repro.core import packets as pk
from repro.core import vlm
from repro.core.lut import SystemLUT, Tier


class Stream(enum.Enum):
    CONTEXT = "context"   # high-frequency, low-resolution awareness
    INSIGHT = "insight"   # low-frequency, high-fidelity grounding


def _cache_insert(dst: Dict, src: Dict, slot) -> Dict:
    """Scatter one prefilled request's cache rows (batch 1) into a batched
    decode cache at ``slot``. KV leaves are (L, B, W, ...) — batch axis 1;
    positions are (B, W) — batch axis 0."""
    groups = jax.tree.map(lambda d, s: d.at[:, slot].set(s[:, 0]),
                          dst["groups"], src["groups"])
    positions = dst["positions"].at[slot].set(src["positions"][0])
    return {"groups": groups, "positions": positions}


def _pad_rows(arr: np.ndarray, bucket: int) -> np.ndarray:
    """Pad axis 0 up to ``bucket`` by repeating the last row (rows past the
    real count are sliced away after the call)."""
    n = arr.shape[0]
    if n == bucket:
        return arr
    reps = np.repeat(arr[-1:], bucket - n, axis=0)
    return np.concatenate([arr, reps], axis=0)


@dataclass
class DualStreamExecutor:
    pcfg: LISAPipelineConfig
    params: dict
    bottlenecks: Dict[str, dict]          # tier name -> bottleneck params
    lut: SystemLUT
    # batch buckets for the cloud stages: request counts are padded up to
    # the smallest bucket >= n so the jit cache sees a fixed shape set
    buckets: Tuple[int, ...] = (1, 2, 4, 8, 16)
    # answer length for the generate path (continuous-batching serving)
    max_new_tokens: int = 4
    # route decode attention through the flash-decode Pallas kernel
    flash_decode: bool = True

    def __post_init__(self):
        pcfg = self.pcfg
        self.buckets = tuple(sorted(self.buckets))
        # decode steps run with the flash-decode kernel on the attention
        # hot loop; prefill keeps the full-sequence path
        self._gen_pcfg = dataclasses.replace(
            pcfg, llm=pcfg.llm.replace(use_flash_decode=self.flash_decode))
        self._edge_context = jax.jit(
            lambda p, img: vlm.clip_encode(p, pcfg, img))
        self._edge_insight = jax.jit(
            lambda p, img: vlm.sam_head(p, pcfg, img))
        # one shared jitted bottleneck encode for every tier (tiers differ
        # only in code rank, which the jit cache keys on via shape)
        self._encode = jax.jit(lambda bp, a: bn.encode(bp, a))
        # explicit compile cache: (stage, tier, bucket, query_len) ->
        # jitted callable.
        # Each entry owns exactly one compiled executable (bucket shapes
        # are fixed), so len(self._compiled) == number of XLA compiles.
        self._compiled: Dict[Tuple, Callable] = {}
        # in-flight decode stages (token-level continuous batching): one
        # decode step over all live slots with per-row positions, plus the
        # slot-scatter cache merge and the standalone mask decode
        self._decode_rows = jax.jit(
            lambda p, cache, tok, pos: vlm.llm_decode_step(
                p, self._gen_pcfg, cache, tok, pos))
        self._mask_decode = jax.jit(
            lambda p, feats, seg: vlm.mask_decode(p, pcfg, feats, seg))
        self._cache_insert = jax.jit(_cache_insert)

    # ---- compile cache ----

    def _stage_fn(self, stage: str, width: Optional[int] = None) -> Callable:
        pcfg, T = self.pcfg, self.max_new_tokens
        gcfg = dataclasses.replace(
            pcfg, llm=pcfg.llm.replace(use_flash_decode=self.flash_decode))

        if stage == "cloud_prefill_insight":
            def fn(p, bp, codes, scales, ctx, query):
                a = bn.decode(bp, codes, scales, out_dtype=pcfg.sam.adtype)
                feats = vlm.sam_tail(p, pcfg, a)
                logits0, _, cache = vlm.llm_prefill(p, pcfg, ctx, query,
                                                    width=width)
                return feats, logits0, cache
        elif stage == "cloud_prefill_context":
            def fn(p, ctx, query):
                logits0, _, cache = vlm.llm_prefill(p, pcfg, ctx, query,
                                                    width=width)
                return logits0, cache
        elif stage == "cloud_insight":
            def fn(p, bp, codes, scales, ctx, query):
                a = bn.decode(bp, codes, scales, out_dtype=pcfg.sam.adtype)
                feats = vlm.sam_tail(p, pcfg, a)
                answer_logits, seg = vlm.llm_reason(p, pcfg, ctx, query)
                return vlm.mask_decode(p, pcfg, feats, seg), answer_logits
        elif stage == "cloud_context":
            def fn(p, ctx, query):
                return vlm.llm_reason(p, pcfg, ctx, query)[0]
        elif stage == "cloud_insight_gen":
            def fn(p, bp, codes, scales, ctx, query):
                a = bn.decode(bp, codes, scales, out_dtype=pcfg.sam.adtype)
                feats = vlm.sam_tail(p, pcfg, a)
                tokens, logits0, seg = vlm.llm_generate(p, gcfg, ctx, query, T)
                return vlm.mask_decode(p, pcfg, feats, seg), logits0, tokens
        elif stage == "cloud_context_gen":
            def fn(p, ctx, query):
                tokens, logits0, _ = vlm.llm_generate(p, gcfg, ctx, query, T)
                return logits0, tokens
        else:
            raise ValueError(stage)
        return fn

    def _jitted(self, stage: str, tier_name: Optional[str], bucket: int,
                qlen: int, width: Optional[int] = None) -> Callable:
        # max_new_tokens / flash_decode are baked into the staged fns, so
        # they are part of the key: mutating them after some buckets have
        # compiled must not serve stale-T answers from the old entries
        key = (stage, tier_name, bucket, qlen, self.max_new_tokens,
               self.flash_decode, width)
        fn = self._compiled.get(key)
        if fn is None:
            fn = jax.jit(self._stage_fn(stage, width=width))
            self._compiled[key] = fn
        return fn

    @property
    def num_compiled_stages(self) -> int:
        return len(self._compiled)

    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= n; oversized direct calls round up to a
        multiple of the largest bucket instead of failing (the scheduler
        never builds such microbatches, but per-packet callers may pass
        arbitrarily large frame batches, as the seed path allowed)."""
        for b in self.buckets:
            if n <= b:
                return b
        top = self.buckets[-1]
        return ((n + top - 1) // top) * top

    # ---- edge side ----

    def edge_context(self, images, seq_id: int, now: float
                     ) -> Tuple[pk.Packet, np.ndarray]:
        ctx = np.asarray(self._edge_context(self.params, images))
        return pk.make_context_packet(seq_id, now, ctx), ctx

    def edge_insight(self, images, tier: Tier, seq_id: int, now: float,
                     ctx: Optional[np.ndarray] = None) -> pk.Packet:
        """``ctx``: precomputed CLIP context features for this frame (e.g.
        from an ``edge_context`` call on the same image) — passing them
        keeps the edge at one CLIP pass per frame."""
        a = self._edge_insight(self.params, images)
        codes, scales = self._encode(self.bottlenecks[tier.name], a)
        if ctx is None:
            ctx = np.asarray(self._edge_context(self.params, images))
        return pk.make_insight_packet(seq_id, now, tier.name,
                                      np.asarray(codes), np.asarray(scales),
                                      clip_feats=np.asarray(ctx))

    # ---- cloud side (single packet, kept as the thin compat wrappers) ----

    def cloud_context(self, packet: pk.Packet, query) -> np.ndarray:
        return self.cloud_context_batch([packet], [np.asarray(query)])[0]

    def cloud_insight(self, packet: pk.Packet, query
                      ) -> Tuple[np.ndarray, np.ndarray]:
        return self.cloud_insight_batch([packet], [np.asarray(query)])[0]

    # ---- cloud side (batched serving engine) ----

    def _stack(self, packets: Sequence[pk.Packet],
               queries: Sequence[np.ndarray], keys: Sequence[str]
               ) -> Tuple[List[np.ndarray], np.ndarray, List[int], int]:
        """Concatenate per-packet content rows + queries along the batch
        axis and pad to the bucket. Returns (stacked content arrays in
        ``keys`` order, stacked queries, per-packet row counts, bucket)."""
        rows = [np.asarray(q).reshape(-1, np.asarray(q).shape[-1])
                for q in queries]
        counts = [p.content[keys[0]].shape[0] for p in packets]
        if any(r.shape[0] != c for r, c in zip(rows, counts)):
            raise ValueError(
                f"query batch rows {[r.shape[0] for r in rows]} do not match "
                f"packet batch rows {counts}")
        n = sum(counts)
        bucket = self.bucket_for(n)
        content = [_pad_rows(np.concatenate(
            [np.asarray(p.content[k]) for p in packets], axis=0), bucket)
            for k in keys]
        query = _pad_rows(np.concatenate(rows, axis=0), bucket)
        return content, query, counts, bucket

    @staticmethod
    def _split(arrs: Sequence[np.ndarray], counts: Sequence[int]
               ) -> List[Tuple[np.ndarray, ...]]:
        """Slice off the pad rows and split back into per-packet results."""
        out, lo = [], 0
        for c in counts:
            out.append(tuple(np.asarray(a[lo:lo + c]) for a in arrs))
            lo += c
        return out

    def cloud_context_batch(self, packets: Sequence[pk.Packet],
                            queries: Sequence[np.ndarray]
                            ) -> List[np.ndarray]:
        """Batched Context stage: K packets -> K answer-logit arrays."""
        (ctx,), query, counts, bucket = self._stack(packets, queries, ["ctx"])
        fn = self._jitted("cloud_context", None, bucket, query.shape[-1])
        logits = fn(self.params, jnp.asarray(ctx), jnp.asarray(query))
        return [r[0] for r in self._split([logits], counts)]

    def cloud_insight_batch(self, packets: Sequence[pk.Packet],
                            queries: Sequence[np.ndarray]
                            ) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Batched Insight stage: K same-tier packets -> K
        (mask_logits, answer_logits) pairs."""
        tier = self._same_tier(packets)
        content, query, counts, bucket = self._stack(
            packets, queries, ["codes", "scales", "clip"])
        fn = self._jitted("cloud_insight", tier, bucket, query.shape[-1])
        mask, logits = fn(self.params, self.bottlenecks[tier],
                          *map(jnp.asarray, content), jnp.asarray(query))
        return self._split([mask, logits], counts)

    def cloud_generate_batch(self, packets: Sequence[pk.Packet],
                             queries: Sequence[np.ndarray]
                             ) -> List[Tuple[np.ndarray, ...]]:
        """Batched multi-token serving through the KV-cache decode path.
        Context packets -> (answer_logits, tokens); Insight packets ->
        (mask_logits, answer_logits, tokens). ``tokens`` is the greedy
        ``max_new_tokens``-long answer."""
        if packets[0].kind == "context":
            (ctx,), query, counts, bucket = self._stack(packets, queries,
                                                        ["ctx"])
            fn = self._jitted("cloud_context_gen", None, bucket, query.shape[-1])
            logits, tokens = fn(self.params, jnp.asarray(ctx),
                                jnp.asarray(query))
            return self._split([logits, tokens], counts)
        tier = self._same_tier(packets)
        content, query, counts, bucket = self._stack(
            packets, queries, ["codes", "scales", "clip"])
        fn = self._jitted("cloud_insight_gen", tier, bucket, query.shape[-1])
        mask, logits, tokens = fn(self.params, self.bottlenecks[tier],
                                  *map(jnp.asarray, content),
                                  jnp.asarray(query))
        return self._split([mask, logits, tokens], counts)

    # ---- cloud side (in-flight / token-level continuous batching) ----
    #
    # The one-shot ``cloud_generate_batch`` serves a closed microbatch end
    # to end. The in-flight stages below split that into prefill + single
    # decode steps with *per-row* positions, so a request that arrives
    # while a batch is mid-decode can be prefilled into a free slot and
    # ride the remaining steps of the running batch (the engine's
    # ``InflightDecoder`` drives them).

    def cloud_prefill(self, packet: pk.Packet, query, width: int
                      ) -> Tuple[np.ndarray, Dict, Optional[np.ndarray]]:
        """Prefill one request's [ctx; query] against a ``width``-slot KV
        ring. Returns (first-token logits, per-row cache, sam feats for
        the later mask decode — None for Context packets)."""
        query = np.asarray(query).reshape(-1, np.asarray(query).shape[-1])
        rows, qlen = query.shape
        if packet.kind == "insight":
            tier = packet.tier_name
            fn = self._jitted("cloud_prefill_insight", tier, rows, qlen,
                              width=width)
            feats, logits0, cache = fn(
                self.params, self.bottlenecks[tier],
                jnp.asarray(packet.content["codes"]),
                jnp.asarray(packet.content["scales"]),
                jnp.asarray(packet.content["clip"]), jnp.asarray(query))
            return logits0, cache, feats
        fn = self._jitted("cloud_prefill_context", None, rows, qlen,
                          width=width)
        logits0, cache = fn(self.params,
                            jnp.asarray(packet.content["ctx"]),
                            jnp.asarray(query))
        return logits0, cache, None

    def cloud_decode_rows(self, cache: Dict, tokens, pos
                          ) -> Tuple[np.ndarray, np.ndarray, Dict]:
        """One decode step over all slots. tokens (slots, 1) i32; pos
        (slots,) i32 per-row absolute positions (free slots may carry any
        in-range position; their rows are discarded)."""
        return self._decode_rows(self.params, cache,
                                 jnp.asarray(tokens, jnp.int32),
                                 jnp.asarray(pos, jnp.int32))

    def cloud_mask(self, feats, seg) -> np.ndarray:
        """<SEG>-conditioned mask decode from stored sam feats (the final
        in-flight stage for Insight requests)."""
        return self._mask_decode(self.params, jnp.asarray(feats),
                                 jnp.asarray(seg))

    def cache_insert(self, dst: Dict, src: Dict, slot: int) -> Dict:
        """Merge a batch-1 prefilled cache into the batched decode cache
        at ``slot`` (whole-row overwrite, so freed slots need no reset)."""
        return self._cache_insert(dst, src, jnp.int32(slot))

    @staticmethod
    def empty_decode_cache(like: Dict, slots: int) -> Dict:
        """A ``slots``-row decode cache shaped after a prefilled batch-1
        cache: zero KV, all ring positions empty (-1)."""
        groups = jax.tree.map(
            lambda a: jnp.zeros((a.shape[0], slots) + a.shape[2:], a.dtype),
            like["groups"])
        positions = jnp.full((slots, like["positions"].shape[1]), -1,
                             jnp.int32)
        return {"groups": groups, "positions": positions}

    @staticmethod
    def _same_tier(packets: Sequence[pk.Packet]) -> str:
        tiers = {p.tier_name for p in packets}
        if len(tiers) != 1:
            raise ValueError(f"mixed tiers in one microbatch: {tiers} — "
                             "bucket packets by tier before batching")
        return next(iter(tiers))
