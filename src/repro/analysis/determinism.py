"""Determinism lint (AV5xx): the simulation must replay bit-identically.

Every bench in this repo compares policies on the *same* seeded mission
(fault schedules from ``RandomState(seed)``, bandwidth traces, request
streams). One wall-clock read or global-RNG draw in those paths and the
A/B comparison is comparing different worlds. Scope: the engine,
runtime, network, and data packages (``DETERMINISM_FRAGMENTS``) — the
launch scripts may time themselves all they like.

  * **AV501** — unseeded RNG: global-state draws (``np.random.rand``,
    stdlib ``random.random``), or a ``RandomState()`` /
    ``default_rng()`` constructed without a seed.
  * **AV502** — wall clock: ``time.time/monotonic/perf_counter``,
    ``datetime.now`` — mission time is the simulation's clock.
  * **AV503** — iterating a set: Python sets hash-order their elements,
    so ``for x in {…}`` visits them in an order that varies with
    PYTHONHASHSEED for str/bytes contents. Order-independent reductions
    (``min``/``max``/``sum``/``sorted`` over a set) are fine and not
    flagged.
  * **AV504** — ambient entropy: ``uuid.uuid1/4``, ``os.urandom``,
    ``secrets.*``.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.model import Finding, ModuleInfo, RepoModel, dotted

CHECKER = "determinism"

# rel-path fragments that define the seeded deterministic core
DETERMINISM_FRAGMENTS = ("repro/engine/", "repro/runtime/",
                         "repro/network/", "repro/data/",
                         "repro/core/paging")

_GLOBAL_NP_OK = {"RandomState", "default_rng", "Generator",
                 "SeedSequence", "PRNGKey"}
_CLOCK_CALLS = {"time.time", "time.monotonic", "time.perf_counter",
                "time.process_time", "datetime.now", "datetime.utcnow",
                "datetime.datetime.now", "datetime.datetime.utcnow"}
_ENTROPY_CALLS = {"uuid.uuid1", "uuid.uuid4", "os.urandom"}
_STDLIB_RANDOM_FNS = {"random", "randint", "randrange", "choice",
                      "choices", "shuffle", "sample", "uniform",
                      "gauss", "normalvariate", "seed"}


def in_scope(rel: str) -> bool:
    return any(f in rel for f in DETERMINISM_FRAGMENTS)


def _symbol_for(mod: ModuleInfo, node: ast.AST) -> str:
    best = "<module>"
    best_span = None
    for qual, fn in mod.functions.items():
        n = fn.node
        end = getattr(n, "end_lineno", n.lineno)
        if n.lineno <= node.lineno <= end:
            span = end - n.lineno
            if best_span is None or span < best_span:
                best, best_span = qual, span
    return best


def check(mod: ModuleInfo, repo: RepoModel) -> List[Finding]:
    if not in_scope(mod.rel):
        return []
    findings: List[Finding] = []
    stdlib_random = {a for a, m in mod.import_alias.items()
                     if m == "random"}
    stdlib_random |= {a for a, (m, n) in mod.from_imports.items()
                      if m == "random" and n in _STDLIB_RANDOM_FNS}
    secrets_aliases = {a for a, m in mod.import_alias.items()
                       if m == "secrets"}
    np_aliases = mod.numpy_aliases()

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            f = _check_call(mod, node, stdlib_random, secrets_aliases,
                            np_aliases)
            if f is not None:
                findings.append(f)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            f = _check_set_iter(mod, node.iter, node)
            if f is not None:
                findings.append(f)
        elif isinstance(node, ast.comprehension):
            f = _check_set_iter(mod, node.iter, node.iter)
            if f is not None:
                findings.append(f)
    return findings


def _check_call(mod: ModuleInfo, node: ast.Call, stdlib_random,
                secrets_aliases, np_aliases) -> Optional[Finding]:
    name = dotted(node.func)
    if name is None:
        return None
    parts = name.split(".")
    head, tail = parts[0], parts[-1]

    # np.random.<draw> on the global RNG
    if (len(parts) >= 3 and head in np_aliases
            and parts[1] == "random" and tail not in _GLOBAL_NP_OK):
        return _f(mod, node, "AV501",
                  f"{name}() draws from numpy's global RNG; thread a "
                  "seeded RandomState through instead")
    # RandomState() / default_rng() without a seed argument
    if tail in ("RandomState", "default_rng") and not node.args \
            and not node.keywords:
        return _f(mod, node, "AV501",
                  f"{tail}() without a seed is entropy-seeded; pass the "
                  "mission seed")
    # stdlib random
    if head in stdlib_random and (len(parts) > 1
                                  or tail in _STDLIB_RANDOM_FNS):
        return _f(mod, node, "AV501",
                  f"stdlib {name}() uses the global unseeded RNG")
    # wall clock
    if name in _CLOCK_CALLS or (len(parts) > 1
                                and f"{parts[-2]}.{tail}"
                                in _CLOCK_CALLS):
        return _f(mod, node, "AV502",
                  f"{name}() reads the wall clock; the simulation's "
                  "clock is mission time (Request.time_s)")
    # ambient entropy
    if name in _ENTROPY_CALLS or head in secrets_aliases:
        return _f(mod, node, "AV504",
                  f"{name}() draws ambient entropy; derive ids from the "
                  "seeded stream (request_id counters, prefix_digest)")
    return None


def _check_set_iter(mod: ModuleInfo, it: ast.AST,
                    where: ast.AST) -> Optional[Finding]:
    is_set = (isinstance(it, (ast.Set, ast.SetComp))
              or (isinstance(it, ast.Call)
                  and isinstance(it.func, ast.Name)
                  and it.func.id in ("set", "frozenset"))
              or (isinstance(it, ast.BinOp)
                  and isinstance(it.op, (ast.Sub, ast.BitAnd, ast.BitOr))
                  and any(isinstance(s, ast.Call)
                          and isinstance(s.func, ast.Name)
                          and s.func.id in ("set", "frozenset")
                          for s in (it.left, it.right))))
    if not is_set:
        return None
    return _f(mod, where, "AV503",
              "iterating a set: hash order varies with PYTHONHASHSEED; "
              "sort it (or reduce with min/max) before iterating")


def _f(mod: ModuleInfo, node: ast.AST, code: str,
       message: str) -> Finding:
    return Finding(code=code, checker=CHECKER, path=mod.rel,
                   line=node.lineno, col=node.col_offset,
                   symbol=_symbol_for(mod, node), message=message)
