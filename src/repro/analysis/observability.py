"""Observability lint (AV6xx): engine telemetry goes through the
sanctioned instruments, not ad-hoc side effects.

The engine's observability layer (``engine/observability.py``) exists
so that telemetry is bounded and machine-readable: the ``Tracer`` caps
events per trace, the ``FlightRecorder`` is a fixed ring, histograms
are fixed log buckets. Two anti-patterns defeat it, both scoped to
``src/repro/engine/``:

  * **AV601** — ``print()`` on the serving path: engine modules run
    inside benchmarks and missions whose stdout IS the report;
    diagnostics belong in stream events, the flight recorder, or a
    trace span, never interleaved prints.
  * **AV602** — unbounded event accumulation: ``self.<attr>.append(x)``
    on a plain list that nothing ever bounds. A request future or
    decoder that lives a whole mission must not grow per-event lists
    without a cap. Sanctioned shapes are recognised and not flagged:

      - the attribute is a ``deque`` (``maxlen`` rings);
      - the class bounds it elsewhere — ``pop``/``popleft``/``clear``/
        ``remove``, a ``del self.attr[...]`` slice, or reassignment
        outside ``__init__`` (drain/reset paths);
      - the appending function checks ``len(self.attr)`` first (the
        cap-and-count idiom — see ``RequestFuture.emit``);
      - the appended value escapes the class (returned, or also stored
        under a key), i.e. the list is an index of caller-owned
        objects, not an event log.
  * **AV603** — direct wall-clock reads: ``time.time()`` /
    ``time.perf_counter()`` / ``time.monotonic()`` (and their ``_ns`` /
    ``process_time`` siblings) called inside engine modules. The engine
    runs on the *mission* clock; real wall time is injected once, at
    construction, through the ``wallclock`` hook
    (``AveryEngine(wallclock=time.perf_counter)``) so that replays and
    deterministic tests stay deterministic. A direct clock read is the
    AV502 loophole: host time leaking into serving logic where no test
    can pin it. Both spellings are caught — ``import time`` (plain or
    aliased) attribute calls and ``from time import perf_counter``
    name calls.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.model import Finding, ModuleInfo, RepoModel, dotted

CHECKER = "observability"

# rel-path fragment that defines the serving-engine scope
ENGINE_FRAGMENT = "repro/engine/"

_BOUNDING_METHODS = {"pop", "popleft", "clear", "remove"}

# the stdlib ``time`` functions that read a host clock (AV603); sleep
# and conversion helpers (strftime, gmtime, ...) are deliberately not
# listed — they don't smuggle a timestamp into serving state
_CLOCK_FNS = {"time", "monotonic", "perf_counter", "process_time",
              "time_ns", "monotonic_ns", "perf_counter_ns",
              "process_time_ns"}


def in_scope(rel: str) -> bool:
    return ENGINE_FRAGMENT in rel


def check(mod: ModuleInfo, repo: RepoModel) -> List[Finding]:
    if not in_scope(mod.rel):
        return []
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "print"):
            findings.append(_f(mod, node, "AV601",
                               "print() on the serving path; emit a "
                               "stream event, a trace point, or a "
                               "flight-recorder entry instead"))
        clock = _clock_call(mod, node)
        if clock is not None:
            findings.append(_f(mod, node, "AV603",
                               f"{clock}() in engine code; wall time "
                               "enters the engine once, through the "
                               "injected wallclock hook (AveryEngine("
                               "wallclock=...)) — a direct clock read "
                               "breaks mission-clock determinism"))
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef):
            findings.extend(_check_class(mod, node))
    return findings


# ---------------------------------------------------------------------------
# AV603: direct host-clock reads in engine code
# ---------------------------------------------------------------------------


def _clock_call(mod: ModuleInfo, node: ast.AST) -> Optional[str]:
    """A call that reads a host clock -> its dotted name; None
    otherwise. Resolves through the module's import maps so both
    ``import time as _t; _t.perf_counter()`` and
    ``from time import perf_counter; perf_counter()`` are caught,
    while a user-defined ``perf_counter`` shadowing the name is not."""
    if not isinstance(node, ast.Call):
        return None
    f = node.func
    if isinstance(f, ast.Name):
        src = mod.from_imports.get(f.id)
        if src is not None and src[0] == "time" \
                and src[1] in _CLOCK_FNS:
            return f"time.{src[1]}"
    elif isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
        if mod.import_alias.get(f.value.id) == "time" \
                and f.attr in _CLOCK_FNS:
            return f"time.{f.attr}"
    return None


# ---------------------------------------------------------------------------
# AV602: unbounded self.<attr>.append on a plain list
# ---------------------------------------------------------------------------


def _check_class(mod: ModuleInfo, cls: ast.ClassDef) -> List[Finding]:
    deque_attrs = _deque_attrs(cls)
    bounded_attrs = _bounded_attrs(cls)
    findings: List[Finding] = []
    for fn in (n for n in ast.walk(cls)
               if isinstance(n, (ast.FunctionDef,
                                 ast.AsyncFunctionDef))):
        for call in ast.walk(fn):
            attr = _self_append_attr(call)
            if attr is None:
                continue
            if attr in deque_attrs or attr in bounded_attrs:
                continue
            if _len_guarded(fn, attr):
                continue
            if _value_escapes(fn, call):
                continue
            findings.append(_f(
                mod, call, "AV602",
                f"self.{attr}.append() with no bound in "
                f"{cls.name}: a mission-lifetime object must cap its "
                "event lists (deque(maxlen=...), a len() guard, or a "
                "drain path)"))
    return findings


def _self_append_attr(node: ast.AST) -> Optional[str]:
    """``self.<attr>.append(x)`` -> attr name; None otherwise.
    Subscripted (``self.q[k].append``) and local-alias appends are out
    of scope — the direct-attribute event-log shape is the target."""
    if not (isinstance(node, ast.Call) and node.args
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "append"):
        return None
    owner = node.func.value
    d = dotted(owner)
    if d is None or not d.startswith("self."):
        return None
    parts = d.split(".")
    return parts[1] if len(parts) == 2 else None


def _deque_attrs(cls: ast.ClassDef) -> Set[str]:
    """Attrs assigned a ``deque(...)`` anywhere in the class."""
    out: Set[str] = set()
    for node in ast.walk(cls):
        target, value = _self_assign(node)
        if target is None:
            continue
        if (isinstance(value, ast.Call)
                and _callee_name(value.func) == "deque"):
            out.add(target)
    return out


def _bounded_attrs(cls: ast.ClassDef) -> Set[str]:
    """Attrs the class bounds somewhere: a shrinking method call, a
    ``del self.attr[...]``, or reassignment outside the constructor
    (the drain/reset idiom)."""
    out: Set[str] = set()
    for fn in (n for n in ast.walk(cls)
               if isinstance(n, (ast.FunctionDef,
                                 ast.AsyncFunctionDef))):
        in_ctor = fn.name in ("__init__", "__post_init__", "__new__")
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _BOUNDING_METHODS:
                d = dotted(node.func.value)
                if d and d.startswith("self.") and d.count(".") == 1:
                    out.add(d.split(".")[1])
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    base = t.value if isinstance(t, ast.Subscript) else t
                    d = dotted(base)
                    if d and d.startswith("self.") and d.count(".") == 1:
                        out.add(d.split(".")[1])
            elif not in_ctor:
                target, _ = _self_assign(node)
                if target is not None:
                    out.add(target)
    return out


def _self_assign(node: ast.AST):
    """``self.<attr> = value`` / annotated form -> (attr, value)."""
    if isinstance(node, ast.Assign) and len(node.targets) == 1:
        target, value = node.targets[0], node.value
    elif isinstance(node, ast.AnnAssign) and node.value is not None:
        target, value = node.target, node.value
    else:
        return None, None
    if isinstance(target, ast.Attribute) \
            and isinstance(target.value, ast.Name) \
            and target.value.id == "self":
        return target.attr, value
    return None, None


def _callee_name(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _len_guarded(fn: ast.AST, attr: str) -> bool:
    """Does the function read ``len(self.<attr>)`` anywhere? (the
    cap-and-count idiom: append under a size check)."""
    for node in ast.walk(fn):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "len" and node.args
                and dotted(node.args[0]) == f"self.{attr}"):
            return True
    return False


def _value_escapes(fn: ast.AST, call: ast.Call) -> bool:
    """Is the appended value handed back to the caller (``return x``
    after ``self.xs.append(x)``)? Then the list is an index of caller-
    owned objects, not an event log."""
    arg = call.args[0]
    if not isinstance(arg, ast.Name):
        return False
    for node in ast.walk(fn):
        if (isinstance(node, ast.Return)
                and isinstance(node.value, ast.Name)
                and node.value.id == arg.id):
            return True
    return False


def _symbol_for(mod: ModuleInfo, node: ast.AST) -> str:
    best = "<module>"
    best_span = None
    for qual, fn in mod.functions.items():
        n = fn.node
        end = getattr(n, "end_lineno", n.lineno)
        if n.lineno <= node.lineno <= end:
            span = end - n.lineno
            if best_span is None or span < best_span:
                best, best_span = qual, span
    return best


def _f(mod: ModuleInfo, node: ast.AST, code: str,
       message: str) -> Finding:
    return Finding(code=code, checker=CHECKER, path=mod.rel,
                   line=node.lineno, col=node.col_offset,
                   symbol=_symbol_for(mod, node), message=message)
