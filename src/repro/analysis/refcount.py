"""Refcount-discipline checker (AV4xx): paged-KV ownership.

``PagePool`` pages are manually refcounted: ``alloc`` hands out pages at
refcount 1, ``retain`` bumps a shared prefix's count, and exactly one
``release`` per acquisition keeps ``check_invariants()`` true. The
decoder's discipline (PR 3/6) is that every acquisition is either

  * guarded — a ``try`` on the same function whose handler or
    ``finally`` releases the pages (or delegates to one of the
    decoder's unwind helpers, which release as part of failing/parking
    the slot), or
  * transferred — the page list escapes into an owner that carries the
    release obligation (``_SlotState(private_ids=...)``, an attribute /
    table store, a return).

**AV401** flags a ``pool.alloc(...)`` / ``pool.retain(...)`` that is
neither: a bare acquisition where the first exception between it and
the slot hand-off leaks pages until the pool's invariant check trips in
some later test. ``PagePool``'s own internals (eviction, prefix
insertion) and the unwind helpers themselves are exempt — they *are*
the discipline.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.model import (Finding, FunctionInfo, ModuleInfo,
                                  RepoModel, dotted)

CHECKER = "refcount"

ACQUIRE_METHODS = {"alloc", "retain"}
RELEASE_METHODS = {"release", "release_operator"}
# functions that release as their contract — acquisitions and releases
# inside them are the unwind mechanism, not a leak
UNWIND_HELPERS = ("_fail_step", "_park_slot", "_release_slot",
                  "_finish_slot", "release", "release_operator", "close")
POOL_CLASSES = {"PagePool"}


def _pool_call(node: ast.AST) -> Optional[str]:
    """'alloc'/'retain' if this is a pool acquisition call, else None."""
    if (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in ACQUIRE_METHODS):
        base = dotted(node.func.value)
        if base and "pool" in base.split(".")[-1].lower():
            return node.func.attr
    return None


def _releases_or_unwinds(stmts: List[ast.stmt]) -> bool:
    for stmt in stmts:
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in RELEASE_METHODS):
                return True
            name = dotted(node.func)
            if name and name.split(".")[-1] in UNWIND_HELPERS:
                return True
    return False


def _guarded(fn: FunctionInfo) -> bool:
    """Does any try in this function release/unwind on its exception or
    finally path? (The decoder's idiom: acquire, then a try whose
    ``except … release … raise`` unwinds everything acquired so far.)"""
    for node in fn.body_nodes():
        if isinstance(node, ast.Try):
            if _releases_or_unwinds(node.finalbody):
                return True
            for handler in node.handlers:
                if _releases_or_unwinds(handler.body):
                    return True
    return False


def _escaping_names(fn: FunctionInfo) -> Set[str]:
    """Names handed to a new owner: attribute/subscript stores
    (``self.active[slot] = _SlotState(private_ids=private)``) or
    returns. A plain call argument is NOT an escape — passing pages to
    a helper doesn't transfer the release obligation."""
    out: Set[str] = set()
    for node in fn.body_nodes():
        if isinstance(node, ast.Assign):
            if any(isinstance(t, (ast.Attribute, ast.Subscript))
                   for t in node.targets):
                out |= {n.id for n in ast.walk(node.value)
                        if isinstance(n, ast.Name)}
        elif isinstance(node, ast.Return) and node.value is not None:
            out |= {n.id for n in ast.walk(node.value)
                    if isinstance(n, ast.Name)}
    return out


def check(mod: ModuleInfo, repo: RepoModel) -> List[Finding]:
    findings: List[Finding] = []
    for qual, fn in sorted(mod.functions.items()):
        if fn.class_name in POOL_CLASSES:
            continue                     # the pool's own bookkeeping
        if fn.name in UNWIND_HELPERS:
            continue                     # the unwind mechanism itself
        acquisitions = [(node, kind) for node in fn.body_nodes()
                        if (kind := _pool_call(node)) is not None]
        if not acquisitions:
            continue
        if _guarded(fn):
            continue
        escaping = _escaping_names(fn)
        for node, kind in acquisitions:
            if kind == "alloc" and _result_escapes(fn, node, escaping):
                continue
            findings.append(Finding(
                code="AV401", checker=CHECKER, path=mod.rel,
                line=node.lineno, col=node.col_offset, symbol=fn.qualname,
                message=(f"pool.{kind}() without an unwind-safe release: "
                         "no try/finally-or-except release, no unwind "
                         "helper, and the pages don't escape to an owner "
                         "— an exception here leaks refcounts")))
    return findings


def _result_escapes(fn: FunctionInfo, call: ast.Call,
                    escaping: Set[str]) -> bool:
    """Is the alloc's result bound to a name that escapes to an owner?"""
    for node in fn.body_nodes():
        if not isinstance(node, ast.Assign):
            continue
        if not any(n is call for n in ast.walk(node.value)):
            continue
        names = {t.id for t in node.targets if isinstance(t, ast.Name)}
        if names & escaping:
            return True
    return False
