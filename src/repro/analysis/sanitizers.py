"""Runtime sanitizers: the budgets the static pass can't prove.

averylint's recompile/hostsync checkers catch the *patterns* that cause
compile churn and implicit transfers; these sanitizers measure the
*fact*, on the live engine, and turn it into a hard budget:

  * :class:`RecompileSanitizer` — walks the engine's jit roots (the
    executor's fixed jits, its keyed ``_compiled`` cache, every live
    decoder's draft-model jits) and sums ``_cache_size()`` over them:
    the total number of distinct traces XLA has compiled. ``arm()``
    after warmup snapshots the count; ``check(budget=0)`` raises
    :class:`RecompileBudgetError` if steady state compiled anything new.
  * ``transfer_guard_ctx()`` — ``jax.transfer_guard("disallow")`` as a
    nullable context manager. Under it, any *implicit* device↔host
    transfer in the guarded region raises; explicit ``jnp.asarray`` /
    ``device_get`` stay allowed, which is exactly the engine's
    discipline (the executor jnp-wraps every numpy operand at the stage
    boundary).

Both are engine knobs — ``AveryEngine(debug_recompiles=True)`` arms a
sanitizer the engine checks on every pump after ``arm_sanitizers()``;
``debug_transfers=True`` wraps each decode pump/drain in the guard.
``python -m repro.analysis.sanitizers --smoke`` runs both against a
real in-flight engine (CI's averylint step).
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Iterable, List


class RecompileBudgetError(AssertionError):
    """Steady-state decode compiled something new."""


def _unwrap(executor: Any) -> Any:
    """Chase fault-injection wrappers down to the real executor."""
    seen = set()
    while id(executor) not in seen:
        seen.add(id(executor))
        inner = getattr(executor, "_inner", None)
        if inner is None:
            break
        executor = inner
    return executor


def _is_jitted(obj: Any) -> bool:
    return callable(getattr(obj, "_cache_size", None))


def named_jit_roots(engine: Any) -> "Dict[str, Any]":
    """Every jitted callable reachable from the engine, labelled by
    where it hangs: ``executor.<attr>`` for the executor's fixed jits,
    ``executor.<attr>[<key>]`` for keyed compile-cache entries, and
    ``decoder[<qlen>].<attr>`` / ``draft[<qlen>].<attr>`` for each live
    decoder's jits. Re-discovered on every count so jits that appear
    *after* arming (a new cache entry, a new decoder's draft) are
    counted — that is the point. The labels are what the compile
    observatory attributes compile events to."""
    objs: List[Any] = [("executor", _unwrap(engine.executor))]
    for qlen, dec in getattr(engine, "_inflight", {}).items():
        objs.append((f"decoder[{qlen}]", dec))
        draft = _unwrap(getattr(dec, "draft", None))
        if draft is not None:
            objs.append((f"draft[{qlen}]", draft))
    roots: "Dict[str, Any]" = {}
    seen = set()

    def add(label: str, val: Any) -> None:
        if _is_jitted(val) and id(val) not in seen:
            seen.add(id(val))
            roots[label] = val

    for prefix, obj in objs:
        if obj is None:
            continue
        for name, val in vars(obj).items():
            add(f"{prefix}.{name}", val)
            if isinstance(val, dict):
                for k, v in val.items():
                    add(f"{prefix}.{name}[{k}]", v)
            elif isinstance(val, (list, tuple)):
                for i, v in enumerate(val):
                    add(f"{prefix}.{name}[{i}]", v)
    return roots


def jit_roots(engine: Any) -> List[Any]:
    """The engine's jit roots, unlabelled (see :func:`named_jit_roots`)."""
    return list(named_jit_roots(engine).values())


class RecompileSanitizer:
    """Counts distinct compiled traces across the engine's jit roots."""

    def __init__(self, engine: Any):
        self.engine = engine
        self.armed_at: "int | None" = None

    def compile_count(self) -> int:
        return sum(int(f._cache_size()) for f in jit_roots(self.engine))

    def arm(self) -> int:
        """Snapshot after warmup; subsequent compiles are violations."""
        self.armed_at = self.compile_count()
        return self.armed_at

    def new_compiles(self) -> int:
        if self.armed_at is None:
            return 0
        return self.compile_count() - self.armed_at

    def check(self, budget: int = 0) -> None:
        n = self.new_compiles()
        if n > budget:
            raise RecompileBudgetError(
                f"steady-state decode compiled {n} new trace(s) "
                f"(budget {budget}); a per-request shape or captured "
                "scalar is churning the jit cache")


def transfer_guard_ctx(enabled: bool = True):
    """``jax.transfer_guard('disallow')`` or a no-op context."""
    if not enabled:
        return contextlib.nullcontext()
    import jax
    return jax.transfer_guard("disallow")


# ---------------------------------------------------------------------------
# CI smoke: both sanitizers against a real in-flight engine
# ---------------------------------------------------------------------------


def _smoke() -> int:
    import numpy as np

    from repro.configs.lisa_mini import CONFIG as PCFG
    from repro.core import DualStreamExecutor, paper_lut, profile as prof
    from repro.core.intent import Intent
    from repro.data import floodseg
    from repro.engine import AveryEngine

    lut = paper_lut()
    params, bns, _ = prof.random_init_system(PCFG, lut=lut)
    execu = DualStreamExecutor(pcfg=PCFG, params=params, bottlenecks=bns,
                               lut=lut, max_new_tokens=3,
                               flash_decode=False)
    # kv_pages pre-sizes the pool and max_prefixes bounds the prefix
    # store: an under-sized pool doubles its backing buffer mid-decode,
    # recompiling every paged stage for the new shape — the first churn
    # class this sanitizer caught (see docs/analysis.md)
    engine = AveryEngine(lut=lut, executor=execu, batching="inflight",
                         max_batch=4, kv_pages=64, max_prefixes=8,
                         debug_recompiles=True, debug_transfers=True)

    rng = np.random.RandomState(7)

    def submit(k: int, sid: int, t: float) -> Any:
        kind = "any" if k % 3 == 2 else "segment"
        b = floodseg.make_batch(rng, 1, kind, augment=False)
        if kind == "any":
            pkt, _ = execu.edge_context(b["images"], sid, t)
            intent = Intent.CONTEXT
        else:
            pkt = execu.edge_insight(b["images"], lut.tiers[k % 2], sid, t)
            intent = Intent.INSIGHT
        return engine.submit_packet(pkt, b["query"], intent, time_s=t)

    # warmup: mixed-intent/mixed-tier traffic through every stage shape
    futs = [submit(i, i, float(i)) for i in range(6)]
    engine.drain()
    warm = engine.arm_sanitizers()

    # steady state: same shape mix; pump with the transfer guard live
    futs = [submit(i, 100 + i, 100.0 + i) for i in range(6)]
    for _ in range(16):
        engine.pump()
    engine.drain()
    assert all(f.done() for f in futs)
    engine.check_sanitizers()           # raises on any new compile
    print(f"[sanitizers] smoke ok: {warm} traces at arm, "
          "0 new compiles, 0 implicit transfers in steady state")
    return 0


def main(argv: "Iterable[str] | None" = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.sanitizers")
    ap.add_argument("--smoke", action="store_true",
                    help="run both sanitizers against a real in-flight "
                         "engine (used by scripts/ci_fast.sh)")
    args = ap.parse_args(list(argv) if argv is not None else None)
    if args.smoke:
        return _smoke()
    ap.print_help()
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
