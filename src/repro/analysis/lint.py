"""averylint driver: ``python -m repro.analysis.lint src/``.

Parses every ``.py`` under the targets (no imports, no execution —
``jax`` need not be installed), builds the shared :class:`RepoModel`,
runs the six checkers, filters through the checked-in baseline, and
exits nonzero on any *new* finding.

Usage::

    python -m repro.analysis.lint src/                 # human output
    python -m repro.analysis.lint --json src/          # machine output
    python -m repro.analysis.lint --write-baseline src/
    python -m repro.analysis.lint --no-baseline src/   # everything

The baseline is discovered by walking upward from the first target to
the nearest ``.averylint-baseline.json`` (``--baseline PATH``
overrides); finding paths/fingerprints are relative to that file's
directory so the same baseline works from any CWD.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis import (baseline as baseline_mod, determinism,
                            futures, hostsync, observability, recompile,
                            refcount)
from repro.analysis.model import (Finding, ModuleInfo, RepoModel,
                                  parse_module)

CHECKERS: List[Tuple[str, Callable[..., List[Finding]]]] = [
    ("recompile", recompile.check),
    ("hostsync", hostsync.check),
    ("futures", futures.check),
    ("refcount", refcount.check),
    ("determinism", determinism.check),
    ("observability", observability.check),
]

_SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules"}


def collect_files(targets: Sequence[Path]) -> List[Path]:
    files: List[Path] = []
    for target in targets:
        if target.is_file() and target.suffix == ".py":
            files.append(target)
        elif target.is_dir():
            for p in sorted(target.rglob("*.py")):
                if not any(part in _SKIP_DIRS or part.startswith(".")
                           for part in p.parts):
                    files.append(p)
    return files


def build_model(files: Sequence[Path], root: Path) -> RepoModel:
    modules: List[ModuleInfo] = []
    for path in files:
        try:
            rel = path.resolve().relative_to(root).as_posix()
        except ValueError:
            rel = path.as_posix()
        mod = parse_module(path, rel)
        if mod is not None:
            modules.append(mod)
    return RepoModel(modules)


def run_checkers(repo: RepoModel,
                 only: Optional[Sequence[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for name, check in CHECKERS:
        if only and name not in only:
            continue
        for rel in sorted(repo.modules):
            findings.extend(check(repo.modules[rel], repo))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return findings


def lint_paths(targets: Sequence[Path], root: Path,
               only: Optional[Sequence[str]] = None) -> List[Finding]:
    """Library entry point (the self-run test uses this)."""
    return run_checkers(build_model(collect_files(targets), root),
                        only=only)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-aware static analysis for the AVERY engine")
    ap.add_argument("targets", nargs="+", type=Path)
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable findings on stdout")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="explicit baseline file (default: search "
                         "upward from the first target)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline; report everything as new")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current findings")
    ap.add_argument("--checker", action="append", default=None,
                    choices=[name for name, _ in CHECKERS],
                    help="run only this checker (repeatable)")
    args = ap.parse_args(argv)

    for t in args.targets:
        if not t.exists():
            print(f"averylint: no such path: {t}", file=sys.stderr)
            return 2

    bl_path = args.baseline
    if bl_path is None and not args.no_baseline:
        bl_path = baseline_mod.find_baseline(args.targets[0])
    root = (bl_path.resolve().parent if bl_path is not None
            else Path.cwd())
    baselined: Dict[str, str] = {}
    if bl_path is not None and bl_path.is_file() and not args.no_baseline:
        baselined = baseline_mod.load(bl_path)

    findings = lint_paths(args.targets, root, only=args.checker)

    if args.write_baseline:
        out = bl_path or (root / baseline_mod.BASELINE_NAME)
        baseline_mod.write(out, findings, reasons=baselined)
        print(f"averylint: wrote {len(findings)} entr"
              f"{'y' if len(findings) == 1 else 'ies'} to {out}")
        return 0

    new, old = baseline_mod.split(findings, baselined)

    if args.as_json:
        print(json.dumps({
            "new": [f.as_dict() for f in new],
            "baselined": [f.as_dict() for f in old],
            "counts": {"new": len(new), "baselined": len(old)},
        }, indent=2))
    else:
        for f in new:
            print(f.render())
        if old:
            print(f"averylint: {len(old)} baselined finding"
                  f"{'' if len(old) == 1 else 's'} suppressed")
        if new:
            print(f"averylint: {len(new)} new finding"
                  f"{'' if len(new) == 1 else 's'}")
        else:
            print("averylint: clean")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
