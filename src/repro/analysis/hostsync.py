"""Host-sync lint (AV2xx): host/device boundary discipline.

Two contracts from the engine arc:

  * **AV201** — the host-only scheduling modules stay pure Python.
    ``engine/scheduler.py``, ``engine/policy.py``, ``engine/faults.py``,
    and ``engine/observability.py`` run inside the pump loop between
    device steps; a ``jnp`` import there invites device work (and
    implicit transfers) onto the scheduling path. Any jax import or
    ``jnp.*`` use in those files is flagged.
  * **AV202** — host-sync primitives inside traced code:
    ``float()/int()/bool()`` on a traced value, ``.item()``,
    ``np.asarray()/np.array()``. Under ``jax.jit`` each of these forces
    a device→host readback mid-trace (or a tracer error at runtime).
    Static shapes are exempt: ``int(x.shape[0])``, ``len(x)``,
    ``x.ndim`` and friends are Python values during tracing.
  * **AV203** — ``if``/``while`` predicated on device values inside
    traced code (``if jnp.any(mask):``): control flow on a tracer is a
    concretisation error; use ``jnp.where`` / ``lax.cond``.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.model import (Finding, FunctionInfo, ModuleInfo,
                                  RepoModel, dotted)

CHECKER = "hostsync"

# rel-path suffixes that must stay free of jax (pure-Python host path)
HOST_ONLY_SUFFIXES = (
    "engine/scheduler.py",
    "engine/policy.py",
    "engine/faults.py",
    "engine/observability.py",
)

_SYNC_BUILTINS = {"float", "int", "bool", "complex"}
_SHAPE_ATTRS = {"shape", "ndim", "size", "dtype"}


def is_host_only(rel: str) -> bool:
    return rel.endswith(HOST_ONLY_SUFFIXES)


def check(mod: ModuleInfo, repo: RepoModel) -> List[Finding]:
    findings: List[Finding] = []
    if is_host_only(mod.rel):
        findings.extend(_check_host_only(mod))
    for fn in repo.traced_functions(mod):
        findings.extend(_check_traced_fn(mod, fn))
    return findings


# ---------------------------------------------------------------------------
# AV201: jax in host-only modules
# ---------------------------------------------------------------------------


def _check_host_only(mod: ModuleInfo) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        what: Optional[str] = None
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "jax" or alias.name.startswith("jax."):
                    what = f"import {alias.name}"
        elif isinstance(node, ast.ImportFrom):
            m = node.module or ""
            if m == "jax" or m.startswith("jax."):
                what = f"from {m} import ..."
        if what is not None:
            findings.append(Finding(
                code="AV201", checker=CHECKER, path=mod.rel,
                line=node.lineno, col=node.col_offset, symbol="<module>",
                message=(f"{what} in a host-only scheduling module; "
                         "scheduler/policy/faults run on the pump's host "
                         "path and must stay pure Python (numpy is fine)")))
    return findings


# ---------------------------------------------------------------------------
# AV202 / AV203: host syncs inside traced regions
# ---------------------------------------------------------------------------


def _shape_names(fn: FunctionInfo) -> set:
    """Local names bound from shape tuples (``B, T, pp = x.shape``) —
    Python-static during tracing."""
    names: set = set()
    for node in fn.body_nodes():
        if not isinstance(node, ast.Assign):
            continue
        value = node.value
        while isinstance(value, ast.Subscript):
            value = value.value
        if not (isinstance(value, ast.Attribute)
                and value.attr in _SHAPE_ATTRS):
            continue
        for t in node.targets:
            for sub in ast.walk(t):
                if isinstance(sub, ast.Name):
                    names.add(sub.id)
    return names


def _is_static_arg(arg: ast.AST, static_names: set = frozenset()) -> bool:
    """Is this expression a Python-static value during tracing?"""
    if isinstance(arg, ast.Constant):
        return True
    if isinstance(arg, ast.Name):
        return arg.id in static_names
    if isinstance(arg, ast.Call):
        name = dotted(arg.func)
        if name in ("len", "round", "min", "max", "abs"):
            return all(_is_static_arg(a, static_names)
                       or isinstance(a, ast.Name) for a in arg.args)
    # x.shape / x.ndim / x.shape[i] / math.prod(x.shape) fragments
    node = arg
    while isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute) and node.attr in _SHAPE_ATTRS:
        return True
    if isinstance(arg, ast.BinOp):
        return (_is_static_arg(arg.left, static_names)
                and _is_static_arg(arg.right, static_names))
    return False


def _device_test(test: ast.AST, mod: ModuleInfo) -> Optional[str]:
    """Does this predicate read a device value (``jnp.any(x)`` etc.)?"""
    aliases = mod.jax_aliases()
    for node in ast.walk(test):
        if isinstance(node, ast.Call):
            name = dotted(node.func)
            if name and name.split(".")[0] in aliases:
                return name
    return None


def _check_traced_fn(mod: ModuleInfo, fn: FunctionInfo) -> List[Finding]:
    findings: List[Finding] = []
    np_aliases = mod.numpy_aliases()
    static_names = _shape_names(fn)
    for node in fn.body_nodes():
        if isinstance(node, ast.Call):
            func = node.func
            # .item() — the canonical blocking readback
            if isinstance(func, ast.Attribute) and func.attr == "item":
                findings.append(_f(mod, fn, node, (
                    ".item() inside a traced region forces a device→host "
                    "sync; keep the value on device or move the readback "
                    "outside jit")))
                continue
            # float(x)/int(x)/bool(x) on a non-static value
            if (isinstance(func, ast.Name)
                    and func.id in _SYNC_BUILTINS and node.args
                    and not _is_static_arg(node.args[0], static_names)):
                findings.append(_f(mod, fn, node, (
                    f"{func.id}() on a traced value concretises the "
                    "tracer (host sync); shape-derived ints are fine, "
                    "array values are not")))
                continue
            # np.asarray / np.array pulls the tracer to host
            name = dotted(func)
            if name and "." in name:
                base, attr = name.rsplit(".", 1)
                if base in np_aliases and attr in ("asarray", "array"):
                    findings.append(_f(mod, fn, node, (
                        f"{name}() inside a traced region copies device "
                        "data to host; use jnp equivalents under jit")))
        elif isinstance(node, (ast.If, ast.While, ast.IfExp)):
            hit = _device_test(node.test, mod)
            if hit is not None:
                findings.append(Finding(
                    code="AV203", checker=CHECKER, path=mod.rel,
                    line=node.lineno, col=node.col_offset,
                    symbol=fn.qualname,
                    message=(f"branching on a device value ({hit}) inside "
                             "a traced region; use jnp.where or lax.cond")))
    return findings


def _f(mod: ModuleInfo, fn: FunctionInfo, node: ast.AST,
       message: str) -> Finding:
    return Finding(code="AV202", checker=CHECKER, path=mod.rel,
                   line=node.lineno, col=node.col_offset,
                   symbol=fn.qualname, message=message)
