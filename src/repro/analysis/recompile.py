"""Recompile lint (AV1xx): compile-cache churn at review time.

PR 1's explicit ``(stage, tier, bucket, qlen)`` compile cache exists
because one stray ``jax.jit`` in a per-request path turns steady-state
serving into a recompile loop. This checker enforces the discipline the
executor follows:

  * **AV101** — ``jax.jit`` / ``jax.pmap`` / ``pl.pallas_call`` invoked
    inside a function body without landing in a cache. Allowed homes:
    module level, a constructor (``__init__`` / ``__post_init__`` — one
    build per object), a memoised function (``functools.lru_cache`` /
    ``cache``), or a call whose result is stored into an attribute /
    subscript slot (``self._fn = jax.jit(...)``,
    ``self._compiled[key] = jax.jit(...)``) directly or through a local
    (``fn = jax.jit(...); cache[key] = fn``). Everything else builds a
    fresh traced wrapper per call — compile churn.
  * **AV102** — a jitted closure (``jax.jit(lambda ...)`` or
    ``jax.jit(local_fn)``) capturing a per-call-varying Python value: a
    parameter or loop variable of the enclosing (non-constructor,
    non-memoised) function. The captured scalar bakes into the trace,
    so every new value is a new compile — the exact churn class the
    executor's keyed cache prevents by putting such values in the key.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from repro.analysis.model import (Finding, FunctionInfo, ModuleInfo,
                                  RepoModel, is_jit_callee,
                                  is_pallas_callee)

CHECKER = "recompile"


def _enclosing_chain(mod: ModuleInfo, fn: FunctionInfo
                     ) -> List[FunctionInfo]:
    """``fn`` plus every enclosing function, outermost last."""
    chain = [fn]
    qual = fn.qualname
    while "." in qual:
        qual = qual.rsplit(".", 1)[0]
        parent = mod.functions.get(qual)
        if parent is not None:
            chain.append(parent)
    return chain


def _stored_names(fn: FunctionInfo) -> Set[str]:
    """Local names whose value is stored into an attribute/subscript or
    returned — the 'this escapes into a cache the caller owns' set."""
    out: Set[str] = set()
    for node in fn.body_nodes():
        if isinstance(node, ast.Assign):
            if any(isinstance(t, (ast.Attribute, ast.Subscript))
                   for t in node.targets) and isinstance(node.value,
                                                         ast.Name):
                out.add(node.value.id)
        elif isinstance(node, ast.Return) and node.value is not None:
            # ``return fn, (...)`` escapes fn to the caller;
            # ``return fn(x)`` returns a result — fn stays per-call
            called = {c.func.id for c in ast.walk(node.value)
                      if isinstance(c, ast.Call)
                      and isinstance(c.func, ast.Name)}
            out |= {n.id for n in ast.walk(node.value)
                    if isinstance(n, ast.Name)} - called
    return out


def _loop_called_names(fn: FunctionInfo) -> Set[str]:
    """Names invoked inside a loop body — a jit bound to one of these is
    amortized over the loop (the training-driver idiom:
    ``step = jax.jit(step_fn); for ...: step(...)``)."""
    out: Set[str] = set()
    for node in fn.body_nodes():
        if isinstance(node, (ast.For, ast.AsyncFor, ast.While)):
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call) and isinstance(sub.func,
                                                            ast.Name):
                    out.add(sub.func.id)
    return out


def _loop_targets(fn: FunctionInfo) -> Set[str]:
    out: Set[str] = set()
    for node in fn.body_nodes():
        if isinstance(node, (ast.For, ast.AsyncFor)):
            for t in ast.walk(node.target):
                if isinstance(t, ast.Name):
                    out.add(t.id)
        elif isinstance(node, (ast.comprehension,)):
            for t in ast.walk(node.target):
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


def _free_names(node: ast.AST) -> Set[str]:
    """Names a lambda/def body reads that it does not bind itself."""
    bound: Set[str] = set()
    if isinstance(node, (ast.Lambda, ast.FunctionDef,
                         ast.AsyncFunctionDef)):
        a = node.args
        bound = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
        if a.vararg:
            bound.add(a.vararg.arg)
        if a.kwarg:
            bound.add(a.kwarg.arg)
        body = node.body if isinstance(node.body, list) else [node.body]
    else:
        body = [node]
    reads: Set[str] = set()
    for stmt in body:
        for n in ast.walk(stmt):
            if isinstance(n, ast.Name):
                if isinstance(n.ctx, ast.Load):
                    reads.add(n.id)
                else:
                    bound.add(n.id)
    return reads - bound


def check(mod: ModuleInfo, repo: RepoModel) -> List[Finding]:
    findings: List[Finding] = []
    # map: every Call node -> enclosing function (None = module level)
    for fn, call in _jit_calls(mod):
        kind = ("pl.pallas_call"
                if is_pallas_callee(call.func, mod) else "jax.jit")
        if fn is None:
            continue                       # module level: compiled once
        chain = _enclosing_chain(mod, fn)
        if any(f.is_cached or f.is_constructor for f in chain):
            continue                       # memoised or built-once
        if kind == "pl.pallas_call" and repo.is_traced(mod, fn.qualname):
            # a pallas_call inside a traced function compiles with its
            # enclosing jit — the supported kernel idiom
            continue
        if _is_aot(mod, call):
            continue                       # jax.jit(f).lower(...): AOT
        how = _holding(mod, fn, call)
        if how is None:
            findings.append(Finding(
                code="AV101", checker=CHECKER, path=mod.rel,
                line=call.lineno, col=call.col_offset,
                symbol=fn.qualname,
                message=(f"{kind} built inside a per-call code path; hoist "
                         "to module level, a constructor, or a keyed "
                         "compile cache (see DualStreamExecutor._jitted)")))
            continue
        if how == "attr":
            # a single attribute slot is an unkeyed cache: a captured
            # per-call-varying value churns it
            _check_captured_scalars(mod, fn, call, findings)
    return findings


def _is_aot(mod: ModuleInfo, call: ast.Call) -> bool:
    """``jax.jit(f).lower(...)`` — deliberate ahead-of-time compile."""
    for node in ast.walk(mod.tree):
        if (isinstance(node, ast.Attribute) and node.value is call
                and node.attr in ("lower", "trace", "eval_shape")):
            return True
    return False


def _jit_calls(mod: ModuleInfo):
    """(enclosing FunctionInfo | None, Call) for every jit-like call."""
    nodes_to_fn = {}
    for qual, fn in mod.functions.items():
        for node in fn.body_nodes():
            nodes_to_fn[id(node)] = fn
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) and (
                is_jit_callee(node.func, mod)
                or is_pallas_callee(node.func, mod)):
            yield nodes_to_fn.get(id(node)), node


def _loop_spans(fn: FunctionInfo) -> List[Tuple[int, int]]:
    return [(n.lineno, getattr(n, "end_lineno", n.lineno))
            for n in fn.body_nodes()
            if isinstance(n, (ast.For, ast.AsyncFor, ast.While))]


def _holding(mod: ModuleInfo, fn: FunctionInfo, call: ast.Call
             ) -> Optional[str]:
    """How this in-body jit's result is legitimately held: 'attr' /
    'subscript' (cache slot), 'return' (caller owns it), 'local'
    (bound once outside any loop and amortized over a loop), or None —
    nothing holds it, it's per-call churn."""
    stored = _stored_names(fn)
    loop_called = _loop_called_names(fn)
    spans = _loop_spans(fn)
    in_loop = any(lo <= call.lineno <= hi for lo, hi in spans)
    for node in fn.body_nodes():
        if isinstance(node, ast.Assign) and _contains(node.value, call):
            for t in node.targets:
                if isinstance(t, ast.Subscript):
                    return "subscript"     # cache[key] = jax.jit(...)
                if isinstance(t, ast.Attribute):
                    return "attr"          # self._fn = jax.jit(...)
                if isinstance(t, ast.Name) and not in_loop:
                    if t.id in stored:
                        return "return"    # escapes to the caller
                    if t.id in loop_called:
                        return "local"     # built once, looped over
        elif isinstance(node, ast.Return) and node.value is not None \
                and _contains(node.value, call):
            return "return"
    return None


def _contains(tree: ast.AST, needle: ast.AST) -> bool:
    return any(n is needle for n in ast.walk(tree))


def _check_captured_scalars(mod: ModuleInfo, fn: FunctionInfo,
                            call: ast.Call,
                            findings: List[Finding]) -> None:
    """AV102: the jitted closure captures a per-call-varying local."""
    if not call.args:
        return
    arg = call.args[0]
    target: Optional[ast.AST] = None
    if isinstance(arg, ast.Lambda):
        target = arg
    elif isinstance(arg, ast.Name):
        nested = mod.functions.get(f"{fn.qualname}.{arg.id}")
        if nested is not None:
            target = nested.node
    if target is None:
        return
    varying = fn.param_names | _loop_targets(fn)
    captured = sorted(_free_names(target) & varying)
    if captured:
        findings.append(Finding(
            code="AV102", checker=CHECKER, path=mod.rel,
            line=call.lineno, col=call.col_offset, symbol=fn.qualname,
            message=(f"jitted closure captures per-call-varying "
                     f"value(s) {captured} from {fn.name}(); each new "
                     "value bakes a new trace — key the compile cache "
                     "on them instead")))
