"""averylint: repo-aware static analysis + runtime sanitizers.

The static half (``python -m repro.analysis.lint src/``) runs five
AST checkers over the tree — no imports, no jax required:

  recompile    AV101/AV102  jit/pallas_call built in per-call paths
  hostsync     AV201-AV203  host/device boundary discipline
  futures      AV301/AV302  every RequestFuture resolves
  refcount     AV401        PagePool acquire/release pairing
  determinism  AV501-AV504  seeded paths stay replayable

The runtime half (``repro.analysis.sanitizers``) complements it with
hard budgets the static pass can't prove: a recompile sanitizer that
counts jit cache growth across a steady-state decode window, and a
transfer sanitizer wrapping ``jax.transfer_guard("disallow")`` around
the pump. Both are engine knobs:
``AveryEngine(debug_recompiles=True, debug_transfers=True)``.

``sanitizers`` imports jax, so it is *not* re-exported here — the lint
driver must stay importable on a box without the serving deps.
"""
from repro.analysis.model import Finding, ModuleInfo, RepoModel

__all__ = ["Finding", "ModuleInfo", "RepoModel"]
