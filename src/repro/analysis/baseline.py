"""Baseline (grandfather) file for averylint.

A baseline entry suppresses one finding by fingerprint —
``code:path:symbol:message-hash`` — which survives line drift but not a
rename or a message change, so a suppressed site that moves files or
mutates resurfaces as *new*. Every entry must carry a ``reason``: the
baseline is a list of debts with justifications, not a mute button.

File format (checked in at the repo root as
``.averylint-baseline.json``)::

    {
      "version": 1,
      "entries": [
        {"fingerprint": "AV501:...", "reason": "why this is OK"}
      ]
    }

``repro.analysis.lint`` searches upward from the lint target for the
file, reports baselined findings separately, exits nonzero only on new
ones, and ``--write-baseline`` regenerates the file from the current
findings (stamping ``reason: "TODO: justify"`` on new entries so the
review catches them).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional

from repro.analysis.model import Finding

BASELINE_NAME = ".averylint-baseline.json"
VERSION = 1


def find_baseline(start: Path) -> Optional[Path]:
    """Nearest ``.averylint-baseline.json`` at or above ``start``."""
    node = start.resolve()
    if node.is_file():
        node = node.parent
    for parent in [node, *node.parents]:
        cand = parent / BASELINE_NAME
        if cand.is_file():
            return cand
    return None


def load(path: Path) -> Dict[str, str]:
    """fingerprint -> reason."""
    data = json.loads(path.read_text())
    if data.get("version") != VERSION:
        raise ValueError(f"{path}: unsupported baseline version "
                         f"{data.get('version')!r}")
    out: Dict[str, str] = {}
    for entry in data.get("entries", []):
        out[entry["fingerprint"]] = entry.get("reason", "")
    return out


def write(path: Path, findings: Iterable[Finding],
          reasons: Optional[Dict[str, str]] = None) -> None:
    """Regenerate the baseline from current findings, keeping reasons
    for fingerprints that already had one."""
    reasons = reasons or {}
    entries: List[Dict[str, str]] = []
    seen = set()
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.code)):
        if f.fingerprint in seen:
            continue
        seen.add(f.fingerprint)
        entries.append({
            "fingerprint": f.fingerprint,
            "reason": reasons.get(f.fingerprint, "TODO: justify"),
        })
    path.write_text(json.dumps({"version": VERSION, "entries": entries},
                               indent=2) + "\n")


def split(findings: List[Finding], baselined: Dict[str, str]
          ) -> "tuple[List[Finding], List[Finding]]":
    """(new, grandfathered) partition of ``findings``."""
    new = [f for f in findings if f.fingerprint not in baselined]
    old = [f for f in findings if f.fingerprint in baselined]
    return new, old
