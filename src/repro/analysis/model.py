"""The repo model under ``averylint``: parsed modules, resolved
imports, a function table, and the traced-region closure.

Every checker consumes the same picture of the tree, built once by the
driver (``repro.analysis.lint``):

  * ``ModuleInfo`` — one parsed file: its AST, dotted module name, the
    local-name -> module import map, and every function/lambda with a
    stable qualname (``Class.method``, ``outer.inner``,
    ``f.<lambda@L12>``).
  * ``RepoModel`` — the whole lint target. Its one non-trivial product
    is the **traced set**: the transitive closure of functions that
    execute under ``jax.jit`` tracing. Seeds are jit decorators, direct
    ``jax.jit(fn)`` / ``jax.jit(lambda ...)`` wraps, and the
    stage-factory idiom (``jax.jit(self._stage_fn(...))`` marks the
    factory's returned closures); the closure propagates through
    resolvable call edges — same-module calls, ``self.method`` calls,
    and cross-module ``alias.fn`` calls through the import map. The
    host-sync checker asks "is this ``.item()`` inside traced code?"
    against that set instead of guessing from file names.

The model is purely syntactic — nothing is imported or executed, so the
linter runs on a tree that doesn't even have its dependencies
installed.
"""
from __future__ import annotations

import ast
import hashlib
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple, Union

FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]

# decorator / wrapper spellings that put a function under jax tracing
JIT_NAMES = {"jit", "pmap"}
JIT_MODULES = {"jax"}
PALLAS_CALL_NAMES = {"pallas_call"}
# memoisation decorators: a jit built under one of these is built once
# per distinct key, not per call
CACHE_DECORATORS = {"lru_cache", "cache", "cached_property"}


@dataclass(frozen=True)
class Finding:
    """One lint finding. The ``fingerprint`` identifies it across line
    drift (baselines key on it): path + code + enclosing symbol + a
    hash of the message, but not the line number."""
    code: str          # e.g. "AV101"
    checker: str       # e.g. "recompile"
    path: str          # lint-root-relative posix path
    line: int
    col: int
    symbol: str        # enclosing qualname, or "<module>"
    message: str

    @property
    def fingerprint(self) -> str:
        digest = hashlib.sha1(self.message.encode()).hexdigest()[:10]
        return f"{self.code}:{self.path}:{self.symbol}:{digest}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.code} "
                f"[{self.checker}] {self.message} (in {self.symbol})")

    def as_dict(self) -> Dict[str, object]:
        return {
            "code": self.code, "checker": self.checker, "path": self.path,
            "line": self.line, "col": self.col, "symbol": self.symbol,
            "message": self.message, "fingerprint": self.fingerprint,
        }


@dataclass
class FunctionInfo:
    qualname: str
    node: FuncNode
    module: "ModuleInfo"
    class_name: Optional[str] = None   # nearest enclosing class, if any

    @property
    def name(self) -> str:
        return getattr(self.node, "name", "<lambda>")

    @property
    def is_constructor(self) -> bool:
        return self.name in ("__init__", "__post_init__", "__new__")

    @property
    def is_cached(self) -> bool:
        """Decorated with a memoiser (functools.lru_cache / cache)."""
        for dec in getattr(self.node, "decorator_list", []):
            target = dec.func if isinstance(dec, ast.Call) else dec
            if decorator_name(target) in CACHE_DECORATORS:
                return True
        return False

    def body_nodes(self, include_nested: bool = False
                   ) -> Iterable[ast.AST]:
        """Walk this function's own statements, not those of nested
        function/lambda definitions (each is its own FunctionInfo)."""
        body = (self.node.body if isinstance(self.node.body, list)
                else [self.node.body])
        stack: List[ast.AST] = list(body)
        while stack:
            node = stack.pop()
            yield node
            if not include_nested and isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.Lambda)):
                # still yield decorators/defaults, which run in this scope
                for dec in getattr(node, "decorator_list", []):
                    stack.append(dec)
                continue
            stack.extend(ast.iter_child_nodes(node))

    @property
    def param_names(self) -> Set[str]:
        a = self.node.args
        names = [p.arg for p in
                 a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return set(names)


def decorator_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def dotted(node: ast.AST) -> Optional[str]:
    """Render a Name/Attribute chain as ``a.b.c``; None otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclass
class ModuleInfo:
    path: Path                      # absolute
    rel: str                        # posix path relative to the lint root
    modname: str                    # dotted module name (best effort)
    tree: ast.Module
    functions: Dict[str, FunctionInfo] = field(default_factory=dict)
    # local alias -> dotted module ("jnp" -> "jax.numpy")
    import_alias: Dict[str, str] = field(default_factory=dict)
    # local name -> (module, attr) for from-imports
    from_imports: Dict[str, Tuple[str, str]] = field(default_factory=dict)

    def jax_aliases(self) -> Set[str]:
        """Local names bound to the jax package or its submodules."""
        out = {a for a, m in self.import_alias.items()
               if m == "jax" or m.startswith("jax.")}
        out |= {a for a, (m, _) in self.from_imports.items()
                if m == "jax" or m.startswith("jax.")}
        return out

    def numpy_aliases(self) -> Set[str]:
        return {a for a, m in self.import_alias.items() if m == "numpy"}

    def resolves_to(self, local: str, full: str) -> bool:
        """Does the local name ``local`` refer to ``full`` (e.g.
        ``jit`` -> ``jax.jit``) via a from-import?"""
        got = self.from_imports.get(local)
        return got is not None and f"{got[0]}.{got[1]}" == full


def _modname_for(rel: str) -> str:
    parts = list(Path(rel).with_suffix("").parts)
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    if "repro" in parts:                  # anchor on the package root
        parts = parts[parts.index("repro"):]
    return ".".join(parts) if parts else "<root>"


class _Indexer(ast.NodeVisitor):
    """Collects imports and the function table with qualnames."""

    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.scope: List[str] = []      # qualname parts
        self.class_stack: List[str] = []

    def _register(self, node: FuncNode, name: str) -> FunctionInfo:
        qualname = ".".join(self.scope + [name]) if self.scope else name
        info = FunctionInfo(
            qualname=qualname, node=node, module=self.mod,
            class_name=self.class_stack[-1] if self.class_stack else None)
        self.mod.functions[qualname] = info
        return info

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self.mod.import_alias[alias.asname
                                  or alias.name.split(".")[0]] = (
                alias.name if alias.asname else alias.name.split(".")[0])
            if alias.asname:
                self.mod.import_alias[alias.asname] = alias.name

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module is None:
            return
        for alias in node.names:
            self.mod.from_imports[alias.asname or alias.name] = (
                node.module, alias.name)

    def _visit_func(self, node, name: str) -> None:
        self._register(node, name)
        self.scope.append(name)
        self.generic_visit(node)
        self.scope.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_func(node, node.name)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_func(node, node.name)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self._visit_func(node, f"<lambda@{node.lineno}>")

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.scope.append(node.name)
        self.class_stack.append(node.name)
        self.generic_visit(node)
        self.class_stack.pop()
        self.scope.pop()


def parse_module(path: Path, rel: str) -> Optional[ModuleInfo]:
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError):
        return None
    mod = ModuleInfo(path=path, rel=rel, modname=_modname_for(rel),
                     tree=tree)
    _Indexer(mod).visit(tree)
    return mod


# ---------------------------------------------------------------------------
# jit-wrap recognition
# ---------------------------------------------------------------------------


def is_jit_callee(func: ast.AST, mod: ModuleInfo) -> bool:
    """Is this Call's ``func`` one of jax's tracing wrappers
    (``jax.jit`` / ``jax.pmap``, a from-imported ``jit``, or
    ``functools.partial(jax.jit, ...)``)?"""
    if isinstance(func, ast.Attribute) and func.attr in JIT_NAMES:
        base = dotted(func.value)
        return base is not None and (
            base in JIT_MODULES
            or mod.import_alias.get(base, "") in JIT_MODULES)
    if isinstance(func, ast.Name):
        return any(mod.resolves_to(func.id, f"jax.{n}") for n in JIT_NAMES)
    if isinstance(func, ast.Call):        # functools.partial(jax.jit, ...)
        name = decorator_name(func.func)
        if name == "partial" and func.args:
            return is_jit_callee(func.args[0], mod)
    return False


def is_pallas_callee(func: ast.AST, mod: ModuleInfo) -> bool:
    if isinstance(func, ast.Attribute) and func.attr in PALLAS_CALL_NAMES:
        return True
    if isinstance(func, ast.Name):
        return (func.id in PALLAS_CALL_NAMES
                or any(mod.resolves_to(func.id, f"jax.experimental.pallas."
                                                f"{n}")
                       for n in PALLAS_CALL_NAMES))
    if isinstance(func, ast.Call):
        name = decorator_name(func.func)
        if name == "partial" and func.args:
            return is_pallas_callee(func.args[0], mod)
    return False


def has_jit_decorator(node: FuncNode, mod: ModuleInfo) -> bool:
    for dec in getattr(node, "decorator_list", []):
        if is_jit_callee(dec, mod):               # @jax.jit / @jit
            return True
        if isinstance(dec, ast.Call) and is_jit_callee(dec.func, mod):
            return True                           # @jax.jit(...) form
        if isinstance(dec, ast.Call) and is_jit_callee(dec, mod):
            return True                           # @partial(jax.jit, ...)
    return False


# ---------------------------------------------------------------------------
# the repo model + traced closure
# ---------------------------------------------------------------------------

FuncKey = Tuple[str, str]            # (module rel path, qualname)


class RepoModel:
    def __init__(self, modules: List[ModuleInfo]):
        self.modules: Dict[str, ModuleInfo] = {m.rel: m for m in modules}
        self.by_modname: Dict[str, ModuleInfo] = {}
        for m in modules:
            self.by_modname.setdefault(m.modname, m)
        self._edges: Dict[FuncKey, Set[FuncKey]] = {}
        self._traced: Set[FuncKey] = set()
        self._build()

    # ---- public queries ----

    def is_traced(self, mod: ModuleInfo, qualname: str) -> bool:
        return (mod.rel, qualname) in self._traced

    def traced_functions(self, mod: ModuleInfo) -> List[FunctionInfo]:
        return [f for q, f in sorted(mod.functions.items())
                if (mod.rel, q) in self._traced]

    # ---- construction ----

    def _build(self) -> None:
        seeds: Set[FuncKey] = set()
        for mod in self.modules.values():
            seeds |= self._module_seeds(mod)
            for qual, fn in mod.functions.items():
                self._edges[(mod.rel, qual)] = self._call_edges(mod, fn)
        # propagate: traced functions trace everything they call
        work = list(seeds)
        self._traced = set(seeds)
        while work:
            key = work.pop()
            for callee in self._edges.get(key, ()):
                if callee not in self._traced:
                    self._traced.add(callee)
                    work.append(callee)

    def _module_seeds(self, mod: ModuleInfo) -> Set[FuncKey]:
        seeds: Set[FuncKey] = set()
        for qual, fn in mod.functions.items():
            if has_jit_decorator(fn.node, mod):
                seeds.add((mod.rel, qual))
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and (is_jit_callee(node.func, mod)
                         or is_pallas_callee(node.func, mod))):
                continue
            if not node.args:
                continue
            seeds |= self._resolve_jit_arg(mod, node.args[0])
        return seeds

    def _resolve_jit_arg(self, mod: ModuleInfo, arg: ast.AST
                         ) -> Set[FuncKey]:
        """Functions put under tracing by ``jax.jit(<arg>)``."""
        if isinstance(arg, ast.Lambda):
            key = self._lambda_key(mod, arg)
            return {key} if key else set()
        target = self._resolve_callable(mod, arg)
        if target is not None:
            return {target}
        if isinstance(arg, ast.Call):
            # the stage-factory idiom: jax.jit(self._stage_fn(...)) —
            # whatever closures the factory returns run under tracing
            factory = self._resolve_callable(mod, arg.func)
            if factory is not None:
                return self._factory_returns(factory)
        return set()

    def _lambda_key(self, mod: ModuleInfo, node: ast.Lambda
                    ) -> Optional[FuncKey]:
        for qual, fn in mod.functions.items():
            if fn.node is node:
                return (mod.rel, qual)
        return None

    def _resolve_callable(self, mod: ModuleInfo, node: ast.AST
                          ) -> Optional[FuncKey]:
        """Resolve a Name/Attribute callable reference to a function in
        the model (same module, ``self.method``, ``Class.method``, or a
        cross-module ``alias.fn``)."""
        if isinstance(node, ast.Name):
            hit = self._lookup(mod, node.id)
            if hit:
                return hit
            imp = mod.from_imports.get(node.id)
            if imp:
                other = self.by_modname.get(imp[0])
                if other:
                    return self._lookup(other, imp[1])
            return None
        d = dotted(node)
        if d is None:
            return None
        head, _, tail = d.partition(".")
        if head == "self" and tail and "." not in tail:
            # self.method: try every Class.method match in this module
            for qual in mod.functions:
                if qual.endswith(f".{tail}"):
                    return (mod.rel, qual)
            return None
        if tail:
            # Class.method in this module
            hit = self._lookup(mod, d)
            if hit:
                return hit
            # alias.fn / alias.Class.method through the import map
            imp = mod.from_imports.get(head)
            target_mod = None
            if imp is not None:
                target_mod = self.by_modname.get(f"{imp[0]}.{imp[1]}")
            if target_mod is None and head in mod.import_alias:
                target_mod = self.by_modname.get(mod.import_alias[head])
            if target_mod is not None:
                return self._lookup(target_mod, tail)
        return None

    def _lookup(self, mod: ModuleInfo, qualname: str
                ) -> Optional[FuncKey]:
        if qualname in mod.functions:
            return (mod.rel, qualname)
        # a bare function name may live nested (outer.inner) — prefer
        # the top-level match only
        return None

    def _factory_returns(self, factory: FuncKey) -> Set[FuncKey]:
        mod = self.modules[factory[0]]
        fn = mod.functions[factory[1]]
        out: Set[FuncKey] = set()
        for node in fn.body_nodes():
            if not isinstance(node, ast.Return) or node.value is None:
                continue
            for ref in ast.walk(node.value):
                if isinstance(ref, ast.Name):
                    nested = f"{fn.qualname}.{ref.id}"
                    if nested in mod.functions:
                        out.add((mod.rel, nested))
                elif isinstance(ref, ast.Lambda):
                    key = self._lambda_key(mod, ref)
                    if key:
                        out.add(key)
        return out

    def _call_edges(self, mod: ModuleInfo, fn: FunctionInfo
                    ) -> Set[FuncKey]:
        edges: Set[FuncKey] = set()
        for node in fn.body_nodes():
            if isinstance(node, ast.Call):
                target = self._resolve_callable(mod, node.func)
                if target is not None and target != (mod.rel, fn.qualname):
                    edges.add(target)
                # nested local call: outer.inner
                if isinstance(node.func, ast.Name):
                    nested = f"{fn.qualname}.{node.func.id}"
                    if nested in mod.functions:
                        edges.add((mod.rel, nested))
        return edges
