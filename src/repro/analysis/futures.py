"""Future-resolution checker (AV3xx): every ``RequestFuture`` resolves.

The engine's hardest liveness contract — the one ``--chaos`` asserts
dynamically — is that a submitted request always terminates: every
``RequestFuture`` eventually sees ``set_result``, on the served path,
the failure-taxonomy paths, *and* exception unwinds. Two static rules
approximate it:

  * **AV301** — a function constructs a ``RequestFuture`` but the
    handle neither escapes (stored into an attribute/subscript table
    like ``self._futures[rid] = fut``, returned, or passed onward) nor
    is resolved locally. A future nobody holds is a request nobody can
    finish.
  * **AV302** — a ``try`` whose body works on a held future has an
    ``except`` handler that neither resolves the future, re-raises, nor
    delegates to a fail helper (``_fail*`` / ``_cloud_failed`` /
    ``_send_failed`` / ``_reject*`` / ``_cancel*`` / ``_finish*``).
    Swallowing the exception leaks the request: the caller's
    ``result()`` drains forever.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.model import (Finding, FunctionInfo, ModuleInfo,
                                  RepoModel, dotted)

CHECKER = "futures"

FUTURE_TYPES = {"RequestFuture"}
RESOLVER_METHODS = {"set_result", "set_exception", "resolve", "cancel"}
FAIL_HELPER_PREFIXES = ("_fail", "_cloud_failed", "_send_failed",
                        "_reject", "_cancel", "_finish", "_park")


def _constructs_future(node: ast.AST) -> bool:
    """Is this expression a ``RequestFuture(...)`` construction?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = dotted(sub.func)
            if name and name.split(".")[-1] in FUTURE_TYPES:
                return True
    return False


def _future_names(fn: FunctionInfo) -> Set[str]:
    """Local names bound to a future: constructed, annotated as
    ``RequestFuture``, or fetched from a ``*futures*`` table."""
    names: Set[str] = set()
    node = fn.node
    if not isinstance(node, ast.Lambda):
        for arg in (node.args.posonlyargs + node.args.args
                    + node.args.kwonlyargs):
            ann = arg.annotation
            if ann is not None:
                d = dotted(ann) or (ann.value if isinstance(
                    ann, ast.Constant) else None)
                if d and str(d).split(".")[-1] in FUTURE_TYPES:
                    names.add(arg.arg)
    for stmt in fn.body_nodes():
        if not isinstance(stmt, ast.Assign):
            continue
        targets = [t.id for t in stmt.targets if isinstance(t, ast.Name)]
        if not targets:
            continue
        if _constructs_future(stmt.value):
            names.update(targets)
        elif isinstance(stmt.value, ast.Subscript):
            base = dotted(stmt.value.value)
            if base and "futures" in base.split(".")[-1]:
                names.update(targets)
    return names


def _touches(node: ast.AST, names: Set[str]) -> bool:
    return any(isinstance(n, ast.Name) and n.id in names
               for n in ast.walk(node))


def _resolves(node: ast.AST, names: Set[str]) -> bool:
    """Does this subtree call a resolver method on a held future?"""
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Call)
                and isinstance(sub.func, ast.Attribute)
                and sub.func.attr in RESOLVER_METHODS
                and isinstance(sub.func.value, ast.Name)
                and sub.func.value.id in names):
            return True
    return False


def _calls_fail_helper(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = dotted(sub.func)
            if name and name.split(".")[-1].startswith(
                    FAIL_HELPER_PREFIXES):
                return True
    return False


def _escapes(fn: FunctionInfo, names: Set[str]) -> Set[str]:
    """Subset of ``names`` that escape the function: stored into an
    attribute/subscript slot, returned, or passed as a call argument."""
    out: Set[str] = set()
    for node in fn.body_nodes():
        if isinstance(node, ast.Assign):
            if any(isinstance(t, (ast.Attribute, ast.Subscript))
                   for t in node.targets):
                out |= {n.id for n in ast.walk(node.value)
                        if isinstance(n, ast.Name) and n.id in names}
        elif isinstance(node, ast.Return) and node.value is not None:
            out |= {n.id for n in ast.walk(node.value)
                    if isinstance(n, ast.Name) and n.id in names}
        elif isinstance(node, ast.Call):
            for arg in list(node.args) + [kw.value for kw in
                                          node.keywords]:
                if isinstance(arg, ast.Name) and arg.id in names:
                    out.add(arg.id)
    return out


def check(mod: ModuleInfo, repo: RepoModel) -> List[Finding]:
    findings: List[Finding] = []
    for qual, fn in sorted(mod.functions.items()):
        if isinstance(fn.node, ast.Lambda):
            continue
        names = _future_names(fn)
        if not names:
            continue
        findings.extend(_check_constructed(mod, fn, names))
        findings.extend(_check_unwinds(mod, fn, names))
    return findings


def _check_constructed(mod: ModuleInfo, fn: FunctionInfo,
                       names: Set[str]) -> List[Finding]:
    """AV301 on futures this function itself constructs."""
    constructed: Set[str] = set()
    line_of = {}
    for stmt in fn.body_nodes():
        if isinstance(stmt, ast.Assign) and _constructs_future(stmt.value):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    constructed.add(t.id)
                    line_of[t.id] = stmt.lineno
    if not constructed:
        return []
    escaped = _escapes(fn, constructed)
    resolved = {n for n in constructed
                if _resolves(fn.node, {n})}
    leaked = sorted(constructed - escaped - resolved)
    return [Finding(
        code="AV301", checker=CHECKER, path=mod.rel,
        line=line_of[n], col=0, symbol=fn.qualname,
        message=(f"RequestFuture '{n}' is constructed but neither stored "
                 "in a futures table, returned, nor resolved — no path "
                 "can ever finish this request"))
        for n in leaked]


def _check_unwinds(mod: ModuleInfo, fn: FunctionInfo,
                   names: Set[str]) -> List[Finding]:
    """AV302 on try/except blocks that touch a held future."""
    findings: List[Finding] = []
    for node in fn.body_nodes():
        if not isinstance(node, ast.Try):
            continue
        body_touches = any(_touches(s, names) for s in node.body)
        if not body_touches:
            continue
        # a finally that resolves/delegates covers every handler
        final = ast.Module(body=node.finalbody, type_ignores=[])
        if node.finalbody and (_resolves(final, names)
                               or _calls_fail_helper(final)):
            continue
        for handler in node.handlers:
            h = ast.Module(body=handler.body, type_ignores=[])
            if (_resolves(h, names) or _calls_fail_helper(h)
                    or any(isinstance(s, ast.Raise)
                           for s in ast.walk(h))):
                continue
            findings.append(Finding(
                code="AV302", checker=CHECKER, path=mod.rel,
                line=handler.lineno, col=handler.col_offset,
                symbol=fn.qualname,
                message=("except handler swallows an exception on a path "
                         "holding a RequestFuture without resolving it, "
                         "re-raising, or calling a fail helper — the "
                         "request can leak unresolved")))
    return findings
