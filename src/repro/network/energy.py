"""Analytic compute/energy device models (DESIGN.md §4.2).

This container is CPU-only, so latency/energy numbers in the benchmarks
are *derived*, not timed: FLOPs come from analytic per-block formulas
(cross-checked against ``compiled.cost_analysis()`` in the dry-run), and
device constants below convert them to seconds / joules.

Constants:
  * Edge (paper's UAV computer): NVIDIA Jetson AGX Xavier, MODE_30W_ALL.
    Peak is ~16 TOPS fp16, but the *effective* ViT throughput implied by
    the paper's Fig. 8 (split@1 = patch-embed + 1 SAM block + CLIP ≈
    0.232 s) is ~2 TFLOP/s; average active SoC power implied by
    3.12 J / 0.232 s ≈ 13.5 W — we use 2 TFLOP/s and 15 W. With these,
    our analytic model lands within ~10% of every Fig. 8 point we can
    check (see EXPERIMENTS.md §Paper-claims).
  * Cloud/TPU target: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI (the roofline constants).
  * Radio: long-range uplink ~ 120 nJ/bit transmit energy.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ModelConfig

# --- hardware constants ---
JETSON_FLOPS = 1.28e12          # effective fp16 FLOP/s (Fig. 8 calibrated:
                                # split@1 edge latency == 0.2318 s)
JETSON_POWER_W = 15.0           # average active power in MODE_30W_ALL
TPU_V5E_FLOPS = 197e12          # bf16 FLOP/s per chip
TPU_V5E_HBM_BPS = 819e9         # bytes/s
TPU_V5E_ICI_BPS = 50e9          # bytes/s per link
TPU_V5E_POWER_W = 170.0         # nameplate per-chip power envelope
RADIO_J_PER_BIT = 120e-9


@dataclass(frozen=True)
class EdgeDevice:
    flops_per_sec: float = JETSON_FLOPS
    power_watts: float = JETSON_POWER_W

    def latency_s(self, flops: float) -> float:
        return flops / self.flops_per_sec

    def compute_energy_j(self, flops: float) -> float:
        return self.latency_s(flops) * self.power_watts

    def tx_energy_j(self, payload_bytes: float) -> float:
        return payload_bytes * 8 * RADIO_J_PER_BIT


@dataclass(frozen=True)
class CloudDevice:
    """The cloud serving chip's roofline constants (TPU v5e defaults).
    ``roofline_s`` is the lower bound a stage's measured wall time is
    compared against: max of compute-bound and bandwidth-bound time."""
    flops_per_sec: float = TPU_V5E_FLOPS
    hbm_bytes_per_sec: float = TPU_V5E_HBM_BPS
    power_watts: float = TPU_V5E_POWER_W

    def latency_s(self, flops: float) -> float:
        return flops / self.flops_per_sec

    def roofline_s(self, flops: float, hbm_bytes: float) -> float:
        return max(flops / self.flops_per_sec,
                   hbm_bytes / self.hbm_bytes_per_sec)

    def compute_energy_j(self, flops: float) -> float:
        return self.latency_s(flops) * self.power_watts


# ---------------------------------------------------------------------------
# analytic FLOPs (2 * MACs convention, matching XLA cost_analysis)
# ---------------------------------------------------------------------------


def attn_block_flops(d: int, d_ff: int, seq: int, heads: int,
                     kv_heads: int, head_dim: int, gated: bool) -> float:
    """One transformer block, full-sequence, per batch element."""
    qkvo = 2 * seq * d * (heads * head_dim + 2 * kv_heads * head_dim
                          + heads * head_dim)
    scores = 2 * seq * seq * heads * head_dim * 2   # QK^T and PV
    mlp = 2 * seq * d * d_ff * (3 if gated else 2)
    return float(qkvo + scores + mlp)


def encoder_flops(cfg: ModelConfig, seq: int, num_blocks: int = -1) -> float:
    """Encoder prefix of ``num_blocks`` blocks (-1 = all), per image."""
    n = cfg.num_layers if num_blocks < 0 else num_blocks
    return n * attn_block_flops(cfg.d_model, cfg.d_ff, seq, cfg.num_heads,
                                cfg.num_kv_heads, cfg.resolved_head_dim,
                                cfg.gated_mlp)


def bottleneck_flops(d: int, rank: int, seq: int) -> float:
    return float(2 * seq * d * rank)


def decode_token_flops(cfg: ModelConfig, ctx_len: int) -> float:
    """One autoregressive decode step (single token, KV cache of
    ``ctx_len`` attended positions), per batch row: qkvo + mlp are the
    seq=1 slice of :func:`attn_block_flops`; scores attend the full
    cached context."""
    d, heads, head_dim = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    qkvo = 2 * d * (heads * head_dim + 2 * cfg.num_kv_heads * head_dim
                    + heads * head_dim)
    scores = 2 * ctx_len * heads * head_dim * 2     # QK^T and PV
    mlp = 2 * d * cfg.d_ff * (3 if cfg.gated_mlp else 2)
    return float(cfg.num_layers * (qkvo + scores + mlp))


def decode_token_hbm_bytes(cfg: ModelConfig, ctx_len: int,
                           dtype_bytes: int = 2) -> float:
    """Dominant HBM traffic of one decode step, per batch row: the K and
    V cache reads over ``ctx_len`` positions in every layer (weight
    reads amortise over the batch; activations are tiny at seq=1)."""
    return float(2 * cfg.num_layers * ctx_len * cfg.num_kv_heads
                 * cfg.resolved_head_dim * dtype_bytes)


def patch_embed_flops(d: int, patch: int, seq: int, in_ch: int = 3) -> float:
    return float(2 * seq * patch * patch * in_ch * d)


# ---------------------------------------------------------------------------
# per-frame edge cost at a deployment geometry (used by the engine's
# profiled mission path; previously lived in runtime.mission)
# ---------------------------------------------------------------------------


def edge_insight_flops(deploy, ratio: float) -> float:
    """Edge-side FLOPs per Insight frame at the deployment geometry:
    patch embed + SAM blocks [0, k) + bottleneck encode + CLIP encoder.
    ``deploy`` is a ``LISAPipelineConfig``."""
    from repro.core import bottleneck as bn
    d = deploy.sam.d_model
    orig_bytes = 2 if deploy.sam.param_dtype == "bfloat16" else 4
    rank = bn.rank_for_ratio(d, ratio, orig_bytes)
    return (patch_embed_flops(d, deploy.patch_size, deploy.sam_tokens)
            + encoder_flops(deploy.sam, deploy.sam_tokens,
                            deploy.split_layer)
            + bottleneck_flops(d, rank, deploy.sam_tokens)
            + patch_embed_flops(deploy.clip.d_model,
                                deploy.context_patch_size, deploy.clip_tokens)
            + encoder_flops(deploy.clip, deploy.clip_tokens))


def full_edge_flops(deploy) -> float:
    """Full onboard execution of the Insight segmentation backbone."""
    d = deploy.sam.d_model
    return (patch_embed_flops(d, deploy.patch_size, deploy.sam_tokens)
            + encoder_flops(deploy.sam, deploy.sam_tokens))
