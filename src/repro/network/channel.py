"""Simulated uplink channel: serialises packet transmissions against a
bandwidth trace. Transmission of a packet occupies the link for
bytes*8 / bw(t) seconds (integrated across trace samples); the channel is
FIFO, single-flow — matching the paper's single-UAV uplink model.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.core.packets import Packet
from repro.network.traces import BandwidthTrace


@dataclass
class TransmitRecord:
    packet: Packet
    start_s: float
    end_s: float

    @property
    def latency_s(self) -> float:
        return self.end_s - self.packet.created_at


@dataclass
class Channel:
    trace: BandwidthTrace
    busy_until: float = 0.0
    log: List[TransmitRecord] = field(default_factory=list)

    def measure_bandwidth(self, t: float) -> float:
        """The controller's Sense stage reads the current estimate (the
        paper assumes an onboard bandwidth monitor)."""
        return self.trace.at(t)

    def transmit(self, packet: Packet, now: float) -> TransmitRecord:
        """Send a packet; returns the delivery record. Integrates the
        per-second trace so long transmissions see bandwidth changes."""
        t = max(now, self.busy_until)
        start = t
        remaining_bits = packet.payload_bytes * 8.0
        while remaining_bits > 0:
            bw = self.trace.at(t) * 1e6              # bits/s
            # bits transferable until the next whole-second boundary
            boundary = float(int(t) + 1)
            dt = boundary - t
            cap = bw * dt
            if cap >= remaining_bits:
                t += remaining_bits / bw
                remaining_bits = 0.0
            else:
                remaining_bits -= cap
                t = boundary
        rec = TransmitRecord(packet=packet, start_s=start, end_s=t)
        self.busy_until = t
        self.log.append(rec)
        return rec
