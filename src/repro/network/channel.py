"""Simulated uplink channel: serialises packet transmissions against a
bandwidth trace. Transmission of a packet occupies the link for
bytes*8 / bw(t) seconds (integrated across trace samples); the channel is
FIFO, single-flow — matching the paper's single-UAV uplink model.

Blackout semantics: trace samples at or below ``blackout_floor_mbps``
carry no usable capacity (disaster traces drop to zero — dividing by the
sample would blow up, and a zero tail would spin forever since
``trace.at`` clamps to the last sample). Dead air accrues instead; after
``blackout_timeout_s`` consecutive dead seconds, or when the trace is
exhausted into a dead tail, the transmission *fails deterministically*:
the record comes back with ``delivered=False`` and ``end_s`` at the
give-up time, so the control policy can defer or retry instead of
hanging the mission loop.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.core.packets import Packet
from repro.network.traces import BandwidthTrace


@dataclass
class TransmitRecord:
    packet: Packet
    start_s: float
    end_s: float
    delivered: bool = True             # False: gave up in a blackout

    @property
    def latency_s(self) -> float:
        return self.end_s - self.packet.created_at


@dataclass
class Channel:
    trace: BandwidthTrace
    busy_until: float = 0.0
    # below this rate a second is dead air (no partial progress is
    # accumulated against an effectively-down link)
    blackout_floor_mbps: float = 0.05
    # consecutive dead seconds tolerated before the transmission fails
    blackout_timeout_s: float = 30.0
    log: List[TransmitRecord] = field(default_factory=list)
    # transmit-log cap: a long mission (or a chaos storm retrying every
    # frame) must not grow the log without bound — keep the newest
    # ``max_log`` records and count the rest as dropped
    max_log: int = 4096
    n_logged: int = 0

    def measure_bandwidth(self, t: float) -> float:
        """The controller's Sense stage reads the current estimate (the
        paper assumes an onboard bandwidth monitor)."""
        return self.trace.at(t)

    def transmit(self, packet: Packet, now: float) -> TransmitRecord:
        """Send a packet; returns the delivery record. Integrates the
        per-second trace so long transmissions see bandwidth changes;
        terminates on every trace (see the module docstring's blackout
        semantics)."""
        t = max(now, self.busy_until)
        start = t
        remaining_bits = packet.payload_bytes * 8.0
        dead_s = 0.0
        while remaining_bits > 0:
            bw = self.trace.at(t) * 1e6              # bits/s
            # bits transferable until the next whole-second boundary
            boundary = float(int(t) + 1)
            dt = boundary - t
            if bw <= self.blackout_floor_mbps * 1e6:
                # dead interval: past the trace end it stays dead forever
                # (at() clamps), so fail immediately; inside the trace,
                # wait it out up to the timeout
                dead_s += dt
                t = boundary
                if (t >= self.trace.duration_s
                        or dead_s >= self.blackout_timeout_s):
                    return self._record(packet, start, t, delivered=False)
                continue
            dead_s = 0.0
            cap = bw * dt
            if cap >= remaining_bits:
                t += remaining_bits / bw
                remaining_bits = 0.0
            else:
                remaining_bits -= cap
                t = boundary
        return self._record(packet, start, t, delivered=True)

    def _record(self, packet: Packet, start: float, end: float,
                delivered: bool) -> TransmitRecord:
        """The link stays occupied through a failed attempt (the airtime
        was spent), preserving FIFO order for whatever follows."""
        rec = TransmitRecord(packet=packet, start_s=start, end_s=end,
                             delivered=delivered)
        self.busy_until = end
        self.log.append(rec)
        self.n_logged += 1
        if len(self.log) > self.max_log:
            del self.log[:len(self.log) - self.max_log]
        return rec

    @property
    def records_dropped(self) -> int:
        """Transmit records evicted by the ``max_log`` cap."""
        return self.n_logged - len(self.log)
