from repro.network.channel import Channel, TransmitRecord
from repro.network.energy import (EdgeDevice, JETSON_FLOPS, JETSON_POWER_W,
                                  RADIO_J_PER_BIT, TPU_V5E_FLOPS,
                                  TPU_V5E_HBM_BPS, TPU_V5E_ICI_BPS)
from repro.network.traces import (BandwidthTrace, constant_trace, paper_trace,
                                  random_trace)

__all__ = ["Channel", "TransmitRecord", "BandwidthTrace", "paper_trace",
           "random_trace", "constant_trace", "EdgeDevice",
           "JETSON_FLOPS", "JETSON_POWER_W", "RADIO_J_PER_BIT",
           "TPU_V5E_FLOPS", "TPU_V5E_HBM_BPS", "TPU_V5E_ICI_BPS"]
