"""Scripted bandwidth traces (paper §5.3.1).

The paper's 20-minute trace emulates a disaster environment with stable
periods, high volatility, and sustained drops, within 8–20 Mbps (uplink
proxy for degraded 5G). ``paper_trace`` reproduces that structure;
``random_trace`` generates seeded variants for property tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Tuple

import numpy as np


@dataclass(frozen=True)
class BandwidthTrace:
    """Piecewise-per-second bandwidth (Mbps)."""
    samples: np.ndarray           # (T,) one sample per second
    name: str = "trace"

    @property
    def duration_s(self) -> float:
        return float(len(self.samples))

    def at(self, t: float) -> float:
        i = min(len(self.samples) - 1, max(0, int(t)))
        return float(self.samples[i])

    def mean(self) -> float:
        return float(np.mean(self.samples))


def paper_trace(seed: int = 0, duration_s: int = 1200) -> BandwidthTrace:
    """20 minutes: stable -> volatile -> sustained drop -> recovery ->
    volatile -> stable, clipped to [8, 20] Mbps."""
    rng = np.random.RandomState(seed)
    segs: List[np.ndarray] = []

    def stable(n, level, jitter=0.4):
        return level + rng.randn(n) * jitter

    def volatile(n, lo=9.0, hi=19.5):
        # Ornstein-Uhlenbeck-ish walk with occasional jumps
        out = np.empty(n)
        x = (lo + hi) / 2
        for i in range(n):
            x += 0.25 * ((lo + hi) / 2 - x) + rng.randn() * 2.2
            if rng.rand() < 0.05:
                x = rng.uniform(lo, hi)
            out[i] = x
        return out

    def drop(n, level=8.6, jitter=0.3):
        return level + np.abs(rng.randn(n)) * jitter

    n = duration_s
    plan = [(0.20, lambda k: stable(k, 18.0)),
            (0.15, lambda k: volatile(k)),
            (0.20, lambda k: drop(k)),
            (0.10, lambda k: stable(k, 14.0, 0.8)),
            (0.20, lambda k: volatile(k)),
            (0.15, lambda k: stable(k, 17.0))]
    for frac, fn in plan:
        segs.append(fn(int(round(frac * n))))
    samples = np.concatenate(segs)[:n]
    if len(samples) < n:
        samples = np.concatenate([samples, stable(n - len(samples), 17.0)])
    return BandwidthTrace(np.clip(samples, 8.0, 20.0), name=f"paper-{seed}")


def random_trace(seed: int, duration_s: int = 300, lo: float = 8.0,
                 hi: float = 20.0) -> BandwidthTrace:
    rng = np.random.RandomState(seed)
    x = rng.uniform(lo, hi)
    out = np.empty(duration_s)
    for i in range(duration_s):
        x = np.clip(x + rng.randn() * 1.5, lo, hi)
        out[i] = x
    return BandwidthTrace(out, name=f"rand-{seed}")


def constant_trace(mbps: float, duration_s: int = 300) -> BandwidthTrace:
    return BandwidthTrace(np.full(duration_s, mbps), name=f"const-{mbps}")
