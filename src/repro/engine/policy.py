"""Control-policy plug point: how the engine picks an operating tier.

The paper's §5.3 adaptive-vs-static comparison is a policy swap, not a
``mode=`` string: every policy maps (sensed bandwidth, intent,
requirements, LUT, mission goal) to a ``TierDecision``. Three ship:

  * ``AdaptivePolicy`` — Algorithm 1 verbatim (Sense/Gate/Evaluate/
    Select via ``core.controller.select_configuration``); an empty
    feasible set yields ``tier=None, feasible=False`` (the mission
    idles that frame).
  * ``StaticTierPolicy`` — the fixed-tier baselines (High Accuracy /
    Balanced / High Throughput); never checks feasibility, matching the
    paper's static baselines that keep transmitting into a degraded
    link.
  * ``BestEffortPolicy`` — adaptive with graceful degradation (the
    fleet finding): when no tier satisfies F_I it transmits the
    lightest tier anyway, reporting ``feasible=False`` so starvation is
    still accounted.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Protocol, runtime_checkable

from repro.core.controller import (MissionGoal, NoFeasibleInsightTier,
                                   PowerConfig, select_configuration)
from repro.core.intent import Intent, IntentRequirements
from repro.core.lut import SystemLUT, Tier


@dataclass(frozen=True)
class TierDecision:
    """A policy's verdict for one request."""
    stream: str                       # "context" | "insight"
    tier: Optional[Tier]              # None: Context stream or infeasible
    feasible: bool = True             # F_I/Q_I satisfied by the choice
    throughput_pps: float = 0.0       # induced update rate f*


@runtime_checkable
class ControlPolicy(Protocol):
    def select(self, bandwidth_mbps: float, intent: Intent,
               requirements: IntentRequirements, lut: SystemLUT, *,
               goal: MissionGoal = MissionGoal.PRIORITIZE_ACCURACY,
               finetuned: bool = False) -> TierDecision:
        ...

    # Policies may additionally expose
    #   allow_speculation(stats: SpecStats, cfg: SpeculativeConfig) -> bool
    # — the engine consults it before every speculative verify step, so
    # the drafting lever rides the same Sense/Evaluate/Select loop as
    # tier selection (a policy without the hook leaves drafting on).
    #
    # Policies may also expose
    #   adapt_to_load(decision, load, lut, bandwidth_mbps) -> TierDecision
    # — scheduler feedback as a self-awareness input: ``load`` is the
    # live queue pressure (engine.scheduler's ``load()`` dict) and the
    # policy may revise its fresh decision against it, e.g. downshift
    # the Insight tier under a deep backlog so admission latency is
    # traded against per-frame fidelity. A policy without the hook (or
    # one that returns the decision unchanged) keeps Select's verdict.


def _context_decision(bandwidth_mbps: float, lut: SystemLUT) -> TierDecision:
    return TierDecision(stream="context", tier=None, feasible=True,
                        throughput_pps=lut.context.max_pps(bandwidth_mbps))


@dataclass(frozen=True)
class AdaptivePolicy:
    """Algorithm 1: adaptive tier selection under the mission goal.

    ``overload_queue_depth`` arms the scheduler-feedback loop: once the
    engine's admission queues hold at least that many requests, fresh
    Insight decisions downshift one notch toward the lightest tier
    (smaller prefill payloads clear a backlog faster). None (default)
    disables the hook — existing behavior is untouched."""
    power: PowerConfig = field(default_factory=PowerConfig)
    overload_queue_depth: Optional[int] = None

    def select(self, bandwidth_mbps, intent, requirements, lut, *,
               goal=MissionGoal.PRIORITIZE_ACCURACY,
               finetuned=False) -> TierDecision:
        if intent is not Intent.INSIGHT:
            return _context_decision(bandwidth_mbps, lut)
        try:
            sel = select_configuration(bandwidth_mbps, self.power, goal,
                                       intent, requirements, lut,
                                       finetuned=finetuned)
        except NoFeasibleInsightTier:
            return TierDecision(stream="insight", tier=None, feasible=False)
        return TierDecision(stream="insight", tier=sel.tier, feasible=True,
                            throughput_pps=sel.throughput_pps)

    def allow_speculation(self, stats, cfg) -> bool:
        """Embodied self-awareness applied to the serving substrate:
        keep drafting while the observed acceptance rate earns its keep,
        disable it once enough samples show acceptance below the
        configured floor (a draft pass below the floor costs more small-
        model steps than the verify pass saves)."""
        if stats.drafted < cfg.min_draft_samples:
            return True                   # still warming up the estimate
        return stats.acceptance_rate >= cfg.acceptance_floor

    def adapt_to_load(self, decision: TierDecision, load: dict,
                      lut: SystemLUT,
                      bandwidth_mbps: float) -> TierDecision:
        """Scheduler feedback as embodied self-awareness: under a deep
        admission backlog, trade one notch of Insight fidelity for
        faster queue clearance (the heaviest tier strictly cheaper than
        Select's pick). Context decisions and shallow queues pass
        through untouched."""
        if (self.overload_queue_depth is None
                or decision.stream != "insight" or decision.tier is None
                or load.get("queue_depth", 0) < self.overload_queue_depth):
            return decision
        cheaper = [t for t in lut.tiers
                   if t.payload_mb < decision.tier.payload_mb]
        if not cheaper:
            return decision               # already the lightest
        tier = max(cheaper, key=lambda t: t.payload_mb)
        return TierDecision(stream="insight", tier=tier,
                            feasible=decision.feasible,
                            throughput_pps=tier.max_pps(bandwidth_mbps))


@dataclass(frozen=True)
class StaticTierPolicy:
    """Fixed-tier baseline: always transmit ``tier_name`` (§5.3.1)."""
    tier_name: str

    def select(self, bandwidth_mbps, intent, requirements, lut, *,
               goal=MissionGoal.PRIORITIZE_ACCURACY,
               finetuned=False) -> TierDecision:
        if intent is not Intent.INSIGHT:
            return _context_decision(bandwidth_mbps, lut)
        tier = lut.by_name(self.tier_name)
        return TierDecision(stream="insight", tier=tier, feasible=True,
                            throughput_pps=tier.max_pps(bandwidth_mbps))

    def allow_speculation(self, stats, cfg) -> bool:
        """Static baseline: never adapts — drafting stays on no matter
        what the acceptance rate says (mirroring the fixed-tier
        baselines that keep transmitting into a degraded link)."""
        return True

    def adapt_to_load(self, decision: TierDecision, load: dict,
                      lut: SystemLUT,
                      bandwidth_mbps: float) -> TierDecision:
        """Static baseline: queue pressure changes nothing."""
        return decision


@dataclass(frozen=True)
class BestEffortPolicy:
    """Adaptive with graceful degradation: infeasible frames transmit the
    lightest tier instead of idling, flagged ``feasible=False``."""
    inner: AdaptivePolicy = field(default_factory=AdaptivePolicy)

    def select(self, bandwidth_mbps, intent, requirements, lut, *,
               goal=MissionGoal.PRIORITIZE_ACCURACY,
               finetuned=False) -> TierDecision:
        decision = self.inner.select(bandwidth_mbps, intent, requirements,
                                     lut, goal=goal, finetuned=finetuned)
        if decision.stream == "insight" and decision.tier is None:
            tier = min(lut.tiers, key=lambda t: t.payload_mb)
            return TierDecision(stream="insight", tier=tier, feasible=False,
                                throughput_pps=tier.max_pps(bandwidth_mbps))
        return decision

    def allow_speculation(self, stats, cfg) -> bool:
        return self.inner.allow_speculation(stats, cfg)

    def adapt_to_load(self, decision: TierDecision, load: dict,
                      lut: SystemLUT,
                      bandwidth_mbps: float) -> TierDecision:
        return self.inner.adapt_to_load(decision, load, lut,
                                        bandwidth_mbps)


@dataclass(frozen=True)
class RetryPolicy:
    """Fault-tolerance policy: what the engine does when an attempt
    fails (uplink blackout, packet drop, or a cloud-stage error).

    A failed attempt retries after exponential backoff, and the retry
    **re-runs Select at the retry time** — the paper's adaptation loop
    applied to faults: the self-aware controller re-senses bandwidth and
    picks a tier for the world as it is *after* the failure. With
    ``downshift=True`` the retry is additionally forced onto a strictly
    cheaper compression tier than the failed attempt's (or the lightest
    tier, if the failure already happened at the bottom): a link that
    just ate a packet gets a smaller one next, whatever the sensed
    bandwidth claims (the sense lie / stale-estimate case).

    ``max_attempts`` bounds total attempts (first try included); the
    engine additionally stops retrying once the request's deadline
    (``IntentRequirements.max_latency_s``) would pass before the retry
    even starts.
    """
    max_attempts: int = 3
    backoff_base_s: float = 0.5
    backoff_factor: float = 2.0
    downshift: bool = True

    def backoff_s(self, attempt: int) -> float:
        """Backoff before the retry following failed attempt number
        ``attempt`` (1-based: the first retry waits ``backoff_base_s``)."""
        return self.backoff_base_s * self.backoff_factor ** (attempt - 1)

    def downshifted(self, decision: TierDecision, prev_tier,
                    lut: SystemLUT, bandwidth_mbps: float) -> TierDecision:
        """Post-Select downshift enforcement for a retry: keep the fresh
        decision when it is already strictly cheaper than the failed
        attempt's tier, otherwise force the heaviest tier still cheaper
        than it (or the lightest tier overall — a retry is degraded
        service by definition, so an infeasible re-Select degrades
        rather than idles)."""
        if (not self.downshift or prev_tier is None
                or decision.stream != "insight"):
            return decision
        if (decision.tier is not None
                and decision.tier.payload_mb < prev_tier.payload_mb):
            return decision
        cheaper = [t for t in lut.tiers
                   if t.payload_mb < prev_tier.payload_mb]
        tier = (max(cheaper, key=lambda t: t.payload_mb) if cheaper
                else min(lut.tiers, key=lambda t: t.payload_mb))
        return TierDecision(
            stream="insight", tier=tier,
            feasible=decision.feasible and decision.tier is not None,
            throughput_pps=tier.max_pps(bandwidth_mbps))


def policy_from_mode(mode: str, static_tier: Optional[str] = None,
                     fallback: bool = False) -> ControlPolicy:
    """Deprecation shim: map the pre-engine ``MissionSpec`` knobs
    (``mode="avery"|"static"``, ``static_tier=``, ``fallback=``) onto the
    policy objects. New code should pass a policy directly."""
    if mode == "static":
        if static_tier is None:
            raise ValueError("mode='static' requires static_tier")
        return StaticTierPolicy(static_tier)
    if mode != "avery":
        raise ValueError(f"unknown mission mode {mode!r}")
    return BestEffortPolicy() if fallback else AdaptivePolicy()
