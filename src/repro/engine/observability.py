"""Engine observability: span tracing, a metrics registry, a flight
recorder.

The engine's latency story used to be a flat ``stats()`` dict of
lifetime counters — no way to answer "where did this Context frame's
1.74 s go?" or "what was TTFT during the blackout?". This module gives
the serving stack three instruments, all host-only (no jax — averylint
AV201 enforces it) and all on the **mission clock** (no wall-clock
reads — AV502; wall timings come from a caller-injected ``wallclock``):

  * :class:`Tracer` — per-request lifecycle spans
    (``edge_encode -> transmit -> queue -> prefill|prefix_hit ->
    decode``, segmented across preemptions) plus point events
    (``decode_step``/``verify_step``, ``park``/``resume``, ``retry``,
    ``blackout``, ``cancelled``, ...), exportable as Chrome/Perfetto
    ``trace_event`` JSON (one track per operator, one per decode slot).
    Disabled (the default) every hook is a single attribute check; the
    engine guards each call site with ``if tracer.enabled`` so an
    untraced serve records nothing and allocates nothing.
  * :class:`MetricsRegistry` — typed :class:`Counter` / :class:`Gauge` /
    :class:`Histogram`. Histograms use fixed log-spaced buckets with
    percentile estimates read off the bucket edges: O(1) observe, O(1)
    memory, no unbounded sample lists (AV602's whole point).
  * :class:`FlightRecorder` — a bounded ring of the last N engine
    events that dumps to JSON when something dies (``CloudStageError``
    exhausting retries, a deadline cancellation, a ``PagePool``
    invariant failure, a ``RecompileBudgetError``), so chaos-harness
    failures become diagnosable artifacts instead of bare asserts.

``validate_trace`` / ``validate_chrome_trace`` check the span-model
invariants (ordered, non-overlapping phase spans; park/resume pairing;
cancel events terminal) — tests and the ci_fast trace smoke run them
against live tracers and dumped artifacts alike.
"""
from __future__ import annotations

import json
import math
import os
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

# phase spans a request may record, in lifecycle order (validation
# vocabulary; point events are open-ended)
PHASE_SPANS = ("edge_encode", "transmit", "queue", "prefill",
               "prefix_hit", "decode")
# Chrome-export track families: pid 1 = operators, pid 2 = decode
# slots, pid 3 = device stages (the StageProfiler's wall-clock view)
DEVICE_TRACK_PID = 3
_EPS = 1e-9


# ---------------------------------------------------------------------------
# span tracing
# ---------------------------------------------------------------------------


@dataclass
class Span:
    """One interval (or instant, ``t0 == t1``) on a request's timeline,
    in mission seconds."""
    name: str
    t0: float
    t1: float
    slot: Optional[int] = None        # decode slot, when bound to one
    args: Dict[str, Any] = field(default_factory=dict)


@dataclass
class RequestTrace:
    """Everything recorded for one request: phase spans (non-
    overlapping lifecycle intervals) and point events (instants)."""
    request_id: int
    operator_id: str = ""
    intent: str = ""
    t_begin: float = 0.0
    spans: List[Span] = field(default_factory=list)
    points: List[Span] = field(default_factory=list)
    dropped: int = 0                  # events shed past the per-trace cap


class Tracer:
    """Near-zero-overhead span recorder keyed by request id.

    ``enabled=False`` (the default) makes every method an immediate
    return; call sites on hot paths additionally guard with
    ``if tracer.enabled`` so a disabled tracer costs one branch and
    leaves zero residue. ``max_requests`` caps live traces (oldest
    evicted first); ``max_events`` caps spans+points per trace.
    """

    def __init__(self, enabled: bool = False, max_requests: int = 4096,
                 max_events: int = 512):
        self.enabled = bool(enabled)
        self.max_requests = int(max_requests)
        self.max_events = int(max_events)
        self._traces: Dict[int, RequestTrace] = {}
        self.n_evicted = 0

    def __len__(self) -> int:
        return len(self._traces)

    def clear(self) -> None:
        self._traces = {}
        self.n_evicted = 0

    def _get(self, rid: int) -> RequestTrace:
        tr = self._traces.get(rid)
        if tr is None:
            tr = self._traces[rid] = RequestTrace(request_id=int(rid))
            if len(self._traces) > self.max_requests:
                oldest = next(iter(self._traces))
                del self._traces[oldest]
                self.n_evicted += 1
        return tr

    def begin(self, rid: int, operator_id: str = "", intent: str = "",
              t: float = 0.0) -> None:
        """Open a trace at submission time (idempotent)."""
        if not self.enabled:
            return
        tr = self._get(rid)
        tr.operator_id = operator_id
        tr.intent = str(intent)
        tr.t_begin = t

    def span(self, rid: int, name: str, t0: float, t1: float,
             slot: Optional[int] = None, **args: Any) -> None:
        """Record one phase span ``[t0, t1]``."""
        if not self.enabled:
            return
        tr = self._get(rid)
        if len(tr.spans) + len(tr.points) >= self.max_events:
            tr.dropped += 1
            return
        tr.spans.append(Span(name, t0, t1, slot=slot, args=args))

    def point(self, rid: int, name: str, t: float,
              slot: Optional[int] = None, **args: Any) -> None:
        """Record one instant event at ``t``."""
        if not self.enabled:
            return
        tr = self._get(rid)
        if len(tr.spans) + len(tr.points) >= self.max_events:
            tr.dropped += 1
            return
        tr.points.append(Span(name, t, t, slot=slot, args=args))

    def trace(self, rid: int) -> Optional[RequestTrace]:
        return self._traces.get(rid)

    def traces(self) -> List[RequestTrace]:
        return list(self._traces.values())

    # -- Chrome/Perfetto trace_event export --

    def to_chrome(self) -> Dict[str, Any]:
        """Export every trace as a Chrome ``trace_event`` JSON document
        (open in Perfetto / ``chrome://tracing``). Track layout: pid 1
        holds one thread per operator (the request-lifecycle view),
        pid 2 one thread per decode slot (the batch-residency view).
        Timestamps are mission seconds scaled to microseconds."""
        events: List[Dict[str, Any]] = []
        operators: Dict[str, int] = {}
        slots: Dict[int, int] = {}
        for tr in self._traces.values():
            op = tr.operator_id or "?"
            tid = operators.setdefault(op, len(operators) + 1)
            for sp in tr.spans:
                events.append(_chrome_span(sp, tr, pid=1, tid=tid,
                                           ph="X"))
                if sp.slot is not None:
                    stid = slots.setdefault(sp.slot, sp.slot + 1)
                    events.append(_chrome_span(sp, tr, pid=2, tid=stid,
                                               ph="X"))
            for pt in tr.points:
                events.append(_chrome_span(pt, tr, pid=1, tid=tid,
                                           ph="i"))
                if pt.slot is not None:
                    stid = slots.setdefault(pt.slot, pt.slot + 1)
                    events.append(_chrome_span(pt, tr, pid=2, tid=stid,
                                               ph="i"))
        meta = [
            {"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
             "args": {"name": "operators"}},
            {"ph": "M", "name": "process_name", "pid": 2, "tid": 0,
             "args": {"name": "decode slots"}},
        ]
        for op in sorted(operators):
            meta.append({"ph": "M", "name": "thread_name", "pid": 1,
                         "tid": operators[op], "args": {"name": op}})
        for s in sorted(slots):
            meta.append({"ph": "M", "name": "thread_name", "pid": 2,
                         "tid": slots[s], "args": {"name": f"slot {s}"}})
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def dump(self, path: str) -> str:
        doc = self.to_chrome()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f)
        return path


def _chrome_span(sp: Span, tr: RequestTrace, pid: int, tid: int,
                 ph: str) -> Dict[str, Any]:
    args = {"rid": tr.request_id, "intent": tr.intent}
    args.update(sp.args)
    ev: Dict[str, Any] = {"name": sp.name, "cat": "phase" if ph == "X"
                          else "event", "ph": ph, "pid": pid, "tid": tid,
                          "ts": sp.t0 * 1e6, "args": args}
    if ph == "X":
        ev["dur"] = max(0.0, sp.t1 - sp.t0) * 1e6
    else:
        ev["s"] = "t"
    return ev


# ---------------------------------------------------------------------------
# trace validation (the span-model invariants)
# ---------------------------------------------------------------------------


def validate_trace(tr: RequestTrace) -> List[str]:
    """Check one trace against the span-model invariants. Returns a
    list of problem descriptions (empty = valid):

      * every span has ``t1 >= t0``;
      * phase spans are recorded in monotonically ordered, non-
        overlapping lifecycle order (``next.t0 >= prev.t1``);
      * phase-span names come from :data:`PHASE_SPANS`;
      * ``resume`` events never outnumber ``park`` events, and a served
        request's parks are all resumed;
      * a ``cancelled`` point, if present, is the trace's last point.
    """
    problems: List[str] = []
    rid = tr.request_id
    prev: Optional[Span] = None
    for sp in tr.spans:
        if sp.name not in PHASE_SPANS:
            problems.append(f"rid {rid}: unknown phase span {sp.name!r}")
        if sp.t1 < sp.t0 - _EPS:
            problems.append(
                f"rid {rid}: span {sp.name} ends before it starts "
                f"({sp.t0} -> {sp.t1})")
        if prev is not None and sp.t0 < prev.t1 - _EPS:
            problems.append(
                f"rid {rid}: span {sp.name}@{sp.t0} overlaps "
                f"{prev.name} ending {prev.t1}")
        prev = sp
    kinds = [pt.name for pt in tr.points]
    n_park = kinds.count("park")
    n_resume = kinds.count("resume")
    if n_resume > n_park:
        problems.append(
            f"rid {rid}: {n_resume} resumes for {n_park} parks")
    if "served" in kinds and n_park != n_resume:
        problems.append(
            f"rid {rid}: served with {n_park} parks but "
            f"{n_resume} resumes")
    if "cancelled" in kinds and kinds[-1] != "cancelled":
        problems.append(
            f"rid {rid}: events continue after the cancel "
            f"(last is {kinds[-1]!r})")
    return problems


def validate_traces(tracer: Tracer) -> List[str]:
    problems: List[str] = []
    for tr in tracer.traces():
        problems.extend(validate_trace(tr))
    return problems


def validate_chrome_trace(doc: Dict[str, Any]) -> List[str]:
    """Validate a dumped ``trace_event`` document: rebuild each
    request's trace from the operator-track events (every event carries
    its ``rid``) and run :func:`validate_trace` over it."""
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["not a trace_event document (no traceEvents list)"]
    rebuilt: Dict[int, RequestTrace] = {}
    for ev in events:
        ph = ev.get("ph")
        if ph in ("X", "i") and ev.get("pid") == DEVICE_TRACK_PID:
            # device-stage events are batch-level (no rid); check the
            # timeline shape instead of the request lifecycle
            if not isinstance(ev.get("ts"), (int, float)):
                return [f"device event {ev.get('name')!r} has no "
                        f"numeric ts"]
            if ph == "X" and float(ev.get("dur", 0.0)) < 0.0:
                return [f"device span {ev.get('name')!r} has negative "
                        f"dur"]
            continue
        if ph not in ("X", "i") or ev.get("pid") != 1:
            continue
        rid = ev.get("args", {}).get("rid")
        if rid is None:
            return [f"event {ev.get('name')!r} carries no args.rid"]
        tr = rebuilt.setdefault(int(rid), RequestTrace(request_id=rid))
        t0 = float(ev["ts"]) / 1e6
        if ph == "X":
            tr.spans.append(Span(ev["name"], t0,
                                 t0 + float(ev.get("dur", 0.0)) / 1e6))
        else:
            tr.points.append(Span(ev["name"], t0, t0))
    if not rebuilt:
        return ["trace holds no request events"]
    problems: List[str] = []
    for rid in sorted(rebuilt):
        problems.extend(validate_trace(rebuilt[rid]))
    return problems


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class Counter:
    """Monotone event count."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-written level (queue depth, live slots)."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed log-bucket latency histogram: O(1) observe, O(1) memory,
    percentiles estimated from bucket upper edges and clamped to the
    observed [min, max] (exact at the extremes, one-bucket-resolution
    in between). Buckets span ``[lo, hi)`` with ``per_decade`` buckets
    per decade, plus an underflow and an overflow bucket."""

    def __init__(self, name: str, lo: float = 1e-4, hi: float = 1e4,
                 per_decade: int = 8):
        if lo <= 0 or hi <= lo:
            raise ValueError(f"need 0 < lo < hi, got [{lo}, {hi}]")
        self.name = name
        self.lo = float(lo)
        self.per_decade = int(per_decade)
        n = int(math.ceil(math.log10(hi / lo) * per_decade))
        # bucket i (1-indexed) holds values in (edge[i-1], edge[i]]
        self.edges = [lo * 10.0 ** (i / per_decade)
                      for i in range(1, n + 1)]
        self.counts = [0] * (n + 2)   # [underflow, buckets..., overflow]
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v <= self.lo:
            self.counts[0] += 1
            return
        idx = int(math.log10(v / self.lo) * self.per_decade) + 1
        if idx > len(self.edges):
            idx = len(self.edges) + 1
        elif v > self.edges[idx - 1]:   # float fuzz at a bucket edge
            idx += 1
        self.counts[idx] += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Estimate the q-th percentile (0..100) from the bucket edges;
        0.0 on an empty histogram."""
        if not self.count:
            return 0.0
        target = max(1, int(math.ceil(self.count * q / 100.0)))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= target:
                if i == 0:
                    edge = self.lo
                elif i > len(self.edges):
                    edge = self.vmax
                else:
                    edge = self.edges[i - 1]
                return min(max(edge, self.vmin), self.vmax)
        return self.vmax

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    def as_dict(self) -> Dict[str, float]:
        return {"count": self.count, "mean": self.mean,
                "p50": self.p50, "p95": self.p95, "p99": self.p99,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0}

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other``'s observations into this histogram (bucket
        counts add; min/max widen). Both must share the same bucket
        geometry — merging is what aggregates per-shard or per-decoder
        histograms, and mismatched edges would silently misbin."""
        if (self.lo != other.lo or self.per_decade != other.per_decade
                or len(self.edges) != len(other.edges)):
            raise ValueError(
                f"histogram geometry mismatch: {self.name} "
                f"[lo={self.lo}, n={len(self.edges)}, "
                f"per_decade={self.per_decade}] vs {other.name} "
                f"[lo={other.lo}, n={len(other.edges)}, "
                f"per_decade={other.per_decade}]")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self


class MetricsRegistry:
    """Name-keyed instrument store. Instruments are created on first
    touch and live for the registry's lifetime; names use a
    ``base[:label]`` convention (``ttft_s:latency``,
    ``transmit_s:tier=Balanced``)."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, lo: float = 1e-4, hi: float = 1e4,
                  per_decade: int = 8) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(
                name, lo=lo, hi=hi, per_decade=per_decade)
        return h

    def as_dict(self) -> Dict[str, float]:
        """Flat snapshot of every instrument — the full surface,
        including dynamically labelled histograms (per tier, per
        operator) that ``AveryEngine.stats`` keeps out of its fixed key
        set."""
        out: Dict[str, float] = {}
        for name in sorted(self._counters):
            out[name] = self._counters[name].value
        for name in sorted(self._gauges):
            out[name] = self._gauges[name].value
        for name in sorted(self._histograms):
            for k, v in self._histograms[name].as_dict().items():
                out[f"{name}/{k}"] = v
        return out


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------


class FlightRecorder:
    """Bounded ring of the engine's last ``capacity`` events. Always
    cheap enough to leave on (a deque append per event); ``dump``
    writes the ring plus context to JSON. With ``autodump_dir`` set the
    engine dumps automatically when a request dies hard (terminal cloud
    error, deadline cancellation) or an invariant trips (page-pool
    audit, recompile budget); dump filenames are derived from the dump
    counter, not the wall clock (mission replay stays deterministic)."""

    def __init__(self, capacity: int = 256,
                 autodump_dir: Optional[str] = None):
        self.capacity = int(capacity)
        self.autodump_dir = autodump_dir
        self._ring: deque = deque(maxlen=self.capacity)
        self.n_recorded = 0
        self.n_dumps = 0
        self.last_dump: Optional[str] = None

    def __len__(self) -> int:
        return len(self._ring)

    def record(self, kind: str, t: float, request_id: int = -1,
               data: Optional[Dict[str, Any]] = None) -> None:
        self._ring.append({"kind": kind, "t": t, "rid": request_id,
                           "data": data or {}})
        self.n_recorded += 1

    def snapshot(self) -> List[Dict[str, Any]]:
        return list(self._ring)

    def clear(self) -> None:
        self._ring.clear()

    def dump(self, reason: str, path: Optional[str] = None,
             stats: Optional[Dict[str, Any]] = None) -> Optional[str]:
        """Write the ring to ``path`` (or, when None, to
        ``autodump_dir/flight_<n>_<reason>.json``; no-op without a
        directory). Returns the written path."""
        if path is None:
            if self.autodump_dir is None:
                return None
            path = os.path.join(self.autodump_dir,
                                f"flight_{self.n_dumps:03d}_{reason}.json")
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        doc = {"reason": reason, "n_recorded": self.n_recorded,
               "capacity": self.capacity, "events": self.snapshot(),
               "stats": _jsonable(stats or {})}
        with open(path, "w") as f:
            json.dump(doc, f, default=str)
        self.n_dumps += 1
        self.last_dump = path
        return path


def _jsonable(d: Dict[str, Any]) -> Dict[str, Any]:
    return {str(k): (v if isinstance(v, (int, float, str, bool,
                                         type(None))) else str(v))
            for k, v in d.items()}
