"""Speculative decoding for the Insight path: Context-stream drafts,
paged multi-token verification.

AVERY's dual-stream design keeps a small, high-frequency Context model
warm next to the large Insight model. Speculative decoding turns that
asymmetry into serving throughput: the small model *drafts* k candidate
answer tokens autoregressively, and the serving model *verifies* all of
them (plus the row's last accepted token) in one paged multi-token pass
(``vlm.llm_verify_step_paged`` over the shared page pool). Under greedy
decoding, a draft token is accepted iff it equals the serving model's
own greedy continuation at that position, so the emitted stream is
token-exact with ``llm_generate`` — acceptance only changes how many
serving-model passes the answer costs, never its content.

Per verify round a row emits between 1 token (first draft rejected: the
serving model's correction) and min(k+1, tokens remaining) tokens (all
drafts accepted + one bonus from the final logits). The draft model
rides a per-slot contiguous ring cache and needs **no rollback**:
rejected draft writes sit at positions ahead of the committed stream,
the position mask hides them, and the real token at that position
overwrites the slot when it is eventually fed. The *paged* serving
cache does roll back — ``PagePool.rollback_to`` frees decode pages past
the accepted length after every round (``core.paging``).

The acceptance rate is a self-awareness signal: ``SpecStats`` feeds the
engine's ``ControlPolicy`` (``AdaptivePolicy.allow_speculation``), which
disables drafting when acceptance falls below a floor — the same
embodied Sense/Evaluate/Select loop the paper applies to tier
selection, applied to the serving substrate itself.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import vlm


@functools.lru_cache(maxsize=None)
def _draft_fns(pcfg, width: int):
    """Jitted draft-model stages, cached per (config, cache width) at
    module level: decoders retire on ``engine.drain()`` and their
    ``DraftModel``s with them — fresh ``jax.jit`` wrappers would
    recompile the (unchanged) draft stages on every burst. Configs are
    frozen dataclasses, so they key the cache directly; params ride in
    as arguments and never retrigger compilation."""
    prefill = jax.jit(
        lambda p, c, q: vlm.llm_prefill(p, pcfg, c, q, width=width))
    step = jax.jit(
        lambda p, ca, t, pos: vlm.llm_decode_step(p, pcfg, ca, t, pos))
    insert = jax.jit(DraftModel._insert_row)
    return prefill, step, insert


@dataclass(frozen=True)
class SpeculativeConfig:
    """Knobs of the speculative-decoding subsystem (the engine's
    ``speculative=`` argument accepts one of these, ``True`` for the
    defaults, or an int for ``draft_tokens``)."""
    draft_tokens: int = 3          # k: drafts proposed per verify round
    # drafting disables when cumulative acceptance falls below the floor
    # (after min_draft_samples drafted tokens) — the policy hook
    # ``ControlPolicy.allow_speculation`` applies these
    acceptance_floor: float = 0.35
    min_draft_samples: int = 16
    # draft model override: defaults to the target's own (warm) Context-
    # stream LLM — lisa_mini geometry, shared weights, so drafts are
    # free-of-divergence; plug a distinct small LM via these two
    draft_params: Optional[dict] = None
    draft_pcfg: Optional[Any] = None

    def __post_init__(self):
        if self.draft_tokens < 1:
            raise ValueError(
                f"draft_tokens must be >= 1, got {self.draft_tokens}")


@dataclass
class SpecStats:
    """Cumulative speculation telemetry (one per decoder; the engine
    aggregates across decoders). ``acceptance_rate`` is the self-
    awareness signal the control policy gates drafting on."""
    drafted: int = 0            # draft tokens submitted to verification
    accepted: int = 0           # draft tokens the serving model agreed with
    emitted: int = 0            # tokens emitted by drafting rows
    row_steps: int = 0          # (row, verify-step) pairs that drafted
    disabled_steps: int = 0     # steps the policy vetoed drafting on
    pages_rolled_back: int = 0  # KV pages freed by speculative rollback

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / self.drafted if self.drafted else 0.0

    @property
    def tokens_per_step(self) -> float:
        """Mean tokens emitted per drafting row per verify step — 1.0 is
        the plain-decode floor; k+1 the full-acceptance ceiling."""
        return self.emitted / self.row_steps if self.row_steps else 0.0

    def note_chunk(self, drafted: int, accepted: int, emitted: int,
                   metrics: Optional[Any] = None) -> None:
        """Fold one drafting row's verify-chunk outcome in; with a
        ``MetricsRegistry`` attached the per-chunk acceptance fraction
        also feeds the ``spec_accept_rate`` histogram (the registry's
        view of the same self-awareness signal the policy gates on)."""
        self.drafted += drafted
        self.accepted += accepted
        self.emitted += emitted
        self.row_steps += 1
        if metrics is not None and drafted:
            metrics.histogram("spec_accept_rate").observe(
                accepted / drafted)

    def merge(self, other: "SpecStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))

    def as_dict(self) -> Dict[str, float]:
        return {
            "spec_drafted": self.drafted,
            "spec_accepted": self.accepted,
            "spec_acceptance_rate": self.acceptance_rate,
            "spec_tokens_per_step": self.tokens_per_step,
            "spec_disabled_steps": self.disabled_steps,
            "spec_pages_rolled_back": self.pages_rolled_back,
        }


def greedy_accept(drafts: Sequence[int], greedy: Sequence[int]
                  ) -> int:
    """Greedy acceptance rule: number of leading draft tokens that equal
    the serving model's own greedy continuation at their position
    (``greedy[i]`` = argmax of the verify logits after chunk token i, so
    draft i+1 is accepted iff it equals ``greedy[i]``)."""
    m = 0
    while m < len(drafts) and int(drafts[m]) == int(greedy[m]):
        m += 1
    return m


class DraftModel:
    """The Context-stream draft model, batched over the in-flight slots.

    Wraps a lisa_mini-geometry LM (by default the target's own LLM
    weights — the warm Context model) behind the contiguous
    prefill/decode path: ``admit`` prefills a slot's ``[ctx; query]``
    prefix into its row of a ``(slots, width)`` ring cache, ``draft``
    runs lockstep batched single-token steps (per-row positions) that
    catch up on newly committed tokens and then self-feed k proposals.

    No rollback is needed here: a rejected draft's k/v sits at a
    position ahead of the committed stream, the position mask hides it
    from every later step, and the slot is overwritten when the real
    token at that position is fed. Idle rows park their step on the
    reserved last ring slot (``width - 1``), which no real position ever
    maps to.
    """

    def __init__(self, params: dict, pcfg: Any, *, slots: int,
                 prefix_len: int, max_new_tokens: int, draft_tokens: int,
                 flash_decode: bool = False,
                 prefix_rows: Optional[Dict[Any, Dict]] = None,
                 prefix_cap: Optional[int] = None,
                 fns_factory: Optional[Any] = None):
        self.pcfg = dataclasses.replace(
            pcfg, llm=pcfg.llm.replace(use_flash_decode=flash_decode))
        self.params = params
        self.slots = int(slots)
        self.prefix_len = int(prefix_len)
        # widest real position: catching up tokens[: T] then self-feeding
        # k-1 drafts reaches prefix + T + k - 2; slot width-1 is the park
        self.width = self.prefix_len + int(max_new_tokens) \
            + int(draft_tokens)
        self.park_pos = self.width - 1
        self.cache: Optional[Dict] = None
        # emitted (target-committed) tokens each row has consumed
        self.fed = np.zeros((self.slots,), np.int64)
        self.n_steps = 0           # batched draft decode steps (telemetry)
        self.n_prefills = 0
        # prefilled [ctx; query] cache rows keyed like the target's
        # prefix store, so repeat-prefix admissions skip the draft
        # prefill too (LRU-capped: entries are one (1, width) ring
        # each). The dict may be shared across decoders — the engine
        # passes one per engine, next to its kv_pool, so the rows
        # survive decoder retirement like the target's prefix pages do;
        # entries are namespaced by ring width so mixed-qlen decoders
        # can't hand each other wrong-shaped rows.
        self._prefix_rows: Dict[Any, Dict] = (
            prefix_rows if prefix_rows is not None else {})
        self._prefix_cap = (prefix_cap if prefix_cap is not None
                            else 2 * self.slots)
        # ``fns_factory`` (sharded serving): the engine's serving
        # context supplies jitted prefill/step/insert with explicit
        # mesh shardings (``ShardedServingContext.draft_fns``); the
        # default is the module-level jit cache, which survives decoder
        # retirement the same way
        if fns_factory is not None:
            self._prefill, self._step, self._insert = fns_factory(
                self.pcfg, self.width, self.params)
        else:
            self._prefill, self._step, self._insert = _draft_fns(self.pcfg,
                                                                 self.width)

    @staticmethod
    def _insert_row(dst: Dict, src: Dict, row) -> Dict:
        """Scatter a 1-row prefill cache into row ``row`` of the slot
        cache: kv leaves (L, B, W, ...) at axis 1, positions (B, W)."""
        return {
            "groups": jax.tree.map(lambda d, s: d.at[:, row].set(s[:, 0]),
                                   dst["groups"], src["groups"]),
            "positions": dst["positions"].at[row].set(src["positions"][0]),
        }

    def admit(self, row: int, ctx, query, key: Any = None) -> None:
        """Prefill one slot's ``[ctx; query]`` prefix into its cache row.
        ``key`` (the target prefix store's (operator, digest) key) lets
        repeat-prefix admissions reuse the stored prefill row instead of
        re-running the draft prefill — the draft-side analogue of the
        page pool's prefix sharing (here by copy, since the ring cache
        is per-row mutable)."""
        skey = (key, self.width) if key is not None else None
        row_cache = self._prefix_rows.get(skey) if skey is not None else None
        if row_cache is None:
            ctx = jnp.asarray(ctx)
            if ctx.shape[-1] != self.pcfg.llm.d_model:
                raise ValueError(
                    f"draft model width {self.pcfg.llm.d_model} does not "
                    f"match context features {ctx.shape[-1]}")
            _, _, row_cache = self._prefill(self.params, ctx,
                                            jnp.asarray(query))
            self.n_prefills += 1
            if skey is not None:
                self._prefix_rows[skey] = row_cache
                while len(self._prefix_rows) > self._prefix_cap:
                    self._prefix_rows.pop(next(iter(self._prefix_rows)))
        else:                          # refresh recency
            self._prefix_rows[skey] = self._prefix_rows.pop(skey)
        if self.cache is None:
            self.cache = jax.tree.map(
                lambda a: jnp.zeros((a.shape[0], self.slots)
                                    + a.shape[2:], a.dtype),
                row_cache["groups"])
            self.cache = {
                "groups": self.cache,
                "positions": jnp.full((self.slots, self.width), -1,
                                      jnp.int32),
            }
        self.cache = self._insert(self.cache, row_cache,
                                  jnp.int32(row))
        self.fed[row] = 0

    def release(self, row: int) -> None:
        self.fed[row] = 0          # admit() re-prefills the row wholesale

    def commit(self, row: int, n_fed: int) -> None:
        """Mark emitted tokens up to ``n_fed`` as already consumed: an
        accepted draft's k/v sits in this cache at exactly the position
        the committed token occupies (same token, same position — it
        *was* the draft), so the next round needn't re-feed it. Only
        moves forward; the rejected tail is left to the position mask."""
        self.fed[row] = max(self.fed[row], n_fed)

    def draft(self, jobs: Dict[int, List[int]], k: int,
              budgets: Optional[Dict[int, int]] = None
              ) -> Dict[int, List[int]]:
        """One drafting round: for each row in ``jobs`` (row -> emitted
        token list), feed the emitted tokens it hasn't consumed yet,
        then self-feed until the row's proposal budget is collected
        (``budgets[row]``, default k — callers cap it by the tokens the
        verify step can still use, so answer tails don't burn draft
        steps on discarded proposals). All rows advance in lockstep
        batched decode steps; rows that finish early (or aren't
        drafting) park on the reserved slot. Returns row -> proposed
        tokens."""
        if not jobs:
            return {}
        want = {r: min(k, (budgets or {}).get(r, k)) for r in jobs}
        queue = {r: list(toks[int(self.fed[r]):]) for r, toks in
                 jobs.items()}
        for r, pend in queue.items():
            assert pend, f"row {r} has no unfed committed token"
        pos_next = {r: self.prefix_len + int(self.fed[r]) for r in jobs}
        drafts: Dict[int, List[int]] = {r: [] for r in jobs}
        while any(len(drafts[r]) < want[r] for r in jobs):
            toks = np.zeros((self.slots, 1), np.int32)
            pos = np.full((self.slots,), self.park_pos, np.int32)
            feeding = []
            for r in jobs:
                if len(drafts[r]) >= want[r]:
                    continue
                t = queue[r].pop(0) if queue[r] else drafts[r][-1]
                toks[r, 0] = t
                pos[r] = pos_next[r]
                pos_next[r] += 1
                feeding.append(r)
            logits, _, self.cache = self._step(self.params, self.cache,
                                               jnp.asarray(toks),
                                               jnp.asarray(pos))
            logits = np.asarray(logits)
            self.n_steps += 1
            for r in feeding:
                if not queue[r]:       # fed the stream tail or a draft
                    drafts[r].append(int(np.argmax(logits[r])))
        for r, toks_ in jobs.items():
            self.fed[r] = len(toks_)
        return drafts
