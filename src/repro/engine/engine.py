"""`AveryEngine` — the one front door to the AVERY system.

Before the engine, every entry point (`launch/serve.py`,
`runtime/mission.py`, `runtime/fleet.py`, each benchmark) hand-wired its
own executor + controller + channel + scheduler loop. The engine owns
that wiring once:

    engine  = AveryEngine(lut=lut, executor=execu,
                          transport=ChannelTransport.from_trace(trace),
                          policy=AdaptivePolicy())
    session = engine.session("operator-0")
    future  = session.submit(prompt="segment the stranded person",
                             images=frame, query=query, time_s=t)
    ...
    response = future.result()          # drives the engine to completion

Per submission the engine runs the paper's full per-frame pipeline:
Sense (``Transport.bandwidth``), Gate (intent classification), Evaluate/
Select (``ControlPolicy``), edge compute (executor stages or the
analytic Jetson model), packet transmission (``Transport.send``), and
cloud serving — either closed tier-bucketed microbatches
(``MicrobatchScheduler``) or the token-level in-flight batch
(``InflightDecoder``), where a request submitted mid-decode joins the
running batch between steps.

``OperatorSession`` carries per-operator context: mission goal, intent
requirements, prompt history, an optional per-UAV transport/policy
override (the fleet runtime gives every UAV its own bandwidth share
this way), and the fidelity oracle for profiled missions.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core import packets as pk
from repro.core.controller import MissionGoal
from repro.core.intent import (DEFAULT_REQUIREMENTS, Intent,
                               IntentRequirements, classify_intent)
from repro.core.lut import SystemLUT
from repro.core.paging import PagePool
from repro.engine.api import Request, RequestFuture, Response
from repro.engine.inflight import InflightDecoder
from repro.engine.observability import (FlightRecorder, MetricsRegistry,
                                        Tracer)
from repro.engine.policy import (AdaptivePolicy, ControlPolicy, RetryPolicy,
                                 TierDecision)
from repro.engine.scheduler import QOS_CLASSES, FifoScheduler, qos_class
from repro.engine.speculative import SpecStats, SpeculativeConfig
from repro.engine.transport import LoopbackTransport, Transport
from repro.network.energy import EdgeDevice, edge_insight_flops

BATCHING_MODES = ("microbatch", "generate", "inflight")

# registry keys of the engine's terminal/telemetry counters; the
# ``stats()`` names and the legacy ``n_*`` attribute surface both read
# through these (see the properties on AveryEngine)
_COUNTER_KEYS = ("submitted", "completed", "infeasible", "blackouts",
                 "deadline_cancelled", "cloud_errors", "rejected",
                 "starved", "retries", "downshifts", "load_downshifts")


@dataclass
class OperatorSession:
    """Per-operator (or per-UAV) context riding on a shared engine."""
    engine: "AveryEngine"
    operator_id: str
    goal: MissionGoal = MissionGoal.PRIORITIZE_ACCURACY
    finetuned: bool = False
    requirements: Dict[Intent, IntentRequirements] = field(
        default_factory=lambda: dict(DEFAULT_REQUIREMENTS))
    # per-session overrides of the engine-level plug points (fleet: one
    # uplink share and one controller per UAV)
    transport: Optional[Transport] = None
    policy: Optional[ControlPolicy] = None
    oracle: Optional[Any] = None       # FidelityOracle for profiled frames
    # scheduling priority for every request on this session (a command
    # post outranks routine UAV telemetry); per-request override wins
    priority: int = 0
    history: List[tuple] = field(default_factory=list)

    def classify(self, prompt: str) -> Intent:
        return classify_intent(prompt)

    def submit(self, prompt: str = "", images: Any = None,
               query: Optional[np.ndarray] = None, time_s: float = 0.0,
               intent: Optional[Intent] = None,
               priority: Optional[int] = None) -> RequestFuture:
        """Full serving path: edge inference -> transport -> cloud batch."""
        return self.engine.submit(
            Request(prompt=prompt, intent=intent, images=images, query=query,
                    time_s=time_s,
                    priority=self.priority if priority is None
                    else int(priority)), self)

    def submit_frame(self, t: float,
                     intent: Intent = Intent.INSIGHT) -> Response:
        """Profiled mission frame: analytic edge model + LUT/oracle
        fidelity instead of device inference (the §5.3 simulator path)."""
        return self.engine.submit_frame(self, t, intent=intent)

    def close(self) -> int:
        """End this operator's mission: release their cached prefix
        pages from the engine's KV pool. Returns the number of prefix
        entries freed."""
        return self.engine.release_prefixes(self.operator_id)


class AveryEngine:
    """Owns the executor, LUT, scheduler/in-flight decoder, transports,
    policies, and telemetry; all entry points drive it, none wire it."""

    def __init__(self, lut: SystemLUT, executor: Any = None, *,
                 transport: Optional[Transport] = None,
                 policy: Optional[ControlPolicy] = None,
                 max_batch: int = 8, batching: str = "microbatch",
                 deploy: Any = None, edge_device: Optional[EdgeDevice] = None,
                 share_prefixes: bool = True,
                 kv_pages: Optional[int] = None,
                 max_prefixes: Optional[int] = None,
                 speculative: Any = None,
                 mesh: Any = None,
                 retry: Optional[RetryPolicy] = None,
                 scheduler: Any = None,
                 debug_invariants: bool = False,
                 debug_recompiles: bool = False,
                 debug_transfers: bool = False,
                 trace: Any = False,
                 flight_events: int = 256,
                 flight_dir: Optional[str] = None,
                 wallclock: Optional[Callable[[], float]] = None,
                 profile: Any = False):
        """``speculative`` (in-flight batching only): ``True`` enables
        Context-stream draft + paged multi-token verify with defaults,
        an int sets ``draft_tokens``, a ``SpeculativeConfig`` sets
        everything, and ``"nano"`` drafts with the truly-small
        ``lisa_nano`` geometry (the target's truncated trunk — see
        ``configs/lisa_nano``); the active ``ControlPolicy``'s
        ``allow_speculation`` gates drafting on the observed acceptance
        rate. ``max_prefixes`` LRU-caps the shared prefix store.
        ``mesh`` (a ``jax.sharding.Mesh``) runs the paged serving stack
        tensor-parallel: the executor is wrapped in a
        ``ShardedServingContext`` (params model-sharded, KV pool
        kv-heads over the "model" axis, page tables replicated) and the
        engine's ``PagePool`` keeps its device buffers mesh-resident.
        ``retry`` (a ``RetryPolicy``) turns transmission blackouts and
        cloud-stage faults into bounded backoff-and-downshift retries
        instead of terminal failures; ``scheduler`` (``engine.scheduler``)
        plugs the admission policy — the default ``FifoScheduler``
        preserves strict arrival order; a ``QoSScheduler`` adds
        intent-aware classes, weighted-fair + strict-priority admission,
        per-operator rate limits, and preemption. The engine keeps the
        given instance as a prototype: rate buckets and telemetry are
        fleet-wide, each in-flight decoder gets a ``spawn()``.
        ``debug_invariants`` audits the KV pool
        (``PagePool.check_invariants``) after every pump/drain/
        cancellation — cheap, but meant for tests and chaos runs.
        ``debug_recompiles`` attaches a
        :class:`repro.analysis.sanitizers.RecompileSanitizer`: call
        ``arm_sanitizers()`` after warmup and every later pump/drain
        raises ``RecompileBudgetError`` if steady state compiled a new
        trace. ``debug_transfers`` wraps each in-flight decode
        pump/drain in ``jax.transfer_guard("disallow")`` — any implicit
        device↔host transfer on the decode path raises (explicit
        ``jnp.asarray`` stays allowed). See docs/analysis.md.

        Observability (docs/observability.md): ``trace`` (``True`` or a
        configured :class:`~repro.engine.observability.Tracer`) records
        per-request mission-clock spans across the whole lifecycle,
        exportable with :meth:`dump_trace`; disabled (the default)
        every hook is a single branch. The metrics registry
        (``engine.metrics``) is always on — it backs the ``stats()``
        counters and the TTFT/queue-wait/transmit histograms. The
        flight recorder keeps the last ``flight_events`` engine events
        and, with ``flight_dir`` set, auto-dumps JSON when a request
        dies hard (terminal cloud error, deadline cancel) or an
        invariant trips (page-pool audit, recompile budget).
        ``wallclock`` injects a wall-time source (pass
        ``time.perf_counter``; engine code must not read the wall
        clock itself — averylint AV502) to fill the wall decode/verify
        step histograms.

        ``profile`` (``True`` or a configured
        :class:`~repro.engine.profiler.StageProfiler`) adds device-level
        observability on top: every jitted executor stage call is
        block-until-ready wall-timed into per-(stage, tier, bucket)
        histograms, compile events are recorded per jit root (the
        compile observatory), per-request FLOPs/HBM-bytes/joules ride
        the responses (the cost ledger), and ``dump_trace`` gains a
        device track (pid 3). Off by default — zero residue when off;
        ``profile=True`` requires ``wallclock`` (the profiler times wall
        seconds and engine code never reads the wall clock itself)."""
        if batching not in BATCHING_MODES:
            raise ValueError(f"batching must be one of {BATCHING_MODES}")
        self.lut = lut
        if mesh is not None:
            if executor is None:
                raise ValueError(
                    "mesh= sharded serving needs an executor to wrap")
            if batching != "inflight":
                # only the paged in-flight stages run sharded; a
                # microbatch/generate engine would silently serve
                # unsharded while reporting mesh telemetry
                raise ValueError(
                    "mesh= shards the paged in-flight serving stack; "
                    "construct the engine with batching='inflight'")
            from repro.sharding.serving import ShardedServingContext
            if not isinstance(executor, ShardedServingContext):
                executor = ShardedServingContext(executor, mesh)
        self.mesh = mesh
        # device-level profiling: resolve the knob, then wrap the
        # executor so every jitted stage call is wall-timed (the wrap
        # sits outermost — mesh context and fault injectors included)
        self.profiler = self._resolve_profiler(profile, wallclock)
        self.cost_model = None
        if self.profiler is not None:
            pcfg = getattr(executor, "pcfg", None)
            if pcfg is not None:
                from repro.engine.profiler import CloudCostModel
                self.cost_model = CloudCostModel(pcfg)
            if executor is not None:
                executor = self.profiler.wrap(executor)
        self.executor = executor
        self.transport: Transport = transport or LoopbackTransport()
        self.policy: ControlPolicy = policy or AdaptivePolicy()
        self.batching = batching
        self.max_batch = max_batch
        self.edge_device = edge_device or EdgeDevice()
        self._deploy = deploy
        self._scheduler = None
        if executor is not None and batching in ("microbatch", "generate"):
            # runtime imports the engine package; defer the reverse edge
            from repro.runtime.scheduler import MicrobatchScheduler
            self._scheduler = MicrobatchScheduler(
                executor=executor, max_batch=max_batch,
                generate=(batching == "generate"))
        # one paged KV pool shared by every in-flight decoder: prefix
        # pages cached for one qlen survive that decoder's retirement
        self.kv_pool = PagePool(
            page_size=getattr(executor, "page_size", 16),
            share_prefixes=share_prefixes, initial_pages=kv_pages,
            max_prefixes=max_prefixes,
            placement=getattr(executor, "place_pool", None),
            shards=getattr(executor, "model_shards", 1))
        self.spec_config = self._resolve_speculative(speculative)
        if self.spec_config is not None and batching != "inflight":
            raise ValueError(
                "speculative decoding rides the in-flight batch; "
                "construct the engine with batching='inflight'")
        # draft prefill rows shared across decoders, like kv_pool: a
        # repeat-prefix frame after a drain skips the draft prefill too
        self._draft_prefix_rows: Dict = {}
        self._inflight: Dict[int, InflightDecoder] = {}   # qlen -> decoder
        self._retired_inflight = (0, 0)   # (steps, slot-steps) of evicted
        self._retired_faults = (0, 0)     # (cancels, stage faults) of evicted
        self._retired_spec = SpecStats()  # spec telemetry of evicted
        self._futures: Dict[int, RequestFuture] = {}
        self._order: List[int] = []
        self._seq = 0
        self.sessions: List[OperatorSession] = []
        self.retry = retry
        self.scheduler_proto = scheduler if scheduler is not None \
            else FifoScheduler()
        self.debug_invariants = debug_invariants
        self.debug_transfers = debug_transfers
        self._recompile_sanitizer = None
        if debug_recompiles:
            from repro.analysis.sanitizers import RecompileSanitizer
            self._recompile_sanitizer = RecompileSanitizer(self)
        # mission-clock watermark: the latest time the engine has seen
        # (submissions, deliveries, retry backoffs). Deadline sweeps
        # cancel in-flight requests the watermark has passed.
        self._now = 0.0
        # observability: tracer (off by default — one branch per hook),
        # metrics registry (always on; backs the terminal counters and
        # the latency histograms), flight recorder (bounded event ring,
        # auto-dumps into flight_dir on hard failures). Terminal
        # outcomes are mutually exclusive: every submitted request
        # lands in exactly one of {completed, infeasible, blackouts,
        # deadline_cancelled, cloud_errors, rejected}; "starved"
        # separately counts *served* best-effort responses with
        # feasible=False (those also count as completed).
        self.tracer = trace if isinstance(trace, Tracer) \
            else Tracer(enabled=bool(trace))
        self.metrics = MetricsRegistry()
        self.flight = FlightRecorder(capacity=flight_events,
                                     autodump_dir=flight_dir)
        self._wallclock = wallclock
        self._counters = {key: self.metrics.counter(key)
                          for key in _COUNTER_KEYS}
        self.served_by_operator: Dict[str, int] = {}
        bind = getattr(self.scheduler_proto, "bind_metrics", None)
        if bind is not None:
            bind(self.metrics)
        if self.profiler is not None:
            # bind the mission clock, jit-root census, and flight
            # recorder now that they all exist
            self.profiler.attach(self)

    @staticmethod
    def _resolve_profiler(profile: Any, wallclock):
        from repro.engine.profiler import StageProfiler
        if isinstance(profile, StageProfiler):
            return profile
        if not profile:
            return None
        if wallclock is None:
            raise ValueError(
                "profile=True needs wallclock= (the profiler measures "
                "wall seconds; engine code never reads the wall clock "
                "itself — pass time.perf_counter)")
        return StageProfiler(wallclock)

    # ---- counters (registry-backed; n_* is the legacy read surface) ----

    def _bump(self, key: str, n: int = 1) -> None:
        self._counters[key].inc(n)

    @property
    def n_submitted(self) -> int:
        return self._counters["submitted"].value

    @property
    def n_completed(self) -> int:
        return self._counters["completed"].value

    @property
    def n_infeasible(self) -> int:
        return self._counters["infeasible"].value

    @property
    def n_blackouts(self) -> int:
        return self._counters["blackouts"].value

    @property
    def n_deadline(self) -> int:
        return self._counters["deadline_cancelled"].value

    @property
    def n_cloud_errors(self) -> int:
        return self._counters["cloud_errors"].value

    @property
    def n_rejected(self) -> int:
        return self._counters["rejected"].value

    @property
    def n_starved(self) -> int:
        return self._counters["starved"].value

    @property
    def n_retries(self) -> int:
        return self._counters["retries"].value

    @property
    def n_downshifts(self) -> int:
        return self._counters["downshifts"].value

    @property
    def n_load_downshifts(self) -> int:
        return self._counters["load_downshifts"].value

    def _resolve_speculative(self, speculative: Any
                             ) -> Optional[SpeculativeConfig]:
        if speculative is None or speculative is False:
            return None
        if speculative is True:
            return SpeculativeConfig()
        if isinstance(speculative, str):
            if speculative != "nano":
                raise ValueError(
                    f"unknown speculative mode {speculative!r}; the only "
                    f"named mode is 'nano'")
            if self.executor is None:
                raise ValueError(
                    "speculative='nano' slices its draft from the "
                    "executor's weights; construct the engine with one")
            from repro.configs import lisa_nano
            return SpeculativeConfig(
                draft_pcfg=lisa_nano.CONFIG,
                draft_params=lisa_nano.nano_draft_params(
                    self.executor.params))
        if isinstance(speculative, int):
            return SpeculativeConfig(draft_tokens=speculative)
        if isinstance(speculative, SpeculativeConfig):
            return speculative
        raise ValueError(
            f"speculative must be bool, int, str, or SpeculativeConfig, "
            f"got {speculative!r}")

    def _merged_spec_stats(self) -> SpecStats:
        """Engine-lifetime speculation telemetry: retired decoders'
        counters plus every live decoder's."""
        spec = SpecStats()
        spec.merge(self._retired_spec)
        for d in self._inflight.values():
            spec.merge(d.spec_stats)
        return spec

    def _spec_gate(self, stats: SpecStats) -> bool:
        """The policy's drafting gate. Decided on the *engine-lifetime*
        acceptance stats, not the calling decoder's own (``stats``) —
        decoders retire on every ``drain`` and a per-burst view would
        re-enable a drafting scheme the floor already rejected, re-
        paying the warm-up waste each burst. Policies without the hook
        leave drafting on."""
        allow = getattr(self.policy, "allow_speculation", None)
        if allow is None:
            return True
        return bool(allow(self._merged_spec_stats(), self.spec_config))

    # ---- sessions ----

    def session(self, operator_id: Optional[str] = None, **kwargs: Any
                ) -> OperatorSession:
        if operator_id is None:
            operator_id = f"operator-{len(self.sessions)}"
        sess = OperatorSession(engine=self, operator_id=operator_id, **kwargs)
        self.sessions.append(sess)
        return sess

    @property
    def deploy(self):
        if self._deploy is None:
            from repro.configs.lisa7b import CONFIG as deploy
            self._deploy = deploy
        return self._deploy

    def bind_deploy(self, deploy: Any) -> None:
        """Pin the edge deployment geometry on a shared engine; rejects a
        conflicting rebind instead of silently using the wrong one."""
        if deploy is None:
            return
        if self._deploy is not None and self._deploy is not deploy:
            raise ValueError(
                "engine already bound to a different deploy geometry")
        self._deploy = deploy

    # ---- the shared Sense/Gate/Select front ----

    def _decide(self, session: OperatorSession, intent: Intent, t: float
                ) -> tuple:
        transport = session.transport or self.transport
        policy = session.policy or self.policy
        bw = transport.bandwidth(t)
        decision = policy.select(bw, intent, session.requirements[intent],
                                 self.lut, goal=session.goal,
                                 finetuned=session.finetuned)
        return transport, decision, bw

    # ---- full serving path ----

    def _register(self, request: Request, session: OperatorSession
                  ) -> RequestFuture:
        """Shared bookkeeping for every serving entry point."""
        request.request_id, self._seq = self._seq, self._seq + 1
        request.operator_id = session.operator_id
        fut = RequestFuture(request, self)
        self._futures[request.request_id] = fut
        self._order.append(request.request_id)
        self._bump("submitted")
        if self.tracer.enabled:
            self.tracer.begin(
                request.request_id, request.operator_id,
                intent=request.intent.name if request.intent else "",
                t=request.time_s)
        return fut

    def _deadline_for(self, session: OperatorSession, intent: Intent,
                      t: float) -> Optional[float]:
        max_latency = session.requirements[intent].max_latency_s
        return None if max_latency is None else t + max_latency

    def submit(self, request: Request, session: OperatorSession
               ) -> RequestFuture:
        if self.executor is None:      # before any bookkeeping: a raise
            raise RuntimeError(        # must not leave a ghost request
                "this engine has no executor; real submissions need one "
                "(profiled missions go through session.submit_frame)")
        intent = request.intent
        if intent is None:
            intent = request.intent = session.classify(request.prompt)
        session.history.append((request.time_s, request.prompt, intent))
        fut = self._register(request, session)
        fut.meta["session"] = session
        fut.meta["deadline"] = self._deadline_for(session, intent,
                                                  request.time_s)
        self._advance(request.time_s)
        if self._reject_overload(fut, session, request.time_s):
            return fut
        self._attempt(fut, request.time_s)
        self._sweep_deadlines()
        return fut

    def _reject_overload(self, fut: RequestFuture,
                         session: OperatorSession, t: float) -> bool:
        """Admission control at the front door: an operator over its
        rate limit is shed *before* any edge compute or transmission —
        the cheapest possible rejection. Resolves the future with
        ``failure="rejected"`` and returns True when shed."""
        reason = self.scheduler_proto.admission_check(
            session.operator_id, t)
        if reason is None:
            return False
        self._bump("rejected")
        fut.emit("rejected", t, reason=reason)
        fut.set_result(Response(
            request_id=fut.request.request_id,
            operator_id=session.operator_id, intent=fut.request.intent,
            feasible=False, failure="rejected",
            attempts=max(1, fut.attempts), t_submit=t, t_delivered=t,
            t_finished=self._now))
        return True

    # ---- attempts, retries, failures ----

    def _attempt(self, fut: RequestFuture, t: float,
                 prev_tier: Any = None) -> None:
        """One full serving attempt at mission time ``t``: Sense/Select
        (downshifted below ``prev_tier`` on a retry), edge (re-)encode at
        the chosen tier, transmit, enqueue on the cloud. Failures route
        through ``_send_failed`` which retries or resolves."""
        request = fut.request
        session: OperatorSession = fut.meta["session"]
        intent = request.intent
        transport, decision, bw = self._decide(session, intent, t)
        decision = self._adapt_to_load(session, decision, bw)
        if prev_tier is not None and self.retry is not None:
            decision = self.retry.downshifted(decision, prev_tier, self.lut,
                                              bw)
            if (decision.tier is not None
                    and decision.tier.payload_mb < prev_tier.payload_mb):
                self._bump("downshifts")
        fut.attempts += 1
        fut.emit("tier_selected", t, bandwidth_mbps=bw,
                 tier=decision.tier.name if decision.tier else None,
                 feasible=decision.feasible, attempt=fut.attempts)
        if decision.stream == "insight" and decision.tier is None:
            self._bump("infeasible")
            fut.emit("infeasible", t)
            fut.set_result(Response(
                request_id=request.request_id,
                operator_id=session.operator_id, intent=intent,
                feasible=False, failure="infeasible",
                attempts=max(1, fut.attempts), t_submit=request.time_s,
                t_delivered=t))
            return
        if intent is Intent.CONTEXT:
            packet, _ = self.executor.edge_context(
                request.images, request.request_id, t)
        else:
            packet = self.executor.edge_insight(
                request.images, decision.tier, request.request_id, t)
        if self.tracer.enabled:
            self.tracer.span(request.request_id, "edge_encode", t, t,
                             tier=decision.tier.name if decision.tier
                             else None)
        rec = transport.send(packet, t)
        self._advance(rec.end_s)
        if not rec.delivered:            # uplink blackout / drop
            self._send_failed(fut, decision, rec)
            return
        self._note_transmit(fut, packet, decision, rec)
        self._enqueue_cloud(fut, packet, request.query, decision, rec)

    def _adapt_to_load(self, session: OperatorSession,
                       decision: TierDecision, bw: float) -> TierDecision:
        """Scheduler feedback as a self-awareness input: policies with
        an ``adapt_to_load`` hook see the live queue pressure and may
        trade fidelity for admission latency (AdaptivePolicy downshifts
        the Insight tier under deep backlogs; Static never adapts; see
        engine/policy.py — the same optional-hook pattern as the
        speculation gate)."""
        policy = session.policy or self.policy
        hook = getattr(policy, "adapt_to_load", None)
        if hook is None or self.batching != "inflight":
            return decision
        adapted = hook(decision, self.scheduler_proto.load(), self.lut, bw)
        if (adapted.tier is not None and decision.tier is not None
                and adapted.tier.payload_mb < decision.tier.payload_mb):
            self._bump("load_downshifts")
        return adapted

    def _note_transmit(self, fut: RequestFuture, packet: pk.Packet,
                       decision: TierDecision, rec: Any) -> None:
        """Delivered-packet telemetry shared by both attempt paths: the
        ``transmitted`` stream event, the transmit-latency histograms
        (global + per tier), and the trace's transmit span."""
        fut.emit("transmitted", rec.end_s, payload_mb=packet.payload_mb)
        dt = max(0.0, rec.end_s - rec.start_s)
        tier = decision.tier.name if decision.tier else "context"
        self.metrics.histogram("transmit_s").observe(dt)
        self.metrics.histogram(f"transmit_s:tier={tier}").observe(dt)
        if self.tracer.enabled:
            self.tracer.span(fut.request.request_id, "transmit",
                             rec.start_s, rec.end_s,
                             payload_mb=packet.payload_mb, tier=tier)

    def _observe_event(self, request: Request, kind: str, t: float,
                       data: Dict[str, Any]) -> None:
        """Every ``RequestFuture.emit`` lands here: the flight recorder
        sees all lifecycle events; the tracer records the ones that are
        not already covered by a span (transmit/queue)."""
        self.flight.record(kind, t, request_id=request.request_id,
                           data=data)
        if self.tracer.enabled and kind not in ("transmitted", "queued"):
            self.tracer.point(request.request_id, kind, t, **data)

    def _attempt_packet(self, fut: RequestFuture, t: float) -> None:
        """Retry path for pre-encoded submissions: re-send the same
        packet (no images to re-encode means no tier downshift)."""
        session: OperatorSession = fut.meta["session"]
        transport = session.transport or self.transport
        packet: pk.Packet = fut.meta["fixed_packet"]
        decision: TierDecision = fut.meta["decision"]
        fut.attempts += 1
        rec = transport.send(packet, t)
        self._advance(rec.end_s)
        if not rec.delivered:
            self._send_failed(fut, decision, rec)
            return
        self._note_transmit(fut, packet, decision, rec)
        self._enqueue_cloud(fut, packet, fut.request.query, decision, rec)

    def _send_failed(self, fut: RequestFuture, decision: TierDecision,
                     rec: Any) -> None:
        """The transport gave up on the packet (bandwidth blackout or a
        drop). With a ``RetryPolicy`` and budget left — attempts below
        the cap, deadline not yet passed at the backed-off retry time —
        the engine retries; otherwise the request resolves as a failed
        delivery (no cloud work) so the caller can react instead of
        hanging."""
        fut.emit("blackout", rec.end_s)
        fut.meta.update(decision=decision, rec=rec)
        if self._can_retry(fut, rec.end_s):
            self._retry(fut, rec.end_s, decision.tier)
            return
        self._bump("blackouts")
        fut.set_result(self._base_response(fut, feasible=False,
                                           failure="blackout"))

    def _cloud_failed(self, fut: RequestFuture, out: Dict[str, Any]) -> None:
        """A cloud serving stage died under this request (the in-flight
        decoder already released its pages). Retry — back through edge
        encode and the transport, downshifted — or resolve failed."""
        decision: TierDecision = fut.meta["decision"]
        t_fail = max(self._now, fut.meta["rec"].end_s)
        fut.emit("cloud_error", t_fail, error=out.get("error", ""))
        if self._can_retry(fut, t_fail):
            self._retry(fut, t_fail, decision.tier)
            return
        self._bump("cloud_errors")
        fut.set_result(self._base_response(fut, feasible=False,
                                           failure="cloud_error"))
        self.flight.dump("cloud_error", stats=self.stats)

    def _can_retry(self, fut: RequestFuture, t_fail: float) -> bool:
        if self.retry is None or fut.attempts >= self.retry.max_attempts:
            return False
        deadline = fut.meta.get("deadline")
        t_retry = t_fail + self.retry.backoff_s(fut.attempts)
        return deadline is None or t_retry < deadline

    def _retry(self, fut: RequestFuture, t_fail: float,
               prev_tier: Any) -> None:
        t = t_fail + self.retry.backoff_s(fut.attempts)
        self._bump("retries")
        fut.emit("retry", t, attempt=fut.attempts + 1)
        self._advance(t)
        if fut.meta.get("fixed_packet") is not None:
            self._attempt_packet(fut, t)
        else:
            self._attempt(fut, t, prev_tier=prev_tier)

    # ---- deadlines (IntentRequirements.max_latency_s) ----

    def _advance(self, t: float) -> None:
        if t > self._now:
            self._now = t

    def _sweep_deadlines(self) -> None:
        """Cancel every unresolved request whose deadline the mission
        clock has passed: remove it from its decoder (slot + pages
        released refcount-safely) and resolve its future with a
        ``deadline`` failure, so ``result()`` never hangs on it."""
        for fut in list(self._futures.values()):
            if fut.done():
                continue
            deadline = fut.meta.get("deadline")
            if deadline is None or self._now < deadline:
                continue
            self._cancel_request(fut, deadline)

    def _cancel_request(self, fut: RequestFuture, deadline: float) -> None:
        rid = fut.request.request_id
        for dec in self._inflight.values():
            if dec.cancel(rid):
                break
        self._bump("deadline_cancelled")
        fut.emit("cancelled", deadline, reason="deadline")
        fut.set_result(self._base_response(fut, feasible=False,
                                           failure="deadline"))
        self.flight.dump("deadline_cancel", stats=self.stats)
        if self.debug_invariants:
            self._audit_pool()

    def submit_packet(self, packet: pk.Packet, query, intent: Intent,
                      time_s: float = 0.0,
                      session: Optional[OperatorSession] = None,
                      priority: Optional[int] = None) -> RequestFuture:
        """Low-level entry: serve an already-encoded packet (benchmarks
        and tests that prepare edge payloads out of band)."""
        if self.executor is None:
            raise RuntimeError(
                "this engine has no executor; real submissions need one "
                "(profiled missions go through session.submit_frame)")
        session = session or (self.sessions[0] if self.sessions
                              else self.session("_direct"))
        fut = self._register(Request(intent=intent, query=np.asarray(query),
                                     time_s=time_s,
                                     priority=session.priority
                                     if priority is None
                                     else int(priority)), session)
        decision = TierDecision(
            stream=packet.kind,
            tier=self.lut.by_name(packet.tier_name) if packet.tier_name
            else None)
        fut.meta.update(session=session, fixed_packet=packet,
                        decision=decision,
                        deadline=self._deadline_for(session, intent, time_s))
        self._advance(time_s)
        if self._reject_overload(fut, session, time_s):
            return fut
        self._attempt_packet(fut, time_s)
        self._sweep_deadlines()
        return fut

    # ---- cloud dispatch: closed microbatches or the in-flight batch ----

    def _enqueue_cloud(self, fut: RequestFuture, packet: pk.Packet, query,
                       decision: TierDecision, rec: Any) -> None:
        fut.meta.update(decision=decision, rec=rec)
        rid = fut.request.request_id
        if self.batching == "inflight":
            qlen = int(np.asarray(query).shape[-1])
            dec = self._inflight.get(qlen)
            if dec is None:
                dec = self._inflight[qlen] = InflightDecoder(
                    self.executor, slots=self.max_batch, pool=self.kv_pool,
                    spec=self.spec_config, spec_gate=self._spec_gate,
                    spec_prefix_rows=self._draft_prefix_rows,
                    scheduler=self.scheduler_proto.spawn(),
                    clock=lambda: self._now,
                    tracer=self.tracer, metrics=self.metrics,
                    wallclock=self._wallclock,
                    profiler=self.profiler, cost=self.cost_model)
            dec.submit(rid, fut.request.intent, packet, query,
                       on_done=self._resolve_inflight,
                       operator_id=fut.request.operator_id,
                       priority=fut.request.priority,
                       deadline=fut.meta.get("deadline"),
                       t_submit=rec.end_s)
            if fut.done():           # shed at enqueue (bounded queue)
                return
            # actual admission may happen steps later if slots are full;
            # the decoder stamps the real join point on the response
            fut.emit("queued", rec.end_s)
            dec.pump(1)              # keep the batch running between submits
            return
        from repro.runtime.scheduler import ServeRequest
        self._scheduler.submit(ServeRequest(
            seq_id=rid, intent=fut.request.intent, packet=packet,
            query=np.asarray(query), arrival_s=fut.request.time_s))
        for res in self._scheduler.step_ready():
            self._resolve_scheduled(res)

    def _base_response(self, fut: RequestFuture, **kw: Any) -> Response:
        rec = fut.meta["rec"]
        decision: TierDecision = fut.meta["decision"]
        return Response(
            request_id=fut.request.request_id,
            operator_id=fut.request.operator_id,
            intent=fut.request.intent,
            tier_name=decision.tier.name if decision.tier else None,
            feasible=kw.pop("feasible", decision.feasible),
            failure=kw.pop("failure", None),
            attempts=max(1, fut.attempts),
            t_submit=fut.request.time_s,
            t_delivered=rec.end_s, **kw)

    def _resolve_scheduled(self, res: Any) -> None:
        fut = self._futures[res.seq_id]
        if fut.done():          # e.g. already cancelled past its deadline
            return
        fut.emit("served", fut.meta["rec"].end_s, batch_size=res.batch_size)
        resp = self._base_response(
            fut, answer_logits=res.answer_logits,
            mask_logits=res.mask_logits, tokens=res.tokens,
            batch_size=res.batch_size)
        resp.t_finished = self._now
        fut.set_result(resp)
        self._bump("completed")
        self._note_served(fut.request.operator_id)
        if not resp.feasible:
            self._bump("starved")        # served best-effort, F_I unmet

    def _resolve_inflight(self, out: Dict[str, Any]) -> None:
        fut = self._futures[out["seq_id"]]
        if fut.done():          # e.g. already cancelled past its deadline
            return
        failure = out.get("failure")
        if failure == "cloud_error":
            self._cloud_failed(fut, out)
            return
        if failure == "deadline":
            # the decoder's pre-admission sweep: expired while pending,
            # resolved without paying the prefill
            self._bump("deadline_cancelled")
            fut.emit("cancelled", self._now, reason="deadline")
            fut.set_result(self._base_response(
                fut, feasible=False, failure="deadline",
                t_finished=self._now))
            self.flight.dump("deadline_cancel", stats=self.stats)
            return
        if failure == "rejected":
            # shed at enqueue: the scheduler's bounded queue is full
            self._bump("rejected")
            fut.emit("rejected", self._now, reason=out.get("reason", ""))
            fut.set_result(self._base_response(
                fut, feasible=False, failure="rejected",
                t_finished=self._now))
            return
        fut.emit("served", fut.meta["rec"].end_s,
                 joined_step=out["joined_step"],
                 prefix_hit=out["prefix_hit"])
        resp = self._base_response(
            fut, answer_logits=out["answer_logits"],
            mask_logits=out["mask_logits"], tokens=out["tokens"],
            batch_size=out["batch_size"])
        resp.joined_step = out["joined_step"]
        resp.prefix_hit = out["prefix_hit"]
        resp.speculative = out.get("speculative")
        resp.preemptions = out.get("preemptions", 0)
        resp.queue_wait_s = out.get("queue_wait")
        resp.t_finished = self._now
        tft = out.get("t_first_token")
        if tft is not None:
            resp.ttft_s = max(0.0, tft - fut.request.time_s)
        flops = out.get("cloud_flops")
        if flops is not None:
            # the cost ledger (profiled engines only): analytic
            # FLOPs/HBM-bytes accumulated per slot by the decoder,
            # joules from the cloud device's power envelope
            resp.cloud_flops = flops
            resp.cloud_hbm_bytes = out.get("cloud_hbm_bytes", 0.0)
            if self.cost_model is not None:
                resp.cloud_energy_j = self.cost_model.energy_j(flops)
            if self.profiler is not None:
                self.profiler.note_ledger(
                    resp.cloud_flops, resp.cloud_hbm_bytes or 0.0,
                    resp.cloud_energy_j or 0.0)
        self._observe_served(fut, resp)
        fut.set_result(resp)
        self._bump("completed")
        self._note_served(fut.request.operator_id)
        if not resp.feasible:
            self._bump("starved")        # served best-effort, F_I unmet

    def _observe_served(self, fut: RequestFuture, resp: Response) -> None:
        """Per-QoS-class serving histograms (in-flight path): TTFT,
        queue wait, and end-to-end token throughput."""
        cls = qos_class(fut.request.intent)
        if resp.ttft_s is not None:
            self.metrics.histogram(f"ttft_s:{cls}").observe(resp.ttft_s)
        if resp.queue_wait_s is not None:
            self.metrics.histogram(f"queue_wait_s:{cls}").observe(
                resp.queue_wait_s)
        if resp.tokens is not None and resp.t_finished is not None:
            dur = resp.t_finished - fut.request.time_s
            if dur > 0.0:
                n_tok = int(np.asarray(resp.tokens).shape[-1])
                self.metrics.histogram(f"tokens_per_s:{cls}",
                                       hi=1e6).observe(n_tok / dur)

    def _note_served(self, operator_id: str) -> None:
        self.served_by_operator[operator_id] = \
            self.served_by_operator.get(operator_id, 0) + 1

    def pump(self) -> None:
        """Advance cloud serving without waiting: serve any full
        microbatches, or one in-flight decode step per live decoder.
        Sweeps deadlines first — an overdue request must not consume a
        decode step it can no longer use."""
        self._sweep_deadlines()
        if self._scheduler is not None:
            for res in self._scheduler.step_ready():
                self._resolve_scheduled(res)
        with self._transfer_guard():
            for dec in self._inflight.values():
                dec.pump(1)
        if self.tracer.enabled:
            load = self.scheduler_proto.load()
            for key in sorted(load):
                self.metrics.gauge(key).set(load[key])
        if self.debug_invariants:
            self._audit_pool()
        self.check_sanitizers()

    def drain(self, release_operator: Optional[str] = None
              ) -> List[Response]:
        """Serve everything outstanding. Returns the responses delivered
        since the last drain, in submission order — delivered requests
        are evicted from the engine's tables (their ``RequestFuture``
        keeps the response), so a submit/drain/submit stream neither
        re-returns history nor accumulates served payloads.

        Cached prefix pages survive the drain (cross-burst reuse is the
        point of the prefix store); pass ``release_operator`` to also
        free that operator's prefix pages once their requests are served
        (``OperatorSession.close`` does this for you)."""
        self._sweep_deadlines()
        if self._scheduler is not None:
            for res in self._scheduler.drain():
                self._resolve_scheduled(res)
        for qlen, dec in list(self._inflight.items()):
            with self._transfer_guard():
                dec.drain()
            # retire the idle decoder: fold its counters into the engine
            # and drop it so per-qlen decoders don't accumulate forever
            steps, slots = self._retired_inflight
            self._retired_inflight = (steps + dec.n_steps,
                                      slots + dec.n_slot_steps)
            cancels, faults = self._retired_faults
            self._retired_faults = (cancels + dec.n_cancelled,
                                    faults + dec.n_stage_faults)
            self._retired_spec.merge(dec.spec_stats)
            del self._inflight[qlen]
        out, remaining = [], []
        for rid in self._order:
            fut = self._futures[rid]
            if fut.done():
                out.append(fut._response)
                del self._futures[rid]
            else:
                remaining.append(rid)
        self._order = remaining
        if release_operator is not None:
            self.release_prefixes(release_operator)
        if self.debug_invariants:
            self._audit_pool()
        self.check_sanitizers()
        return out

    # ---- runtime sanitizers (repro.analysis.sanitizers) ----

    def _transfer_guard(self):
        """``jax.transfer_guard('disallow')`` around the decode pump
        when ``debug_transfers`` is on; a no-op context otherwise."""
        from repro.analysis.sanitizers import transfer_guard_ctx
        return transfer_guard_ctx(self.debug_transfers)

    def arm_sanitizers(self) -> Optional[int]:
        """Snapshot the compile-cache census after warmup. From here on
        every pump/drain asserts a zero-recompile budget (requires
        ``debug_recompiles=True``; returns the trace count at arm, or
        None when the sanitizer is off)."""
        if self._recompile_sanitizer is None:
            return None
        return self._recompile_sanitizer.arm()

    def check_sanitizers(self, budget: int = 0) -> None:
        """Raise ``RecompileBudgetError`` if steady state compiled more
        than ``budget`` new traces since ``arm_sanitizers()``. No-op
        until armed. A trip dumps the flight ring first so the failing
        run leaves a diagnosable artifact."""
        san = self._recompile_sanitizer
        if san is not None and san.armed_at is not None:
            try:
                san.check(budget)
            except Exception:
                self.flight.dump("recompile_budget")
                raise

    def _audit_pool(self) -> None:
        """``PagePool.check_invariants`` with a flight dump on failure:
        a tripped page-pool invariant in a chaos run becomes a JSON
        artifact instead of a bare assert."""
        try:
            self.kv_pool.check_invariants()
        except Exception:
            self.flight.dump("pool_invariant")
            raise

    # ---- observability exports (docs/observability.md) ----

    def dump_trace(self, path: str) -> str:
        """Write every recorded request trace as Chrome/Perfetto
        ``trace_event`` JSON (open at https://ui.perfetto.dev). Tracks:
        one per operator (pid 1), one per decode slot (pid 2), and —
        with profiling on — one per device stage (pid 3)."""
        if self.profiler is None:
            return self.tracer.dump(path)
        import json
        import os
        doc = self.tracer.to_chrome()
        doc["traceEvents"] = (doc["traceEvents"]
                              + self.profiler.chrome_events())
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f)
        return path

    def dump_flight(self, path: str, reason: str = "manual"
                    ) -> Optional[str]:
        """Write the flight-recorder ring (last N engine events plus a
        ``stats()`` snapshot) as JSON to ``path``."""
        return self.flight.dump(reason, path=path, stats=self.stats)

    def release_prefixes(self, operator_id: str) -> int:
        """Free one operator's cached prefix pages (their store pin —
        pages shared with still-active requests free when those
        finish) and their cached draft prefill rows. Returns the number
        of prefix entries released."""
        for skey in [k for k in self._draft_prefix_rows
                     if k[0][0] == operator_id]:
            del self._draft_prefix_rows[skey]
        return self.kv_pool.release_operator(operator_id)

    # ---- profiled mission path (analytic edge + LUT/oracle fidelity) ----

    def submit_frame(self, session: OperatorSession, t: float,
                     intent: Intent = Intent.INSIGHT) -> Response:
        rid, self._seq = self._seq, self._seq + 1
        self._bump("submitted")
        self._advance(t)
        if self.tracer.enabled:
            self.tracer.begin(rid, session.operator_id,
                              intent=intent.name, t=t)
        reason = self.scheduler_proto.admission_check(session.operator_id,
                                                      t)
        if reason is not None:       # rate-limited: shed pre-edge-compute
            self._bump("rejected")
            self.flight.record("rejected", t, request_id=rid)
            if self.tracer.enabled:
                self.tracer.point(rid, "rejected", t, reason=reason)
            return Response(request_id=rid,
                            operator_id=session.operator_id,
                            intent=intent, feasible=False,
                            failure="rejected", t_submit=t, t_delivered=t,
                            t_finished=t)
        deadline = self._deadline_for(session, intent, t)
        transport, decision, bw = self._decide(session, intent, t)
        if decision.stream == "context":
            return self._context_frame(session, transport, rid, t)
        attempts, t_try, prev_tier = 0, t, None
        compute_total = energy_total = 0.0
        while True:
            attempts += 1
            if decision.tier is None:
                self._bump("infeasible")
                self.flight.record("infeasible", t_try, request_id=rid)
                if self.tracer.enabled:
                    self.tracer.point(rid, "infeasible", t_try)
                return Response(request_id=rid,
                                operator_id=session.operator_id,
                                intent=intent, feasible=False,
                                failure="infeasible", attempts=attempts,
                                t_submit=t, t_delivered=t_try,
                                edge_compute_s=compute_total,
                                edge_energy_j=energy_total)
            tier = decision.tier
            flops = edge_insight_flops(self.deploy, tier.ratio)
            compute_s = self.edge_device.latency_s(flops)
            compute_total += compute_s
            energy_total += (self.edge_device.compute_energy_j(flops)
                             + self.edge_device.tx_energy_j(
                                 tier.payload_mb * 1e6))
            packet = pk.Packet(kind="insight", tier_name=tier.name,
                               seq_id=rid, created_at=t_try,
                               payload_bytes=int(tier.payload_mb * 1e6))
            rec = transport.send(packet, t_try + compute_s)
            self._advance(rec.end_s)
            if not rec.delivered:
                self.flight.record("blackout", rec.end_s, request_id=rid)
            if self.tracer.enabled:
                self.tracer.span(rid, "edge_encode", t_try,
                                 t_try + compute_s, tier=tier.name)
                if rec.delivered:
                    self.tracer.span(rid, "transmit", rec.start_s,
                                     rec.end_s, tier=tier.name)
                else:
                    self.tracer.point(rid, "blackout", rec.end_s)
            if rec.delivered:
                break
            # blackout: retry with backoff + downshift while the budget
            # (attempt cap, deadline) holds — same loop as the real path
            t_next = (rec.end_s + self.retry.backoff_s(attempts)
                      if self.retry is not None else rec.end_s)
            if (self.retry is None or attempts >= self.retry.max_attempts
                    or (deadline is not None and t_next >= deadline)):
                self._bump("blackouts")
                return Response(request_id=rid,
                                operator_id=session.operator_id,
                                intent=intent, tier_name=tier.name,
                                feasible=False, failure="blackout",
                                attempts=attempts, t_submit=t,
                                t_delivered=rec.end_s,
                                edge_compute_s=compute_total,
                                edge_energy_j=energy_total)
            self._bump("retries")
            self.flight.record("retry", t_next, request_id=rid)
            if self.tracer.enabled:
                self.tracer.point(rid, "retry", t_next)
            prev_tier, t_try = tier, t_next
            self._advance(t_try)
            transport, decision, bw = self._decide(session, intent, t_try)
            decision = self.retry.downshifted(decision, prev_tier, self.lut,
                                              bw)
            if (decision.tier is not None
                    and decision.tier.payload_mb < prev_tier.payload_mb):
                self._bump("downshifts")
        if deadline is not None and rec.end_s >= deadline:
            self._bump("deadline_cancelled")
            self.flight.record("cancelled", rec.end_s, request_id=rid)
            if self.tracer.enabled:
                self.tracer.point(rid, "cancelled", rec.end_s,
                                  reason="deadline")
            self.flight.dump("deadline_cancel", stats=self.stats)
            return Response(request_id=rid, operator_id=session.operator_id,
                            intent=intent, tier_name=tier.name,
                            feasible=False, failure="deadline",
                            attempts=attempts, t_submit=t,
                            t_delivered=rec.end_s,
                            edge_compute_s=compute_total,
                            edge_energy_j=energy_total)
        iou = (session.oracle.measure(tier)
               if session.oracle is not None else None)
        self.flight.record("served", rec.end_s, request_id=rid)
        if self.tracer.enabled:
            self.tracer.point(rid, "served", rec.end_s)
        self._bump("completed")
        self._note_served(session.operator_id)
        if not decision.feasible:
            self._bump("starved")        # served best-effort, F_I unmet
        return Response(request_id=rid, operator_id=session.operator_id,
                        intent=intent, tier_name=tier.name,
                        feasible=decision.feasible, attempts=attempts,
                        iou=iou, t_submit=t, t_delivered=rec.end_s,
                        edge_compute_s=compute_total,
                        edge_energy_j=energy_total)

    def _context_frame(self, session: OperatorSession, transport: Transport,
                       rid: int, t: float) -> Response:
        """Profiled Context-stream frame: the CLIP-only edge pathway and
        the fixed lightweight payload (always feasible, no tier)."""
        from repro.network.energy import encoder_flops, patch_embed_flops
        deploy = self.deploy
        flops = (patch_embed_flops(deploy.clip.d_model,
                                   deploy.context_patch_size,
                                   deploy.clip_tokens)
                 + encoder_flops(deploy.clip, deploy.clip_tokens))
        compute_s = self.edge_device.latency_s(flops)
        payload_mb = self.lut.context.payload_mb
        energy = (self.edge_device.compute_energy_j(flops)
                  + self.edge_device.tx_energy_j(payload_mb * 1e6))
        packet = pk.Packet(kind="context", tier_name=None, seq_id=rid,
                           created_at=t,
                           payload_bytes=int(payload_mb * 1e6))
        rec = transport.send(packet, t + compute_s)
        self._advance(rec.end_s)
        self.flight.record("served" if rec.delivered else "blackout",
                           rec.end_s, request_id=rid)
        if self.tracer.enabled:
            self.tracer.span(rid, "edge_encode", t, t + compute_s)
            if rec.delivered:
                self.tracer.span(rid, "transmit", rec.start_s, rec.end_s)
                self.tracer.point(rid, "served", rec.end_s)
            else:
                self.tracer.point(rid, "blackout", rec.end_s)
        if not rec.delivered:
            self._bump("blackouts")
        else:
            self._bump("completed")
            self._note_served(session.operator_id)
        return Response(request_id=rid, operator_id=session.operator_id,
                        intent=Intent.CONTEXT, tier_name=None,
                        feasible=rec.delivered,
                        failure=None if rec.delivered else "blackout",
                        t_submit=t, t_delivered=rec.end_s,
                        edge_compute_s=compute_s, edge_energy_j=energy)

    # ---- telemetry ----

    @property
    def stats(self) -> Dict[str, float]:
        out: Dict[str, float] = {
            "submitted": self.n_submitted,
            "completed": self.n_completed,
            "infeasible": self.n_infeasible,
            "blackouts": self.n_blackouts,
            "deadline_cancelled": self.n_deadline,
            "cloud_errors": self.n_cloud_errors,
            "rejected": self.n_rejected,
            "starved": self.n_starved,
            "retries": self.n_retries,
            "downshifts": self.n_downshifts,
            "load_downshifts": self.n_load_downshifts,
        }
        # scheduler telemetry (queue depth/waits per class, preemptions,
        # rejection reasons) and per-operator served counts — the
        # fairness surface for fleet-scale multi-tenant serving
        out.update(self.scheduler_proto.stats())
        for op, n in self.served_by_operator.items():
            out[f"served_op:{op}"] = n
        if self._scheduler is not None:
            out["n_microbatches"] = self._scheduler.n_microbatches
            out["mean_batch_size"] = self._scheduler.mean_batch_size
        if self.batching == "inflight":
            steps, slots = self._retired_inflight
            steps += sum(d.n_steps for d in self._inflight.values())
            slots += sum(d.n_slot_steps for d in self._inflight.values())
            out["inflight_steps"] = steps
            out["mean_live_slots"] = slots / steps if steps else 0.0
            cancels, faults = self._retired_faults
            out["inflight_cancelled"] = cancels + sum(
                d.n_cancelled for d in self._inflight.values())
            out["stage_faults"] = faults + sum(
                d.n_stage_faults for d in self._inflight.values())
            out.update(self.kv_pool.stats())
            if self.spec_config is not None:
                out.update(self._merged_spec_stats().as_dict())
        if self.executor is not None:
            out["compiled_stages"] = self.executor.num_compiled_stages
        if self._recompile_sanitizer is not None:
            out["compiled_traces"] = \
                self._recompile_sanitizer.compile_count()
            if self._recompile_sanitizer.armed_at is not None:
                out["new_compiles_since_arm"] = \
                    self._recompile_sanitizer.new_compiles()
        if self.mesh is not None:
            out["mesh_devices"] = self.mesh.size
            out["model_shards"] = getattr(self.executor, "model_shards", 1)
        # observability summary (docs/observability.md): fixed keys read
        # off the registry's latency histograms — present whether or not
        # the tracer is on, so traced and untraced runs report the same
        # surface. The full labelled registry is engine.metrics.as_dict().
        for cls in QOS_CLASSES:
            ttft = self.metrics.histogram(f"ttft_s:{cls}")
            out[f"ttft_{cls}_p50_s"] = ttft.p50
            out[f"ttft_{cls}_p99_s"] = ttft.p99
            out[f"ttft_{cls}_n"] = ttft.count
            out[f"queue_wait_{cls}_p95_s"] = self.metrics.histogram(
                f"queue_wait_s:{cls}").p95
            out[f"tokens_per_s_{cls}_p50"] = self.metrics.histogram(
                f"tokens_per_s:{cls}", hi=1e6).p50
        transmit = self.metrics.histogram("transmit_s")
        out["transmit_p50_s"] = transmit.p50
        out["transmit_p99_s"] = transmit.p99
        decode = self.metrics.histogram("decode_step_s")
        out["decode_step_p50_s"] = decode.p50
        out["decode_step_p99_s"] = decode.p99
        out["flight_events"] = len(self.flight)
        out["flight_dumps"] = self.flight.n_dumps
        # device-level profiler summary (docs/observability.md §Profiler):
        # only present when profiling was requested, so the default stats
        # surface is byte-identical with the profiler off.
        if self.profiler is not None:
            out.update(self.profiler.stats_block())
        return out
