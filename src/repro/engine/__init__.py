"""AVERY engine: the intent-driven request/response front door.

  api         — Request / Response / StreamEvent / RequestFuture
  transport   — Transport protocol; ChannelTransport, LoopbackTransport
  policy      — ControlPolicy protocol; Adaptive / StaticTier / BestEffort;
                RetryPolicy (backoff + tier downshift on failure)
  scheduler   — admission policy: FifoScheduler (default), QoSScheduler
                (intent QoS classes, weighted-fair + strict-priority,
                rate limits, page-rollback preemption)
  faults      — chaos injection: FaultInjector (transport), FaultyExecutor
  inflight    — token-level continuous batching (join a running decode)
  speculative — Context-stream DraftModel + paged multi-token verify
  observability — Tracer (per-request spans -> Perfetto JSON),
                MetricsRegistry (counters/gauges/log-bucket histograms),
                FlightRecorder (bounded ring, crash dumps)
  profiler    — StageProfiler (device-level stage timing + Perfetto
                device track), CompileObservatory (graded compile-event
                visibility), CloudCostModel (per-request FLOPs/bytes/
                joules ledger)
  engine      — AveryEngine + OperatorSession

All entry points (serving launcher, mission simulator, fleet runtime,
benchmarks) construct and drive the system through this package.
"""
from repro.engine.api import Request, RequestFuture, Response, StreamEvent
from repro.engine.engine import AveryEngine, OperatorSession
from repro.engine.faults import (CloudStageError, FaultInjector,
                                 FaultyExecutor)
from repro.engine.inflight import InflightDecoder
from repro.engine.observability import (Counter, FlightRecorder, Gauge,
                                        Histogram, MetricsRegistry,
                                        RequestTrace, Span, Tracer,
                                        validate_chrome_trace,
                                        validate_trace, validate_traces)
from repro.engine.policy import (AdaptivePolicy, BestEffortPolicy,
                                 ControlPolicy, RetryPolicy,
                                 StaticTierPolicy, TierDecision,
                                 policy_from_mode)
from repro.engine.profiler import (CloudCostModel, CompileObservatory,
                                   StageProfiler)
from repro.engine.scheduler import (QOS_LATENCY, QOS_THROUGHPUT,
                                    FifoScheduler, QoSScheduler,
                                    jain_index, qos_class)
from repro.engine.speculative import (DraftModel, SpecStats,
                                      SpeculativeConfig)
from repro.engine.transport import (ChannelTransport, LoopbackTransport,
                                    Transport)

__all__ = [
    "Request", "Response", "StreamEvent", "RequestFuture",
    "AveryEngine", "OperatorSession", "InflightDecoder",
    "ControlPolicy", "TierDecision", "AdaptivePolicy", "StaticTierPolicy",
    "BestEffortPolicy", "RetryPolicy", "policy_from_mode",
    "FifoScheduler", "QoSScheduler", "jain_index", "qos_class",
    "QOS_LATENCY", "QOS_THROUGHPUT",
    "CloudStageError", "FaultInjector", "FaultyExecutor",
    "DraftModel", "SpecStats", "SpeculativeConfig",
    "Transport", "ChannelTransport", "LoopbackTransport",
    "Tracer", "Span", "RequestTrace", "MetricsRegistry",
    "Counter", "Gauge", "Histogram", "FlightRecorder",
    "StageProfiler", "CompileObservatory", "CloudCostModel",
    "validate_trace", "validate_traces", "validate_chrome_trace",
]
