"""Device-level performance observability: the stage profiler, compile
observatory, and cost/energy ledger.

PR 9's instruments observe the *mission-clock request lifecycle*; this
module observes the *device*: where wall time, compiles, FLOPs, and
joules actually go, per executor stage. AVERY's controller is embodied
self-awareness — it adapts because it can measure itself — and these
are the measurements the adaptive policy (and the perf-regression gate
in ``scripts/perf_gate.py``) stand on.

Three instruments, one opt-in knob (``AveryEngine(profile=...)``, off
by default, zero residue when off):

  * :class:`StageProfiler` — wraps every jitted executor stage entry
    point (:class:`ProfiledExecutor`) and the draft model
    (:class:`ProfiledDraft`) with block-until-ready-bounded per-call
    wall timing into per-(stage, tier, bucket) log-bucket
    :class:`~repro.engine.observability.Histogram`\\ s, keeps a bounded
    span ring, and exports the spans as a **device track** (pid 3) into
    the engine's Perfetto ``dump_trace`` document so operator spans and
    device stages line up on one timeline. Wall time comes from the
    engine's injectable ``wallclock`` (AV502/AV603: engine code never
    reads the wall clock itself), span placement from the mission clock.
  * :class:`CompileObservatory` — diffs a per-call census of the
    engine's labelled jit roots (``analysis.sanitizers.named_jit_roots``
    — executor fixed jits, keyed ``_compiled`` cache entries, draft
    jits) and records every compile event: stage name, root label,
    compile wall time, cumulative count. Surfaced in ``engine.stats()``
    and the flight recorder, it turns PR 8's fatal recompile budget
    into graded visibility — pool-growth churn becomes a visible spike,
    not just an exception.
  * :class:`CloudCostModel` — joins measured stage timings with the
    analytic FLOPs/HBM-bytes/energy models in ``network/energy.py`` to
    attribute per-request compute cost (``Response``-level
    FLOPs/bytes/joules via the in-flight decoder's per-slot ledger) and
    an achieved-vs-roofline fraction for the paged decode stages.

The ledger covers the paged LLM serving stages (prefill on a prefix
miss, plus every decode/verify token at its attended context length);
edge/SAM/mask costs already have analytic models in
``network/energy.py`` and stay out of the per-request ledger.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable, Dict, List, Optional

from repro.engine.observability import (DEVICE_TRACK_PID, FlightRecorder,
                                        MetricsRegistry)

# the fixed stage vocabulary: stats() keys derive from this tuple so the
# profiled stats surface is deterministic whether or not a stage ran
PROFILED_STAGES = ("edge_context", "edge_insight", "cloud_sam_feats",
                   "cloud_prefix", "pool_write", "cloud_decode_rows",
                   "cloud_verify_rows", "cloud_mask", "draft_admit",
                   "draft")
# the stages whose ledger FLOPs/bytes the roofline fraction compares
# against measured wall time (the paged LLM path the cost model covers)
_LEDGER_STAGES = ("cloud_prefix", "cloud_decode_rows", "cloud_verify_rows")


def _block(out: Any) -> None:
    """Block until every jax leaf of ``out`` is ready, so the wall-time
    delta bounds the stage's device work instead of its dispatch."""
    import jax
    jax.block_until_ready(out)


class CloudCostModel:
    """Analytic per-stage cost of the paged LLM serving path on the
    cloud device: prefill FLOPs per admitted prefix, per-token decode
    FLOPs and HBM bytes at the row's attended context length, joules
    from the device's power envelope."""

    def __init__(self, pcfg: Any, device: Optional[Any] = None):
        from repro.network.energy import CloudDevice
        self.llm = pcfg.llm
        self.device = device if device is not None else CloudDevice()

    def prefill_flops(self, prefix_len: int) -> float:
        from repro.network.energy import encoder_flops
        return encoder_flops(self.llm, int(prefix_len))

    def token_flops(self, ctx_len: int) -> float:
        from repro.network.energy import decode_token_flops
        return decode_token_flops(self.llm, int(ctx_len))

    def token_hbm_bytes(self, ctx_len: int) -> float:
        from repro.network.energy import decode_token_hbm_bytes
        return decode_token_hbm_bytes(self.llm, int(ctx_len))

    def energy_j(self, flops: float) -> float:
        return self.device.compute_energy_j(flops)


class CompileObservatory:
    """Records every compile event by diffing a census of the engine's
    labelled jit roots around each profiled stage call. The census is
    re-discovered each time (``named_jit_roots``), so roots that appear
    mid-flight — a new ``_compiled`` cache entry, a fresh decoder's
    draft — are observed the first time they run."""

    def __init__(self, max_events: int = 256,
                 flight: Optional[FlightRecorder] = None):
        self._roots_fn: Optional[Callable[[], Dict[str, Any]]] = None
        self._flight = flight
        self._last: Dict[str, int] = {}
        self.events: deque = deque(maxlen=int(max_events))
        self.n_compiles = 0
        self.n_events = 0
        self.compile_wall_s = 0.0

    def bind(self, roots_fn: Callable[[], Dict[str, Any]],
             flight: Optional[FlightRecorder] = None) -> None:
        self._roots_fn = roots_fn
        if flight is not None:
            self._flight = flight

    def census(self) -> Dict[str, int]:
        if self._roots_fn is None:
            return {}
        out = {}
        for label, fn in self._roots_fn().items():
            try:
                out[label] = int(fn._cache_size())
            except Exception:
                continue
        return out

    def prime(self) -> None:
        """Take the baseline census without recording events (existing
        traces are not *new* compiles)."""
        self._last = self.census()

    def note(self, stage: str, wall_s: float, t: float) -> None:
        """Diff the census after one profiled ``stage`` call; any cache
        growth is a compile event whose wall time is (conservatively)
        the whole call's wall time — compilation dominates a compiling
        call by orders of magnitude."""
        for label, n in self.census().items():
            prev = self._last.get(label, 0)
            if n <= prev:
                self._last[label] = n
                continue
            delta = n - prev
            self._last[label] = n
            self.n_compiles += delta
            self.n_events += 1
            self.compile_wall_s += wall_s
            self.events.append({"stage": stage, "root": label,
                                "delta": delta, "wall_s": wall_s, "t": t})
            if self._flight is not None:
                self._flight.record("compile", t, data={
                    "stage": stage, "root": label, "delta": delta,
                    "wall_s": wall_s})

    @property
    def n_roots(self) -> int:
        return len(self._last)


class StageProfiler:
    """Per-stage device timing + compile observatory + cost ledger.

    Construct with the same injectable ``wallclock`` the engine uses
    (``AveryEngine(profile=True, wallclock=time.perf_counter)`` builds
    one for you), then the engine wraps its executor via :meth:`wrap`
    and binds the mission clock / jit-root census via :meth:`attach`.
    Every profiled call costs two wallclock reads, one
    ``block_until_ready``, a histogram bump, and a census diff — the
    overhead budget (<5% on a profiled serve) is pinned in tests.
    """

    def __init__(self, wallclock: Callable[[], float],
                 max_spans: int = 2048, max_compile_events: int = 256,
                 device: Optional[Any] = None):
        if wallclock is None:
            raise ValueError(
                "StageProfiler needs an injected wallclock (engine code "
                "never reads the wall clock itself — AV502/AV603)")
        self._wallclock = wallclock
        self._clock: Callable[[], float] = lambda: 0.0
        self._device = device
        self.registry = MetricsRegistry()
        self.spans: deque = deque(maxlen=int(max_spans))
        self.observatory = CompileObservatory(
            max_events=max_compile_events)
        self.n_calls = 0
        self.wall_s = 0.0
        # the cost ledger: totals attributed to finished responses
        self.ledger_flops = 0.0
        self.ledger_hbm_bytes = 0.0
        self.ledger_energy_j = 0.0

    # -- engine binding --

    def attach(self, engine: Any) -> None:
        """Bind the mission clock, the labelled jit-root census, and
        the flight recorder. Called by the engine at construction; safe
        to call again (rebinds)."""
        self._clock = lambda: engine._now
        if self._device is None:
            cost = getattr(engine, "cost_model", None)
            if cost is not None:
                self._device = cost.device

        def roots() -> Dict[str, Any]:
            from repro.analysis.sanitizers import named_jit_roots
            return named_jit_roots(engine)

        self.observatory.bind(roots, flight=getattr(engine, "flight",
                                                    None))
        self.observatory.prime()

    def wrap(self, executor: Any) -> "ProfiledExecutor":
        return ProfiledExecutor(executor, self)

    def wrap_draft(self, draft: Any) -> "ProfiledDraft":
        return ProfiledDraft(draft, self)

    # -- the timed call path --

    def _call(self, stage: str, fn: Callable, args: tuple, kwargs: dict,
              tier: Optional[str] = None,
              bucket: Optional[int] = None) -> Any:
        w0 = self._wallclock()
        out = fn(*args, **kwargs)
        _block(out)
        dt = self._wallclock() - w0
        t = self._clock()
        self.n_calls += 1
        self.wall_s += dt
        self.registry.histogram(f"stage_s:{stage}").observe(dt)
        if tier is not None:
            self.registry.histogram(
                f"stage_s:{stage}:tier={tier}").observe(dt)
        if bucket is not None:
            self.registry.histogram(
                f"stage_s:{stage}:b{int(bucket)}").observe(dt)
        self.spans.append((stage, tier, bucket, t, dt))
        self.observatory.note(stage, dt, t)
        return out

    # -- the cost ledger --

    def note_ledger(self, flops: float, hbm_bytes: float,
                    energy_j: float) -> None:
        self.ledger_flops += flops
        self.ledger_hbm_bytes += hbm_bytes
        self.ledger_energy_j += energy_j

    # -- export --

    def chrome_events(self) -> List[Dict[str, Any]]:
        """The device track: pid 3, one thread per stage, one ``X`` span
        per profiled call. The mission clock does not advance during a
        synchronous drain, so same-stage spans are packed end to end
        from their mission timestamp (the *durations* are the data; the
        packing keeps the track readable and the timeline monotone)."""
        tids: Dict[str, int] = {}
        cursor: Dict[int, float] = {}
        events: List[Dict[str, Any]] = []
        for stage, tier, bucket, t, dt in self.spans:
            tid = tids.setdefault(stage, len(tids) + 1)
            ts = max(t * 1e6, cursor.get(tid, 0.0))
            dur = max(0.0, dt) * 1e6
            cursor[tid] = ts + dur
            args: Dict[str, Any] = {"stage": stage}
            if tier is not None:
                args["tier"] = tier
            if bucket is not None:
                args["bucket"] = int(bucket)
            events.append({"name": stage, "cat": "device", "ph": "X",
                           "pid": DEVICE_TRACK_PID, "tid": tid,
                           "ts": ts, "dur": dur, "args": args})
        for ev in self.observatory.events:
            tid = tids.setdefault(ev["stage"], len(tids) + 1)
            events.append({"name": f"compile:{ev['root']}",
                           "cat": "compile", "ph": "i", "s": "t",
                           "pid": DEVICE_TRACK_PID, "tid": tid,
                           "ts": ev["t"] * 1e6,
                           "args": {"root": ev["root"],
                                    "delta": ev["delta"],
                                    "wall_s": ev["wall_s"]}})
        meta: List[Dict[str, Any]] = [
            {"ph": "M", "name": "process_name", "pid": DEVICE_TRACK_PID,
             "tid": 0, "args": {"name": "device stages"}}]
        for stage in sorted(tids):
            meta.append({"ph": "M", "name": "thread_name",
                         "pid": DEVICE_TRACK_PID, "tid": tids[stage],
                         "args": {"name": stage}})
        return meta + events

    def stats_block(self) -> Dict[str, float]:
        """The profiler's contribution to ``engine.stats`` — a fixed,
        deterministic key set (derived from :data:`PROFILED_STAGES`)
        regardless of which stages actually ran."""
        out: Dict[str, float] = {}
        measured_ledger_wall = 0.0
        for stage in PROFILED_STAGES:
            h = self.registry.histogram(f"stage_s:{stage}")
            out[f"stage_{stage}_calls"] = h.count
            out[f"stage_{stage}_p50_s"] = h.p50
            if stage in _LEDGER_STAGES:
                measured_ledger_wall += h.total
        out["profiled_stage_calls"] = self.n_calls
        out["profiled_wall_s"] = self.wall_s
        out["compile_events"] = self.observatory.n_compiles
        out["compile_wall_s"] = self.observatory.compile_wall_s
        out["compiled_roots"] = self.observatory.n_roots
        out["ledger_flops_total"] = self.ledger_flops
        out["ledger_hbm_bytes_total"] = self.ledger_hbm_bytes
        out["ledger_energy_j_total"] = self.ledger_energy_j
        frac = 0.0
        if self._device is not None and measured_ledger_wall > 0.0:
            frac = self._device.roofline_s(
                self.ledger_flops,
                self.ledger_hbm_bytes) / measured_ledger_wall
        out["decode_roofline_frac"] = frac
        return out


class ProfiledExecutor:
    """Executor wrapper that times every jitted stage entry point
    through the profiler (the same ``_inner`` + ``__getattr__`` shape as
    ``FaultyExecutor``, so sanitizer jit-root discovery unwraps it)."""

    def __init__(self, inner: Any, profiler: StageProfiler):
        self._inner = inner
        self._profiler = profiler

    def __getattr__(self, name: str) -> Any:
        return getattr(self._inner, name)

    def edge_context(self, *a: Any, **kw: Any) -> Any:
        return self._profiler._call("edge_context",
                                    self._inner.edge_context, a, kw)

    def edge_insight(self, *a: Any, **kw: Any) -> Any:
        tier = kw.get("tier", a[1] if len(a) > 1 else None)
        return self._profiler._call(
            "edge_insight", self._inner.edge_insight, a, kw,
            tier=getattr(tier, "name", None))

    def cloud_sam_feats(self, *a: Any, **kw: Any) -> Any:
        pkt = kw.get("packet", a[0] if a else None)
        return self._profiler._call(
            "cloud_sam_feats", self._inner.cloud_sam_feats, a, kw,
            tier=getattr(pkt, "tier_name", None))

    def cloud_prefix(self, *a: Any, **kw: Any) -> Any:
        q = kw.get("query", a[1] if len(a) > 1 else None)
        qlen = None if q is None else int(q.shape[-1])
        return self._profiler._call("cloud_prefix",
                                    self._inner.cloud_prefix, a, kw,
                                    bucket=qlen)

    def pool_write(self, *a: Any, **kw: Any) -> Any:
        return self._profiler._call("pool_write",
                                    self._inner.pool_write, a, kw)

    def cloud_decode_rows(self, *a: Any, **kw: Any) -> Any:
        toks = kw.get("tokens", a[3] if len(a) > 3 else None)
        bucket = None if toks is None else int(toks.shape[0])
        return self._profiler._call(
            "cloud_decode_rows", self._inner.cloud_decode_rows, a, kw,
            bucket=bucket)

    def cloud_verify_rows(self, *a: Any, **kw: Any) -> Any:
        toks = kw.get("tokens", a[3] if len(a) > 3 else None)
        bucket = None if toks is None else int(toks.shape[0])
        return self._profiler._call(
            "cloud_verify_rows", self._inner.cloud_verify_rows, a, kw,
            bucket=bucket)

    def cloud_mask(self, *a: Any, **kw: Any) -> Any:
        return self._profiler._call("cloud_mask",
                                    self._inner.cloud_mask, a, kw)


class ProfiledDraft:
    """Draft-model wrapper timing ``admit`` (the draft prefill) and
    ``draft`` (the lockstep proposal steps) as profiler stages;
    everything else (``commit``/``release``/telemetry attrs) delegates."""

    def __init__(self, inner: Any, profiler: StageProfiler):
        self._inner = inner
        self._profiler = profiler

    def __getattr__(self, name: str) -> Any:
        return getattr(self._inner, name)

    def admit(self, *a: Any, **kw: Any) -> Any:
        return self._profiler._call("draft_admit", self._inner.admit,
                                    a, kw)

    def draft(self, *a: Any, **kw: Any) -> Any:
        return self._profiler._call("draft", self._inner.draft, a, kw)
