"""Transport plug point: how packets cross the edge/cloud boundary.

The engine is transport-agnostic: it senses bandwidth and hands packets
to a ``Transport``; what happens on the wire is an implementation.
Two implementations ship:

  * ``ChannelTransport`` — the paper's simulated FIFO uplink
    (``repro.network.Channel`` against a bandwidth trace); delivery time
    integrates the per-second trace, and the transmit log feeds the
    latency telemetry.
  * ``LoopbackTransport`` — in-process zero-delay link for benchmarks and
    tests: constant sensed bandwidth, instant delivery. Swapping it in
    removes the network from a measurement without touching the loop.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Protocol, runtime_checkable

from repro.core.packets import Packet
from repro.network.channel import Channel, TransmitRecord
from repro.network.traces import BandwidthTrace


@runtime_checkable
class Transport(Protocol):
    """Minimal link contract: sense + send."""

    def bandwidth(self, t: float) -> float:
        """Sensed uplink bandwidth (Mbps) at mission time ``t`` — the
        controller's Sense stage."""
        ...

    def send(self, packet: Packet, t: float) -> TransmitRecord:
        """Put ``packet`` on the link at time ``t``; returns the delivery
        record (start_s/end_s in mission time)."""
        ...


@dataclass
class ChannelTransport:
    """Simulated uplink: a FIFO ``Channel`` over a bandwidth trace."""
    channel: Channel

    @classmethod
    def from_trace(cls, trace: BandwidthTrace) -> "ChannelTransport":
        return cls(Channel(trace))

    def bandwidth(self, t: float) -> float:
        return self.channel.measure_bandwidth(t)

    def send(self, packet: Packet, t: float) -> TransmitRecord:
        return self.channel.transmit(packet, t)

    @property
    def records(self) -> List[TransmitRecord]:
        return self.channel.log

    @property
    def records_dropped(self) -> int:
        return self.channel.records_dropped


@dataclass
class LoopbackTransport:
    """In-process link: constant sensed bandwidth, instant delivery."""
    bandwidth_mbps: float = 1000.0
    records: List[TransmitRecord] = field(default_factory=list)
    # same bound as Channel.max_log: benchmarks loop this transport for
    # thousands of sends and must not accumulate records without bound
    max_records: int = 4096
    n_sent: int = 0

    def bandwidth(self, t: float) -> float:
        return self.bandwidth_mbps

    def send(self, packet: Packet, t: float) -> TransmitRecord:
        rec = TransmitRecord(packet=packet, start_s=t, end_s=t)
        self.records.append(rec)
        self.n_sent += 1
        if len(self.records) > self.max_records:
            del self.records[:len(self.records) - self.max_records]
        return rec

    @property
    def records_dropped(self) -> int:
        return self.n_sent - len(self.records)
