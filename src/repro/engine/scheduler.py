"""Fleet-scale multi-tenant admission: QoS classes, weighted-fair
queues, preemption picking, and per-operator rate limits.

The ``InflightDecoder`` used to admit strictly FIFO from one ``deque``,
so a burst of Insight prefills from one UAV starved every other
operator's Context traffic. This module makes admission a pluggable
policy:

**QoS classes.** Requests map to two classes by intent — Context is the
*latency* class (an operator is waiting on situational awareness),
Insight the *throughput* class (segmentation masks aggregate downstream)
— with an explicit integer ``priority`` override per request/session
layered on top. Higher priority is strictly served first; within a
priority band the classes share slots weighted-fairly.

**Weighted-fair admission.** ``QoSScheduler`` arbitrates the classes
with stride/deficit accounting: each class carries a pass counter that
advances by ``1 / weight`` per admission, and the backlogged class with
the lowest counter admits next. Over any backlogged interval class ``c``
receives ``weight_c / sum(weights)`` of the slots — Insight can't
monopolize, Context can't starve it either. A class returning from idle
is caught up to the backlog floor so it can't bank credit while empty.

**Preemption.** When an urgent request — deadline inside
``preempt_slack_s``, or a latency-class/priority request waiting past
``latency_patience_s`` — would otherwise keep queueing, the scheduler
nominates the lowest-ranked active decode as a victim. The decoder parks
it: private decode pages roll back (``PagePool.rollback_to``), the
prefix reference drops, the generated-so-far tokens ride the request
back to the *front* of its class queue, and on re-admission the row
replays them from its prefix. Greedy decoding is deterministic, so the
resumed request is token-exact with an uninterrupted run (pinned by
tests and the fleet-storm bench); the cost is re-decoding the replayed
tokens, surfaced as ``tokens_replayed``. ``max_resumes`` bounds how
often one request may be parked (anti-thrash), and a victim must rank
*strictly* below the preemptor, so preemption chains terminate.

**Overload control.** A token bucket per ``operator_id`` (shared across
every decoder spawned from one prototype) sheds arrivals from operators
exceeding their rate at the engine front door — before any edge compute
or cloud prefill — and a bounded per-class queue sheds the tail under
global overload. Both resolve the request with ``failure="rejected"``
and a reason (``rate_limit`` / ``queue_full``) instead of letting it
rot in a queue it can never clear.

``FifoScheduler`` preserves the old behavior exactly (one FIFO queue,
never rejects, never preempts) and is the engine default. Telemetry
(queue depth, time-in-queue, preemptions, rejections) lives on a single
object shared by a prototype and everything it ``spawn``s, so
``AveryEngine.stats()`` survives decoder retirement.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.intent import Intent

QOS_LATENCY = "latency"        # Context: an operator is waiting
QOS_THROUGHPUT = "throughput"  # Insight: aggregate throughput matters
QOS_CLASSES = (QOS_LATENCY, QOS_THROUGHPUT)


def qos_class(intent: Intent) -> str:
    """Intent -> QoS class (the class table in docs/engine.md)."""
    return QOS_LATENCY if intent is Intent.CONTEXT else QOS_THROUGHPUT


def _rank(intent: Intent, priority: int) -> Tuple[int, int]:
    """Total order used for strict-priority pops and preemption: the
    explicit priority band first, latency class over throughput within
    a band."""
    return (int(priority), 1 if qos_class(intent) is QOS_LATENCY else 0)


def jain_index(counts) -> float:
    """Jain's fairness index over per-operator served counts: 1.0 is
    perfectly even, 1/n is one operator taking everything."""
    xs = np.asarray(list(counts), dtype=np.float64)
    if xs.size == 0 or not np.any(xs):
        return 1.0
    return float(xs.sum() ** 2 / (xs.size * (xs ** 2).sum()))


@dataclass
class _TokenBucket:
    """Per-operator rate limiter on the mission clock."""
    rate_per_s: float
    burst: float
    tokens: float
    t_last: float

    def take(self, now: float) -> bool:
        self.tokens = min(self.burst, self.tokens
                          + max(0.0, now - self.t_last) * self.rate_per_s)
        self.t_last = max(self.t_last, now)
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


@dataclass
class SchedTelemetry:
    """Counters shared by a scheduler prototype and all its spawns —
    engine stats read these, so they survive decoder retirement."""
    preemptions: int = 0
    resumed_served: int = 0           # finished after >=1 preemption
    tokens_replayed: int = 0
    rejected_rate_limit: int = 0
    rejected_queue_full: int = 0
    expired_pending: int = 0          # dead on arrival at admission
    admitted: Dict[str, int] = field(
        default_factory=lambda: {c: 0 for c in QOS_CLASSES})
    # per-admission time-in-queue samples (mission seconds), bounded
    waits: Dict[str, Deque[float]] = field(
        default_factory=lambda: {c: deque(maxlen=4096)
                                 for c in QOS_CLASSES})

    def note_admitted(self, cls: str, wait_s: float) -> None:
        self.admitted[cls] += 1
        self.waits[cls].append(float(wait_s))

    def wait_percentile(self, cls: str, q: float) -> float:
        w = self.waits[cls]
        return float(np.percentile(list(w), q)) if w else 0.0


class _SchedulerBase:
    """Shared plumbing: the spawn/prototype split, telemetry, and the
    stats surface. A prototype lives on the engine (rate limiting +
    stats); each ``InflightDecoder`` gets a ``spawn()`` with its own
    queues but shared telemetry and token buckets. Constructing a
    scheduler and handing it straight to a decoder (no spawn) also
    works — the instance is then both."""

    def __init__(self) -> None:
        self.telemetry = SchedTelemetry()
        self._buckets: Dict[str, _TokenBucket] = {}
        self._children: List[Any] = []
        self._metrics: Optional[Any] = None

    # -- prototype side --

    def spawn(self):
        child = self._fresh()
        child.telemetry = self.telemetry
        child._buckets = self._buckets
        child._metrics = self._metrics
        self._children.append(child)
        return child

    def bind_metrics(self, registry: Any) -> None:
        """Attach the engine's :class:`MetricsRegistry`: admissions then
        also feed the per-class ``sched_wait_s:<cls>`` histograms (the
        bounded-deque percentiles in ``stats()`` stay authoritative for
        back-compat; the registry adds p99 and the full surface)."""
        self._metrics = registry
        for child in self._children:
            child._metrics = registry

    def _fresh(self):                          # pragma: no cover
        raise NotImplementedError

    def admission_check(self, operator_id: str,
                        now: float) -> Optional[str]:
        """Engine front door: may this operator submit at ``now``?
        Returns a rejection reason or None. Base: no rate limiting."""
        return None

    def _depth(self, cls: str) -> int:
        views = [self] + [c for c in self._children if len(c)]
        return sum(v._class_depth(cls) for v in views)

    def load(self) -> Dict[str, int]:
        """Live queue pressure, the policy's ``adapt_to_load`` input."""
        d = {c: self._depth(c) for c in QOS_CLASSES}
        return {"queue_depth": sum(d.values()),
                "queue_depth_latency": d[QOS_LATENCY],
                "queue_depth_throughput": d[QOS_THROUGHPUT]}

    def stats(self) -> Dict[str, float]:
        t = self.telemetry
        out: Dict[str, float] = {
            "sched_preemptions": t.preemptions,
            "sched_resumed_served": t.resumed_served,
            "sched_tokens_replayed": t.tokens_replayed,
            "sched_rejected_rate_limit": t.rejected_rate_limit,
            "sched_rejected_queue_full": t.rejected_queue_full,
            "sched_expired_pending": t.expired_pending,
        }
        for cls in QOS_CLASSES:
            out[f"sched_queue_depth_{cls}"] = self._depth(cls)
            out[f"sched_admitted_{cls}"] = t.admitted[cls]
            out[f"sched_wait_{cls}_p50_s"] = t.wait_percentile(cls, 50)
            out[f"sched_wait_{cls}_p95_s"] = t.wait_percentile(cls, 95)
        return out

    # -- decoder side (per-spawn queues) --

    def _class_depth(self, cls: str) -> int:   # pragma: no cover
        raise NotImplementedError

    def __len__(self) -> int:
        return sum(self._class_depth(c) for c in QOS_CLASSES)

    @property
    def has_pending(self) -> bool:
        return len(self) > 0

    def note_admitted(self, item, now: float) -> None:
        """Record one admission: accumulate the request's queue wait
        (summed across preemption round-trips) and sample it."""
        wait = max(0.0, now - item.t_enqueue)
        item.queue_wait += wait
        cls = qos_class(item.intent)
        self.telemetry.note_admitted(cls, wait)
        if self._metrics is not None:
            self._metrics.histogram(f"sched_wait_s:{cls}").observe(wait)

    def note_expired(self) -> None:
        self.telemetry.expired_pending += 1

    def note_preempted(self) -> None:
        self.telemetry.preemptions += 1

    def note_resumed_served(self) -> None:
        self.telemetry.resumed_served += 1

    def note_replayed(self, n: int = 1) -> None:
        self.telemetry.tokens_replayed += n

    def pick_preemption(self, active: Dict[int, Any],
                        now: float) -> Optional[Tuple[Any, int]]:
        """Nominate ``(pending_item, victim_slot)`` — the item is popped
        from its queue — or None. Base: never preempt."""
        return None


class FifoScheduler(_SchedulerBase):
    """Today's behavior, verbatim: one FIFO queue, arrival order, no
    rejection, no preemption. ``queue`` is the real deque (tests and
    benches seed it directly)."""

    def __init__(self) -> None:
        super().__init__()
        self.queue: Deque[Any] = deque()

    def _fresh(self) -> "FifoScheduler":
        return FifoScheduler()

    def _class_depth(self, cls: str) -> int:
        return sum(1 for it in self.queue
                   if qos_class(getattr(it, "intent", Intent.INSIGHT))
                   == cls)

    def __len__(self) -> int:
        return len(self.queue)

    def snapshot(self) -> List[Any]:
        return list(self.queue)

    def enqueue(self, item, now: float) -> Optional[str]:
        self.queue.append(item)
        return None

    def pop_next(self, now: float):
        return self.queue.popleft() if self.queue else None

    def requeue_preempted(self, item, now: float) -> None:
        self.queue.appendleft(item)

    def remove(self, seq_id: int) -> bool:
        for i, it in enumerate(self.queue):
            if it.seq_id == seq_id:
                del self.queue[i]
                return True
        return False


class QoSScheduler(_SchedulerBase):
    """Intent-aware QoS: per-class queues, strict priority bands,
    stride/deficit weighted-fair arbitration, token-bucket rate limits
    per operator, bounded queues, and preemption picking.

    ``rate_per_s``/``burst`` set a default per-operator limit (None
    disables); ``rate_overrides`` maps operator_id -> (rate, burst).
    ``max_queue`` bounds each class queue (None = unbounded).
    """

    def __init__(self,
                 weights: Optional[Dict[str, float]] = None,
                 max_queue: Optional[int] = None,
                 rate_per_s: Optional[float] = None,
                 burst: Optional[float] = None,
                 rate_overrides: Optional[
                     Dict[str, Tuple[float, float]]] = None,
                 preempt: bool = True,
                 preempt_slack_s: float = 0.25,
                 latency_patience_s: float = 0.5,
                 max_resumes: int = 2):
        super().__init__()
        self.weights = dict(weights or {QOS_LATENCY: 2.0,
                                        QOS_THROUGHPUT: 1.0})
        for cls in QOS_CLASSES:
            if self.weights.get(cls, 0.0) <= 0.0:
                raise ValueError(f"weight for {cls!r} must be positive")
        self.max_queue = max_queue
        self.rate_per_s = rate_per_s
        self.burst = burst if burst is not None else (
            2.0 * rate_per_s if rate_per_s else 1.0)
        self.rate_overrides = dict(rate_overrides or {})
        self.preempt = preempt
        self.preempt_slack_s = float(preempt_slack_s)
        self.latency_patience_s = float(latency_patience_s)
        self.max_resumes = int(max_resumes)
        self._queues: Dict[str, List[Any]] = {c: [] for c in QOS_CLASSES}
        # stride accounting: pass counter per class, +1/weight per pop
        self._pass: Dict[str, float] = {c: 0.0 for c in QOS_CLASSES}

    def _fresh(self) -> "QoSScheduler":
        return QoSScheduler(
            weights=self.weights, max_queue=self.max_queue,
            rate_per_s=self.rate_per_s, burst=self.burst,
            rate_overrides=self.rate_overrides, preempt=self.preempt,
            preempt_slack_s=self.preempt_slack_s,
            latency_patience_s=self.latency_patience_s,
            max_resumes=self.max_resumes)

    def _class_depth(self, cls: str) -> int:
        return len(self._queues[cls])

    def snapshot(self) -> List[Any]:
        return [it for c in QOS_CLASSES for it in self._queues[c]]

    # -- rate limiting (engine front door) --

    def admission_check(self, operator_id: str,
                        now: float) -> Optional[str]:
        rate = self.rate_overrides.get(operator_id,
                                       (self.rate_per_s, self.burst))
        if rate[0] is None:
            return None
        bucket = self._buckets.get(operator_id)
        if bucket is None:
            bucket = self._buckets[operator_id] = _TokenBucket(
                rate_per_s=float(rate[0]), burst=float(rate[1]),
                tokens=float(rate[1]), t_last=now)
        if bucket.take(now):
            return None
        self.telemetry.rejected_rate_limit += 1
        return "rate_limit"

    # -- queueing --

    def enqueue(self, item, now: float) -> Optional[str]:
        cls = qos_class(item.intent)
        q = self._queues[cls]
        if self.max_queue is not None and len(q) >= self.max_queue:
            self.telemetry.rejected_queue_full += 1
            return "queue_full"
        if not q:
            self._catch_up(cls)
        q.append(item)
        return None

    def _catch_up(self, cls: str) -> None:
        """A class returning from idle must not spend credit banked
        while empty: lift its pass counter to the backlog floor."""
        others = [self._pass[c] for c in QOS_CLASSES
                  if c is not cls and self._queues[c]]
        if others:
            self._pass[cls] = max(self._pass[cls], min(others))

    def pop_next(self, now: float):
        cands = [c for c in QOS_CLASSES if self._queues[c]]
        if not cands:
            return None
        # strict priority: only classes holding the top band compete
        head = {c: max(it.priority for it in self._queues[c])
                for c in cands}
        band = max(head.values())
        band_cands = [c for c in cands if head[c] == band]
        cls = min(band_cands,
                  key=lambda c: (self._pass[c], QOS_CLASSES.index(c)))
        self._pass[cls] += 1.0 / self.weights[cls]
        q = self._queues[cls]
        idx = next(i for i, it in enumerate(q) if it.priority == band)
        return q.pop(idx)

    def requeue_preempted(self, item, now: float) -> None:
        """A parked victim has seniority: front of its class queue
        (priority order at pop still holds — same-priority FIFO just
        resumes it first)."""
        cls = qos_class(item.intent)
        if not self._queues[cls]:
            self._catch_up(cls)
        self._queues[cls].insert(0, item)

    def remove(self, seq_id: int) -> bool:
        for q in self._queues.values():
            for i, it in enumerate(q):
                if it.seq_id == seq_id:
                    del q[i]
                    return True
        return False

    # -- preemption --

    def _urgent(self, item, cls: str, now: float) -> bool:
        if item.deadline is not None \
                and now + self.preempt_slack_s >= item.deadline:
            return True
        waited = now - item.t_enqueue
        if cls is QOS_LATENCY and waited >= self.latency_patience_s:
            return True
        return item.priority > 0 and waited >= self.latency_patience_s

    def pick_preemption(self, active: Dict[int, Any],
                        now: float) -> Optional[Tuple[Any, int]]:
        """The most urgent pending request may evict the lowest-ranked
        active decode — only one ranked *strictly* below it, never one
        already parked ``max_resumes`` times. Pops the item from its
        queue on success (the caller admits it into the freed slot)."""
        if not self.preempt or not active:
            return None
        best = None          # (rank, waited, cls, index)
        for cls in QOS_CLASSES:
            for i, item in enumerate(self._queues[cls]):
                if not self._urgent(item, cls, now):
                    continue
                key = (_rank(item.intent, item.priority),
                       now - item.t_enqueue)
                if best is None or key > best[0]:
                    best = (key, cls, i)
        if best is None:
            return None
        (rank, _), cls, idx = best
        victim = None        # (rank, tokens_done, slot)
        for slot, st in active.items():
            vr = _rank(st.req.intent, st.req.priority)
            if vr >= rank or st.req.resumes >= self.max_resumes:
                continue
            key = (vr, len(st.tokens))
            if victim is None or key < victim[0]:
                victim = (key, slot)
        if victim is None:
            return None
        return self._queues[cls].pop(idx), victim[1]
