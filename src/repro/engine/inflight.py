"""Token-level continuous batching: the in-flight decode batch over a
paged, shared-prefix KV cache.

The ``MicrobatchScheduler`` closes a microbatch before serving it — a
request that arrives one step after a generate batch launched waits for
the whole batch. The ``InflightDecoder`` removes that barrier: between
any two decode steps a newly arrived request is prefilled into a free
slot and rides the remaining steps of the running batch (ROADMAP
"in-flight batching", the vLLM-style continuous batching discipline).

KV is **paged** (``core.paging``): each slot addresses the shared page
pool through a per-row page table instead of owning a contiguous
``width`` ring. Admission is keyed on prefix reuse — the ``[ctx; query]``
prefix is content-hashed per operator, the first frame pays the LLM
prefill and pins read-only prefix pages, and every repeat-prefix frame
(successive frames of one UAV under a standing query) maps the same
pages plus fresh private decode pages and skips the prefill entirely.
So N UAVs x M frames pay N prefix prefills, and slot KV memory scales
with distinct prefixes + live decode tokens, not slots x width.

Per slot lifecycle (mirroring ``vlm.llm_generate``'s seg convention):
prefix prefill (or store hit) emits token 0; each lockstep decode step
feeds the slot's last token at its own position into its own write slot;
after ``T`` steps the slot's final step has read the <SEG> hidden state
at the last generated token, the mask decodes from the per-frame SAM
features (always computed — frames differ even when the prefix repeats),
and the slot's private pages free for reuse. Slots may mix tiers and
intents; Context requests ride the same T decode steps as Insight ones,
matching ``cloud_generate_batch`` exactly (the equivalence tests pin
token-level parity, including under slot reuse).

One decoder serves one query length (page tables are fixed-shape per
qlen); decoders on one engine share one ``PagePool``, so prefix pages
cached by a retired decoder stay warm for its successors.

Admission order is pluggable (``engine.scheduler``): the default
``FifoScheduler`` reproduces the historical single-deque behavior;
``QoSScheduler`` adds intent-aware classes, weighted-fair + strict-
priority pops, bounded queues, and preemption — an urgent queued
request parks the lowest-ranked active decode (pages rolled back, its
generated tokens carried along) and the victim later resumes token-
exactly by replaying them from its prefix. Expired deadlines resolve
at the admission boundary, before any prefill is paid.

With a ``SpeculativeConfig`` the decoder runs the draft/verify loop
(``engine.speculative``): each pump step first lets the Context-stream
``DraftModel`` propose k tokens per speculating row, then scores every
row's chunk — its last accepted token plus the drafts, plain rows a
chunk of one — through the serving model in a single paged multi-token
pass (``cloud_verify_rows``). Greedy acceptance advances each row by
1..k+1 tokens per step; decode pages are allocated ahead for the draft
overhang and rolled back past the accepted length on rejection
(``PagePool.grow_to``/``rollback_to``), and the acceptance-rate stats
feed the control policy's drafting gate. Output is token-exact with the
plain path (and with ``llm_generate``) by construction — a draft is
accepted only where it equals the serving model's own greedy pick.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core import packets as pk
from repro.core.intent import Intent
from repro.core.paging import (TRASH_PAGE, PagePool, pages_for,
                               prefix_digest, prefix_positions)
from repro.engine.faults import CloudStageError
from repro.engine.observability import Tracer
from repro.engine.scheduler import FifoScheduler, qos_class
from repro.engine.speculative import (DraftModel, SpecStats,
                                      SpeculativeConfig, greedy_accept)


@dataclass
class _PendingRequest:
    seq_id: int
    intent: Intent
    packet: pk.Packet
    query: np.ndarray
    on_done: Callable[[Dict[str, Any]], None]
    operator_id: str = ""
    speculative: Optional[bool] = None   # None -> decoder default
    # scheduling state (see engine.scheduler)
    priority: int = 0                 # strict band; higher admits first
    deadline: Optional[float] = None  # mission-clock expiry
    t_enqueue: float = 0.0            # when this wait segment started
    queue_wait: float = 0.0           # total time queued (all segments)
    resumes: int = 0                  # times parked by preemption
    resume_tokens: Optional[List[int]] = None  # generated-so-far tokens
    t_first_token: Optional[float] = None  # first admission (TTFT anchor)


@dataclass
class _SlotState:
    req: _PendingRequest
    tokens: List[int]                 # greedy answer tokens so far
    logits0: np.ndarray               # (1, V) first-token logits
    feats: Optional[Any]              # (1, T_sam, d_sam) or None (context)
    pos: int                          # absolute position of the next token
    joined_step: int                  # global step index at admission
    prefix_ids: Tuple[int, ...]       # shared prefix pages (one ref held)
    private_ids: List[int]            # this slot's decode pages
    prefix_hit: bool
    speculative: bool = False         # drafting enabled for this row
    seg: Optional[np.ndarray] = None  # <SEG> state once the final token fed
    steps_done: int = 0
    batch_acc: int = 0                # sum of co-active slots over steps
    replay: Optional[Deque[int]] = None  # parked tokens to re-decode
    t_admit: float = 0.0              # this residency segment's start
    flops: float = 0.0                # attributed cloud FLOPs (cost ledger)
    hbm_bytes: float = 0.0            # attributed HBM traffic (cost ledger)


class InflightDecoder:
    """Drives the executor's paged in-flight stages over a fixed slot
    layout.

    One decoder serves one query length (the prefill shape); the engine
    keys decoders by qlen the same way the microbatch scheduler keys
    batches. ``submit`` admits into a free slot immediately (prefix
    lookup/prefill + page allocation); ``step`` advances every live slot
    one token; ``drain`` runs admission + steps until no work remains.
    """

    def __init__(self, executor, slots: int = 8,
                 pool: Optional[PagePool] = None,
                 spec: Optional[SpeculativeConfig] = None,
                 spec_gate: Optional[Callable[[SpecStats], bool]] = None,
                 spec_prefix_rows: Optional[Dict[Any, Any]] = None,
                 scheduler: Optional[Any] = None,
                 clock: Optional[Callable[[], float]] = None,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[Any] = None,
                 wallclock: Optional[Callable[[], float]] = None,
                 profiler: Optional[Any] = None,
                 cost: Optional[Any] = None):
        self.executor = executor
        # device-level observability (engine.profiler): the profiler
        # wraps lazily built draft models; the cost model attributes
        # analytic FLOPs/HBM bytes to each request as it decodes
        self._profiler = profiler
        self._cost = cost
        # observability (engine.observability): the engine threads its
        # tracer/registry through; a standalone decoder records nothing
        self.tracer = tracer if tracer is not None else Tracer()
        self._metrics = metrics
        self._wallclock = wallclock
        # admission policy (engine.scheduler): the engine passes a
        # per-decoder spawn sharing fleet-wide telemetry/rate buckets;
        # standalone decoders default to plain FIFO
        self.scheduler = scheduler if scheduler is not None \
            else FifoScheduler()
        self._clock = clock or (lambda: 0.0)
        self.slots = int(slots)
        self.T = int(executor.max_new_tokens)
        self.pool = pool if pool is not None else PagePool(
            page_size=executor.page_size)
        if self.pool.page_size != executor.page_size:
            raise ValueError(
                f"pool page_size {self.pool.page_size} != executor "
                f"page_size {executor.page_size}")
        # speculative decoding: config + the policy's drafting gate; the
        # DraftModel is built lazily once the prefix geometry is known
        self.spec = spec
        self.spec_gate = spec_gate or (lambda stats: True)
        self.spec_stats = SpecStats()
        # engine-shared draft prefill rows (survive decoder retirement,
        # like the target's prefix pages); None -> private to this decoder
        self.spec_prefix_rows = spec_prefix_rows
        self.draft: Optional[DraftModel] = None
        self.active: Dict[int, _SlotState] = {}
        self.qlen: Optional[int] = None
        # per-slot paging state, shaped once qlen is known
        self.page_tables: Optional[np.ndarray] = None   # (slots, n_pages)
        self.positions: Optional[np.ndarray] = None     # (slots, W_virtual)
        self.step_idx = 0                 # global decode-step counter
        self.n_steps = 0
        self.n_slot_steps = 0             # sum of live slots across steps
        self.n_served = 0
        self.n_cancelled = 0              # requests removed via cancel()
        self.n_stage_faults = 0           # CloudStageErrors absorbed
        self.n_preempted = 0              # rows parked for urgent work
        self.n_rejected = 0               # shed at enqueue (queue bound)
        self.n_expired = 0                # dead on arrival at admission
        self._admitting = False           # reentrancy guard (see admit)

    @property
    def pending(self):
        """Compat view of queued admissions. The FIFO path exposes its
        real deque (tests/benches seed it directly); QoS schedulers
        return a read-only snapshot across their class queues."""
        q = getattr(self.scheduler, "queue", None)
        return q if q is not None else self.scheduler.snapshot()

    # ---- geometry (fixed once qlen is known) ----

    @property
    def prefix_len(self) -> int:
        return self.executor.pcfg.clip_tokens + self.qlen

    @property
    def n_prefix_pages(self) -> int:
        return pages_for(self.prefix_len, self.pool.page_size)

    @property
    def n_private_pages(self) -> int:
        return pages_for(self.T, self.pool.page_size)

    @property
    def width(self) -> int:
        """Virtual sequence width of one row (page-padded)."""
        return (self.n_prefix_pages + self.n_private_pages) \
            * self.pool.page_size

    @property
    def has_work(self) -> bool:
        return bool(self.scheduler.has_pending or self.active)

    # ---- queueing ----

    def submit(self, seq_id: int, intent: Intent, packet: pk.Packet, query,
               on_done: Callable[[Dict[str, Any]], None],
               operator_id: str = "",
               speculative: Optional[bool] = None,
               priority: int = 0,
               deadline: Optional[float] = None,
               t_submit: Optional[float] = None) -> None:
        """``speculative``: per-request drafting override — None follows
        the decoder's config (drafting iff a ``SpeculativeConfig`` was
        given), False forces a plain row even on a speculating decoder
        (plain and speculating rows share the verify batch).

        ``priority``/``deadline``/``t_submit`` feed the scheduler:
        strict band, mission-clock expiry (expired items resolve
        ``failure="deadline"`` *before* paying a prefill), and the
        enqueue timestamp for time-in-queue accounting. A bounded or
        rate-limited scheduler may shed the request here — ``on_done``
        then fires immediately with ``failure="rejected"``."""
        query = np.asarray(query).reshape(-1, np.asarray(query).shape[-1])
        if query.shape[0] != 1:
            raise ValueError(
                "in-flight slots hold one sequence each; split "
                f"{query.shape[0]}-row packets at the edge")
        if self.qlen is None:
            self.qlen = int(query.shape[-1])
        elif int(query.shape[-1]) != self.qlen:
            raise ValueError(
                f"decoder serves qlen={self.qlen}, got {query.shape[-1]}")
        now = self._clock()
        item = _PendingRequest(seq_id, intent, packet, query, on_done,
                               operator_id, speculative=speculative,
                               priority=int(priority), deadline=deadline,
                               t_enqueue=t_submit if t_submit is not None
                               else now)
        reason = self.scheduler.enqueue(item, now)
        if reason is not None:
            self.n_rejected += 1
            item.on_done({
                "seq_id": item.seq_id, "intent": item.intent,
                "tier_name": item.packet.tier_name,
                "failure": "rejected", "reason": reason})
            return
        self.admit()

    # ---- admission: prefix reuse + page allocation between steps ----

    @staticmethod
    def _prefix_ctx(packet: pk.Packet) -> np.ndarray:
        """The context features feeding the LLM prefix — the CLIP stream
        riding in either packet kind."""
        return packet.content["clip" if packet.kind == "insight" else "ctx"]

    def admit(self) -> int:
        """Admit queued requests into free slots in scheduler order,
        then let urgent queued work preempt. A ``CloudStageError`` from
        an admission stage fails only that request — its pages are
        unwound refcount-safely by ``_admit_one`` and ``on_done`` fires
        with a ``cloud_error`` failure — and admission continues.
        Reentrant calls (an ``on_done`` callback resubmitting a retry
        mid-admission) are no-ops; the outer loop picks up whatever they
        queued."""
        if self._admitting:
            return 0
        self._admitting = True
        try:
            admitted = 0
            now = self._clock()
            while self.scheduler.has_pending \
                    and len(self.active) < self.slots:
                item = self.scheduler.pop_next(now)
                if item is None:
                    break
                admitted += self._try_admit(item, now)
            # preemption: an urgent pending request (deadline at risk,
            # or latency-class/priority patience exceeded) evicts the
            # lowest-ranked active decode; the victim parks token-
            # exactly and requeues at the front of its class. Bounded
            # by ``slots`` — each round parks one strictly lower-ranked
            # victim, so chains terminate.
            for _ in range(self.slots):
                if not (self.scheduler.has_pending and self.active):
                    break
                pick = self.scheduler.pick_preemption(self.active, now)
                if pick is None:
                    break
                item, victim = pick
                self._park_slot(victim, self.active[victim])
                admitted += self._try_admit(item, now)
            return admitted
        finally:
            self._admitting = False

    def _try_admit(self, item: _PendingRequest, now: float) -> int:
        """Admit one popped item. An already-expired deadline resolves
        ``failure="deadline"`` here — *before* the prefill — so a dead
        request can never waste cloud compute on its way out."""
        if item.deadline is not None and now >= item.deadline:
            self.n_expired += 1
            self.scheduler.note_expired()
            item.on_done({
                "seq_id": item.seq_id, "intent": item.intent,
                "tier_name": item.packet.tier_name,
                "failure": "deadline"})
            return 0
        try:
            slot, st = self._admit_one(item)
            self.scheduler.note_admitted(item, now)
            st.t_admit = now
            if item.t_first_token is None:
                item.t_first_token = now   # token 0 exists from here on
            if self.tracer.enabled:
                rid = item.seq_id
                self.tracer.span(rid, "queue", item.t_enqueue,
                                 max(now, item.t_enqueue))
                if item.resumes and item.resume_tokens is not None:
                    self.tracer.point(rid, "resume", now, slot=slot,
                                      replayed=len(item.resume_tokens))
                self.tracer.span(
                    rid, "prefix_hit" if st.prefix_hit else "prefill",
                    now, now, slot=slot)
            return 1
        except CloudStageError as e:
            self.n_stage_faults += 1
            item.on_done({
                "seq_id": item.seq_id, "intent": item.intent,
                "tier_name": item.packet.tier_name,
                "failure": "cloud_error", "error": str(e)})
            return 0

    def _admit_one(self, item: _PendingRequest
                   ) -> Tuple[int, _SlotState]:
        """Prefill one request into a free slot; returns the slot and
        its state. Any stage failure unwinds exactly the pages acquired
        so far and re-raises, so a fault mid-admission never leaks a
        page or corrupts the prefix store (a faulted miss leaves the
        store either without the entry or with a fully written one)."""
        page = self.pool.page_size
        ctx = self._prefix_ctx(item.packet)
        key = (item.operator_id, prefix_digest(ctx, item.query))
        entry = self.pool.lookup_prefix(key)
        hit = entry is not None
        if not hit:
            logits0, paged = self.executor.cloud_prefix(ctx, item.query)
            self.pool.ensure(
                self.n_prefix_pages, like=paged,
                capacity_hint=1 + self.slots * (self.n_prefix_pages
                                                + self.n_private_pages))
            ids = self.pool.alloc(self.n_prefix_pages)
            try:
                self.pool.kv = self.executor.pool_write(self.pool.kv, paged,
                                                        ids)
            except Exception:
                self.pool.release(ids)
                raise
            entry = self.pool.put_prefix(key, ids, self.prefix_len,
                                         np.asarray(logits0))
        else:
            # a hit rides the stored pages: take this request's ref
            # (a miss already owns its pages' alloc reference)
            self.pool.retain(entry.page_ids)
        # SAM feats before decode-page allocation: a feats fault unwinds
        # by dropping this request's prefix ref alone (the store keeps
        # its own ref, so a retry hits the cached prefix)
        try:
            feats = (self.executor.cloud_sam_feats(item.packet)
                     if item.packet.kind == "insight" else None)
        except Exception:
            self.pool.release(entry.page_ids)
            raise
        speculative = (self.spec is not None
                       and item.speculative is not False)
        # speculating rows allocate decode pages lazily per verify
        # chunk (grow ahead of acceptance, roll back on rejection);
        # plain rows keep the whole answer's pages up front
        private = ([] if speculative
                   else self.pool.alloc(self.n_private_pages))
        slot = min(set(range(self.slots)) - set(self.active))
        if self.page_tables is None:
            n_pages = self.n_prefix_pages + self.n_private_pages
            self.page_tables = np.full((self.slots, n_pages),
                                       TRASH_PAGE, np.int32)
            self.positions = np.full((self.slots, self.width), -1,
                                     np.int32)
        self.page_tables[slot] = (list(entry.page_ids) + private
                                  + [TRASH_PAGE]
                                  * (self.n_private_pages
                                     - len(private)))
        self.positions[slot] = -1
        self.positions[slot, :self.n_prefix_pages * page] = \
            prefix_positions(self.prefix_len, self.n_prefix_pages, page)
        if speculative:
            if self.draft is None:
                self.draft = self._make_draft()
            # same key as the target prefix store: repeat-prefix
            # frames skip the draft prefill too (honouring the
            # pool's sharing knob so baselines stay baselines)
            self.draft.admit(slot, ctx, item.query,
                             key=key if self.pool.share_prefixes
                             else None)
        st = _SlotState(
            req=item, tokens=[int(np.argmax(entry.logits0[0]))],
            logits0=entry.logits0, feats=feats, pos=self.prefix_len,
            joined_step=self.step_idx, prefix_ids=entry.page_ids,
            private_ids=private, prefix_hit=hit,
            speculative=speculative)
        if self._cost is not None and not hit:
            # a prefix hit rides cached pages: only the miss pays (and is
            # charged for) the full-sequence prefill
            st.flops = self._cost.prefill_flops(self.prefix_len)
        if item.resume_tokens:
            # a parked victim resumes from its prefix: token 0 re-emerges
            # from the (cached or re-prefilled) prefix logits, the rest
            # replay through the decode loop. Greedy decoding makes the
            # replay byte-identical to the original run, so the resumed
            # request stays token-exact with an uninterrupted one.
            st.replay = deque(item.resume_tokens[1:])
        self.active[slot] = st
        return slot, st

    # ---- cancellation (deadline enforcement) ----

    def cancel(self, seq_id: int) -> bool:
        """Remove one request from the decoder — pending or mid-decode —
        releasing its slot and pages refcount-safely. The caller (the
        engine's deadline sweep) resolves the request's future; the
        decoder only reclaims resources. Returns False when ``seq_id``
        is not here (already finished, or queued on another decoder)."""
        if self.scheduler.remove(seq_id):
            self.n_cancelled += 1
            return True
        for s, st in list(self.active.items()):
            if st.req.seq_id == seq_id:
                self._release_slot(s, st)
                self.n_cancelled += 1
                self.admit()          # the freed slot lets queued work in
                return True
        return False

    def _make_draft(self) -> DraftModel:
        cfg = self.spec
        draft = DraftModel(
            cfg.draft_params or self.executor.params,
            cfg.draft_pcfg or self.executor.pcfg,
            slots=self.slots, prefix_len=self.prefix_len,
            max_new_tokens=self.T, draft_tokens=cfg.draft_tokens,
            flash_decode=getattr(self.executor, "flash_decode", False),
            prefix_rows=self.spec_prefix_rows,
            prefix_cap=self.pool.max_prefixes,
            # sharded serving context: draft stages jitted with mesh
            # shardings so the draft rides the same tensor parallelism
            fns_factory=getattr(self.executor, "draft_fns", None))
        if self._profiler is not None:
            draft = self._profiler.wrap_draft(draft)
        return draft

    # ---- the lockstep decode step ----

    def step(self) -> int:
        """Advance every live slot (no-op when idle); returns the number
        of requests that finished on this step. Plain rows advance one
        token; speculating rows advance by however many drafted tokens
        the serving model accepts (1..k+1), sharing the same verify
        batch."""
        if not self.active:
            return 0
        draft_rows = {}
        if self.spec is not None and self.draft is not None:
            # resumed rows replay their parked tokens through the plain
            # path first (drafting against a replay is pointless — the
            # outcome is already known); they rejoin drafting once the
            # replay drains
            candidates = {s: st for s, st in self.active.items()
                          if st.speculative and len(st.tokens) < self.T
                          and not st.replay}
            if candidates and self.spec_gate(self.spec_stats):
                draft_rows = candidates
            elif candidates:
                self.spec_stats.disabled_steps += 1
        if draft_rows:
            return self._step_verify(draft_rows)
        return self._step_plain()

    def _step_plain(self) -> int:
        """One single-token decode step over all live rows (the non-
        speculative path; also serves speculating rows whose drafting
        the policy has disabled, and rows that only need their final
        <SEG> read)."""
        base = self.n_prefix_pages * self.pool.page_size
        toks = np.zeros((self.slots, 1), np.int32)
        # free rows decode garbage through the trash page (their page
        # tables were reset on release); outputs are discarded
        pos = np.zeros((self.slots,), np.int32)
        write_slot = np.zeros((self.slots,), np.int32)
        for s, st in self.active.items():
            # speculating rows manage decode pages lazily — make sure the
            # slot being written is covered (no-op for plain rows, whose
            # pages were allocated up front)
            self._grow_private(s, st, len(st.tokens))
            toks[s, 0] = st.tokens[-1]
            pos[s] = st.pos
            write_slot[s] = base + len(st.tokens) - 1
        wc = self._wallclock
        w0 = wc() if wc is not None else 0.0
        try:
            logits, seg, self.pool.kv = self.executor.cloud_decode_rows(
                self.pool.kv, self.page_tables, self.positions, toks, pos,
                write_slot)
        except CloudStageError as e:
            return self._fail_step(e)
        if wc is not None and self._metrics is not None:
            self._metrics.histogram("decode_step_s").observe(wc() - w0)
        logits, seg = np.asarray(logits), np.asarray(seg)
        live = len(self.active)
        self.n_steps += 1
        self.n_slot_steps += live
        now = self._clock()
        finished = 0
        for s, st in list(self.active.items()):
            n = len(st.tokens)
            self.positions[s, base + n - 1] = st.pos
            st.steps_done += 1
            st.batch_acc += live
            if self._cost is not None:
                # one fed token attending st.pos + 1 cached positions
                st.flops += self._cost.token_flops(st.pos + 1)
                st.hbm_bytes += self._cost.token_hbm_bytes(st.pos + 1)
            if self.tracer.enabled:
                self.tracer.point(st.req.seq_id, "decode_step", now,
                                  slot=s, step=self.step_idx)
            if n < self.T:
                if st.replay:
                    # replaying a parked run: the stored token IS the
                    # greedy pick (deterministic decode), so feeding it
                    # keeps the resumed row token-exact
                    st.tokens.append(st.replay.popleft())
                    self.scheduler.note_replayed()
                else:
                    st.tokens.append(int(np.argmax(logits[s])))
                st.pos += 1
                continue
            # final step: this row's seg is the <SEG> state at the last
            # generated token (llm_generate's convention for every T)
            st.seg = seg[s]
            finished += self._finish_slot(s, st)
        self.step_idx += 1
        if finished:
            self.admit()              # freed slots let queued requests in
        return finished

    def _step_verify(self, draft_rows: Dict[int, _SlotState]) -> int:
        """One speculative verify step: drafting rows carry their last
        accepted token plus k Context-stream drafts, every other live
        row a chunk of one; a single paged multi-token pass scores them
        all, greedy acceptance advances each row, and decode pages past
        each row's accepted length roll back."""
        k = self.spec.draft_tokens
        C = k + 1
        page = self.pool.page_size
        base = self.n_prefix_pages * page
        proposals = self.draft.draft(
            {s: st.tokens for s, st in draft_rows.items()}, k,
            budgets={s: self.T - len(st.tokens)
                     for s, st in draft_rows.items()})
        toks = np.zeros((self.slots, C), np.int32)
        pos = np.zeros((self.slots,), np.int32)
        write_slot = np.zeros((self.slots,), np.int32)
        clens = np.ones((self.slots,), np.int32)
        n_drafted: Dict[int, int] = {}
        for s, st in self.active.items():
            n = len(st.tokens)
            toks[s, 0] = st.tokens[-1]
            pos[s] = st.pos
            write_slot[s] = base + n - 1
            if s in proposals:
                j = min(k, self.T - n)        # never draft past the answer
                n_drafted[s] = j
                toks[s, 1:1 + j] = proposals[s][:j]
                clens[s] = 1 + j
            # cover the chunk (incl. the draft overhang) with decode pages
            self._grow_private(s, st, n - 1 + int(clens[s]))
        wc = self._wallclock
        w0 = wc() if wc is not None else 0.0
        try:
            logits, seg, self.pool.kv = self.executor.cloud_verify_rows(
                self.pool.kv, self.page_tables, self.positions, toks, pos,
                write_slot, clens)
        except CloudStageError as e:
            return self._fail_step(e)
        if wc is not None and self._metrics is not None:
            self._metrics.histogram("verify_step_s").observe(wc() - w0)
        logits, seg = np.asarray(logits), np.asarray(seg)
        live = len(self.active)
        self.n_steps += 1
        self.n_slot_steps += live
        now = self._clock()
        finished = 0
        for s, st in list(self.active.items()):
            n = len(st.tokens)
            j = n_drafted.get(s, 0)
            if self._cost is not None:
                # every fed chunk token costs device compute whether or
                # not its draft is accepted — rejected drafts are real
                # FLOPs, which is exactly what the ledger should show
                for i in range(int(clens[s])):
                    st.flops += self._cost.token_flops(st.pos + i + 1)
                    st.hbm_bytes += self._cost.token_hbm_bytes(
                        st.pos + i + 1)
            # greedy[i]: the serving model's own pick after chunk token i
            greedy = np.argmax(logits[s, :1 + j], axis=-1)
            m = greedy_accept(toks[s, 1:1 + j], greedy) if j else 0
            # chunk tokens 0..m are now committed: the real last token
            # plus m accepted drafts
            for i in range(m + 1):
                self.positions[s, base + n - 1 + i] = st.pos + i
            new = [int(g) for g in greedy[:m + 1]][:self.T - n]
            st.tokens.extend(new)
            st.pos += len(new)
            if st.replay:
                # a resumed row riding someone else's verify batch
                # advances by the model's own greedy picks — identical
                # to the parked tokens — so its replay drains in step
                for _ in new:
                    if st.replay:
                        st.replay.popleft()
                        self.scheduler.note_replayed()
            st.steps_done += 1
            st.batch_acc += live
            if self.tracer.enabled:
                self.tracer.point(st.req.seq_id, "verify_step", now,
                                  slot=s, step=self.step_idx,
                                  drafted=j, accepted=int(m))
            if j:
                # accepted drafts the draft model itself fed (d_1..d_{j-1}
                # — the j-th came off the last feed's logits) already live
                # in its cache at their committed positions: skip their
                # catch-up feed next round
                self.draft.commit(s, n + min(m, j - 1))
                self.spec_stats.note_chunk(j, m, len(new),
                                           metrics=self._metrics)
                # rollback: free decode pages past the accepted length
                dropped = self.pool.rollback_to(st.private_ids, n + m)
                if dropped:
                    self.spec_stats.pages_rolled_back += len(dropped)
                    lo = self.n_prefix_pages + len(st.private_ids)
                    self.page_tables[s, lo:lo + len(dropped)] = TRASH_PAGE
            if n - 1 + m >= self.T - 1:
                # the answer's final token was fed and accepted in this
                # chunk: its hidden state is the <SEG> read
                st.seg = seg[s, self.T - n]
                finished += self._finish_slot(s, st)
        self.step_idx += 1
        if finished:
            self.admit()
        return finished

    def _grow_private(self, slot: int, st: _SlotState, tokens: int) -> None:
        """Extend one row's private decode pages to cover ``tokens``
        virtual slots (speculative allocation ahead of acceptance) and
        map the fresh pages into its page table."""
        lo = self.n_prefix_pages + len(st.private_ids)
        fresh = self.pool.grow_to(st.private_ids, tokens)
        if fresh:
            self.page_tables[slot, lo:lo + len(fresh)] = fresh

    def _fail_step(self, err: CloudStageError) -> int:
        """A batch-wide decode/verify stage died: the step failed for
        every live row (the paged pass is one device call). Release all
        slots first — pages back, tables parked — then report each
        request as a ``cloud_error`` (callbacks may resubmit retries
        into the now-free slots), then admit queued work."""
        self.n_stage_faults += 1
        failed = list(self.active.items())
        for s, st in failed:
            self._release_slot(s, st)
        for _, st in failed:
            st.req.on_done({
                "seq_id": st.req.seq_id, "intent": st.req.intent,
                "tier_name": st.req.packet.tier_name,
                "failure": "cloud_error", "error": str(err)})
        self.admit()
        return 0

    def _finish_slot(self, s: int, st: _SlotState) -> int:
        """Deliver a finished row: decode its mask from the stored SAM
        feats and the captured <SEG> state, hand the result back, and
        release its pages."""
        if self.tracer.enabled:
            # close this residency segment: preemption round-trips give
            # one decode span per segment, bounded by park/resume points
            now = self._clock()
            self.tracer.span(st.req.seq_id, "decode", st.t_admit,
                             max(now, st.t_admit), slot=s,
                             tokens=len(st.tokens))
        mask = None
        if st.feats is not None:
            try:
                mask = np.asarray(self.executor.cloud_mask(
                    st.feats, st.seg[None]))
            except CloudStageError as e:
                self.n_stage_faults += 1
                self._release_slot(s, st)
                st.req.on_done({
                    "seq_id": st.req.seq_id, "intent": st.req.intent,
                    "tier_name": st.req.packet.tier_name,
                    "failure": "cloud_error", "error": str(e)})
                return 1
        st.req.on_done({
            "seq_id": st.req.seq_id,
            "intent": st.req.intent,
            "tier_name": st.req.packet.tier_name,
            "answer_logits": st.logits0,
            "mask_logits": mask,
            "tokens": np.asarray(st.tokens, np.int32)[None, :],
            "batch_size": st.batch_acc / max(1, st.steps_done),
            "joined_step": st.joined_step,
            "prefix_hit": st.prefix_hit,
            "speculative": st.speculative,
            "preemptions": st.req.resumes,
            "queue_wait": st.req.queue_wait,
            "t_first_token": st.req.t_first_token,
            "cloud_flops": st.flops if self._cost is not None else None,
            "cloud_hbm_bytes": st.hbm_bytes
            if self._cost is not None else None,
        })
        if st.req.resumes:
            self.scheduler.note_resumed_served()
        self._release_slot(s, st)
        self.n_served += 1
        return 1

    def _release_slot(self, slot: int, st: _SlotState) -> None:
        """Return the slot's pages (prefix ref + private pages) and park
        its row on the trash page so later steps can't touch live KV."""
        self.pool.release(st.prefix_ids)
        self.pool.release(st.private_ids)
        self.page_tables[slot] = TRASH_PAGE
        self.positions[slot] = -1
        if st.speculative and self.draft is not None:
            self.draft.release(slot)
        del self.active[slot]

    def _park_slot(self, slot: int, st: _SlotState) -> None:
        """Preempt one active decode: roll its private pages back to
        empty (``PagePool.rollback_to`` — the same machinery as a
        speculative rejection, dropped all the way), drop its prefix
        reference, and requeue the request at the front of its class
        carrying its generated-so-far tokens. Re-admission replays them
        from the (usually still cached) prefix, token-exactly."""
        if self.tracer.enabled:
            now = self._clock()
            self.tracer.span(st.req.seq_id, "decode", st.t_admit,
                             max(now, st.t_admit), slot=slot,
                             tokens=len(st.tokens))
            self.tracer.point(st.req.seq_id, "park", now, slot=slot)
        self.pool.rollback_to(st.private_ids, 0)
        self.pool.release(st.prefix_ids)
        self.page_tables[slot] = TRASH_PAGE
        self.positions[slot] = -1
        if st.speculative and self.draft is not None:
            self.draft.release(slot)
        del self.active[slot]
        item = st.req
        # fold any undrained replay back in: tokens already committed
        # to st.tokens are the authoritative resume point
        item.resume_tokens = list(st.tokens)
        item.resumes += 1
        item.t_enqueue = self._clock()
        self.n_preempted += 1
        self.scheduler.note_preempted()
        self.scheduler.requeue_preempted(item, item.t_enqueue)

    def pump(self, max_steps: int = 1) -> None:
        # admission first: pending requests must start even when no batch
        # is running (the engine's lazy-drive paths reach here with
        # ``active`` empty but ``pending`` not)
        self.admit()
        for _ in range(max_steps):
            if not self.active:
                break
            self.step()

    def drain(self) -> None:
        self.admit()
        while self.active:
            self.step()

    @property
    def mean_live_slots(self) -> float:
        return self.n_slot_steps / max(1, self.n_steps)
