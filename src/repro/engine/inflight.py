"""Token-level continuous batching: the in-flight decode batch.

The ``MicrobatchScheduler`` closes a microbatch before serving it — a
request that arrives one step after a generate batch launched waits for
the whole batch. The ``InflightDecoder`` removes that barrier: it owns a
fixed-slot batched KV cache and advances it one decode step at a time
with *per-row* positions, so between any two steps a newly arrived
request can be prefilled into a free slot and ride the remaining steps
of the running batch (ROADMAP "in-flight batching" item, the vLLM-style
continuous batching discipline).

Per slot lifecycle (mirroring ``vlm.llm_generate``'s seg convention):
prefill over [ctx; query] emits token 0; each lockstep decode step feeds
the slot's last token at its own position; after ``T`` steps the slot's
final step has read the <SEG> hidden state at the last generated token,
the mask decodes from the stored SAM features, and the slot frees for
the next pending request. Slots may mix tiers and intents — the decode
loop runs on the LLM cache only; tier-specific work (bottleneck decode,
SAM tail) happened at prefill. Context requests ride the same T decode
steps as Insight ones: the serving contract is a T-token answer for both
streams, matching ``cloud_generate_batch`` exactly (the equivalence
tests pin token-level parity).

One decoder serves one query length, each with its own ``slots``-wide
cache — ``max_batch`` caps concurrency per qlen, not globally; idle
decoders release their cache and are retired by ``AveryEngine.drain``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional

import numpy as np

from repro.core import packets as pk
from repro.core.intent import Intent


@dataclass
class _PendingRequest:
    seq_id: int
    intent: Intent
    packet: pk.Packet
    query: np.ndarray
    on_done: Callable[[Dict[str, Any]], None]


@dataclass
class _SlotState:
    req: _PendingRequest
    tokens: List[int]                 # greedy answer tokens so far
    logits0: np.ndarray               # (1, V) first-token logits
    feats: Optional[Any]              # (1, T_sam, d_sam) or None (context)
    pos: int                          # absolute position of the next token
    joined_step: int                  # global step index at admission
    steps_done: int = 0
    batch_acc: int = 0                # sum of co-active slots over steps


class InflightDecoder:
    """Drives the executor's in-flight stages over a fixed slot layout.

    One decoder serves one query length (the prefill shape); the engine
    keys decoders by qlen the same way the microbatch scheduler keys
    batches. ``submit`` admits into a free slot immediately (prefill +
    cache scatter); ``step`` advances every live slot one token;
    ``drain`` runs admission + steps until no work remains.
    """

    def __init__(self, executor, slots: int = 8):
        self.executor = executor
        self.slots = int(slots)
        self.T = int(executor.max_new_tokens)
        self.pending: Deque[_PendingRequest] = deque()
        self.active: Dict[int, _SlotState] = {}
        self.cache = None
        self.qlen: Optional[int] = None
        self.step_idx = 0                 # global decode-step counter
        self.n_steps = 0
        self.n_slot_steps = 0             # sum of live slots across steps
        self.n_served = 0

    # ---- queueing ----

    def submit(self, seq_id: int, intent: Intent, packet: pk.Packet, query,
               on_done: Callable[[Dict[str, Any]], None]) -> None:
        query = np.asarray(query).reshape(-1, np.asarray(query).shape[-1])
        if query.shape[0] != 1:
            raise ValueError(
                "in-flight slots hold one sequence each; split "
                f"{query.shape[0]}-row packets at the edge")
        if self.qlen is None:
            self.qlen = int(query.shape[-1])
        elif int(query.shape[-1]) != self.qlen:
            raise ValueError(
                f"decoder serves qlen={self.qlen}, got {query.shape[-1]}")
        self.pending.append(_PendingRequest(seq_id, intent, packet, query,
                                            on_done))
        self.admit()

    @property
    def width(self) -> int:
        return self.executor.pcfg.clip_tokens + self.qlen + self.T

    @property
    def has_work(self) -> bool:
        return bool(self.pending or self.active)

    # ---- admission: prefill into free slots between steps ----

    def admit(self) -> int:
        admitted = 0
        while self.pending and len(self.active) < self.slots:
            item = self.pending.popleft()
            logits0, cache1, feats = self.executor.cloud_prefill(
                item.packet, item.query, width=self.width)
            if self.cache is None:
                self.cache = self.executor.empty_decode_cache(cache1,
                                                              self.slots)
            slot = min(set(range(self.slots)) - set(self.active))
            self.cache = self.executor.cache_insert(self.cache, cache1, slot)
            logits0 = np.asarray(logits0)
            self.active[slot] = _SlotState(
                req=item, tokens=[int(np.argmax(logits0[0]))],
                logits0=logits0, feats=feats,
                pos=self.executor.pcfg.clip_tokens + self.qlen,
                joined_step=self.step_idx)
            admitted += 1
        return admitted

    # ---- the lockstep decode step ----

    def step(self) -> int:
        """Advance every live slot one token (no-op when idle); returns
        the number of requests that finished on this step."""
        if not self.active:
            return 0
        toks = np.zeros((self.slots, 1), np.int32)
        # free slots decode garbage into their own (about-to-be-
        # overwritten) rows; park them on the last ring slot
        pos = np.full((self.slots,), self.width - 1, np.int32)
        for s, st in self.active.items():
            toks[s, 0] = st.tokens[-1]
            pos[s] = st.pos
        logits, seg, self.cache = self.executor.cloud_decode_rows(
            self.cache, toks, pos)
        logits, seg = np.asarray(logits), np.asarray(seg)
        live = len(self.active)
        self.n_steps += 1
        self.n_slot_steps += live
        finished = 0
        for s, st in list(self.active.items()):
            st.steps_done += 1
            st.batch_acc += live
            if st.steps_done < self.T:
                st.tokens.append(int(np.argmax(logits[s])))
                st.pos += 1
                continue
            # final step: this row's seg is the <SEG> state at the last
            # generated token (llm_generate's convention for every T)
            mask = None
            if st.feats is not None:
                mask = np.asarray(self.executor.cloud_mask(
                    st.feats, seg[s:s + 1]))
            st.req.on_done({
                "seq_id": st.req.seq_id,
                "intent": st.req.intent,
                "tier_name": st.req.packet.tier_name,
                "answer_logits": st.logits0,
                "mask_logits": mask,
                "tokens": np.asarray(st.tokens, np.int32)[None, :],
                "batch_size": st.batch_acc / max(1, st.steps_done),
                "joined_step": st.joined_step,
            })
            del self.active[s]
            self.n_served += 1
            finished += 1
        self.step_idx += 1
        if finished:
            self.admit()              # freed slots let queued requests in
        if not self.active and not self.pending:
            self.cache = None         # release the slot KV between bursts
        return finished

    def pump(self, max_steps: int = 1) -> None:
        for _ in range(max_steps):
            if not self.active:
                break
            self.step()

    def drain(self) -> None:
        self.admit()
        while self.active:
            self.step()

    @property
    def mean_live_slots(self) -> float:
        return self.n_slot_steps / max(1, self.n_steps)
