"""Chaos injection for the serving path: deterministic, seeded faults
on the transport and on the cloud executor stages.

AVERY's premise is survival under the unstable networks endemic to
disaster zones, so the fault model must be *drivable*: every failure the
engine claims to tolerate needs a switch that produces it on demand,
over any transport (a ``LoopbackTransport`` in a unit test, not just a
hand-built bandwidth trace), reproducibly (seeded — same schedule, same
faults), and observably (per-fault telemetry).

Two wrappers ship:

  * ``FaultInjector`` — wraps any ``Transport``. Scheduled **blackout
    windows** fail sends outright (``delivered=False`` with ``end_s`` at
    the window's end, the natural retry resume point), scheduled
    **latency-spike windows** delay delivery past a deadline without
    failing it, seeded Bernoulli **packet drops** model loss the sender
    can't predict, and **bandwidth-sense lies** feed the controller's
    Sense stage a wrong number inside chosen windows (the self-awareness
    loop acting on bad telemetry — the hardest fault to excuse).
  * ``FaultyExecutor`` — wraps a ``DualStreamExecutor`` (or the sharded
    context) and raises ``CloudStageError`` on chosen cloud stages
    mid-decode, by per-stage call index (``fail_at``) or a seeded rate
    (``p_fail``). Faults raise *before* delegating, so the wrapped
    executor, the KV pool, and the prefix store are never half-updated:
    a retried request re-admits against intact state ("retries never
    corrupt the prefix store" — pinned in tests).

The engine's fault tolerance (``RetryPolicy`` backoff + tier downshift,
per-request deadlines, ``InflightDecoder.cancel``) is exercised against
these wrappers by ``tests/test_faults.py`` and the
``bench_serving --chaos`` storm workload.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.packets import Packet
from repro.engine.transport import Transport
from repro.network.channel import TransmitRecord


class CloudStageError(RuntimeError):
    """A cloud serving stage failed mid-request (injected by
    ``FaultyExecutor``, or raised by a real backend). The in-flight
    decoder converts it into per-request ``cloud_error`` failures with
    pages released refcount-safely; the engine's ``RetryPolicy`` decides
    whether to re-run the request."""


@dataclass
class FaultInjector:
    """Deterministic fault-injecting ``Transport`` wrapper.

    Scheduled faults are half-open mission-time windows ``[start, end)``
    matched against the send time; random faults draw from one seeded
    stream in send order, so an identical request sequence sees an
    identical fault sequence (the chaos-determinism contract).

    ``blackouts``   — windows where every send fails (``delivered=False``,
                      ``end_s`` = window end: the link's comeback time).
    ``spikes``      — ``(start, end, extra_s)`` windows where delivered
                      sends arrive ``extra_s`` late (deadline killer).
    ``drop_rate``   — seeded Bernoulli per-send packet loss.
    ``sense_lies``  — ``(start, end, mbps)`` windows where ``bandwidth``
                      reports ``mbps`` instead of the truth, so the
                      controller Selects on bad telemetry.
    ``recorder``    — optional ``FlightRecorder``-compatible sink: every
                      injected fault is recorded as an engine event, so
                      a post-mortem flight dump shows the faults
                      interleaved with the lifecycle they broke.
    """
    inner: Transport
    seed: int = 0
    blackouts: Sequence[Tuple[float, float]] = ()
    spikes: Sequence[Tuple[float, float, float]] = ()
    drop_rate: float = 0.0
    sense_lies: Sequence[Tuple[float, float, float]] = ()
    recorder: Optional[Any] = None
    n_sends: int = 0
    n_blackout_failures: int = 0
    n_drops: int = 0
    n_spiked: int = 0
    n_sense_lies: int = 0

    def __post_init__(self):
        self._rng = np.random.RandomState(self.seed)

    # ---- Transport protocol ----

    def bandwidth(self, t: float) -> float:
        for lo, hi, mbps in self.sense_lies:
            if lo <= t < hi:
                self.n_sense_lies += 1
                return float(mbps)
        return self.inner.bandwidth(t)

    def send(self, packet: Packet, t: float) -> TransmitRecord:
        self.n_sends += 1
        end = self._blackout_end(t)
        if end is not None:
            self.n_blackout_failures += 1
            self._note("fault_blackout", t, packet, until=end)
            return TransmitRecord(packet=packet, start_s=t, end_s=end,
                                  delivered=False)
        # one draw per non-blackout send keeps the stream aligned with
        # the send sequence whatever the drop rate is
        if self._rng.rand() < self.drop_rate:
            self.n_drops += 1
            self._note("fault_drop", t, packet)
            return TransmitRecord(packet=packet, start_s=t, end_s=t,
                                  delivered=False)
        rec = self.inner.send(packet, t)
        if rec.delivered:
            extra = sum(e for lo, hi, e in self.spikes if lo <= t < hi)
            if extra:
                self.n_spiked += 1
                self._note("fault_spike", t, packet, extra_s=extra)
                rec = TransmitRecord(packet=rec.packet, start_s=rec.start_s,
                                     end_s=rec.end_s + extra,
                                     delivered=True)
        return rec

    def _note(self, kind: str, t: float, packet: Packet,
              **data: Any) -> None:
        if self.recorder is not None:
            self.recorder.record(kind, t, request_id=packet.seq_id,
                                 data=data)

    # ---- schedule / telemetry ----

    def _blackout_end(self, t: float) -> Optional[float]:
        ends = [hi for lo, hi in self.blackouts if lo <= t < hi]
        return max(ends) if ends else None

    @property
    def records(self):
        return getattr(self.inner, "records", [])

    @property
    def records_dropped(self) -> int:
        return getattr(self.inner, "records_dropped", 0)

    def stats(self) -> Dict[str, float]:
        return {
            "fault_sends": self.n_sends,
            "fault_blackout_failures": self.n_blackout_failures,
            "fault_drops": self.n_drops,
            "fault_spiked": self.n_spiked,
            "fault_sense_lies": self.n_sense_lies,
        }


# the in-flight serving stages a FaultyExecutor can fail; edge stages
# and plain attributes delegate untouched
FAULTABLE_STAGES = ("cloud_prefix", "pool_write", "cloud_sam_feats",
                    "cloud_decode_rows", "cloud_verify_rows", "cloud_mask")


class FaultyExecutor:
    """Fault-injecting executor wrapper: raises ``CloudStageError`` on
    chosen cloud stages, *before* delegating to the wrapped executor, so
    no fault ever leaves the executor/pool half-updated.

    ``fail_at``  — ``{stage: iterable of 0-based call indices}`` that
                   raise (the deterministic chaos schedule).
    ``p_fail``   — seeded Bernoulli failure rate applied to every stage
                   in ``stages`` on calls not already planned.
    """

    def __init__(self, inner: Any,
                 fail_at: Optional[Dict[str, Sequence[int]]] = None,
                 p_fail: float = 0.0, seed: int = 0,
                 stages: Sequence[str] = FAULTABLE_STAGES):
        unknown = set(fail_at or ()) - set(FAULTABLE_STAGES)
        if unknown:
            raise ValueError(
                f"unknown faultable stages {sorted(unknown)}; choose from "
                f"{FAULTABLE_STAGES}")
        self._inner = inner
        self._fail_at = {k: set(v) for k, v in (fail_at or {}).items()}
        self._p_fail = float(p_fail)
        self._stages = tuple(stages)
        self._rng = np.random.RandomState(seed)
        self.calls: Dict[str, int] = {s: 0 for s in FAULTABLE_STAGES}
        self.n_faults = 0

    def __getattr__(self, name: str) -> Any:
        return getattr(self._inner, name)

    def _gate(self, stage: str) -> None:
        i, self.calls[stage] = self.calls[stage], self.calls[stage] + 1
        hit = i in self._fail_at.get(stage, ())
        if not hit and self._p_fail and stage in self._stages:
            hit = bool(self._rng.rand() < self._p_fail)
        if hit:
            self.n_faults += 1
            raise CloudStageError(f"injected fault: {stage} call {i}")

    # ---- faultable in-flight stages ----

    def cloud_prefix(self, *a, **kw):
        self._gate("cloud_prefix")
        return self._inner.cloud_prefix(*a, **kw)

    def pool_write(self, *a, **kw):
        self._gate("pool_write")
        return self._inner.pool_write(*a, **kw)

    def cloud_sam_feats(self, *a, **kw):
        self._gate("cloud_sam_feats")
        return self._inner.cloud_sam_feats(*a, **kw)

    def cloud_decode_rows(self, *a, **kw):
        self._gate("cloud_decode_rows")
        return self._inner.cloud_decode_rows(*a, **kw)

    def cloud_verify_rows(self, *a, **kw):
        self._gate("cloud_verify_rows")
        return self._inner.cloud_verify_rows(*a, **kw)

    def cloud_mask(self, *a, **kw):
        self._gate("cloud_mask")
        return self._inner.cloud_mask(*a, **kw)
