"""Typed request/response surface of the AVERY engine.

Every way into the system — the serving launcher, the mission simulator,
the fleet runtime, benchmarks — speaks these types. A ``Request`` is one
operator utterance (prompt + optional frame + tokenised query) at a
point in mission time; the engine classifies its intent, selects a tier
through the active ``ControlPolicy``, moves the packet over the active
``Transport``, and serves it on the cloud executor. The ``Response``
carries the semantic product (answer logits / mask / generated tokens)
plus the timing, energy, and batching telemetry the runtimes and
benchmarks report. ``StreamEvent``s record the request's lifecycle for
observability and tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.intent import Intent


@dataclass
class Request:
    """One operator utterance submitted to the engine."""
    prompt: str = ""
    intent: Optional[Intent] = None    # None -> classified from the prompt
    images: Optional[Any] = None       # edge frame(s) (real serving path)
    query: Optional[np.ndarray] = None  # (B, L) tokenised model query
    time_s: float = 0.0                # mission-clock submission time
    # scheduling: strict-priority override (0 = normal; higher admits
    # first and may preempt lower-ranked active decodes — see
    # engine/scheduler.py)
    priority: int = 0
    # filled in by the engine
    request_id: int = -1
    operator_id: str = ""


@dataclass
class StreamEvent:
    """Lifecycle marker: queued, tier_selected, transmitted, blackout,
    prefilled, joined_batch, served, infeasible, retry, cloud_error,
    cancelled, rejected. ``t`` is mission time: emit sites that pass no
    timestamp get the engine's mission-clock watermark stamped in, so a
    response's event stream is always orderable."""
    kind: str
    t: float = 0.0
    data: Dict[str, Any] = field(default_factory=dict)


# cap on a single request's event stream: retries and preemption round-
# trips multiply events, and a future that lives a whole mission must
# not accumulate them without bound (averylint AV602's contract)
MAX_STREAM_EVENTS = 256


@dataclass
class Response:
    request_id: int
    operator_id: str
    intent: Intent
    tier_name: Optional[str] = None    # None for Context-stream requests
    feasible: bool = True              # Algorithm-1 feasibility verdict
    # terminal failure taxonomy — exactly one of:
    #   None          served (the semantic product is present)
    #   "blackout"    every transmission attempt died on the uplink
    #   "deadline"    cancelled past IntentRequirements.max_latency_s
    #   "infeasible"  no admissible tier (strict policy idles the frame)
    #   "cloud_error" a cloud serving stage failed and retries ran out
    #   "rejected"    shed by admission control (operator over its rate
    #                 limit, or the scheduler's bounded queue was full)
    # ``feasible`` keeps its pre-failure-taxonomy semantics (False on
    # every failed response, and on served best-effort starved frames).
    failure: Optional[str] = None
    attempts: int = 1                  # transmission attempts (1 = no retry)
    # semantic products
    answer_logits: Optional[np.ndarray] = None
    mask_logits: Optional[np.ndarray] = None
    tokens: Optional[np.ndarray] = None
    iou: Optional[float] = None        # profiled-mode fidelity measurement
    # timing / energy / batching telemetry
    t_submit: float = 0.0
    t_delivered: float = 0.0           # packet delivery on the uplink
    edge_compute_s: float = 0.0
    edge_energy_j: float = 0.0
    # device batch this request rode in: the microbatch size, or (in-
    # flight path) the fractional mean of co-active slots over its steps
    batch_size: float = 1.0
    joined_step: Optional[int] = None  # in-flight: decode step it joined at
    # in-flight: whether the [ctx; query] prefix was served from the
    # shared prefix store (no prefill paid) — None outside that path
    prefix_hit: Optional[bool] = None
    # in-flight: whether this request decoded speculatively (Context-
    # stream drafts + paged multi-token verify) — None outside that path
    speculative: Optional[bool] = None
    # scheduling telemetry (in-flight path): total time queued before
    # admission (summed across preemption round-trips), times this
    # request was preempted-and-parked, and the mission-clock watermark
    # at resolution — (t_finished - t_submit) is the end-to-end latency
    # the fleet-storm bench reports per QoS class
    queue_wait_s: Optional[float] = None
    preemptions: int = 0
    t_finished: Optional[float] = None
    # in-flight path: time-to-first-token — admission (prefill or prefix
    # hit, when token 0 exists) minus submission, on the mission clock;
    # preemption round-trips don't move it (the first token stands)
    ttft_s: Optional[float] = None
    # cost/energy ledger (profiled engines only — docs/observability.md
    # §Profiler): analytic cloud FLOPs/HBM bytes attributed to this
    # request's prefill + decode steps, and the joules they imply on the
    # cloud device model. None when the engine runs unprofiled.
    cloud_flops: Optional[float] = None
    cloud_hbm_bytes: Optional[float] = None
    cloud_energy_j: Optional[float] = None
    events: List[StreamEvent] = field(default_factory=list)

    @property
    def latency_s(self) -> float:
        return self.t_delivered - self.t_submit


class RequestFuture:
    """Handle for an in-flight request. ``result()`` drives the owning
    engine until the request is served (joining any running decode batch
    on the way), so callers can fire-and-collect without hand-managing
    the scheduler."""

    def __init__(self, request: Request, engine: "Any"):
        self.request = request
        self._engine = engine
        self._response: Optional[Response] = None
        self.events: List[StreamEvent] = []
        self.events_dropped = 0
        # engine-side bookkeeping: decision/rec of the latest attempt,
        # owning session, absolute deadline (None = no SLO)
        self.meta: Dict[str, Any] = {}
        self.attempts = 0

    def emit(self, kind: str, t: Optional[float] = None,
             **data: Any) -> None:
        """Record one lifecycle event. ``t=None`` stamps the engine's
        mission-clock watermark; every emit also feeds the engine's
        observability hook (flight recorder + tracer point events)."""
        if t is None:
            t = getattr(self._engine, "_now", 0.0)
        if len(self.events) < MAX_STREAM_EVENTS:
            self.events.append(StreamEvent(kind=kind, t=t, data=data))
        else:
            self.events_dropped += 1
        observe = getattr(self._engine, "_observe_event", None)
        if observe is not None:
            observe(self.request, kind, t, data)

    def done(self) -> bool:
        return self._response is not None

    def set_result(self, response: Response) -> None:
        response.events = self.events
        self._response = response

    def result(self) -> Response:
        if self._response is None:
            self._engine.drain()
        assert self._response is not None, "engine.drain() left request open"
        return self._response
