from repro.data import floodseg, lm, requests

__all__ = ["floodseg", "lm", "requests"]
