"""Synthetic token streams for the generic-architecture training paths.

Zipf-distributed tokens with a deterministic short-range structure
(bigram coupling) so language-model training has learnable signal; plus
batch builders matching every modality's input contract
(repro.models.model docstring).
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.models.config import ModelConfig


def zipf_tokens(rng: np.random.RandomState, shape, vocab: int,
                alpha: float = 1.2) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    flat = rng.choice(vocab, size=int(np.prod(shape)), p=probs)
    return flat.reshape(shape).astype(np.int32)


def lm_batch(rng: np.random.RandomState, cfg: ModelConfig, batch: int,
             seq: int) -> Dict[str, np.ndarray]:
    if cfg.modality == "audio":
        frames = rng.randn(batch, seq, cfg.frontend_dim).astype(np.float32)
        targets = zipf_tokens(rng, (batch, seq), cfg.vocab_size)
        # HuBERT-style span masking: ~8% starts, span 4
        mask = np.zeros((batch, seq), bool)
        starts = rng.rand(batch, seq) < 0.08
        for off in range(4):
            mask[:, off:] |= starts[:, :seq - off] if off else starts
        return {"frames": frames, "targets": targets, "mask_positions": mask}
    if cfg.modality == "vlm":
        tokens = zipf_tokens(rng, (batch, seq), cfg.vocab_size)
        nv = cfg.num_vision_tokens
        vis = rng.randn(batch, nv, cfg.frontend_dim).astype(np.float32)
        # M-RoPE position triples: vision tokens get (t=0, h, w) grid
        # positions; text continues with equal (t, h, w) ids.
        side = max(1, int(round(nv ** 0.5)))
        hpos = (np.arange(nv) // side).astype(np.int32)
        wpos = (np.arange(nv) % side).astype(np.int32)
        tpos = np.zeros(nv, np.int32)
        text = np.arange(seq - nv, dtype=np.int32) + side
        pos = np.stack([
            np.concatenate([tpos, text]),
            np.concatenate([hpos, text]),
            np.concatenate([wpos, text]),
        ])                                        # (3, S)
        pos = np.broadcast_to(pos[:, None, :], (3, batch, seq)).copy()
        return {"tokens": tokens, "vision_embeds": vis, "positions": pos}
    tokens = zipf_tokens(rng, (batch, seq), cfg.vocab_size)
    # inject learnable bigram structure: token 2k+1 follows 2k
    follow = rng.rand(batch, seq - 1) < 0.3
    tokens[:, 1:] = np.where(follow & (tokens[:, :-1] % 2 == 0),
                             np.minimum(tokens[:, :-1] + 1, cfg.vocab_size - 1),
                             tokens[:, 1:])
    return {"tokens": tokens}


def lm_stream(seed: int, cfg: ModelConfig, batch: int,
              seq: int) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.RandomState(seed)
    while True:
        yield lm_batch(rng, cfg, batch, seq)
