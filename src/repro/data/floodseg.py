"""Flood-ReasonSeg-proxy: procedural flood scenes with NL-style queries and
exact segmentation masks (DESIGN.md §6 — stands in for the paper's ~100
curated flood images, which do not exist offline).

Scenes are 32x32x3 float images: a flood waterline with water texture
below, land/building texture above, rooftop slabs, and two target classes
mirroring the paper's dataset: PERSON (3x3 cross shape, warm colour,
often on rooftops) and VEHICLE (4x3 slab, cool colour, often partially
submerged). Queries come in ReasonSeg style:
  * Insight: "segment the stranded persons" -> GT mask of that class
  * Context: "are there any persons?"        -> yes/no answer token

Token language (vocab 64): fixed ids below; queries are 8-token sequences.
Photometric augmentation (brightness/contrast/noise jitter) mirrors the
paper's augmentation pipeline (§5.1.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

IMG = 32
PAD, BOS, EOS = 0, 1, 2
TOK_SEGMENT, TOK_ANY, TOK_COUNT = 3, 4, 5
TOK_PERSON, TOK_VEHICLE = 6, 7
ANS_NO, ANS_YES = 8, 9          # answer tokens (also used as labels)
ANS_COUNT0 = 10                 # ANS_COUNT0 + n for counts 0..4
QUERY_LEN = 8
VOCAB = 64

CLASSES = {"person": TOK_PERSON, "vehicle": TOK_VEHICLE}

INSIGHT_PROMPTS = {
    "person": "Highlight the stranded persons who may need rescue.",
    "vehicle": "Segment the vehicles stranded by floodwater.",
}
CONTEXT_PROMPTS = {
    "person": "Are there any persons in this sector?",
    "vehicle": "Are there any stranded vehicles?",
}


@dataclass
class Scene:
    image: np.ndarray            # (32, 32, 3) float32 in [0, 1]
    masks: Dict[str, np.ndarray]  # class -> (32, 32) bool
    counts: Dict[str, int]


def _texture(rng, h, w, base, jitter):
    return np.clip(base + rng.randn(h, w, 3) * jitter, 0, 1)


def generate_scene(rng: np.random.RandomState) -> Scene:
    img = np.zeros((IMG, IMG, 3), np.float32)
    waterline = rng.randint(10, 24)
    img[waterline:] = _texture(rng, IMG - waterline, IMG,
                               np.array([0.15, 0.3, 0.55]), 0.05)
    img[:waterline] = _texture(rng, waterline, IMG,
                               np.array([0.45, 0.4, 0.35]), 0.07)
    masks = {c: np.zeros((IMG, IMG), bool) for c in CLASSES}
    counts = {c: 0 for c in CLASSES}

    # rooftops (context structures, not targets)
    for _ in range(rng.randint(1, 4)):
        y = rng.randint(0, max(1, waterline - 5))
        x = rng.randint(0, IMG - 7)
        h, w = rng.randint(3, 6), rng.randint(5, 8)
        img[y:y + h, x:x + w] = _texture(rng, h, w,
                                         np.array([0.55, 0.55, 0.58]), 0.03)

    # vehicles: 4x3 slabs near/below the waterline (partially submerged)
    for _ in range(rng.randint(0, 4)):
        y = rng.randint(max(0, waterline - 3), IMG - 4)
        x = rng.randint(0, IMG - 5)
        col = np.array([0.2, 0.5, 0.7]) + rng.randn(3) * 0.08
        img[y:y + 3, x:x + 4] = np.clip(col, 0, 1)
        masks["vehicle"][y:y + 3, x:x + 4] = True
        counts["vehicle"] += 1

    # persons: 3x3 crosses, warm colour, often on rooftops / dry land
    for _ in range(rng.randint(0, 4)):
        y = rng.randint(1, IMG - 2)
        x = rng.randint(1, IMG - 2)
        col = np.clip(np.array([0.85, 0.35, 0.25]) + rng.randn(3) * 0.06, 0, 1)
        img[y, x - 1:x + 2] = col
        img[y - 1:y + 2, x] = col
        masks["person"][y, x - 1:x + 2] = True
        masks["person"][y - 1:y + 2, x] = True
        counts["person"] += 1

    return Scene(image=img, masks=masks, counts=counts)


def photometric_augment(rng: np.random.RandomState,
                        image: np.ndarray) -> np.ndarray:
    """Brightness/contrast/noise jitter (paper §5.1.2 augmentation)."""
    b = rng.uniform(-0.08, 0.08)
    c = rng.uniform(0.85, 1.15)
    noise = rng.randn(*image.shape) * 0.02
    return np.clip((image - 0.5) * c + 0.5 + b + noise, 0, 1).astype(np.float32)


def encode_query(kind: str, cls: str) -> np.ndarray:
    verb = {"segment": TOK_SEGMENT, "any": TOK_ANY, "count": TOK_COUNT}[kind]
    q = [BOS, verb, CLASSES[cls], EOS] + [PAD] * (QUERY_LEN - 4)
    return np.array(q, np.int32)


def make_batch(rng: np.random.RandomState, batch_size: int,
               kind: str = "segment", augment: bool = True,
               cls: Optional[str] = None) -> Dict[str, np.ndarray]:
    """kind: 'segment' (Insight) | 'any' | 'count' (Context)."""
    images, queries, masks, answers = [], [], [], []
    for _ in range(batch_size):
        scene = generate_scene(rng)
        c = cls or ("person" if rng.rand() < 0.5 else "vehicle")
        img = photometric_augment(rng, scene.image) if augment else scene.image
        images.append(img)
        queries.append(encode_query(kind, c))
        masks.append(scene.masks[c])
        if kind == "any":
            answers.append(ANS_YES if scene.counts[c] > 0 else ANS_NO)
        elif kind == "count":
            answers.append(ANS_COUNT0 + min(4, scene.counts[c]))
        else:
            answers.append(ANS_YES if scene.counts[c] > 0 else ANS_NO)
    return {
        "images": np.stack(images),
        "query": np.stack(queries),
        "mask": np.stack(masks),
        "answer": np.array(answers, np.int32),
    }


def train_val_streams(seed: int, batch_size: int,
                      kind: str = "segment"
                      ) -> Tuple[Iterator[Dict], Iterator[Dict]]:
    def stream(s, augment):
        rng = np.random.RandomState(s)
        while True:
            yield make_batch(rng, batch_size, kind=kind, augment=augment)
    return stream(seed, True), stream(seed + 10_000, False)
