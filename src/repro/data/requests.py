"""Operator request generator for the mission simulator (paper §5.3.1).

Emits a stream of timestamped operator queries with natural-language
prompts (for the intent gate) and tokenised queries (for the model).
Mission phases mirror the paper's workflow (§4.3): broad Context triage
interleaved with Insight escalations once targets are found.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.data import floodseg


@dataclass(frozen=True)
class OperatorRequest:
    time_s: float
    prompt: str                   # NL prompt fed to the intent gate
    kind: str                     # "segment" | "any" | "count"
    cls: str                      # target class


def mission_requests(seed: int, duration_s: float,
                     insight_fraction: float = 0.7,
                     mean_interval_s: float = 1.0
                     ) -> Iterator[OperatorRequest]:
    """Poisson request arrivals. ``insight_fraction`` of requests escalate
    to Insight-level grounding (the paper's dynamic evaluation drives the
    Insight stream; §5.3)."""
    rng = np.random.RandomState(seed)
    t = 0.0
    while True:
        t += rng.exponential(mean_interval_s)
        if t >= duration_s:
            return
        cls = "person" if rng.rand() < 0.5 else "vehicle"
        if rng.rand() < insight_fraction:
            yield OperatorRequest(t, floodseg.INSIGHT_PROMPTS[cls],
                                  "segment", cls)
        else:
            yield OperatorRequest(t, floodseg.CONTEXT_PROMPTS[cls],
                                  "any", cls)
