"""AVERY reproduction: intent-driven adaptive VLM split computing in JAX.

Subpackages:
  core        the paper's contribution (streams, split, bottleneck,
              controller, LUT, LISA pipeline)
  models      architecture zoo (dense/MoE/SSM/hybrid/audio/VLM)
  configs     the 10 assigned architectures + LISA configs
  kernels     Pallas TPU kernels (bottleneck, flash attention, ssm scan)
  sharding    PartitionSpec rules; launch — mesh/dryrun/train/serve
  optim, data, checkpoint, network, runtime — substrates
"""

__version__ = "1.0.0"
