"""Pytree checkpointing: npz arrays + JSON manifest of the tree structure.

No orbax offline; this is a small, dependable substitute. Arrays are
stored flat under stringified key-paths; the manifest records the
treedef so arbitrary nested dict/list pytrees round-trip exactly.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_pytree(path: str, tree: Any) -> None:
    """path is a directory; writes arrays.npz + manifest.json."""
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"treedef": str(treedef), "num_leaves": len(leaves),
                   "dtypes": [str(np.asarray(l).dtype) for l in leaves],
                   "shapes": [list(np.asarray(l).shape) for l in leaves]},
                  f)
    # store the structure itself for reconstruction
    struct = jax.tree.map(lambda _: 0, tree)
    with open(os.path.join(path, "structure.json"), "w") as f:
        json.dump(_to_jsonable(struct), f)


def _to_jsonable(tree):
    if isinstance(tree, dict):
        return {"__dict__": {k: _to_jsonable(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"__list__": [_to_jsonable(v) for v in tree],
                "__tuple__": isinstance(tree, tuple)}
    return {"__leaf__": True}


def _from_jsonable(spec, leaves_iter):
    if "__leaf__" in spec:
        return next(leaves_iter)
    if "__dict__" in spec:
        return {k: _from_jsonable(v, leaves_iter)
                for k, v in spec["__dict__"].items()}
    vals = [_from_jsonable(v, leaves_iter) for v in spec["__list__"]]
    return tuple(vals) if spec.get("__tuple__") else vals


def load_pytree(path: str) -> Any:
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = [data[f"leaf_{i}"] for i in range(manifest["num_leaves"])]
    with open(os.path.join(path, "structure.json")) as f:
        struct = json.load(f)
    return _from_jsonable(struct, iter(leaves))
