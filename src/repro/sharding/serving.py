"""Sharded paged serving: tensor-parallel prefill / decode / verify of
the serving stack on a device mesh.

Every prior serving layer — batching (PR 1), the engine front door
(PR 2), the paged shared-prefix KV cache (PR 3), speculative decoding
(PR 4) — ran single-device while ``sharding/specs.py`` and
``launch/mesh.py`` only served the *training* state. This module closes
that gap: a ``ShardedServingContext`` wraps a ``DualStreamExecutor`` and
re-exposes the paged in-flight stages (``cloud_prefix`` /
``pool_write`` / ``cloud_decode_rows`` / ``cloud_verify_rows``) plus the
Context-stream draft stages as jitted entry points with **explicit
``in_shardings``/``out_shardings``** over a ``Mesh``, so
``InflightDecoder``, ``DualStreamExecutor`` and the engine work
unchanged on top.

Layout (the megatron discipline the training specs already use):

  * params — replicated-or-model-sharded by the ``specs.param_specs``
    key-path rules (attention heads / d_ff column-parallel over
    "model", output projections row-parallel, norms replicated);
  * paged KV pool — kv-heads axis over "model", the **page axis
    replicated** (every shard holds its head slice of every page), so a
    page-table gather is local on each shard and page-table updates
    never round-trip through the host;
  * page tables, positions, token ids, logits, per-row scalars —
    replicated (``specs.serving_specs``).

The decode/verify **Pallas kernels** have a per-shard head-count path:
under ``shard_map`` each shard would run the kernel on
``n_kv_heads / mesh.shape["model"]`` heads (the ``group`` and
``heads_per_batch`` grid math is already per-shard-shape-driven, so the
kernel body needs no change — only smaller K). On this container the
kernels execute in *interpret mode* and cannot lower inside a GSPMD
partition, so the sharded context pins ``use_flash_decode=False`` and
serves the jnp reference attention, which XLA partitions automatically
(one all-reduce after the row-parallel output projection per layer);
flip the kernel path on under ``shard_map`` on real TPU.

Exactness: sharding only changes *where* each head's arithmetic runs
and the reduction order of the output-projection sum, not the
computation — sharded decode/verify is token-exact with the unsharded
``llm_generate`` path (pinned in ``tests/test_sharding.py`` and the
``--sharded`` benchmark).

Run the end-to-end selftest on a forced host-platform mesh::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m repro.sharding.serving --model=2
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import vlm
from repro.sharding import specs as sh


class ShardedServingContext:
    """Executor facade that runs the paged serving stack under a mesh.

    Owns a model-sharded copy of the weights (``device_put`` once at
    construction) and a lazy cache of jitted stages whose in/out
    shardings come from the ``specs`` key-path rules — the first call
    of each stage shapes its sharding trees via ``jax.eval_shape``,
    after which the stage behaves exactly like the executor method it
    replaces. Edge stages, SAM tail, mask decode, and the closed
    microbatch paths delegate to the wrapped executor (they are
    per-frame work, not the decode hot loop; on a real deployment the
    vision tail would shard the same way — see docs/serving.md).
    """

    def __init__(self, executor: Any, mesh: Mesh):
        self.inner = executor
        self.mesh = mesh
        self.pcfg = executor.pcfg
        self.page_size = executor.page_size
        self.max_new_tokens = executor.max_new_tokens
        self.lut = executor.lut
        # the Pallas kernels cannot lower inside a GSPMD partition on
        # this container (interpret mode); serve the jnp attention ref,
        # which XLA partitions over the head-sharded operands
        self.flash_decode = False
        self._gen_pcfg = dataclasses.replace(
            self.pcfg, llm=self.pcfg.llm.replace(use_flash_decode=False))
        self.model_shards = (mesh.shape["model"]
                             if "model" in mesh.axis_names else 1)
        self._rep = NamedSharding(mesh, P())
        pspecs = sh.param_specs(self.pcfg.llm, executor.params, mesh)
        self.param_shardings = sh.to_shardings(mesh, pspecs)
        self.params = jax.device_put(executor.params, self.param_shardings)
        self._stages: Dict[Any, Callable] = {}

    def __getattr__(self, name: str) -> Any:
        if name == "inner":
            raise AttributeError(name)
        return getattr(self.inner, name)

    # ---- sharding trees ----

    def _kv_sh(self, tree: Any) -> Any:
        """NamedShardings for any serving pytree (pool / paged prefix /
        draft ring / page tables / logits) via the key-path rules."""
        return sh.to_shardings(self.mesh, sh.serving_specs(tree, self.mesh))

    def place_pool(self, kv: Any) -> Any:
        """Place (or re-place after growth) the page pool's device
        buffers with the serving shardings — ``PagePool(placement=...)``
        calls this from ``ensure`` so the pool stays mesh-resident."""
        return jax.device_put(kv, self._kv_sh(kv))

    # ---- lazy jitted stages with explicit shardings ----

    def _lazy(self, key: Any, fn: Callable, in_sh: Callable,
              out_sh: Callable) -> Callable:
        """One jitted stage per key; in/out shardings are computed from
        the first call's arguments/abstract outputs (the sharding trees
        are shape-polymorphic, so later shapes re-trace under the same
        jit without re-deriving them)."""
        stage = self._stages.get(key)
        if stage is None:
            box: Dict[str, Callable] = {}

            def call(*args):
                jitted = box.get("jitted")
                if jitted is None:
                    outs = jax.eval_shape(fn, *args)
                    jitted = box["jitted"] = jax.jit(
                        fn, in_shardings=in_sh(args), out_shardings=out_sh(outs))
                return jitted(*args)

            stage = self._stages[key] = call
        return stage

    @property
    def num_compiled_stages(self) -> int:
        return self.inner.num_compiled_stages + len(self._stages)

    # ---- the paged in-flight stages (InflightDecoder's contract) ----

    def cloud_prefix(self, ctx, query) -> Tuple[Any, Dict]:
        import numpy as np
        query = np.asarray(query).reshape(-1, np.asarray(query).shape[-1])
        if query.shape[0] != 1:
            raise ValueError(
                f"prefix prefill is per-sequence, got {query.shape[0]} rows")
        pcfg, page = self.pcfg, self.page_size

        def fn(p, c, q):
            logits0, _, paged = vlm.llm_prefill_paged(p, pcfg, c, q, page)
            return logits0, jax.tree.map(lambda a: a[:, 0], paged)

        stage = self._lazy(
            "cloud_prefix", fn,
            lambda args: (self.param_shardings, self._rep, self._rep),
            lambda outs: (self._rep, self._kv_sh(outs[1])))
        return stage(self.params, jnp.asarray(ctx), jnp.asarray(query))

    def pool_write(self, pool: Dict, paged_kv: Dict, page_ids) -> Dict:
        def fn(dst, src, ids):
            return jax.tree.map(lambda d, s: d.at[:, ids].set(s), dst, src)

        stage = self._lazy(
            "pool_write", fn,
            lambda args: (self._kv_sh(args[0]), self._kv_sh(args[1]),
                          self._rep),
            lambda outs: self._kv_sh(outs))
        return stage(pool, paged_kv, jnp.asarray(page_ids, jnp.int32))

    def cloud_decode_rows(self, pool: Dict, page_table, positions, tokens,
                          pos, write_slot) -> Tuple[Any, Any, Dict]:
        pcfg = self._gen_pcfg

        def fn(p, pl, pt, posarr, tok, ps, ws):
            return vlm.llm_decode_step_paged(p, pcfg, pl, pt, posarr, tok,
                                             ps, ws)

        stage = self._lazy(
            "cloud_decode_rows", fn,
            lambda args: (self.param_shardings, self._kv_sh(args[1]))
            + (self._rep,) * 5,
            lambda outs: (self._rep, self._rep, self._kv_sh(outs[2])))
        return stage(self.params, pool,
                     jnp.asarray(page_table, jnp.int32),
                     jnp.asarray(positions, jnp.int32),
                     jnp.asarray(tokens, jnp.int32),
                     jnp.asarray(pos, jnp.int32),
                     jnp.asarray(write_slot, jnp.int32))

    def cloud_verify_rows(self, pool: Dict, page_table, positions, tokens,
                          pos, write_slot, chunk_len
                          ) -> Tuple[Any, Any, Dict]:
        pcfg = self._gen_pcfg

        def fn(p, pl, pt, posarr, tok, ps, ws, cl):
            return vlm.llm_verify_step_paged(p, pcfg, pl, pt, posarr, tok,
                                             ps, ws, cl)

        stage = self._lazy(
            "cloud_verify_rows", fn,
            lambda args: (self.param_shardings, self._kv_sh(args[1]))
            + (self._rep,) * 6,
            lambda outs: (self._rep, self._rep, self._kv_sh(outs[2])))
        return stage(self.params, pool,
                     jnp.asarray(page_table, jnp.int32),
                     jnp.asarray(positions, jnp.int32),
                     jnp.asarray(tokens, jnp.int32),
                     jnp.asarray(pos, jnp.int32),
                     jnp.asarray(write_slot, jnp.int32),
                     jnp.asarray(chunk_len, jnp.int32))

    # ---- the Context draft stages (DraftModel's fns_factory hook) ----

    def draft_fns(self, pcfg: Any, width: int, params: dict
                  ) -> Tuple[Callable, Callable, Callable]:
        """Sharded draft-model stages: same contract as
        ``speculative._draft_fns`` (prefill, step, insert) with the
        draft params model-sharded and the contiguous ring cache's
        kv-heads over "model". The draft may run a different geometry
        (``lisa_nano``) than the target — its specs are derived from
        its own param tree."""
        from repro.engine.speculative import DraftModel
        rep = self._rep
        psh = sh.to_shardings(self.mesh,
                              sh.param_specs(pcfg.llm, params, self.mesh))
        prefill = self._lazy(
            ("draft_prefill", pcfg, width),
            lambda p, c, q: vlm.llm_prefill(p, pcfg, c, q, width=width),
            lambda args: (psh, rep, rep),
            lambda outs: (rep, rep, self._kv_sh(outs[2])))
        step = self._lazy(
            ("draft_step", pcfg, width),
            lambda p, ca, t, pos: vlm.llm_decode_step(p, pcfg, ca, t, pos),
            lambda args: (psh, self._kv_sh(args[1]), rep, rep),
            lambda outs: (rep, rep, self._kv_sh(outs[2])))
        insert = self._lazy(
            ("draft_insert", pcfg, width),
            DraftModel._insert_row,
            lambda args: (self._kv_sh(args[0]), self._kv_sh(args[1]), rep),
            lambda outs: self._kv_sh(outs))
        return prefill, step, insert


# ---------------------------------------------------------------------------
# selftest: sharded decode + verify token-exact vs unsharded llm_generate
# ---------------------------------------------------------------------------


def _selftest(model: int = 2, n_requests: int = 3,
              answer_tokens: int = 3, executor: Any = None) -> None:
    """End-to-end exactness pin on the local host mesh: sharded paged
    decode and sharded speculative verify vs the unsharded one-shot
    generate path. The in-process test hands in its fixture
    ``executor``; the ``__main__``/subprocess path builds a random-init
    one. Force a multi-device host platform *before* any jax import
    (the test and CI wrappers set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` in the
    environment); with 2 forced devices and ``model=2`` this is the
    1x2 mesh, with 8 the CI smoke's 2x4."""
    import numpy as np

    from repro.core.intent import Intent
    from repro.core.paging import PagePool
    from repro.data import floodseg
    from repro.engine.inflight import InflightDecoder
    from repro.engine.speculative import SpeculativeConfig
    from repro.launch.mesh import make_local_mesh

    if executor is None:
        from repro.core import DualStreamExecutor, paper_lut, profile as prof
        from repro.configs.lisa_mini import CONFIG as PCFG
        lut = paper_lut()
        params, bns, _ = prof.random_init_system(PCFG, lut=lut)
        executor = DualStreamExecutor(
            pcfg=PCFG, params=params, bottlenecks=bns, lut=lut,
            max_new_tokens=answer_tokens, flash_decode=False, page_size=4)
    lut = executor.lut
    mesh = make_local_mesh(model=model)
    ctx = ShardedServingContext(executor, mesh)

    rng = np.random.RandomState(3)
    reqs = []
    for i in range(n_requests):
        kind = "any" if i % 3 == 2 else "segment"
        b = floodseg.make_batch(rng, 1, kind, augment=False)
        img = jnp.asarray(b["images"])
        if kind == "any":
            pkt, _ = executor.edge_context(img, i, 0.0)
            reqs.append((pkt, b["query"], Intent.CONTEXT))
        else:
            pkt = executor.edge_insight(img, lut.tiers[i % 2], i, 0.0)
            reqs.append((pkt, b["query"], Intent.INSIGHT))

    for spec in (None, SpeculativeConfig(draft_tokens=2)):
        pool = PagePool(page_size=ctx.page_size, placement=ctx.place_pool,
                        shards=ctx.model_shards)
        dec = InflightDecoder(ctx, slots=2, pool=pool, spec=spec)
        done: Dict[int, Dict] = {}
        for i, (pkt, q, it) in enumerate(reqs):
            dec.submit(i, it, pkt, q,
                       lambda out: done.setdefault(out["seq_id"], out))
        dec.drain()
        for i, (pkt, q, it) in enumerate(reqs):
            ref = executor.cloud_generate_batch([pkt], [q])[0]
            mode = "verify" if spec is not None else "decode"
            assert np.array_equal(done[i]["tokens"], ref[-1]), (mode, i)
            np.testing.assert_allclose(
                done[i]["answer_logits"],
                ref[-2] if it is Intent.CONTEXT else ref[1], atol=1e-3)
            if it is Intent.INSIGHT:
                np.testing.assert_allclose(done[i]["mask_logits"], ref[0],
                                           atol=1e-3)
        stats = pool.stats()
        assert stats["kv_pool_bytes"] > 0
        assert stats["kv_pool_bytes_per_shard"] \
            == stats["kv_pool_bytes"] // ctx.model_shards
    print(f"sharded serving selftest: decode + speculative verify "
          f"token-exact on mesh "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"(model_shards={ctx.model_shards}, devices={mesh.size})")


if __name__ == "__main__":
    import sys
    model_arg = 2
    for a in sys.argv[1:]:
        if a.startswith("--model="):
            model_arg = int(a.split("=", 1)[1])
    _selftest(model=model_arg)
