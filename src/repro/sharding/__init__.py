from repro.sharding.specs import (batch_specs, cache_specs, param_specs,
                                  serving_specs, to_shardings)

__all__ = ["param_specs", "batch_specs", "cache_specs", "serving_specs",
           "to_shardings"]
