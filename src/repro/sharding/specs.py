"""PartitionSpec rules for every parameter / batch / cache pytree.

Baseline sharding scheme (DESIGN.md §7):
  * activations/batch  -> batch dims over ("pod","data"), model dim intact
  * attention          -> heads (fused into the projection output axis)
                          over "model"; output projections over input axis
  * MLPs               -> d_ff over "model" (megatron style)
  * MoE experts        -> expert axis over "model" when divisible
                          (deepseek 256 % 16 == 0), else expert-internal
                          d_ff over "model" (granite 40e)
  * Mamba              -> d_inner over "model"
  * embeddings         -> vocab over "model"; norms/routers replicated
  * KV caches          -> batch over ("pod","data"), kv-heads over "model"
                          when divisible

Rules are applied by key-path over abstract pytrees, so they cover every
architecture (incl. nested hybrid caches) without per-arch spec tables.
Axes that do not divide evenly fall back to replication (``_maybe``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

# parameters whose LAST axis shards over "model" (column parallel)
_COL = {"wq", "wk", "wv", "bq", "bk", "bv", "wq_b", "wkv_b", "w_gate",
        "w_up", "in_proj", "dt_proj", "conv_w", "conv_b", "dt_bias", "D",
        "feat_proj", "vision_proj", "patch_w"}
# parameters whose SECOND-TO-LAST axis shards over "model" (row parallel)
_ROW = {"wo", "w_down", "out_proj", "x_proj", "A_log"}
# always replicated
_REP = {"w", "b", "norm_w", "q_norm", "kv_norm", "router", "patch_b", "pos",
        "scale", "step", "clip_proj", "seg_proj", "w1", "b1", "w2"}


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _maybe(dim: int, axis: str, mesh: Mesh) -> Optional[str]:
    n = _axis_size(mesh, axis)
    return axis if n > 1 and dim % n == 0 else None


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _maybe_batch(dim: int, mesh: Mesh):
    axes = _batch_axes(mesh)
    total = 1
    for a in axes:
        total *= _axis_size(mesh, a)
    return axes if axes and dim % total == 0 else None


def _path_names(path) -> list:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(f"[{k.idx}]")
    return names


def _param_rule(path, leaf, cfg: ModelConfig, mesh: Mesh) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    shape = leaf.shape
    nd = len(shape)

    if name == "embed":
        return P(_maybe(shape[0], "model", mesh), None)
    if name in ("head", "answer_head"):
        return P(None, _maybe(shape[-1], "model", mesh))
    if name in _REP or nd <= 1:
        return P(*([None] * nd))

    # MoE expert tensors: (L, E, d, f) / (L, E, f, d)
    if name in ("w_gate", "w_up", "w_down") and nd == 4:
        E = shape[1]
        if _maybe(E, "model", mesh):
            return P(None, "model", None, None)
        # fall back to expert-internal sharding
        if name == "w_down":
            return P(None, None, _maybe(shape[2], "model", mesh), None)
        return P(None, None, None, _maybe(shape[3], "model", mesh))

    if name in _COL:
        spec = [None] * nd
        spec[-1] = _maybe(shape[-1], "model", mesh)
        return P(*spec)
    if name in _ROW and nd >= 2:
        spec = [None] * nd
        spec[-2] = _maybe(shape[-2], "model", mesh)
        return P(*spec)
    # default: replicate
    return P(*([None] * nd))


def _add_fsdp(spec: P, leaf, mesh: Mesh) -> P:
    """ZeRO/FSDP extension (§Perf lever): additionally shard the largest
    still-unsharded axis over "data", so parameters + optimizer state are
    fully sharded; XLA inserts per-layer all-gathers (reduce-scatter on
    the backward) inside the scan body — standard FSDP semantics."""
    n = _axis_size(mesh, "data")
    if n <= 1 or leaf.ndim == 0:
        return spec
    entries = list(spec) + [None] * (leaf.ndim - len(spec))
    best, best_dim = None, 0
    for i, (dim, ax) in enumerate(zip(leaf.shape, entries)):
        if ax is None and dim % n == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best is None or best_dim < n:
        return spec
    entries[best] = "data"
    return P(*entries)


def param_specs(cfg: ModelConfig, abstract_params: Any, mesh: Mesh,
                fsdp: bool = False) -> Any:
    def rule(p, l):
        spec = _param_rule(p, l, cfg, mesh)
        return _add_fsdp(spec, l, mesh) if fsdp else spec
    return jax.tree_util.tree_map_with_path(rule, abstract_params)


def _cache_rule(path, leaf, cfg: ModelConfig, mesh: Mesh) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    shape = leaf.shape
    nd = len(shape)
    if name == "positions":                      # (B, W)
        return P(_maybe_batch(shape[0], mesh), None)
    # stacked per-layer caches: leading layer axis, then batch
    if name in ("k", "v"):                       # (L, B, W, K, hd)
        kv_ax = _maybe(shape[3], "model", mesh)
        hd_ax = None
        if cfg.shard_cache_hd and kv_ax is None:
            hd_ax = _maybe(shape[4], "model", mesh)
        return P(None, _maybe_batch(shape[1], mesh), None, kv_ax, hd_ax)
    if name in ("ckv", "krope"):                 # (L, B, W, r)
        return P(None, _maybe_batch(shape[1], mesh), None, None)
    if name == "h":
        if nd == 4:                              # mamba1 (L, B, di, N)
            return P(None, _maybe_batch(shape[1], mesh),
                     _maybe(shape[2], "model", mesh), None)
        # mamba2 (L, B, nh, P, N) or hybrid (G, ae, B, nh, P, N)
        b_axis = 1 if nd == 5 else 2
        spec = [None] * nd
        spec[b_axis] = _maybe_batch(shape[b_axis], mesh)
        spec[b_axis + 1] = _maybe(shape[b_axis + 1], "model", mesh)
        return P(*spec)
    if name == "conv":                           # (L, B, K-1, C) (+hybrid G)
        b_axis = 1 if nd == 4 else 2
        spec = [None] * nd
        spec[b_axis] = _maybe_batch(shape[b_axis], mesh)
        spec[-1] = _maybe(shape[-1], "model", mesh)
        return P(*spec)
    return P(*([None] * nd))


def cache_specs(cfg: ModelConfig, abstract_cache: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _cache_rule(p, l, cfg, mesh), abstract_cache)


def _serving_rule(path, leaf, mesh: Mesh) -> P:
    """Key-path rule for the *paged serving* pytrees (the in-flight
    decode substrate, not the training state):

      * KV leaves ("k"/"v") — the shared page pool (L, P, page, K, hd),
        a paged prefix (L, n_pages, page, K, hd), or the draft model's
        contiguous ring (L, B, W, K, hd) — shard the kv-heads axis
        (always second-to-last) over "model" when divisible; the page /
        batch / width axes replicate, so page-table indirection stays a
        *local* gather on every shard.
      * everything else — page tables, per-slot positions, token ids,
        logits, per-row scalars, MLA latent caches (which do not page) —
        replicates.
    """
    names = _path_names(path)
    name = names[-1] if names else ""
    shape = leaf.shape
    nd = len(shape)
    if name in ("k", "v") and nd >= 2:
        spec = [None] * nd
        spec[-2] = _maybe(shape[-2], "model", mesh)
        return P(*spec)
    return P(*([None] * nd))


def serving_specs(abstract_tree: Any, mesh: Mesh) -> Any:
    """PartitionSpecs for any serving pytree (pool, paged prefix KV,
    draft ring cache, page tables, logits, scalars) by key-path."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _serving_rule(p, l, mesh), abstract_tree)


def _batch_rule(path, leaf, mesh: Mesh) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    shape = leaf.shape
    nd = len(shape)
    if name == "positions" and nd == 3:          # M-RoPE (3, B, S)
        return P(None, _maybe_batch(shape[1], mesh), None)
    if nd == 0:
        return P()
    spec = [None] * nd
    spec[0] = _maybe_batch(shape[0], mesh)
    return P(*spec)


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _batch_rule(p, l, mesh), batch)


def opt_state_specs(cfg: ModelConfig, abstract_opt: Any, pspecs: Any,
                    mesh: Mesh) -> Any:
    """Optimizer state mirrors the parameter sharding (m, v trees)."""
    return {
        "step": P(),
        "m": pspecs,
        "v": pspecs,
    } if set(abstract_opt) == {"step", "m", "v"} else {
        "step": P(), "m": pspecs,
    }


def to_shardings(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs, is_leaf=lambda x: isinstance(x, P))
