"""zamba2-7b — hybrid Mamba-2 backbone + shared attention block.
[arXiv:2411.15242]

81 Mamba-2 layers, d_model=3584, ssm_state=64; a single *parameter-shared*
attention+MLP block (32 heads MHA, d_ff=14336) is invoked every
``attn_every`` Mamba layers (Zamba2's shared-block design). 81 layers
factor as 9 super-groups x 9 — the nearest divisor of the published
"every ~6 blocks" cadence (adaptation noted in DESIGN.md §3).
"""
from repro.models import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(version=2, state_size=64, expand=2, conv_kernel=4,
                  head_dim=64),
    hybrid=HybridConfig(attn_every=9),
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2411.15242 (Zamba2: Mamba-2 + shared attention blocks)",
)
