"""Architecture config registry.

``get_config(name)`` returns the full assigned config;
``get_reduced(name)`` returns the smoke-test variant of the same family
(≤2 layers, d_model ≤ 512, ≤4 experts — per the assignment).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.models import ModelConfig

from repro.configs import (deepseek_v3_671b, falcon_mamba_7b, granite_moe_3b,
                           hubert_xlarge, lisa7b, lisa_mini, lisa_nano,
                           minicpm3_4b, nemotron_4_340b, phi4_mini_3p8b,
                           qwen15_32b, qwen2_vl_2b, zamba2_7b)

REGISTRY: Dict[str, ModelConfig] = {
    c.CONFIG.name: c.CONFIG
    for c in (falcon_mamba_7b, nemotron_4_340b, qwen15_32b, phi4_mini_3p8b,
              zamba2_7b, hubert_xlarge, granite_moe_3b, deepseek_v3_671b,
              minicpm3_4b, qwen2_vl_2b)
}

LISA_REGISTRY = {
    lisa7b.CONFIG.name: lisa7b.CONFIG,
    lisa_mini.CONFIG.name: lisa_mini.CONFIG,
    lisa_nano.CONFIG.name: lisa_nano.CONFIG,
}

ARCH_IDS: List[str] = list(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_IDS}")
    return REGISTRY[name]


def get_lisa_config(name: str = "lisa-7b"):
    return LISA_REGISTRY[name]


def get_reduced(name: str) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    cfg = get_config(name)
    kw: dict = {
        "name": cfg.name + "-reduced",
        "num_layers": 2,
        "d_model": 256,
        "num_heads": 4,
        "num_kv_heads": min(4, cfg.num_kv_heads),
        "head_dim": 64 if cfg.head_dim else 0,
        "d_ff": min(cfg.d_ff, 512) if cfg.d_ff else 0,
        "vocab_size": min(cfg.vocab_size, 512),
        "param_dtype": "float32",
        "act_dtype": "float32",
        "mtp": False,
        "num_vision_tokens": min(cfg.num_vision_tokens, 8),
        "frontend_dim": min(cfg.frontend_dim, 32) if cfg.frontend_dim else 0,
    }
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(
            cfg.mla, q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
            qk_rope_head_dim=16, v_head_dim=32)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, d_ff_expert=64,
            d_ff_shared=64 if cfg.moe.num_shared_experts else 0,
            first_k_dense=min(1, cfg.moe.first_k_dense),
            d_ff_dense=256 if cfg.moe.first_k_dense else 0)
        kw["d_ff"] = 64
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, state_size=min(cfg.ssm.state_size, 16), head_dim=32)
    if cfg.hybrid is not None:
        kw["hybrid"] = dataclasses.replace(cfg.hybrid, attn_every=1)
    if cfg.rope_style == "mrope":
        kw["mrope_sections"] = (16, 8, 8)  # half-dim 32 with head_dim 64
    return cfg.replace(**kw)
