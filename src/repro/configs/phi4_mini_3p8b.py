"""phi4-mini-3.8b — dense GQA, RoPE + SwiGLU. [arXiv:2412.08905]

32L, d_model=3072, 24 heads (GQA kv=8), d_ff=8192, vocab=200064.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    arch_type="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    head_dim=128,
    tie_embeddings=True,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2412.08905 (Phi-4-mini: RoPE, SwiGLU, GQA kv=8)",
)
