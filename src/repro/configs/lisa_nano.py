"""lisa_nano — a truly-small draft geometry for speculative decoding
(ROADMAP "distilled draft" stepping stone).

PR 4's default draft reuses the target's own Context-stream weights:
acceptance is total, but every draft step costs a full target step, so
on compute-bound hosts speculation sits at wall-clock parity. The nano
draft keeps the target's embedding table, final norm, answer head and
``seg_proj`` but runs only the first ``DRAFT_LAYERS`` transformer
layer(s) of the trunk — a layer-truncated view of the *same* weights,
so a draft step costs ~``DRAFT_LAYERS / num_layers`` of a target step
(4x fewer trunk FLOPs for lisa_mini) with no separate training run.
Truncation is distillation-free early exit: the shared embedding/head
keep the draft's argmax correlated with the target's, and greedy verify
makes the output token-exact regardless of how often they agree —
acceptance only moves the cost. Swap in an actually-distilled LM later
via ``SpeculativeConfig(draft_params=..., draft_pcfg=...)`` unchanged.

Wiring: ``AveryEngine(speculative="nano")`` builds the config and
slices the executor's weights; ``bench_serving --spec`` reports a
``serving/spec_insight_nano`` row next to the shared-weights draft.
"""
import dataclasses

import jax

from repro.configs.lisa_mini import CONFIG as MINI

# trunk layers the draft keeps (of lisa_mini's 4)
DRAFT_LAYERS = 1

CONFIG = dataclasses.replace(
    MINI, name="lisa-nano",
    llm=MINI.llm.replace(name="llm-nano", num_layers=DRAFT_LAYERS))


def nano_draft_params(params: dict) -> dict:
    """Slice a target's LISA params down to the nano draft: first
    ``DRAFT_LAYERS`` LLM layers (leading layer axis of the scanned
    group leaves), shared embed/norm/answer_head/seg_proj. The result
    aliases the target's arrays — no copies, no extra device memory."""
    llm = params["llm"]
    return {
        "llm": {
            "embed": llm["embed"],
            "groups": [jax.tree.map(lambda a: a[:DRAFT_LAYERS],
                                    llm["groups"][0])],
            "norm": llm["norm"],
            "answer_head": llm["answer_head"],
        },
        "seg_proj": params["seg_proj"],
    }
