"""deepseek-v3-671b — MLA + fine-grained MoE (1 shared + 256 routed,
top-8) + multi-token prediction. [arXiv:2412.19437]

61L, d_model=7168, 128 heads MLA (q_lora=1536, kv_lora=512, nope=128,
rope=64, v=128), routed expert d_ff=2048, first 3 layers dense
(d_ff=18432), vocab=129280. The MLA latent KV cache (512+64 per token) is
itself a learned boundary compression — the affinity with AVERY's
bottleneck is discussed in DESIGN.md §3.
"""
from repro.models import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=2048,
    vocab_size=129280,
    attn_type="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, d_ff_shared=2048,
                  first_k_dense=3, d_ff_dense=18432),
    mtp=True,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2412.19437 (DeepSeek-V3: MLA, 1 shared + 256 routed, MTP)",
)
