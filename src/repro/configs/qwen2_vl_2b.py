"""qwen2-vl-2b — VLM decoder with M-RoPE. [arXiv:2409.12191]

28L, d_model=1536, 12 heads (GQA kv=2), d_ff=8960, vocab=151936.
M-RoPE sections (16, 24, 24) over half-dim 64 for (temporal, h, w)
position streams. The ViT vision encoder is a stub per the assignment
carve-out: ``input_specs`` provides 256 precomputed patch embeddings
(dim 1280) per image, projected into the decoder.

This is the arch closest to the paper's own LISA topology (vision
features consumed by a language decoder) — it anchors the
"most representative" §Perf hillclimb.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_style="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1000000.0,
    modality="vlm",
    frontend_dim=1280,
    num_vision_tokens=256,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2409.12191 (Qwen2-VL: M-RoPE, dynamic resolution ViT)",
)
