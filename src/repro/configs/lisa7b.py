"""LISA-7B — the paper's own model (Section 4): SAM ViT-H vision backbone +
CLIP ViT-L context encoder + LLaMA-7B multi-modal LLM + mask decoder.
[LISA: arXiv from CVPR'24, ref 17 in the paper]

Used for the dry-run/roofline path of the paper's exact topology; the
*trained* experiments use the lisa_mini proxy (no pretrained weights
offline — DESIGN.md §6).
"""
from dataclasses import dataclass
from typing import Tuple

from repro.models import ModelConfig


@dataclass(frozen=True)
class LISAPipelineConfig:
    name: str
    sam: ModelConfig            # Insight vision backbone (encoder)
    clip: ModelConfig           # Context encoder
    llm: ModelConfig            # multi-modal reasoning core
    image_size: int             # Insight-stream input resolution
    patch_size: int
    context_image_size: int     # Context-stream (low-res) input
    context_patch_size: int
    split_layer: int = 1        # split@1 (paper §5.2.1)
    bottleneck_ratios: Tuple[float, ...] = (0.25, 0.10, 0.05)
    mask_pixels_per_patch: int = 0  # 0 -> mask at patch resolution

    @property
    def sam_tokens(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    @property
    def clip_tokens(self) -> int:
        return (self.context_image_size // self.context_patch_size) ** 2


def _encoder(name, layers, d, heads, d_ff, dtype="bfloat16") -> ModelConfig:
    return ModelConfig(
        name=name, arch_type="dense", num_layers=layers, d_model=d,
        num_heads=heads, num_kv_heads=heads, d_ff=d_ff, vocab_size=1,
        causal=False, rope_style="none", norm="layernorm", mlp_act="gelu",
        gated_mlp=False, param_dtype=dtype, act_dtype=dtype)


CONFIG = LISAPipelineConfig(
    name="lisa-7b",
    # SAM ViT-H: 32 blocks, d=1280, 16 heads, 1024px / patch 16 -> 4096 tokens
    sam=_encoder("sam-vit-h", 32, 1280, 16, 5120),
    # CLIP ViT-B/16: 12 blocks, d=768, 12 heads, 224px / patch 16 -> 196
    # tokens. (With this geometry the r=0.25 Insight payload lands at
    # 2.92 MB — exactly the paper's Table 3 figure, and the context/insight
    # edge-compute ratio lands near the paper's 6.4x; see bench_streams.)
    clip=_encoder("clip-vit-b16", 12, 768, 12, 3072),
    # LLaMA-7B: 32L d=4096 MHA 32H d_ff=11008
    llm=ModelConfig(
        name="llama-7b", arch_type="dense", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=32, d_ff=11008, vocab_size=32000,
        param_dtype="bfloat16", act_dtype="bfloat16"),
    image_size=1024, patch_size=16,
    context_image_size=224, context_patch_size=16,
    split_layer=1,
)
