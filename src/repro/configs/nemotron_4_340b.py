"""nemotron-4-340b — dense GQA with squared-ReLU MLP. [arXiv:2402.16819]

96L, d_model=18432, 96 heads (GQA kv=8), d_ff=73728, vocab=256000.
Squared-ReLU is a single-projection (non-gated) MLP.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    mlp_act="relu2",
    gated_mlp=False,
    norm="layernorm",
    rope_theta=10000.0,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2402.16819 (Nemotron-4 340B: GQA kv=8, squared-ReLU)",
)
