"""falcon-mamba-7b — pure Mamba-1 SSM, attention-free. [arXiv:2410.05355]

64L, d_model=4096, d_inner=8192 (expand 2), ssm_state=16, vocab=65024.
No KV cache: decode state is O(1) in context length, so long_500k runs
natively (DESIGN.md §3).
"""
from repro.models import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    arch_type="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    attn_type="none",
    rope_style="none",
    ssm=SSMConfig(version=1, state_size=16, expand=2, conv_kernel=4),
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2410.05355 (Falcon Mamba: 7B attention-free Mamba-1)",
)
