"""hubert-xlarge — encoder-only audio transformer. [arXiv:2106.07447]

48L, d_model=1280, 16 heads (kv=16), d_ff=5120, vocab=504 (cluster
codebook). Bidirectional (non-causal); trained with masked-unit
prediction. The conv waveform feature extractor is a stub per the
assignment carve-out: ``input_specs`` provides (B, T, 512) frame features.

Encoder-only ⇒ NO decode step: decode_32k and long_500k are skipped for
this arch (recorded as N/A in EXPERIMENTS.md; DESIGN.md §3).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    rope_style="none",
    norm="layernorm",
    mlp_act="gelu",
    gated_mlp=False,
    modality="audio",
    frontend_dim=512,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2106.07447 (HuBERT X-Large; w2v2-style encoder)",
)
