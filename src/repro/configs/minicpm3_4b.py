"""minicpm3-4b — dense model with MLA attention. [hf:openbmb/MiniCPM3-4B]

62L, d_model=2560, 40 heads (q_lora=768, kv_lora=256, nope=64, rope=32,
v=64), d_ff=6400, vocab=73448.
"""
from repro.models import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    arch_type="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64),
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="hf:openbmb/MiniCPM3-4B (MLA config from model card)",
)
