"""granite-moe-3b-a800m — fine-grained MoE, top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]

32L, d_model=1536, 24 heads (GQA kv=8), vocab=49155, 40 experts with
d_ff_expert=512, top-8 routing. NOTE: the assignment header says
"MoE 40e top-8" while its trailing note says "32 experts"; we take the
primary spec (40 experts) — discrepancy recorded in DESIGN.md §3.
"""
from repro.models import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512),
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (family card)",
)
