"""LISA-mini — the trainable proxy of the paper's LISA topology
(DESIGN.md §6). Small enough to train end-to-end on CPU in minutes, real
enough that the bottleneck tiers produce an honest accuracy-vs-ratio
curve (Table 3 / Fig 7 analogs).

Scene images are 32x32x3 procedural flood scenes (repro.data.floodseg);
SAM-mini consumes 4px patches (64 tokens), CLIP-mini consumes 8px patches
on the same image (16 tokens, the "low-resolution context" pathway).
The mask head emits 4x4=16 pixel logits per patch -> full 32x32 masks.
"""
from repro.configs.lisa7b import LISAPipelineConfig, _encoder
from repro.models import ModelConfig

CONFIG = LISAPipelineConfig(
    name="lisa-mini",
    sam=_encoder("sam-mini", 4, 128, 4, 256, dtype="float32"),
    clip=_encoder("clip-mini", 2, 64, 4, 128, dtype="float32"),
    llm=ModelConfig(
        name="llm-mini", arch_type="dense", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=64,
        param_dtype="float32", act_dtype="float32"),
    image_size=32, patch_size=4,
    context_image_size=32, context_patch_size=8,
    split_layer=1,
    mask_pixels_per_patch=16,
)
