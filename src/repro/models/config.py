"""Model configuration dataclasses.

A single ``ModelConfig`` describes every architecture family the framework
supports (dense GQA / MLA, MoE, Mamba-1/2 SSM, hybrid, encoder-only audio,
VLM). Architecture configs in ``repro/configs`` instantiate these with the
exact assigned hyperparameters.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3, MiniCPM3)."""
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # "einsum" = GShard-faithful one-hot dispatch (baseline);
    # "scatter" = sort-based dispatch that avoids the (T,E,C) temp (§Perf)
    dispatch: str = "einsum"
    # layers [0, first_k_dense) use a plain dense FFN (DeepSeek-V3 style)
    first_k_dense: int = 0
    d_ff_dense: int = 0


@dataclass(frozen=True)
class SSMConfig:
    version: int              # 1 = Mamba-1 selective scan, 2 = Mamba-2 SSD
    state_size: int           # N
    expand: int = 2           # d_inner = expand * d_model
    conv_kernel: int = 4
    head_dim: int = 64        # mamba2 only (P)
    dt_rank: int = 0          # mamba1 only; 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: shared attention block invoked every ``attn_every``
    SSM layers. The attention block's parameters are shared across all
    invocations (true to Zamba2's shared-block design)."""
    attn_every: int = 9


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str            # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // num_heads
    mlp_act: str = "silu"     # silu (=SwiGLU), relu2 (single-proj), gelu
    gated_mlp: bool = True    # SwiGLU-style gate; False for relu2/gelu single
    attn_type: str = "gqa"    # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_style: str = "rope"  # rope | mrope | none (sinusoid for encoders)
    mrope_sections: Tuple[int, ...] = ()
    sliding_window: Optional[int] = None   # if set, attention is windowed
    causal: bool = True       # False -> encoder-only (bidirectional)
    norm: str = "rmsnorm"     # rmsnorm | layernorm
    norm_eps: float = 1e-6
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # multi-token prediction (DeepSeek-V3): one extra block predicting t+2
    mtp: bool = False
    mtp_weight: float = 0.3
    # modality frontend (stubbed per assignment carve-out)
    modality: str = "text"    # text | audio | vlm
    frontend_dim: int = 0     # raw feature dim produced by the stub frontend
    num_vision_tokens: int = 0
    # numerics / execution
    param_dtype: str = "float32"
    act_dtype: str = "float32"
    remat: bool = False       # activation checkpointing around each block
    use_flash: bool = False   # route full-seq attention through Pallas kernel
    # route single-token GQA decode attention through the flash-decode
    # Pallas kernel (kernels/decode_attention): one streaming read of the
    # KV cache per step — the serving decode hot loop (MLA decode keeps
    # the absorbed-matmul path)
    use_flash_decode: bool = False
    # query-chunked attention (§Perf lever): lax.scan over q blocks of this
    # size so only a (chunk x S) score tile is ever materialised — the
    # flash-attention access pattern expressed at the XLA level
    attn_chunk: Optional[int] = None
    # perf-analysis ONLY (never for real compute): replace the
    # score/softmax/PV stage with a pass-through so its HLO cost can be
    # isolated; the flash-kernel-adjusted roofline = this + the kernel's
    # analytic VMEM-resident traffic (q,k,v read + o write once)
    attn_scores_stub: bool = False
    use_ssm_kernel: bool = False  # route SSM scan through Pallas kernel
    # fully unroll layer scans (dry-run cost extraction: XLA counts a while
    # body once, so per-layer costs are measured on small unrolled variants
    # and extrapolated linearly — see launch/dryrun.py)
    scan_unroll: bool = False
    # Megatron-style sequence parallelism (§Perf lever): constrain the
    # residual stream to be sequence-sharded over "model" between blocks,
    # turning per-layer all-reduces into reduce-scatter + all-gather pairs
    seq_shard: bool = False
    # shard decode KV caches on head_dim instead of kv-heads (§Perf lever:
    # kv-head counts like 8 or 40 don't divide the model axis, which leaves
    # the cache replicated and decode collective-bound)
    shard_cache_hd: bool = False
    tie_embeddings: bool = False
    # citation for the assigned config
    source: str = ""

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.act_dtype)

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def supports_decode(self) -> bool:
        return self.causal

    @property
    def subquadratic(self) -> bool:
        """True if a 500k-token decode context is feasible: SSM/hybrid state
        is O(1); windowed attention caches only ``sliding_window`` slots."""
        if self.arch_type in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_sliding_window(self, window: int = 8192) -> "ModelConfig":
        """Beyond-paper variant used for long_500k on dense archs."""
        return self.replace(sliding_window=window)

    def param_count(self) -> int:
        """Analytic parameter count (exact for our layouts)."""
        from repro.models import stack
        return stack.count_params(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top_k experts)."""
        from repro.models import stack
        return stack.count_params(self, active_only=True)
