"""Layer-stack machinery: homogeneous layer groups scanned with ``lax.scan``.

Every architecture is expressed as a sequence of *groups*; each group is a
stack of identical blocks whose parameters carry a leading layer axis, so a
96-layer model lowers to a single scanned HLO body (essential for compile
time on the 512-device dry-run; see DESIGN.md §7).

Group kinds:
  dense   — attention + dense FFN             (dense / audio / vlm archs)
  moe     — attention + MoE FFN               (granite, deepseek)
  mamba1  — Mamba-1 mixer                     (falcon-mamba)
  mamba2  — Mamba-2 mixer                     (zamba2 backbone)
  hybrid  — Zamba2 super-group: one *shared* attention block (parameters
            shared across all invocations) followed by ``attn_every``
            Mamba-2 layers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, moe as moe_lib, ssm
from repro.models.common import layer_norm, rms_norm
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class GroupSpec:
    kind: str
    count: int
    d_ff: int = 0   # dense FFN width for dense/moe-dense groups


def layer_groups(cfg: ModelConfig) -> List[GroupSpec]:
    if cfg.arch_type == "hybrid":
        ae = cfg.hybrid.attn_every
        assert cfg.num_layers % ae == 0, (cfg.num_layers, ae)
        return [GroupSpec("hybrid", cfg.num_layers // ae)]
    if cfg.arch_type == "ssm":
        return [GroupSpec(f"mamba{cfg.ssm.version}", cfg.num_layers)]
    if cfg.moe is not None:
        out = []
        fk = cfg.moe.first_k_dense
        if fk:
            out.append(GroupSpec("dense", fk, cfg.moe.d_ff_dense or cfg.d_ff))
        out.append(GroupSpec("moe", cfg.num_layers - fk))
        return out
    return [GroupSpec("dense", cfg.num_layers, cfg.d_ff)]


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig) -> dict:
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((cfg.d_model,), cfg.pdtype),
                "b": jnp.zeros((cfg.d_model,), cfg.pdtype)}
    return {"w": jnp.ones((cfg.d_model,), cfg.pdtype)}


def apply_norm(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------


def _init_dense_layer(rng: jax.Array, cfg: ModelConfig, d_ff: int) -> dict:
    k1, k2 = jax.random.split(rng)
    return {
        "norm1": init_norm(cfg),
        "attn": attention.init_attention(k1, cfg),
        "norm2": init_norm(cfg),
        "mlp": moe_lib.init_dense_mlp(k2, cfg, d_ff),
    }


def _init_moe_layer(rng: jax.Array, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(rng)
    return {
        "norm1": init_norm(cfg),
        "attn": attention.init_attention(k1, cfg),
        "norm2": init_norm(cfg),
        "moe": moe_lib.init_moe(k2, cfg),
    }


def _init_mamba_layer(rng: jax.Array, cfg: ModelConfig) -> dict:
    init = ssm.init_mamba1 if cfg.ssm.version == 1 else ssm.init_mamba2
    return {"norm": init_norm(cfg), "mixer": init(rng, cfg)}


def _init_hybrid_group(rng: jax.Array, cfg: ModelConfig) -> dict:
    """Only the per-group Mamba-2 layers; the shared attention block lives
    once at the top level (params['shared_attn'])."""
    ae = cfg.hybrid.attn_every
    ks = jax.random.split(rng, ae)
    return jax.vmap(lambda k: _init_mamba_layer(k, cfg))(ks)


def init_group(rng: jax.Array, cfg: ModelConfig, spec: GroupSpec) -> Any:
    ks = jax.random.split(rng, spec.count)
    if spec.kind == "dense":
        return jax.vmap(lambda k: _init_dense_layer(k, cfg, spec.d_ff))(ks)
    if spec.kind == "moe":
        return jax.vmap(lambda k: _init_moe_layer(k, cfg))(ks)
    if spec.kind in ("mamba1", "mamba2"):
        return jax.vmap(lambda k: _init_mamba_layer(k, cfg))(ks)
    if spec.kind == "hybrid":
        return jax.vmap(lambda k: _init_hybrid_group(k, cfg))(ks)
    raise ValueError(spec.kind)


# ---------------------------------------------------------------------------
# block bodies (full sequence)
# ---------------------------------------------------------------------------


def _seq_wsc(cfg, x):
    if not cfg.seq_shard:
        return x
    from repro.models.common import wsc
    return wsc(x, "BATCH", "model", None)


def _dense_block_full(p, cfg, x, positions, mask):
    x = _seq_wsc(cfg, x)
    h, kv = attention.attn_full(p["attn"], cfg, apply_norm(x, p["norm1"], cfg),
                                positions, mask)
    x = _seq_wsc(cfg, x + h)
    x = x + moe_lib.dense_mlp(p["mlp"], cfg, apply_norm(x, p["norm2"], cfg))
    return x, kv


def _moe_block_full(p, cfg, x, positions, mask):
    h, kv = attention.attn_full(p["attn"], cfg, apply_norm(x, p["norm1"], cfg),
                                positions, mask)
    x = x + h
    y, aux = moe_lib.moe_mlp(p["moe"], cfg, apply_norm(x, p["norm2"], cfg))
    return x + y, kv, aux


def _mamba_block_full(p, cfg, x):
    full = ssm.mamba1_full if cfg.ssm.version == 1 else ssm.mamba2_full
    return x + full(p["mixer"], cfg, apply_norm(x, p["norm"], cfg))


def group_forward(params: Any, cfg: ModelConfig, spec: GroupSpec, x: jax.Array,
                  positions: jax.Array, mask: jax.Array,
                  shared_attn: Optional[dict] = None,
                  want_cache: bool = False):
    """Run one group full-sequence. Returns (x, aux_loss, cache_or_None)."""
    if spec.kind == "dense":
        def body(h, lp):
            h2, kv = _dense_block_full(lp, cfg, h, positions, mask)
            return h2, (kv if want_cache else 0)
        body = jax.checkpoint(body) if cfg.remat else body
        x, kvs = jax.lax.scan(body, x, params, unroll=cfg.scan_unroll)
        return x, 0.0, (kvs if want_cache else None)

    if spec.kind == "moe":
        def body(carry, lp):
            h, aux = carry
            h2, kv, a = _moe_block_full(lp, cfg, h, positions, mask)
            return (h2, aux + a), (kv if want_cache else 0)
        body = jax.checkpoint(body) if cfg.remat else body
        (x, aux), kvs = jax.lax.scan(body, (x, jnp.float32(0.0)), params,
                                     unroll=cfg.scan_unroll)
        return x, aux, (kvs if want_cache else None)

    if spec.kind in ("mamba1", "mamba2"):
        def body(h, lp):
            return _mamba_block_full(lp, cfg, h), 0
        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, params, unroll=cfg.scan_unroll)
        return x, 0.0, None

    if spec.kind == "hybrid":
        sa = shared_attn
        def body(h, gp):
            h2, kv = _dense_block_full(sa, cfg, h, positions, mask)
            def mbody(hh, lp):
                return _mamba_block_full(lp, cfg, hh), 0
            h3, _ = jax.lax.scan(mbody, h2, gp, unroll=cfg.scan_unroll)
            return h3, (kv if want_cache else 0)
        body = jax.checkpoint(body) if cfg.remat else body
        x, kvs = jax.lax.scan(body, x, params, unroll=cfg.scan_unroll)
        return x, 0.0, (kvs if want_cache else None)

    raise ValueError(spec.kind)


# ---------------------------------------------------------------------------
# block bodies (single-token decode)
# ---------------------------------------------------------------------------


def _dense_block_decode(p, cfg, x, positions, cache, slot, mask):
    h, c2 = attention.attn_decode(p["attn"], cfg, apply_norm(x, p["norm1"], cfg),
                                  positions, cache, slot, mask)
    x = x + h
    x = x + moe_lib.dense_mlp(p["mlp"], cfg, apply_norm(x, p["norm2"], cfg))
    return x, c2


def _moe_block_decode(p, cfg, x, positions, cache, slot, mask):
    h, c2 = attention.attn_decode(p["attn"], cfg, apply_norm(x, p["norm1"], cfg),
                                  positions, cache, slot, mask)
    x = x + h
    y, _ = moe_lib.moe_mlp(p["moe"], cfg, apply_norm(x, p["norm2"], cfg))
    return x + y, c2


def _dense_block_decode_paged(p, cfg, x, positions, pool, page_table,
                              write_page, write_off, mask, attn_fn=None):
    attn_fn = attn_fn or attention.attn_decode_paged
    h, c2 = attn_fn(
        p["attn"], cfg, apply_norm(x, p["norm1"], cfg), positions, pool,
        page_table, write_page, write_off, mask)
    x = x + h
    x = x + moe_lib.dense_mlp(p["mlp"], cfg, apply_norm(x, p["norm2"], cfg))
    return x, c2


def _moe_block_decode_paged(p, cfg, x, positions, pool, page_table,
                            write_page, write_off, mask, attn_fn=None):
    attn_fn = attn_fn or attention.attn_decode_paged
    h, c2 = attn_fn(
        p["attn"], cfg, apply_norm(x, p["norm1"], cfg), positions, pool,
        page_table, write_page, write_off, mask)
    x = x + h
    y, _ = moe_lib.moe_mlp(p["moe"], cfg, apply_norm(x, p["norm2"], cfg))
    return x + y, c2


def _mamba_block_decode(p, cfg, x, state):
    step = ssm.mamba1_step if cfg.ssm.version == 1 else ssm.mamba2_step
    h, s2 = step(p["mixer"], cfg, apply_norm(x, p["norm"], cfg), state)
    return x + h, s2


def group_decode(params: Any, cfg: ModelConfig, spec: GroupSpec, x: jax.Array,
                 positions: jax.Array, cache: Any, slot: jax.Array,
                 mask: jax.Array, shared_attn: Optional[dict] = None):
    """Single-token decode through one group. Returns (x, new_cache)."""
    if spec.kind == "dense":
        def body(h, inp):
            lp, c = inp
            return _dense_block_decode(lp, cfg, h, positions, c, slot, mask)
        return jax.lax.scan(body, x, (params, cache),
                            unroll=cfg.scan_unroll)

    if spec.kind == "moe":
        def body(h, inp):
            lp, c = inp
            return _moe_block_decode(lp, cfg, h, positions, c, slot, mask)
        return jax.lax.scan(body, x, (params, cache),
                            unroll=cfg.scan_unroll)

    if spec.kind in ("mamba1", "mamba2"):
        def body(h, inp):
            lp, s = inp
            return _mamba_block_decode(lp, cfg, h, s)
        return jax.lax.scan(body, x, (params, cache),
                            unroll=cfg.scan_unroll)

    if spec.kind == "hybrid":
        sa = shared_attn
        def body(h, inp):
            gp, c = inp
            h2, kv2 = _dense_block_decode(sa, cfg, h, positions, c["attn"],
                                          slot, mask)
            def mbody(hh, minp):
                lp, s = minp
                return _mamba_block_decode(lp, cfg, hh, s)
            h3, s2 = jax.lax.scan(mbody, h2, (gp, c["mamba"]),
                                  unroll=cfg.scan_unroll)
            return h3, {"attn": kv2, "mamba": s2}
        return jax.lax.scan(body, x, (params, cache),
                            unroll=cfg.scan_unroll)

    raise ValueError(spec.kind)


def group_decode_paged(params: Any, cfg: ModelConfig, spec: GroupSpec,
                       x: jax.Array, positions: jax.Array, pool: Any,
                       page_table: jax.Array, write_page: jax.Array,
                       write_off: jax.Array, mask: jax.Array):
    """Single-token decode through one group against a shared KV page
    pool (leaves (L, P, page, ...)). Attention-cache stacks only — SSM
    recurrent state has no sequence axis to page. Returns
    (x, new pool)."""
    if spec.kind == "dense":
        def body(h, inp):
            lp, c = inp
            return _dense_block_decode_paged(lp, cfg, h, positions, c,
                                             page_table, write_page,
                                             write_off, mask)
        return jax.lax.scan(body, x, (params, pool),
                            unroll=cfg.scan_unroll)

    if spec.kind == "moe":
        def body(h, inp):
            lp, c = inp
            return _moe_block_decode_paged(lp, cfg, h, positions, c,
                                           page_table, write_page,
                                           write_off, mask)
        return jax.lax.scan(body, x, (params, pool),
                            unroll=cfg.scan_unroll)

    raise NotImplementedError(
        f"paged decode caches cover attention stacks only, not {spec.kind}")


def group_verify_paged(params: Any, cfg: ModelConfig, spec: GroupSpec,
                       x: jax.Array, positions: jax.Array, pool: Any,
                       page_table: jax.Array, write_page: jax.Array,
                       write_off: jax.Array, mask: jax.Array):
    """Multi-token (speculative verify) decode through one group against
    the shared KV page pool: x (B, C, d) chunk tokens, positions /
    write_page / write_off (B, C), mask (B, C, n_pages*page). Same layer
    scan as ``group_decode_paged`` with the multi-query attention body.
    Returns (x, new pool)."""
    if spec.kind == "dense":
        def body(h, inp):
            lp, c = inp
            return _dense_block_decode_paged(
                lp, cfg, h, positions, c, page_table, write_page,
                write_off, mask, attn_fn=attention.attn_verify_paged)
        return jax.lax.scan(body, x, (params, pool),
                            unroll=cfg.scan_unroll)

    if spec.kind == "moe":
        def body(h, inp):
            lp, c = inp
            return _moe_block_decode_paged(
                lp, cfg, h, positions, c, page_table, write_page,
                write_off, mask, attn_fn=attention.attn_verify_paged)
        return jax.lax.scan(body, x, (params, pool),
                            unroll=cfg.scan_unroll)

    raise NotImplementedError(
        f"paged verify covers attention stacks only, not {spec.kind}")


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def group_empty_cache(cfg: ModelConfig, spec: GroupSpec, batch: int,
                      width: int) -> Any:
    def stack(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree)

    if spec.kind in ("dense", "moe"):
        return stack(attention.empty_cache(cfg, batch, width), spec.count)
    if spec.kind in ("mamba1", "mamba2"):
        empty = (ssm.mamba1_empty_state if cfg.ssm.version == 1
                 else ssm.mamba2_empty_state)
        return stack(empty(cfg, batch), spec.count)
    if spec.kind == "hybrid":
        return {
            "attn": stack(attention.empty_cache(cfg, batch, width), spec.count),
            "mamba": stack(stack(ssm.mamba2_empty_state(cfg, batch),
                                 cfg.hybrid.attn_every), spec.count),
        }
    raise ValueError(spec.kind)


# ---------------------------------------------------------------------------
# parameter counting
# ---------------------------------------------------------------------------


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count via abstract init (no allocation)."""
    from repro.models import model as model_lib
    shapes = jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0)))
    import math
    total = sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))
    if active_only and cfg.moe is not None:
        m = cfg.moe
        per_expert = (3 if cfg.gated_mlp else 2) * cfg.d_model * m.d_ff_expert
        n_moe_layers = cfg.num_layers - m.first_k_dense
        total -= n_moe_layers * (m.num_experts - m.top_k) * per_expert
    return total
