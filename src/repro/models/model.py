"""Top-level model API: init / forward / loss / train_step / prefill / decode.

All functions are pure; ``cfg`` is static (closed over before ``jax.jit``).
Batch formats (see ``repro/launch/dryrun.py::input_specs`` for the
ShapeDtypeStruct stand-ins):

  text : {"tokens": (B,S) i32}
  audio: {"frames": (B,S,frontend_dim) f, "targets": (B,S) i32,
          "mask_positions": (B,S) bool}           (HuBERT masked prediction)
  vlm  : {"tokens": (B,S) i32, "vision_embeds": (B,n_vis,frontend_dim) f,
          "positions": (3,B,S) i32}               (M-RoPE position triples)

The audio conv feature extractor and the VLM ViT are *stubs per the
assignment carve-out*: inputs arrive as precomputed frame/patch embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import stack
from repro.models.common import (cache_mask, causal_mask, fan_in_init,
                                 linear, normal_init, sinusoid_positions)
from repro.models.config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, rng: jax.Array) -> Params:
    specs = stack.layer_groups(cfg)
    ks = jax.random.split(rng, len(specs) + 5)
    p: Params = {
        "embed": normal_init(ks[0], (cfg.vocab_size, cfg.d_model), 0.02,
                             cfg.pdtype),
        "groups": [stack.init_group(ks[1 + i], cfg, s)
                   for i, s in enumerate(specs)],
        "final_norm": stack.init_norm(cfg),
    }
    nk = len(specs) + 1
    if not cfg.tie_embeddings:
        p["head"] = fan_in_init(ks[nk], (cfg.d_model, cfg.vocab_size),
                                cfg.pdtype)
    if cfg.arch_type == "hybrid":
        p["shared_attn"] = stack._init_dense_layer(ks[nk + 1], cfg, cfg.d_ff)
    if cfg.modality == "audio":
        p["feat_proj"] = fan_in_init(ks[nk + 2], (cfg.frontend_dim, cfg.d_model),
                                     cfg.pdtype)
    if cfg.modality == "vlm":
        p["vision_proj"] = fan_in_init(ks[nk + 2],
                                       (cfg.frontend_dim, cfg.d_model),
                                       cfg.pdtype)
    if cfg.mtp:
        k_a, k_b = jax.random.split(ks[nk + 3])
        p["mtp"] = {
            "proj": fan_in_init(k_a, (2 * cfg.d_model, cfg.d_model), cfg.pdtype),
            "block": stack._init_dense_layer(
                k_b, cfg, cfg.d_ff or (cfg.moe.d_ff_dense if cfg.moe else 0)),
            "norm": stack.init_norm(cfg),
        }
    return p


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def _embed_tokens(p: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embed"], tokens, axis=0).astype(cfg.adtype)


def _embed_inputs(p: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    """Returns (x (B,S,d), positions) where positions is (B,S) or (3,B,S)."""
    if cfg.modality == "audio":
        frames = batch["frames"]
        B, S, _ = frames.shape
        x = linear(frames.astype(cfg.adtype), p["feat_proj"])
        x = x + sinusoid_positions(S, cfg.d_model, cfg.adtype)[None]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        return x, positions
    if cfg.modality == "vlm":
        tokens = batch["tokens"]
        B, S = tokens.shape
        nv = cfg.num_vision_tokens
        x_vis = linear(batch["vision_embeds"].astype(cfg.adtype),
                       p["vision_proj"])
        x_txt = _embed_tokens(p, cfg, tokens[:, nv:])
        x = jnp.concatenate([x_vis, x_txt], axis=1)
        return x, batch["positions"]
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(p, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions


def _head(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = stack.apply_norm(x, p["final_norm"], cfg)
    w = p["embed"].T if cfg.tie_embeddings else p["head"]
    return linear(x, w)


# ---------------------------------------------------------------------------
# forward (full sequence)
# ---------------------------------------------------------------------------


def forward(p: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            want_cache: bool = False):
    """Returns (logits, aux_loss, caches, hidden)."""
    x, positions = _embed_inputs(p, cfg, batch)
    B, S, _ = x.shape
    if cfg.causal:
        mask = causal_mask(S, cfg.sliding_window)[None]
    else:
        mask = jnp.zeros((1, S, S), jnp.float32)
    aux = jnp.float32(0.0)
    caches = []
    for spec, gparams in zip(stack.layer_groups(cfg), p["groups"]):
        x, a, c = stack.group_forward(gparams, cfg, spec, x, positions, mask,
                                      shared_attn=p.get("shared_attn"),
                                      want_cache=want_cache)
        aux = aux + a
        caches.append(c)
    logits = _head(p, cfg, x)
    return logits, aux, (caches if want_cache else None), x


# ---------------------------------------------------------------------------
# losses / train step
# ---------------------------------------------------------------------------


def _xent(logits: jax.Array, targets: jax.Array,
          mask: Optional[jax.Array] = None) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / (jnp.sum(mask) + 1e-6)


def loss_fn(p: Params, cfg: ModelConfig,
            batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux, _, hidden = forward(p, cfg, batch)
    if cfg.modality == "audio":
        loss = _xent(logits, batch["targets"], batch["mask_positions"])
    else:
        tokens = batch["tokens"]
        lmask = None
        if cfg.modality == "vlm":
            # vision positions carry patch embeddings, not predictable tokens
            lmask = jnp.broadcast_to(
                jnp.arange(tokens.shape[1] - 1) >= cfg.num_vision_tokens,
                tokens[:, 1:].shape)
        loss = _xent(logits[:, :-1], tokens[:, 1:], lmask)
    total = loss + aux
    metrics = {"loss": loss, "aux_loss": aux}
    if cfg.mtp and cfg.modality == "text":
        tokens = batch["tokens"]
        emb_next = _embed_tokens(p, cfg, tokens[:, 1:])
        h_in = jnp.concatenate(
            [stack.apply_norm(hidden[:, :-1], p["mtp"]["norm"], cfg), emb_next],
            axis=-1)
        h_in = linear(h_in, p["mtp"]["proj"])
        S1 = h_in.shape[1]
        positions = jnp.broadcast_to(
            jnp.arange(S1, dtype=jnp.int32)[None], h_in.shape[:2])
        mtp_h, _ = stack._dense_block_full(
            p["mtp"]["block"], cfg, h_in, positions,
            causal_mask(S1, cfg.sliding_window)[None])
        mtp_logits = _head(p, cfg, mtp_h)[:, :-1]
        mtp_loss = _xent(mtp_logits, tokens[:, 2:])
        total = total + cfg.mtp_weight * mtp_loss
        metrics["mtp_loss"] = mtp_loss
    metrics["total_loss"] = total
    return total, metrics


def make_train_step(cfg: ModelConfig, optimizer):
    """optimizer: repro.optim.Optimizer (init/update pair)."""
    def train_step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        params, opt_state = optimizer.apply(params, opt_state, grads)
        metrics["grad_norm"] = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return params, opt_state, metrics
    return train_step


# ---------------------------------------------------------------------------
# inference: prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, width: int) -> Dict[str, Any]:
    """Decode cache. ``width`` is the KV-cache length; for sliding-window
    configs callers should pass min(width, cfg.sliding_window) — slots are a
    ring buffer indexed pos % width. SSM groups carry O(1) state instead."""
    groups = [stack.group_empty_cache(cfg, s, batch, width)
              for s in stack.layer_groups(cfg)]
    return {
        "groups": groups,
        "positions": jnp.full((batch, width), -1, jnp.int32),
    }


def prefill_step(p: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    """Full forward that also returns cache contents (width == S) and the
    last-position logits — the inference-prefill workload shape."""
    logits, aux, caches, _ = forward(p, cfg, batch, want_cache=True)
    if cfg.modality == "audio":
        return logits, None  # encoder-only: no decode, cache is meaningless
    some = batch["tokens"]
    B, S = some.shape[0], logits.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cache = {"groups": caches, "positions": positions}
    return logits[:, -1:], cache


def decode_step(p: Params, cfg: ModelConfig, cache: Dict[str, Any],
                tokens: jax.Array, pos: jax.Array):
    """One decode step. tokens (B,1) i32; pos scalar i32 (absolute position
    of the new token). Returns (logits (B,1,V), new_cache)."""
    assert cfg.supports_decode, f"{cfg.name} is encoder-only"
    B = tokens.shape[0]
    x = _embed_tokens(p, cfg, tokens)

    has_attn = any(s.kind in ("dense", "moe", "hybrid")
                   for s in stack.layer_groups(cfg))
    if has_attn:
        W = cache["positions"].shape[1]
        slot = jnp.asarray(pos, jnp.int32) % W
        pos_arr = jax.lax.dynamic_update_slice(
            cache["positions"],
            jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B, 1)),
            (0, slot))
        mask = cache_mask(pos_arr, pos, cfg.sliding_window)
    else:
        W, slot, pos_arr = 1, jnp.int32(0), cache["positions"]
        mask = jnp.zeros((B, 1), jnp.float32)

    if cfg.rope_style == "mrope":
        positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (3, B, 1))
    else:
        positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B, 1))

    new_groups = []
    for spec, gparams, gcache in zip(stack.layer_groups(cfg), p["groups"],
                                     cache["groups"]):
        x, c2 = stack.group_decode(gparams, cfg, spec, x, positions, gcache,
                                   slot, mask, shared_attn=p.get("shared_attn"))
        new_groups.append(c2)
    logits = _head(p, cfg, x)
    return logits, {"groups": new_groups, "positions": pos_arr}
