"""Attention variants: GQA (optionally biased / sliding-window) and MLA.

Two execution paths per variant:
  * full-sequence (training / prefill) — optionally emits cache contents;
  * single-token decode against a ring-buffer KV cache.

Cache layout (per layer, stacked along a leading layer axis by the stack):
  GQA: {"k": (B, W, K, hd), "v": (B, W, K, hd)}      — k stored post-RoPE
  MLA: {"ckv": (B, W, r_kv), "krope": (B, W, d_r)}   — the latent cache that
       makes DeepSeek-style decode memory-light (this *is* MLA's bottleneck
       affinity noted in DESIGN.md).
Slot-position bookkeeping ((B?, W) absolute positions) lives at the model
level and arrives here as a pre-computed additive mask.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (NEG_INF, apply_mrope, apply_rope,
                                 fan_in_init, linear, zeros_init)
from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------


def init_gqa(rng: jax.Array, cfg: ModelConfig) -> dict:
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    dt = cfg.pdtype
    p = {
        "wq": fan_in_init(ks[0], (d, H * hd), dt),
        "wk": fan_in_init(ks[1], (d, K * hd), dt),
        "wv": fan_in_init(ks[2], (d, K * hd), dt),
        "wo": fan_in_init(ks[3], (H * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init((H * hd,), dt)
        p["bk"] = zeros_init((K * hd,), dt)
        p["bv"] = zeros_init((K * hd,), dt)
    return p


def init_mla(rng: jax.Array, cfg: ModelConfig) -> dict:
    m = cfg.mla
    assert m is not None
    d, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(rng, 5)
    dt = cfg.pdtype
    return {
        "wq_a": fan_in_init(ks[0], (d, m.q_lora_rank), dt),
        "q_norm": jnp.ones((m.q_lora_rank,), dt),
        "wq_b": fan_in_init(ks[1], (m.q_lora_rank, H * qk), dt),
        "wkv_a": fan_in_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dt),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dt),
        "wkv_b": fan_in_init(ks[3], (m.kv_lora_rank,
                                     H * (m.qk_nope_head_dim + m.v_head_dim)), dt),
        "wo": fan_in_init(ks[4], (H * m.v_head_dim, d), dt),
    }


def init_attention(rng: jax.Array, cfg: ModelConfig) -> dict:
    return init_mla(rng, cfg) if cfg.attn_type == "mla" else init_gqa(rng, cfg)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def _rope_q_or_k(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    if cfg.rope_style == "none":
        return x
    if cfg.rope_style == "mrope":
        return apply_mrope(x, positions, cfg.mrope_sections, cfg.rope_theta)
    return apply_rope(x, positions, cfg.rope_theta)


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
          scale: float) -> jax.Array:
    """q (B,S,H,hd) k/v (B,T,K,hd) grouped attention, fp32 softmax.

    mask: additive, broadcastable to (B, 1, S, T). Matmuls run on the
    native (bf16) operands with fp32 accumulation (preferred_element_type)
    — the MXU idiom; no materialised fp32 copies of q/k/v (§Perf).
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = scores + mask.reshape(mask.shape[0], 1, 1, *mask.shape[1:])
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def _sdpa_chunked(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array,
                  mask: jax.Array, scale: float, chunk: int) -> jax.Array:
    """Query-chunked attention: lax.scan over q blocks so only a
    (chunk, S) score tile is live at once — the flash-attention access
    pattern at the XLA level (§Perf memory lever)."""
    B, S, H, hd = q.shape
    nc = S // chunk
    qc = q.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    Bm = mask.shape[0]
    mc = mask.reshape(Bm, nc, chunk, mask.shape[-1]).transpose(1, 0, 2, 3)

    def body(_, xs):
        qb, mb = xs
        return None, _sdpa(qb, k, v, mb, scale)

    _, out = jax.lax.scan(body, None, (qc, mc), unroll=cfg.scan_unroll)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def gqa_full(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
             mask: jax.Array) -> Tuple[jax.Array, dict]:
    """Full-sequence GQA. Returns (out, cache_contents)."""
    B, S, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = linear(x, p["wq"], p.get("bq")).reshape(B, S, H, hd)
    k = linear(x, p["wk"], p.get("bk")).reshape(B, S, K, hd)
    v = linear(x, p["wv"], p.get("bv")).reshape(B, S, K, hd)
    pos1d = positions if cfg.rope_style != "mrope" else positions
    q = _rope_q_or_k(cfg, q, pos1d)
    k = _rope_q_or_k(cfg, k, pos1d)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    if cfg.attn_scores_stub:
        # perf-analysis stub: keep q/k/v projections alive, skip the
        # score/softmax/PV stage (see config docstring)
        out = q + 1e-6 * (jnp.mean(k) + jnp.mean(v))
    elif cfg.use_flash and cfg.causal and cfg.sliding_window is None:
        from repro.kernels.flash_attention import ops as flash_ops
        out = flash_ops.flash_attention(q, k, v, causal=True)
    elif cfg.attn_chunk and S > cfg.attn_chunk and S % cfg.attn_chunk == 0:
        out = _sdpa_chunked(cfg, q, k, v, mask, scale, cfg.attn_chunk)
    else:
        out = _sdpa(q, k, v, mask, scale)
    out = linear(out.reshape(B, S, H * hd), p["wo"])
    return out, {"k": k, "v": v}


def gqa_decode(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
               cache: dict, slot: jax.Array, mask: jax.Array) -> Tuple[jax.Array, dict]:
    """Single-token decode. x (B,1,d); cache k/v (B,W,K,hd); slot scalar
    (shared ring slot) or (B,) vector (per-row slots, in-flight batching);
    mask (B,W) additive over cache slots (already includes the new token's
    slot as valid)."""
    B, S, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = linear(x, p["wq"], p.get("bq")).reshape(B, S, H, hd)
    k = linear(x, p["wk"], p.get("bk")).reshape(B, S, K, hd)
    v = linear(x, p["wv"], p.get("bv")).reshape(B, S, K, hd)
    q = _rope_q_or_k(cfg, q, positions)
    k = _rope_q_or_k(cfg, k, positions)
    if cfg.shard_cache_hd:
        # align the fresh k/v (and q) with the head_dim-sharded cache at the
        # source, so the cache update and attention reads stay local and the
        # only collective left is the small score partial-sum (§Perf)
        from repro.models.common import wsc
        q = wsc(q, "BATCH", None, None, "model")
        k = wsc(k, "BATCH", None, None, "model")
        v = wsc(v, "BATCH", None, None, "model")
    if jnp.ndim(slot) == 0:
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot,
                                                      axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot,
                                                      axis=1)
    else:                               # per-row scatter into the ring
        rows = jnp.arange(B)
        k_cache = cache["k"].at[rows, slot].set(k[:, 0])
        v_cache = cache["v"].at[rows, slot].set(v[:, 0])
    if cfg.use_flash_decode and S == 1 and not cfg.shard_cache_hd:
        from repro.kernels.decode_attention import ops as decode_ops
        out = decode_ops.decode_attention(q[:, 0], k_cache, v_cache,
                                          mask)[:, None]
    else:
        scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
        out = _sdpa(q, k_cache, v_cache, mask[:, None, :], scale)
    out = linear(out.reshape(B, S, H * hd), p["wo"])
    return out, {"k": k_cache, "v": v_cache}


def gqa_decode_paged(p: dict, cfg: ModelConfig, x: jax.Array,
                     positions: jax.Array, pool: dict, page_table: jax.Array,
                     write_page: jax.Array, write_off: jax.Array,
                     mask: jax.Array) -> Tuple[jax.Array, dict]:
    """Single-token decode against a shared KV *page pool*.

    x (B,1,d); pool k/v (P, page, K, hd) — pages shared by every live
    row; page_table (B, n_pages) i32, every entry a valid page id (idle
    rows point at the reserved trash page); write_page/write_off (B,)
    page slot receiving the new token's k/v (idle rows may collide on
    the trash page — their outputs are discarded); mask (B, n_pages*page)
    additive over the row's gathered virtual sequence. Returns
    (out, new pool). Gathered virtual order preserves ascending
    positions and masked slots contribute exactly zero, so outputs match
    the contiguous ring cache bit-for-bit up to reduction order.

    Sharded serving (``sharding/serving.py``) runs this body under a
    mesh with kv-heads sharded over "model": the page gather and both
    einsums stay shard-local per head slice (each shard sees
    K / model_shards kv heads) and the only collective is the
    all-reduce after the row-parallel ``wo``. The flash kernel path is
    per-shard-head-count-ready but needs ``shard_map`` (it cannot lower
    inside a GSPMD partition in interpret mode), so sharded contexts
    pin ``use_flash_decode=False`` — see ``kernels/decode_attention``.
    """
    B, S, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.shard_cache_hd:
        raise NotImplementedError(
            "paged decode does not support the head_dim-sharded cache")
    q = linear(x, p["wq"], p.get("bq")).reshape(B, S, H, hd)
    k = linear(x, p["wk"], p.get("bk")).reshape(B, S, K, hd)
    v = linear(x, p["wv"], p.get("bv")).reshape(B, S, K, hd)
    q = _rope_q_or_k(cfg, q, positions)
    k = _rope_q_or_k(cfg, k, positions)
    k_pool = pool["k"].at[write_page, write_off].set(k[:, 0])
    v_pool = pool["v"].at[write_page, write_off].set(v[:, 0])
    if cfg.use_flash_decode and S == 1:
        from repro.kernels.decode_attention import ops as decode_ops
        out = decode_ops.paged_decode_attention(q[:, 0], k_pool, v_pool,
                                                page_table, mask)[:, None]
    else:
        n, page = page_table.shape[1], k_pool.shape[1]
        kg = k_pool[page_table].reshape(B, n * page, K, hd)
        vg = v_pool[page_table].reshape(B, n * page, K, hd)
        scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
        out = _sdpa(q, kg, vg, mask[:, None, :], scale)
    out = linear(out.reshape(B, S, H * hd), p["wo"])
    return out, {"k": k_pool, "v": v_pool}


def gqa_verify_paged(p: dict, cfg: ModelConfig, x: jax.Array,
                     positions: jax.Array, pool: dict, page_table: jax.Array,
                     write_page: jax.Array, write_off: jax.Array,
                     mask: jax.Array) -> Tuple[jax.Array, dict]:
    """Multi-token decode against the shared KV page pool — the
    speculative verify step.

    x (B, C, d) — each row's chunk of C tokens (last accepted token +
    drafted continuations, ascending positions); positions (B, C);
    write_page/write_off (B, C) per-token page slots receiving the new
    k/v (pad tokens target the reserved trash page — collisions there
    are harmless because trash slots never carry a valid position);
    mask (B, C, n_pages*page) additive per query position, carrying
    both slot validity and causal-within-chunk. The chunk's k/v scatter
    lands *before* attention, so chunk token i attends chunk tokens
    <= i through the pool exactly like C successive decode steps would
    — a C=1 call reproduces ``gqa_decode_paged``."""
    B, C, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.shard_cache_hd:
        raise NotImplementedError(
            "paged verify does not support the head_dim-sharded cache")
    q = linear(x, p["wq"], p.get("bq")).reshape(B, C, H, hd)
    k = linear(x, p["wk"], p.get("bk")).reshape(B, C, K, hd)
    v = linear(x, p["wv"], p.get("bv")).reshape(B, C, K, hd)
    q = _rope_q_or_k(cfg, q, positions)
    k = _rope_q_or_k(cfg, k, positions)
    k_pool = pool["k"].at[write_page, write_off].set(k)
    v_pool = pool["v"].at[write_page, write_off].set(v)
    if cfg.use_flash_decode:
        from repro.kernels.decode_attention import ops as decode_ops
        out = decode_ops.paged_verify_attention(q, k_pool, v_pool,
                                                page_table, mask)
    else:
        n, page = page_table.shape[1], k_pool.shape[1]
        kg = k_pool[page_table].reshape(B, n * page, K, hd)
        vg = v_pool[page_table].reshape(B, n * page, K, hd)
        scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
        out = _sdpa(q, kg, vg, mask, scale)
    out = linear(out.reshape(B, C, H * hd), p["wo"])
    return out, {"k": k_pool, "v": v_pool}


def gqa_empty_cache(cfg: ModelConfig, batch: int, width: int) -> dict:
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = cfg.adtype
    return {
        "k": jnp.zeros((batch, width, K, hd), dt),
        "v": jnp.zeros((batch, width, K, hd), dt),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 / MiniCPM3)
# ---------------------------------------------------------------------------


def _mla_qkv_full(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    from repro.models.common import rms_norm
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_n, qk_r, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q = linear(rms_norm(linear(x, p["wq_a"]), p["q_norm"], cfg.norm_eps), p["wq_b"])
    q = q.reshape(B, S, H, qk_n + qk_r)
    q_nope, q_rope = q[..., :qk_n], q[..., qk_n:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = linear(x, p["wkv_a"])
    ckv = rms_norm(kv_a[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora_rank:].reshape(B, S, 1, qk_r)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def mla_full(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
             mask: jax.Array) -> Tuple[jax.Array, dict]:
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_n, qk_r, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q_nope, q_rope, ckv, k_rope = _mla_qkv_full(p, cfg, x, positions)
    kv = linear(ckv, p["wkv_b"]).reshape(B, S, H, qk_n + dv)
    k_nope, v = kv[..., :qk_n], kv[..., qk_n:]
    scale = 1.0 / jnp.sqrt(float(qk_n + qk_r))

    def attend(qn, qr, mb):
        scores = (jnp.einsum("bshn,bthn->bhst", qn.astype(jnp.float32),
                             k_nope.astype(jnp.float32))
                  + jnp.einsum("bshr,btr->bhst", qr.astype(jnp.float32),
                               k_rope.astype(jnp.float32))) * scale
        scores = scores + mb.reshape(mb.shape[0], 1, *mb.shape[1:])
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhst,bthv->bshv", probs,
                          v.astype(jnp.float32)).astype(x.dtype)

    c = cfg.attn_chunk
    if c and S > c and S % c == 0:
        nc = S // c
        qn_c = q_nope.reshape(B, nc, c, H, qk_n).transpose(1, 0, 2, 3, 4)
        qr_c = q_rope.reshape(B, nc, c, H, qk_r).transpose(1, 0, 2, 3, 4)
        Bm = mask.shape[0]
        m_c = mask.reshape(Bm, nc, c, mask.shape[-1]).transpose(1, 0, 2, 3)

        def body(_, xs):
            return None, attend(*xs)

        _, out = jax.lax.scan(body, None, (qn_c, qr_c, m_c),
                              unroll=cfg.scan_unroll)
        out = out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dv)
    else:
        out = attend(q_nope, q_rope, mask)
    out = linear(out.reshape(B, S, H * dv), p["wo"])
    return out, {"ckv": ckv, "krope": k_rope}


def mla_decode(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
               cache: dict, slot: jax.Array, mask: jax.Array) -> Tuple[jax.Array, dict]:
    """Absorbed-matmul MLA decode: scores are computed in the latent space so
    the cache stays (r_kv + d_r) per token — the memory win of MLA."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_n, qk_r, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q_nope, q_rope, ckv_new, krope_new = _mla_qkv_full(p, cfg, x, positions)
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new, slot, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(cache["krope"], krope_new, slot, axis=1)

    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, H, qk_n + dv)
    w_uk = wkv_b[..., :qk_n]                       # (r, H, qk_n)
    w_uv = wkv_b[..., qk_n:]                       # (r, H, dv)
    # absorb k up-projection into the query
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))   # (B,1,H,r)
    scale = 1.0 / jnp.sqrt(float(qk_n + qk_r))
    scores = (jnp.einsum("bshr,bwr->bhsw", q_lat, ckv.astype(jnp.float32))
              + jnp.einsum("bshr,bwr->bhsw", q_rope.astype(jnp.float32),
                           krope.astype(jnp.float32))) * scale
    scores = scores + mask[:, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhsw,bwr->bshr", probs, ckv.astype(jnp.float32))
    out = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv.astype(jnp.float32)).astype(x.dtype)
    out = linear(out.reshape(B, S, H * dv), p["wo"])
    return out, {"ckv": ckv, "krope": krope}


def mla_empty_cache(cfg: ModelConfig, batch: int, width: int) -> dict:
    m = cfg.mla
    dt = cfg.adtype
    return {
        "ckv": jnp.zeros((batch, width, m.kv_lora_rank), dt),
        "krope": jnp.zeros((batch, width, m.qk_rope_head_dim), dt),
    }


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def attn_full(p, cfg: ModelConfig, x, positions, mask):
    if cfg.attn_type == "mla":
        return mla_full(p, cfg, x, positions, mask)
    return gqa_full(p, cfg, x, positions, mask)


def attn_decode(p, cfg: ModelConfig, x, positions, cache, slot, mask):
    if cfg.attn_type == "mla":
        if jnp.ndim(slot) != 0:
            raise NotImplementedError(
                "per-row decode slots (in-flight batching) are only "
                "implemented for the GQA cache layout")
        return mla_decode(p, cfg, x, positions, cache, slot, mask)
    return gqa_decode(p, cfg, x, positions, cache, slot, mask)


def attn_decode_paged(p, cfg: ModelConfig, x, positions, pool, page_table,
                      write_page, write_off, mask):
    if cfg.attn_type == "mla":
        raise NotImplementedError(
            "the paged KV pool is only implemented for the GQA cache "
            "layout (MLA's latent cache pages differently)")
    return gqa_decode_paged(p, cfg, x, positions, pool, page_table,
                            write_page, write_off, mask)


def attn_verify_paged(p, cfg: ModelConfig, x, positions, pool, page_table,
                      write_page, write_off, mask):
    if cfg.attn_type == "mla":
        raise NotImplementedError(
            "the paged KV pool is only implemented for the GQA cache "
            "layout (MLA's latent cache pages differently)")
    return gqa_verify_paged(p, cfg, x, positions, pool, page_table,
                            write_page, write_off, mask)


def empty_cache(cfg: ModelConfig, batch: int, width: int) -> dict:
    if cfg.attn_type == "mla":
        return mla_empty_cache(cfg, batch, width)
    return gqa_empty_cache(cfg, batch, width)
