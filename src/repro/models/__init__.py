from repro.models.config import (HybridConfig, MLAConfig, MoEConfig,
                                 ModelConfig, SSMConfig)
from repro.models.model import (decode_step, forward, init_cache, init_params,
                                loss_fn, make_train_step, prefill_step)

__all__ = [
    "ModelConfig", "MLAConfig", "MoEConfig", "SSMConfig", "HybridConfig",
    "init_params", "forward", "loss_fn", "make_train_step",
    "init_cache", "prefill_step", "decode_step",
]
