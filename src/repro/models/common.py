"""Shared NN primitives: norms, activations, RoPE (incl. M-RoPE), inits.

Pure-functional JAX. Parameters are pytrees (nested dicts of jnp arrays);
every function takes params explicitly. No flax/haiku dependency.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initialisation
# ---------------------------------------------------------------------------


def normal_init(rng: jax.Array, shape: Sequence[int], scale: float,
                dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(rng, shape, dtype=jnp.float32) * scale).astype(dtype)


def fan_in_init(rng: jax.Array, shape: Sequence[int], dtype=jnp.float32) -> jax.Array:
    """LeCun-style init for a (fan_in, fan_out) weight matrix."""
    scale = 1.0 / math.sqrt(max(1, shape[0]))
    return normal_init(rng, shape, scale, dtype)


def zeros_init(shape: Sequence[int], dtype=jnp.float32) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones_init(shape: Sequence[int], dtype=jnp.float32) -> jax.Array:
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def relu2(x: jax.Array) -> jax.Array:
    """Squared ReLU (Nemotron-4)."""
    r = jax.nn.relu(x)
    return r * r


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS = {"silu": silu, "relu2": relu2, "gelu": gelu}


def linear(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def _rope_angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions (...,) -> angles (..., dim//2) in float32."""
    half = dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    return positions.astype(jnp.float32)[..., None] * inv_freq


def _apply_angles(x: jax.Array, angles: jax.Array) -> jax.Array:
    """Rotate pairs (even, odd interleaved as two halves).

    x: (B, S, H, D); angles: (B, S, D//2) broadcast over heads.
    Uses the 'rotate_half' (contiguous halves) convention.
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = xf[..., :half], xf[..., half:]
    cos = jnp.cos(angles)[..., None, :]  # (B, S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Standard 1-D RoPE. x: (B, S, H, D), positions: (B, S)."""
    angles = _rope_angles(positions, x.shape[-1], theta)
    return _apply_angles(x, angles)


def apply_mrope(x: jax.Array, positions: jax.Array,
                sections: Sequence[int], theta: float = 10000.0) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL). positions: (3, B, S) = (t, h, w) streams.

    ``sections`` partitions the half-dim; section i uses position stream i.
    sum(sections) must equal D // 2.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    parts = []
    for i, sec in enumerate(sections):
        lo = sum(sections[:i])
        inv_freq = 1.0 / (theta ** (jnp.arange(lo, lo + sec, dtype=jnp.float32) / half))
        parts.append(positions[i].astype(jnp.float32)[..., None] * inv_freq)
    angles = jnp.concatenate(parts, axis=-1)  # (B, S, half)
    return _apply_angles(x, angles)


def sinusoid_positions(seq_len: int, dim: int, dtype=jnp.float32) -> jax.Array:
    """Additive sinusoidal position table (encoder-only models)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# masking helpers
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def causal_mask(seq_len: int, window: Optional[int] = None) -> jax.Array:
    """(S, S) additive mask. window=None -> full causal; else sliding window."""
    i = jnp.arange(seq_len)[:, None]
    j = jnp.arange(seq_len)[None, :]
    ok = j <= i
    if window is not None:
        ok = ok & (j > i - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def cache_mask(cache_positions: jax.Array, pos: jax.Array,
               window: Optional[int] = None) -> jax.Array:
    """Additive mask over cache slots for single-token decode.

    cache_positions: (B, W) absolute position stored in each slot (-1 = empty).
    pos: int32 position of the token being decoded — scalar, or (B, 1)
    for per-row positions (in-flight batching); both broadcast against
    the (B, W) slot positions.
    """
    ok = (cache_positions >= 0) & (cache_positions <= pos)
    if window is not None:
        ok = ok & (cache_positions > pos - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def wsc(x, *spec_axes):
    """with_sharding_constraint if a mesh context is active; no-op
    otherwise. "BATCH" resolves to the mesh's batch axes."""
    try:
        import jax
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m.empty or "model" not in m.axis_names:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        batch = tuple(a for a in ("pod", "data") if a in m.axis_names)
        axes = tuple(batch if a == "BATCH" else a for a in spec_axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(m, P(*axes)))
    except Exception:  # noqa: BLE001
        return x
