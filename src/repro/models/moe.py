"""Mixture-of-Experts FFN: top-k router + capacity-based dispatch.

GShard/Switch-style one-hot dispatch (einsum) is the *paper-faithful
baseline* formulation — it is fully shardable under GSPMD (experts or
expert-internal d_ff on the "model" axis; tokens on ("pod","data")).
The §Perf hillclimb iterates on its dispatch-FLOPs overhead.

Supports DeepSeek-V3 topology: ``num_shared_experts`` always-on experts,
``first_k_dense`` leading dense layers, normalized top-k gates, and a
load-balance auxiliary loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import fan_in_init, linear, silu
from repro.models.config import ModelConfig


def init_dense_mlp(rng: jax.Array, cfg: ModelConfig, d_ff: int) -> dict:
    d = cfg.d_model
    ks = jax.random.split(rng, 3)
    dt = cfg.pdtype
    if cfg.gated_mlp:
        return {
            "w_gate": fan_in_init(ks[0], (d, d_ff), dt),
            "w_up": fan_in_init(ks[1], (d, d_ff), dt),
            "w_down": fan_in_init(ks[2], (d_ff, d), dt),
        }
    return {
        "w_up": fan_in_init(ks[0], (d, d_ff), dt),
        "w_down": fan_in_init(ks[1], (d_ff, d), dt),
    }


def dense_mlp(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    from repro.models.common import ACTIVATIONS
    act = ACTIVATIONS[cfg.mlp_act]
    if cfg.gated_mlp:
        return linear(act(linear(x, p["w_gate"])) * linear(x, p["w_up"]), p["w_down"])
    return linear(act(linear(x, p["w_up"])), p["w_down"])


# ---------------------------------------------------------------------------
# MoE layer
# ---------------------------------------------------------------------------


def init_moe(rng: jax.Array, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    E, f = m.num_experts, m.d_ff_expert
    ks = jax.random.split(rng, 5)
    dt = cfg.pdtype
    p = {
        "router": fan_in_init(ks[0], (d, E), jnp.float32),
        "w_gate": fan_in_init(ks[1], (E, d, f), dt),
        "w_up": fan_in_init(ks[2], (E, d, f), dt),
        "w_down": fan_in_init(ks[3], (E, f, d), dt),
    }
    if m.num_shared_experts:
        p["shared"] = init_dense_mlp(
            ks[4], cfg, m.d_ff_shared * m.num_shared_experts)
    return p


def capacity(cfg: ModelConfig, num_tokens: int) -> int:
    m = cfg.moe
    c = int(math.ceil(num_tokens * m.top_k / m.num_experts * m.capacity_factor))
    return max(4, min(num_tokens, c))


def _router(p: dict, cfg: ModelConfig, xt: jax.Array):
    """Shared routing: returns (gate_vals (T,k), gate_idx (T,k), aux)."""
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    logits = (xt.astype(jnp.float32) @ p["router"])               # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                 # (T, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)
    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                  # (E,)
    onehot_any = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)   # (T,k,E)
    ce = jnp.mean(jnp.sum(onehot_any, axis=1), axis=0)            # (E,)
    aux = m.router_aux_weight * E * jnp.sum(me * ce)
    return gate_vals, gate_idx, onehot_any, aux


def _expert_ffn(p: dict, cfg: ModelConfig, xe: jax.Array) -> jax.Array:
    """xe: (E, C, d) -> (E, C, d)."""
    if cfg.gated_mlp:
        h = silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(xe.dtype))) \
            * jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(xe.dtype))
    else:
        h = silu(jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(xe.dtype)))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(xe.dtype))


def _moe_einsum(p: dict, cfg: ModelConfig, xt: jax.Array, gate_vals, gate_idx,
                onehot_any, C: int) -> jax.Array:
    """GShard-faithful one-hot dispatch. Materialises a (T, E, C) dispatch
    tensor — the §Perf baseline whose memory/FLOPs blow-up motivates the
    scatter path below."""
    m = cfg.moe
    T, d = xt.shape
    E, k = m.num_experts, m.top_k
    # capacity assignment: position of each (token, slot) within its expert
    sel = onehot_any.reshape(T * k, E)                            # token-major
    pos_in_e = (jnp.cumsum(sel, axis=0) - sel)                    # (T*k, E)
    pos = jnp.sum(pos_in_e * sel, axis=-1).reshape(T, k)          # (T, k)
    keep = (pos < C).astype(jnp.float32)
    gate_vals = gate_vals * keep
    pos_oh = jax.nn.one_hot(jnp.where(keep > 0, pos, C).astype(jnp.int32),
                            C + 1, dtype=jnp.float32)[..., :C]    # (T,k,C)
    dispatch = jnp.einsum("tke,tkc->tec", onehot_any, pos_oh)     # (T,E,C)
    combine = jnp.einsum("tke,tkc,tk->tec", onehot_any, pos_oh, gate_vals)
    xe = jnp.einsum("tec,td->ecd", dispatch.astype(xt.dtype), xt)  # (E,C,d)
    ye = _expert_ffn(p, cfg, xe)
    return jnp.einsum("tec,ecd->td", combine.astype(xt.dtype), ye)


from repro.models.common import wsc as _wsc


def _positions_in_expert(flat_e: jax.Array) -> jax.Array:
    """Rank of each slot within its expert, via sort — no (T·k, E) temp."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e)                                   # stable
    sorted_e = flat_e[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), jnp.bool_),
                                sorted_e[1:] != sorted_e[:-1]])
    start = jax.lax.associative_scan(jnp.maximum,
                                     jnp.where(is_start, idx, 0))
    pos_sorted = idx - start
    pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    return pos


def _buf_spec(cfg: ModelConfig, E: int, C: int, model_size_hint: int = 16):
    """Shard the expert buffer on E when divisible (expert parallel,
    deepseek 256e) else on the capacity dim (granite 40e)."""
    if E % model_size_hint == 0:
        return ("model", None, None)
    return (None, "model", None)


def _moe_scatter(p: dict, cfg: ModelConfig, xt: jax.Array, gate_vals, gate_idx,
                 C: int) -> jax.Array:
    """Sort-based dispatch (beyond-baseline, §Perf): scatter tokens straight
    into (E, C, d) expert buffers. Dropped slots keep their dest but their
    payload is zeroed (capacity semantics identical to the einsum path).
    The buffer carries an explicit sharding constraint so GSPMD exchanges
    token payloads instead of all-reducing a replicated buffer."""
    m = cfg.moe
    T, d = xt.shape
    E, k = m.num_experts, m.top_k
    flat_e = gate_idx.reshape(T * k).astype(jnp.int32)
    pos = _positions_in_expert(flat_e)                            # (T*k,)
    keep = pos < C
    dest = jnp.where(keep, flat_e * C + pos, E * C - 1)
    src = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(xt.dtype)
    # .add: valid destinations are unique (pos is a rank within the expert);
    # dropped slots all alias E*C-1 but contribute zeros
    buf = jnp.zeros((E * C, d), xt.dtype).at[dest].add(src)
    buf = _wsc(buf.reshape(E, C, d), *_buf_spec(cfg, E, C))
    ye = _wsc(_expert_ffn(p, cfg, buf), *_buf_spec(cfg, E, C))
    ye = ye.reshape(E * C, d)
    gathered = ye[dest] * (gate_vals.reshape(T * k, 1).astype(ye.dtype)
                           * keep[:, None].astype(ye.dtype))
    return jnp.sum(gathered.reshape(T, k, d), axis=1)


def _moe_grouped(p: dict, cfg: ModelConfig, x: jax.Array, gate_vals, gate_idx
                 ) -> jax.Array:
    """GShard-style group-local dispatch (§Perf): groups are batch rows,
    already sharded over the data axes, and capacity is per-group — so the
    scatter/gather never crosses a shard boundary and dispatch is
    collective-free. Expert weights are replicated w.r.t. data (sharded on
    d_ff/E over "model"), so the expert matmul reduces over "model" only."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    C = capacity(cfg, S)

    def local(xg, gv, gi):                       # (S,d), (S,k), (S,k)
        flat_e = gi.reshape(S * k).astype(jnp.int32)
        pos = _positions_in_expert(flat_e)
        keep = pos < C
        dest = jnp.where(keep, flat_e * C + pos, E * C - 1)
        src = jnp.repeat(xg, k, axis=0) * keep[:, None].astype(xg.dtype)
        buf = jnp.zeros((E * C, d), xg.dtype).at[dest].add(src)
        return buf.reshape(E, C, d), dest, keep

    buf, dest, keep = jax.vmap(local)(x, gate_vals.reshape(B, S, k),
                                      gate_idx.reshape(B, S, k))
    buf = _wsc(buf, "BATCH", None, None, None)   # (B, E, C, d)
    if cfg.gated_mlp:
        h = silu(jnp.einsum("becd,edf->becf", buf,
                            p["w_gate"].astype(buf.dtype))) \
            * jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(buf.dtype))
    else:
        h = silu(jnp.einsum("becd,edf->becf", buf,
                            p["w_up"].astype(buf.dtype)))
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(buf.dtype))
    ye = _wsc(ye, "BATCH", None, None, None).reshape(B, E * C, d)

    def combine(yg, dg, kg, gv):                 # (E*C,d), (S*k,), ...
        g = yg[dg] * (gv.reshape(S * k, 1).astype(yg.dtype)
                      * kg[:, None].astype(yg.dtype))
        return jnp.sum(g.reshape(S, k, d), axis=1)

    out = jax.vmap(combine)(ye, dest, keep, gate_vals.reshape(B, S, k))
    return out.reshape(B * S, d)


def moe_mlp(p: dict, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss). Capacity-dropped tokens fall back to
    the shared expert (if any) / residual."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    C = capacity(cfg, T)
    xt = x.reshape(T, d)
    gate_vals, gate_idx, onehot_any, aux = _router(p, cfg, xt)
    if m.dispatch == "grouped" and B > 1:
        out = _moe_grouped(p, cfg, x, gate_vals, gate_idx)
    elif m.dispatch == "scatter" or (m.dispatch == "grouped" and B == 1):
        out = _moe_scatter(p, cfg, xt, gate_vals, gate_idx, C)
    else:
        out = _moe_einsum(p, cfg, xt, gate_vals, gate_idx, onehot_any, C)
    if m.num_shared_experts:
        out = out + dense_mlp(p["shared"], cfg, xt)
    return out.reshape(B, S, d), aux
