"""Mamba-1 (selective scan) and Mamba-2 (SSD) blocks.

Both expose:
  * ``*_full``  — full-sequence path via ``jax.lax.associative_scan``
                  (or the Pallas chunked-scan kernel when enabled);
  * ``*_step``  — O(1) single-token recurrence for decode, carrying
                  {"conv": (B, K-1, d_conv_ch), "h": state}.

This is the attention-free substrate for falcon-mamba-7b and the hybrid
zamba2-7b. Decode state is constant in sequence length, which is why these
archs run the long_500k shape natively (DESIGN.md §3).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import fan_in_init, linear, normal_init, silu
from repro.models.config import ModelConfig


def _dt_rank(cfg: ModelConfig) -> int:
    s = cfg.ssm
    return s.dt_rank if s.dt_rank else max(1, math.ceil(cfg.d_model / 16))


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------


def init_mamba1(rng: jax.Array, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d, di, N, K = cfg.d_model, d_inner(cfg), s.state_size, s.conv_kernel
    R = _dt_rank(cfg)
    ks = jax.random.split(rng, 6)
    dt = cfg.pdtype
    # S4D-real initialisation of A; dt bias so softplus(dt) spans [1e-3, 1e-1]
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_init = jnp.exp(jax.random.uniform(ks[5], (di,), jnp.float32)
                      * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "in_proj": fan_in_init(ks[0], (d, 2 * di), dt),
        "conv_w": normal_init(ks[1], (K, di), 0.1, dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": fan_in_init(ks[2], (di, R + 2 * N), dt),
        "dt_proj": fan_in_init(ks[3], (R, di), dt),
        "dt_bias": dt_bias.astype(dt),
        "A_log": jnp.log(A).astype(dt),
        "D": jnp.ones((di,), dt),
        "out_proj": fan_in_init(ks[4], (di, d), dt),
    }


def _causal_conv_full(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B,S,C), w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


def _scan_combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a2 * a1, a2 * b1 + b2


def _selective_scan(decay: jax.Array, drive: jax.Array) -> jax.Array:
    """h_t = decay_t * h_{t-1} + drive_t, scan over axis 1 (seq)."""
    _, h = jax.lax.associative_scan(_scan_combine, (decay, drive), axis=1)
    return h


def _mamba1_core(p: dict, cfg: ModelConfig, u: jax.Array):
    """Shared Δ/B/C computation. u: (B,S,di) post-conv activations."""
    s = cfg.ssm
    N, R = s.state_size, _dt_rank(cfg)
    dbc = linear(u, p["x_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(linear(dbc[..., :R], p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # (B,S,di)
    Bm = dbc[..., R:R + N]                                        # (B,S,N)
    Cm = dbc[..., R + N:]                                         # (B,S,N)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (di,N)
    decay = jnp.exp(dt[..., None] * A[None, None])                # (B,S,di,N)
    drive = (dt * u.astype(jnp.float32))[..., None] * Bm[:, :, None, :]
    return decay, drive, Cm


def mamba1_full(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    B, S, d = x.shape
    di = d_inner(cfg)
    xz = linear(x, p["in_proj"])
    u, z = xz[..., :di], xz[..., di:]
    u = silu(_causal_conv_full(u, p["conv_w"], p["conv_b"]))
    if cfg.use_ssm_kernel:
        from repro.kernels.ssm_scan import ops as scan_ops
        decay, drive, Cm = _mamba1_core(p, cfg, u)
        h = scan_ops.chunked_scan(decay, drive)
        y = jnp.einsum("bscn,bsn->bsc", h, Cm)
    else:
        decay, drive, Cm = _mamba1_core(p, cfg, u)
        h = _selective_scan(decay, drive)                         # (B,S,di,N)
        y = jnp.einsum("bscn,bsn->bsc", h, Cm)
    y = y + p["D"].astype(jnp.float32) * u.astype(jnp.float32)
    y = (y * silu(z.astype(jnp.float32))).astype(x.dtype)
    return linear(y, p["out_proj"])


def mamba1_empty_state(cfg: ModelConfig, batch: int) -> dict:
    s = cfg.ssm
    di = d_inner(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, di), cfg.adtype),
        "h": jnp.zeros((batch, di, s.state_size), jnp.float32),
    }


def mamba1_step(p: dict, cfg: ModelConfig, x: jax.Array,
                state: dict) -> Tuple[jax.Array, dict]:
    """x: (B, 1, d). Returns (out (B,1,d), new_state)."""
    B = x.shape[0]
    di = d_inner(cfg)
    xz = linear(x[:, 0], p["in_proj"])
    u, z = xz[..., :di], xz[..., di:]
    window = jnp.concatenate([state["conv"], u[:, None, :]], axis=1)  # (B,K,di)
    u = silu(jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(window.dtype))
             + p["conv_b"].astype(window.dtype))
    decay, drive, Cm = _mamba1_core(p, cfg, u[:, None, :])
    h = decay[:, 0] * state["h"] + drive[:, 0]                    # (B,di,N)
    y = jnp.einsum("bcn,bn->bc", h, Cm[:, 0])
    y = y + p["D"].astype(jnp.float32) * u.astype(jnp.float32)
    y = (y * silu(z.astype(jnp.float32))).astype(x.dtype)
    out = linear(y, p["out_proj"])[:, None, :]
    return out, {"conv": window[:, 1:], "h": h}


# ---------------------------------------------------------------------------
# Mamba-2 (SSD, scalar decay per head)
# ---------------------------------------------------------------------------


def _m2_dims(cfg: ModelConfig):
    s = cfg.ssm
    di = d_inner(cfg)
    nh = di // s.head_dim
    return di, nh, s.head_dim, s.state_size


def init_mamba2(rng: jax.Array, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    di, nh, P, N = _m2_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(rng, 4)
    dt = cfg.pdtype
    dt_init = jnp.exp(jax.random.uniform(ks[3], (nh,), jnp.float32)
                      * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))
    # in_proj emits [z(di), x(di), B(N), C(N), dt(nh)]
    return {
        "in_proj": fan_in_init(ks[0], (d, 2 * di + 2 * N + nh), dt),
        "conv_w": normal_init(ks[1], (s.conv_kernel, di + 2 * N), 0.1, dt),
        "conv_b": jnp.zeros((di + 2 * N,), dt),
        "A_log": jnp.zeros((nh,), dt),        # A = -exp(0) = -1 init
        "dt_bias": dt_bias.astype(dt),
        "D": jnp.ones((nh,), dt),
        "norm_w": jnp.ones((di,), dt),
        "out_proj": fan_in_init(ks[2], (di, d), dt),
    }


def _m2_split(p, cfg, raw):
    di, nh, P, N = _m2_dims(cfg)
    z = raw[..., :di]
    xBC = raw[..., di:2 * di + 2 * N]
    dt = raw[..., 2 * di + 2 * N:]
    return z, xBC, dt


def _m2_gated_out(p, cfg, y, z, x_dtype):
    from repro.models.common import rms_norm
    y = (y * silu(z.astype(jnp.float32)))
    y = rms_norm(y.astype(x_dtype), p["norm_w"], cfg.norm_eps)
    return linear(y, p["out_proj"])


def mamba2_full(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    B, S, d = x.shape
    di, nh, P, N = _m2_dims(cfg)
    raw = linear(x, p["in_proj"])
    z, xBC, dt = _m2_split(p, cfg, raw)
    xBC = silu(_causal_conv_full(xBC, p["conv_w"], p["conv_b"]))
    u = xBC[..., :di].reshape(B, S, nh, P)
    Bm = xBC[..., di:di + N]
    Cm = xBC[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (nh,)
    decay = jnp.exp(dt * A[None, None, :])                        # (B,S,nh)
    drive = (dt[..., None] * u.astype(jnp.float32))[..., None] \
        * Bm[:, :, None, None, :].astype(jnp.float32)             # (B,S,nh,P,N)
    if cfg.use_ssm_kernel:
        from repro.kernels.ssm_scan import ops as scan_ops
        h = scan_ops.chunked_scan(
            jnp.broadcast_to(decay[..., None, None], drive.shape).reshape(
                B, S, nh * P, N),
            drive.reshape(B, S, nh * P, N)).reshape(B, S, nh, P, N)
    else:
        h = _selective_scan(jnp.broadcast_to(decay[..., None, None], drive.shape),
                            drive)                                # (B,S,nh,P,N)
    y = jnp.einsum("bshpn,bsn->bshp", h, Cm.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * u.astype(jnp.float32)
    return _m2_gated_out(p, cfg, y.reshape(B, S, di), z, x.dtype)


def mamba2_empty_state(cfg: ModelConfig, batch: int) -> dict:
    s = cfg.ssm
    di, nh, P, N = _m2_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, di + 2 * N), cfg.adtype),
        "h": jnp.zeros((batch, nh, P, N), jnp.float32),
    }


def mamba2_step(p: dict, cfg: ModelConfig, x: jax.Array,
                state: dict) -> Tuple[jax.Array, dict]:
    B = x.shape[0]
    di, nh, P, N = _m2_dims(cfg)
    raw = linear(x[:, 0], p["in_proj"])
    z, xBC, dt = _m2_split(p, cfg, raw)
    window = jnp.concatenate([state["conv"], xBC[:, None, :]], axis=1)
    xBC = silu(jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(window.dtype))
               + p["conv_b"].astype(window.dtype))
    u = xBC[..., :di].reshape(B, nh, P)
    Bm = xBC[..., di:di + N].astype(jnp.float32)
    Cm = xBC[..., di + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A[None, :])                              # (B,nh)
    h = decay[..., None, None] * state["h"] \
        + (dt[..., None] * u.astype(jnp.float32))[..., None] * Bm[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, Cm)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * u.astype(jnp.float32)
    out = _m2_gated_out(p, cfg, y.reshape(B, di), z, x.dtype)[:, None, :]
    return out, {"conv": window[:, 1:], "h": h}
