"""Benchmark: Table 3 — the System Configuration LUT.

Profiles each bottleneck tier on the trained proxy models (Average IoU for
the original and flood-finetuned variants) and the deployment payload
sizes, side-by-side with the paper's published LUT."""
from __future__ import annotations

from benchmarks.common import Timer, emit, ensure_lut
from repro.core.lut import paper_lut


def run(log=print):
    rows = []
    with Timer() as t:
        lut = ensure_lut(log)
    paper = paper_lut()
    for ours, ref in zip(lut.tiers, paper.tiers):
        rows.append(emit(
            f"table3/{ours.name.replace(' ', '_')}", t.us,
            f"ratio={ours.ratio};acc_base={ours.acc_base:.4f};"
            f"acc_ft={ours.acc_finetuned:.4f};payload_mb={ours.payload_mb:.3f};"
            f"paper_acc_base={ref.acc_base:.4f};"
            f"paper_payload_mb={ref.payload_mb:.2f}"))
    # monotonicity check mirrors the paper's ordering
    accs = [t_.acc_base for t_ in lut.tiers]
    rows.append(emit("table3/monotone", t.us,
                     f"acc_order_ok={accs == sorted(accs, reverse=True)}"))
    return rows


if __name__ == "__main__":
    run()
