"""Shared benchmark infrastructure: trained-system cache, engine
construction, warmup/timing, and CSV helpers.

The offline phase (lisa-mini original + flood-finetune + three bottleneck
tiers) is trained once and cached under benchmarks/artifacts/checkpoints;
subsequent benchmark runs load it from disk. Serving benchmarks build
their ``AveryEngine`` through ``make_engine`` (loopback transport, shared
weights/LUT) instead of hand-wiring executors.
"""
from __future__ import annotations

import os
import time
from typing import Dict, Tuple

ART = os.path.join(os.path.dirname(__file__), "artifacts")
CKPT = os.path.join(ART, "checkpoints")
DRYRUN_DIR = os.path.join(ART, "dryrun")

RATIOS = (0.25, 0.10, 0.05)

# offline-phase training budget (tuned for the single-CPU container:
# ~0.25 s/step at batch 16 -> the full offline phase takes ~8 minutes)
TRAIN_STEPS = 800
FT_STEPS = 250
BN_STEPS = 250
BATCH = 16


def ensure_trained_system(log=print) -> Tuple[dict, dict, Dict[float, dict]]:
    """Train (or load) the full offline phase."""
    from repro.checkpoint import load_pytree, save_pytree
    from repro.configs.lisa_mini import CONFIG as pcfg
    from repro.core import profile as prof

    paths = {
        "orig": os.path.join(CKPT, "lisa_mini_original"),
        "ft": os.path.join(CKPT, "lisa_mini_finetuned"),
        **{f"bn{r}": os.path.join(CKPT, f"bottleneck_r{r}") for r in RATIOS},
    }
    if all(os.path.exists(os.path.join(p, "arrays.npz"))
           for p in paths.values()):
        log("[bench] loading cached offline-phase checkpoints")
        params = load_pytree(paths["orig"])
        params_ft = load_pytree(paths["ft"])
        bns = {r: load_pytree(paths[f"bn{r}"]) for r in RATIOS}
        return params, params_ft, bns

    log("[bench] training offline phase (cached for later runs)")
    params, params_ft, bns = prof.train_full_system(
        pcfg, ratios=RATIOS, steps=TRAIN_STEPS, bn_steps=BN_STEPS,
        ft_steps=FT_STEPS, batch_size=BATCH, log=log)
    os.makedirs(CKPT, exist_ok=True)
    save_pytree(paths["orig"], params)
    save_pytree(paths["ft"], params_ft)
    for r in RATIOS:
        save_pytree(paths[f"bn{r}"], bns[r])
    return params, params_ft, bns


def ensure_lut(log=print):
    """Build (or load) the measured System LUT."""
    from repro.configs.lisa_mini import CONFIG as pcfg
    from repro.core import profile as prof
    from repro.core.lut import SystemLUT
    path = os.path.join(CKPT, "lut.json")
    if os.path.exists(path):
        return SystemLUT.load(path)
    params, params_ft, bns = ensure_trained_system(log)
    lut = prof.build_lut(pcfg, params, params_ft, bns)
    os.makedirs(CKPT, exist_ok=True)
    lut.save(path)
    return lut


def init_serving_system(pcfg=None):
    """Weights + per-tier bottlenecks + paper LUT for serving benchmarks:
    cached trained checkpoints when present, random init otherwise
    (serving throughput depends on the geometry, not the weight values)."""
    from repro.core import profile as prof

    if pcfg is None:
        from repro.configs.lisa_mini import CONFIG as pcfg
    params = None
    path = os.path.join(CKPT, "lisa_mini_original", "arrays.npz")
    if os.path.exists(path):
        from repro.checkpoint import load_pytree
        params = load_pytree(os.path.dirname(path))
    return prof.random_init_system(pcfg, params=params)


def make_executor(pcfg=None, params=None, bns=None, lut=None, **kw):
    """A ``DualStreamExecutor`` over the shared serving system."""
    from repro.core import DualStreamExecutor

    if pcfg is None:
        from repro.configs.lisa_mini import CONFIG as pcfg
    if params is None:
        params, bns, lut = init_serving_system(pcfg)
    return DualStreamExecutor(pcfg=pcfg, params=params, bottlenecks=bns,
                              lut=lut, **kw)


def make_engine(executor, **engine_kw):
    """The benchmark front door: an ``AveryEngine`` on an in-process
    loopback link (no simulated channel in the measurement)."""
    from repro.engine import AveryEngine, LoopbackTransport

    engine_kw.setdefault("transport", LoopbackTransport())
    return AveryEngine(lut=executor.lut, executor=executor, **engine_kw)


def time_best(fn, reps: int = 2) -> float:
    """Warm up once (absorbing XLA compiles), then best-of-``reps``."""
    fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.us = (time.time() - self.t0) * 1e6


def emit(name: str, us: float, derived: str) -> str:
    row = f"{name},{us:.0f},{derived}"
    print(row, flush=True)
    return row


def write_bench_json(rows, filename: str = "BENCH_serving.json") -> str:
    """Persist benchmark rows as a machine-readable artifact so the perf
    trajectory is tracked across PRs instead of living only in logs.

    ``rows`` are the strings ``emit`` returns (``name,us,k=v;k=v;...``);
    they merge by row name into ``benchmarks/artifacts/<filename>``, so
    partial runs (``--paged-smoke``, ``--spec``, ``--sharded``) update
    their rows without clobbering the rest. The merged artifact is also
    mirrored to the repo root, where the cross-PR perf trajectory is
    tracked (a committed file, not just a benchmark byproduct).
    Returns the artifact path."""
    import json
    import shutil

    path = os.path.join(ART, filename)
    mirror = os.path.abspath(os.path.join(ART, os.pardir, os.pardir,
                                          filename))
    records = {}
    # merge base: the local artifact, else the committed root mirror —
    # a fresh checkout inherits the tracked trajectory instead of
    # clobbering it down to whichever partial mode ran first (the perf
    # gate treats a vanished row as a regression, by design)
    for prev_path in (path, mirror):
        if not os.path.exists(prev_path):
            continue
        try:
            with open(prev_path) as f:
                prev = json.load(f)
            if isinstance(prev, dict) and isinstance(prev.get("records"),
                                                     dict):
                records = prev["records"]
                break
        except (json.JSONDecodeError, OSError):
            pass                       # corrupt artifact: regenerate
    for row in rows:
        name, us, derived = row.split(",", 2)
        rec = {"us": float(us)}
        for kv in derived.split(";"):
            if "=" not in kv:
                continue
            k, v = kv.split("=", 1)
            try:
                rec[k] = float(v.rstrip("x"))
            except ValueError:
                rec[k] = v
        records[name] = rec
    os.makedirs(ART, exist_ok=True)
    with open(path, "w") as f:
        json.dump({"benchmark": os.path.splitext(filename)[0],
                   "records": records}, f, indent=2, sort_keys=True)
        f.write("\n")
    shutil.copyfile(path, mirror)
    return path
