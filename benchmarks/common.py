"""Shared benchmark infrastructure: trained-system cache + CSV helpers.

The offline phase (lisa-mini original + flood-finetune + three bottleneck
tiers) is trained once and cached under benchmarks/artifacts/checkpoints;
subsequent benchmark runs load it from disk.
"""
from __future__ import annotations

import os
import time
from typing import Dict, Tuple

ART = os.path.join(os.path.dirname(__file__), "artifacts")
CKPT = os.path.join(ART, "checkpoints")
DRYRUN_DIR = os.path.join(ART, "dryrun")

RATIOS = (0.25, 0.10, 0.05)

# offline-phase training budget (tuned for the single-CPU container:
# ~0.25 s/step at batch 16 -> the full offline phase takes ~8 minutes)
TRAIN_STEPS = 800
FT_STEPS = 250
BN_STEPS = 250
BATCH = 16


def ensure_trained_system(log=print) -> Tuple[dict, dict, Dict[float, dict]]:
    """Train (or load) the full offline phase."""
    from repro.checkpoint import load_pytree, save_pytree
    from repro.configs.lisa_mini import CONFIG as pcfg
    from repro.core import profile as prof

    paths = {
        "orig": os.path.join(CKPT, "lisa_mini_original"),
        "ft": os.path.join(CKPT, "lisa_mini_finetuned"),
        **{f"bn{r}": os.path.join(CKPT, f"bottleneck_r{r}") for r in RATIOS},
    }
    if all(os.path.exists(os.path.join(p, "arrays.npz"))
           for p in paths.values()):
        log("[bench] loading cached offline-phase checkpoints")
        params = load_pytree(paths["orig"])
        params_ft = load_pytree(paths["ft"])
        bns = {r: load_pytree(paths[f"bn{r}"]) for r in RATIOS}
        return params, params_ft, bns

    log("[bench] training offline phase (cached for later runs)")
    params, params_ft, bns = prof.train_full_system(
        pcfg, ratios=RATIOS, steps=TRAIN_STEPS, bn_steps=BN_STEPS,
        ft_steps=FT_STEPS, batch_size=BATCH, log=log)
    os.makedirs(CKPT, exist_ok=True)
    save_pytree(paths["orig"], params)
    save_pytree(paths["ft"], params_ft)
    for r in RATIOS:
        save_pytree(paths[f"bn{r}"], bns[r])
    return params, params_ft, bns


def ensure_lut(log=print):
    """Build (or load) the measured System LUT."""
    from repro.configs.lisa_mini import CONFIG as pcfg
    from repro.core import profile as prof
    from repro.core.lut import SystemLUT
    path = os.path.join(CKPT, "lut.json")
    if os.path.exists(path):
        return SystemLUT.load(path)
    params, params_ft, bns = ensure_trained_system(log)
    lut = prof.build_lut(pcfg, params, params_ft, bns)
    os.makedirs(CKPT, exist_ok=True)
    lut.save(path)
    return lut


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.us = (time.time() - self.t0) * 1e6


def emit(name: str, us: float, derived: str) -> str:
    row = f"{name},{us:.0f},{derived}"
    print(row, flush=True)
    return row
