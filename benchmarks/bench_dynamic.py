"""Benchmark: Fig. 9 — 20-minute dynamic adaptation run.

AVERY (Prioritize-Accuracy) vs the three static tiers on the scripted
8–20 Mbps trace: tier switching, throughput stability, accuracy gap."""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import ART, Timer, emit, ensure_lut
from repro.engine import AdaptivePolicy, StaticTierPolicy
from repro.network import paper_trace
from repro.runtime import MissionSpec, run_mission


def run(log=print):
    lut = ensure_lut(log)
    trace = paper_trace(seed=0)
    rows = []
    logs = {}
    # adaptive-vs-static is a ControlPolicy swap on the engine session
    with Timer() as t:
        logs["AVERY"] = run_mission(lut, trace,
                                    MissionSpec(policy=AdaptivePolicy()))
        for tier in ("High Accuracy", "Balanced", "High Throughput"):
            logs[tier] = run_mission(
                lut, trace, MissionSpec(policy=StaticTierPolicy(tier)))
    ha_iou = logs["High Accuracy"].mean_iou
    for name, lg in logs.items():
        switches = sum(1 for a, b in zip(lg.frames, lg.frames[1:])
                       if a.tier != b.tier)
        rows.append(emit(
            f"fig9/{name.replace(' ', '_')}", t.us,
            f"mean_pps={lg.mean_pps:.3f};avg_iou={lg.mean_iou:.4f};"
            f"iou_gap_to_HA_pp={100 * (ha_iou - lg.mean_iou):.2f};"
            f"tier_switches={switches};"
            f"edge_energy_j={lg.total_edge_energy_j:.0f}"))
    # per-minute timelines -> artifact for Fig 9(a-d)
    art = {
        "bandwidth_mbps": trace.samples.tolist(),
        "pps": {k: v.pps_timeline(60.0).tolist() for k, v in logs.items()},
        "tiers": {k: v.tier_timeline(60.0) for k, v in logs.items()},
    }
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "fig9_timelines.json"), "w") as f:
        json.dump(art, f)
    gap = 100 * (ha_iou - logs["AVERY"].mean_iou)
    rows.append(emit("fig9/claims", t.us,
                     f"avery_iou_gap_pp={gap:.3f};paper_gap=0.75;"
                     f"avery_pps={logs['AVERY'].mean_pps:.3f};paper_pps=0.74"))
    return rows


if __name__ == "__main__":
    run()
