"""Benchmark: Fig. 7 — accuracy across SAM split depths (fixed r=0.10).

Trains one bottleneck per split point of lisa-mini's SAM backbone (the
proxy of the paper's ViT-1..ViT-31 sweep) and reports Average IoU per
depth, plus the unsplit upper bound. The paper's observation to reproduce:
early splits match or beat deeper splits, so split@1 wins once the edge
cost (Fig. 8, bench_energy) is accounted."""
from __future__ import annotations

from benchmarks.common import Timer, emit, ensure_trained_system
from repro.configs.lisa_mini import CONFIG as PCFG
from repro.core import training


def run(log=print):
    params, _, _ = ensure_trained_system(log)
    rows = []
    base = training.evaluate_insight(PCFG, params, batches=4)
    rows.append(emit("fig7/no_bottleneck", 0,
                     f"avg_iou={base['avg_iou']:.4f}"))
    for k in range(1, PCFG.sam.num_layers):
        with Timer() as t:
            bp = training.train_bottleneck(
                PCFG, params, ratio=0.10, steps=100, batch_size=12,
                log_every=0, log=lambda s: None, seed=100 + k)
            # evaluate with the bottleneck at split@k
            import jax
            import numpy as np
            import jax.numpy as jnp
            from repro.core import vlm
            from repro.data import floodseg
            rng = np.random.RandomState(999)
            fwd = jax.jit(lambda p, bp_, img, q: vlm.insight_forward(
                p, PCFG, img, q, bn_params=bp_, split_k=k))
            inters = unions = 0.0
            gious = []
            for _ in range(4):
                b = floodseg.make_batch(rng, 32, "segment", augment=False)
                ml, _ = fwd(params, bp, jnp.asarray(b["images"]),
                            jnp.asarray(b["query"]))
                pred = (np.asarray(ml) > 0).astype(np.float64)
                gt = b["mask"].astype(np.float64)
                inter = (pred * gt).sum(axis=(1, 2))
                union = np.maximum(pred, gt).sum(axis=(1, 2))
                inters += inter.sum()
                unions += union.sum()
                gious.append((inter / (union + 1e-6)).mean())
            avg_iou = 0.5 * (float(np.mean(gious))
                             + inters / (unions + 1e-6))
        rows.append(emit(f"fig7/split@{k}", t.us,
                         f"ratio=0.10;avg_iou={avg_iou:.4f}"))
    return rows


if __name__ == "__main__":
    run()
