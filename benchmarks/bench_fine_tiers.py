"""Benchmark (beyond-paper, the paper's §6 future-work item): finer tier
granularity for the runtime controller.

The paper uses 3 tiers and notes "future work will involve more advanced
control policies with higher granularity" (footnote c). We distillation-
train three additional bottleneck pairs (r = 0.20, 0.15, 0.075), build a
6-tier LUT, and re-run the 20-minute dynamic experiment: with smaller
fidelity steps between adjacent feasible tiers, adaptive switching should
cut the IoU gap to the static High-Accuracy baseline well below the
3-tier system's gap, at equal-or-better throughput."""
from __future__ import annotations

import os

from benchmarks.common import CKPT, RATIOS, Timer, emit, ensure_lut, \
    ensure_trained_system
from repro.checkpoint import load_pytree, save_pytree
from repro.configs.lisa_mini import CONFIG as PCFG
from repro.core import profile as prof
from repro.core import training
from repro.core.lut import SystemLUT, Tier
from repro.network import paper_trace
from repro.runtime import MissionSpec, run_mission

EXTRA_RATIOS = (0.20, 0.15, 0.075)


def ensure_fine_bottlenecks(params, log=print):
    out = {}
    for r in EXTRA_RATIOS:
        path = os.path.join(CKPT, f"bottleneck_r{r}")
        if os.path.exists(os.path.join(path, "arrays.npz")):
            out[r] = load_pytree(path)
            continue
        log(f"[fine-tiers] training bottleneck r={r}")
        out[r] = training.train_bottleneck(PCFG, params, r, steps=250,
                                           batch_size=16, log_every=0,
                                           log=lambda s: None)
        save_pytree(path, out[r])
    return out


def run(log=print):
    rows = []
    params, params_ft, bns3 = ensure_trained_system(log)
    lut3 = ensure_lut(log)
    with Timer() as t:
        extra = ensure_fine_bottlenecks(params, log)
        all_bns = {**bns3, **extra}
        tiers = []
        for r, bp in sorted(all_bns.items(), reverse=True):
            acc = training.evaluate_insight(PCFG, params, bn_params=bp,
                                            batches=6)
            acc_ft = training.evaluate_insight(PCFG, params_ft, bn_params=bp,
                                               batches=6)
            tiers.append(Tier(name=f"r={r}", ratio=r,
                              acc_base=acc["avg_iou"],
                              acc_finetuned=acc_ft["avg_iou"],
                              payload_mb=prof.deployment_payload_mb(
                                  __import__("repro.configs.lisa7b",
                                             fromlist=["CONFIG"]).CONFIG, r)))
        lut6 = SystemLUT(tiers=tiers, context=lut3.context)

        trace = paper_trace(seed=0)
        log_ha = run_mission(lut3, trace, MissionSpec(
            mode="static", static_tier="High Accuracy"))
        log3 = run_mission(lut3, trace, MissionSpec(mode="avery"))
        log6 = run_mission(lut6, trace, MissionSpec(mode="avery"))
    for name, lg in [("avery_3tier", log3), ("avery_6tier", log6),
                     ("static_HA", log_ha)]:
        rows.append(emit(f"fine_tiers/{name}", t.us,
                         f"pps={lg.mean_pps:.3f};iou={lg.mean_iou:.4f}"))
    gap3 = 100 * (log_ha.mean_iou - log3.mean_iou)
    gap6 = 100 * (log_ha.mean_iou - log6.mean_iou)
    rows.append(emit(
        "fine_tiers/claims", t.us,
        f"gap_3tier_pp={gap3:.2f};gap_6tier_pp={gap6:.2f};"
        f"improved={gap6 < gap3};paper_future_work=footnote_c"))
    for tier in tiers:
        rows.append(emit(f"fine_tiers/lut/{tier.name}", t.us,
                         f"acc={tier.acc_base:.4f};"
                         f"payload_mb={tier.payload_mb:.3f}"))
    return rows


if __name__ == "__main__":
    run()
