"""Benchmark: Fig. 8 — edge latency & energy per image across split points
(deployment geometry: SAM ViT-H on the calibrated Jetson device model),
including the paper's quoted deltas (sp1 vs sp11/sp29/full-SAM)."""
from __future__ import annotations

from benchmarks.common import Timer, emit
from repro.configs.lisa7b import CONFIG as DEPLOY
from repro.core import bottleneck as bn
from repro.network.energy import (EdgeDevice, bottleneck_flops,
                                  encoder_flops, patch_embed_flops)


def edge_flops_at_split(k: int, ratio: float = 0.10) -> float:
    d = DEPLOY.sam.d_model
    rank = bn.rank_for_ratio(d, ratio, 2)
    f = (patch_embed_flops(d, DEPLOY.patch_size, DEPLOY.sam_tokens)
         + encoder_flops(DEPLOY.sam, DEPLOY.sam_tokens, k)
         + bottleneck_flops(d, rank, DEPLOY.sam_tokens))
    # CLIP runs on the edge for both streams
    f += (patch_embed_flops(DEPLOY.clip.d_model, DEPLOY.context_patch_size,
                            DEPLOY.clip_tokens)
          + encoder_flops(DEPLOY.clip, DEPLOY.clip_tokens))
    return f


def run(log=print):
    dev = EdgeDevice()
    rows = []
    with Timer() as t:
        lat = {}
        eng = {}
        for k in (1, 11, 17, 29, DEPLOY.sam.num_layers):
            f = edge_flops_at_split(k)
            lat[k], eng[k] = dev.latency_s(f), dev.compute_energy_j(f)
        full = (patch_embed_flops(DEPLOY.sam.d_model, DEPLOY.patch_size,
                                  DEPLOY.sam_tokens)
                + encoder_flops(DEPLOY.sam, DEPLOY.sam_tokens))
        lat_f, eng_f = dev.latency_s(full), dev.compute_energy_j(full)
    for k in (1, 11, 17, 29, DEPLOY.sam.num_layers):
        rows.append(emit(
            f"fig8/sp{k}", t.us,
            f"edge_latency_s={lat[k]:.4f};edge_energy_j={eng[k]:.2f}"))
    rows.append(emit("fig8/full_sam_onboard", t.us,
                     f"edge_latency_s={lat_f:.4f};edge_energy_j={eng_f:.2f}"))
    rows.append(emit(
        "fig8/claims", t.us,
        f"sp1_latency_s={lat[1]:.4f};paper_sp1=0.2318;"
        f"energy_reduction_vs_full={100 * (1 - eng[1] / eng_f):.2f}%;"
        f"paper=93.98%;"
        f"sp11_latency_increase={100 * (lat[11] / lat[1] - 1):.1f}%;"
        f"paper=307.29%;"
        f"sp29_energy_increase={100 * (eng[29] / eng[1] - 1):.1f}%;"
        f"paper=1290.23%"))
    return rows


if __name__ == "__main__":
    run()
