"""Benchmark: §5.2.2 — dual-stream on-device cost ratio.

The paper reports the CLIP Context stream is ~6.4x faster on-device than
the Insight stream; we derive the same ratio from the deployment-geometry
FLOPs model, plus the measured (proxy-scale) payload asymmetry."""
from __future__ import annotations

from benchmarks.common import Timer, emit
from repro.configs.lisa7b import CONFIG as DEPLOY
from repro.core import profile as prof
from repro.network.energy import (EdgeDevice, encoder_flops,
                                  patch_embed_flops)
from repro.runtime import edge_insight_flops


def run(log=print):
    rows = []
    with Timer() as t:
        dev = EdgeDevice()
        ctx_flops = (patch_embed_flops(DEPLOY.clip.d_model,
                                       DEPLOY.context_patch_size,
                                       DEPLOY.clip_tokens)
                     + encoder_flops(DEPLOY.clip, DEPLOY.clip_tokens))
        ins_flops = edge_insight_flops(DEPLOY, 0.25)
        ratio = ins_flops / ctx_flops
        ctx_mb = prof.deployment_context_mb(DEPLOY)
        ins_mb = prof.deployment_payload_mb(DEPLOY, 0.25)
    rows.append(emit(
        "streams/context", t.us,
        f"edge_latency_ms={1000 * dev.latency_s(ctx_flops):.1f};"
        f"payload_mb={ctx_mb:.3f}"))
    rows.append(emit(
        "streams/insight", t.us,
        f"edge_latency_ms={1000 * dev.latency_s(ins_flops):.1f};"
        f"payload_mb={ins_mb:.3f}"))
    rows.append(emit("streams/claims", t.us,
                     f"context_speedup={ratio:.1f}x;paper=6.4x"))
    return rows


if __name__ == "__main__":
    run()
