"""Benchmark: the paper's 11.2% claim — learned bottleneck at split@1 vs
raw image compression at MATCHED payload.

The raw-image baseline downsamples the input image so its fp16 pixel
payload equals the bottleneck tier's payload, upsamples on the "cloud",
and runs the full (unsplit) pipeline. Footnote b of the paper explains why
the bottleneck wins: ViT block 1 has already distilled task-salient
features, so compressing them is easier than compressing raw pixels."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RATIOS, Timer, emit, ensure_trained_system
from repro.configs.lisa_mini import CONFIG as PCFG
from repro.core import bottleneck as bn
from repro.core import training, vlm
from repro.data import floodseg


def _eval_raw(params, side: int, batches: int = 6) -> float:
    """Downsample to side x side, upsample back, run the full pipeline."""
    rng = np.random.RandomState(999)
    H = PCFG.image_size

    def fwd(p, img, q):
        small = jax.image.resize(img, (img.shape[0], side, side, 3),
                                 method="linear")
        back = jax.image.resize(small, img.shape, method="linear")
        return vlm.insight_forward(p, PCFG, back, q)

    fwd = jax.jit(fwd)
    inters = unions = 0.0
    gious = []
    for _ in range(batches):
        b = floodseg.make_batch(rng, 32, "segment", augment=False)
        ml, _ = fwd(params, jnp.asarray(b["images"]), jnp.asarray(b["query"]))
        pred = (np.asarray(ml) > 0).astype(np.float64)
        gt = b["mask"].astype(np.float64)
        inter = (pred * gt).sum(axis=(1, 2))
        union = np.maximum(pred, gt).sum(axis=(1, 2))
        inters += inter.sum()
        unions += union.sum()
        gious.append((inter / (union + 1e-6)).mean())
    return 0.5 * (float(np.mean(gious)) + inters / (unions + 1e-6))


def run(log=print):
    params, _, bns = ensure_trained_system(log)
    rows = []
    for r in RATIOS:
        with Timer() as t:
            acc_bn = training.evaluate_insight(PCFG, params, bn_params=bns[r],
                                               batches=6)["avg_iou"]
            # matched raw payload: side^2 * 3 * 2 bytes == bottleneck bytes
            d = PCFG.sam.d_model
            rank = bn.rank_for_ratio(d, r, 4)
            payload = 64 * (rank + 2)          # mini tokens x (codes+scale)
            side = max(2, int((payload / 6) ** 0.5))
            acc_raw = _eval_raw(params, side)
        rows.append(emit(
            f"raw_vs_bottleneck/r{r}", t.us,
            f"bottleneck_iou={acc_bn:.4f};raw_iou={acc_raw:.4f};"
            f"raw_side={side};delta_pp={100 * (acc_bn - acc_raw):.2f};"
            f"paper_delta=11.2"))
    return rows


if __name__ == "__main__":
    run()
