"""Benchmark: the engine's batched serving paths vs the seed per-request
path.

Serves N Insight requests that each need a T-token answer, three ways:

  baseline — the seed serving loop: one jitted call per request at batch
             1, and every answer token re-runs the full [ctx; query;
             generated] forward (no KV cache);
  engine   — ``AveryEngine`` with closed tier-bucketed microbatches
             through ``cloud_generate_batch`` (one batched prefill +
             decode steps against the KV cache) at batch {1,4,8,16};
  inflight — ``AveryEngine`` with token-level in-flight batching: each
             request prefills into a slot of the running decode batch
             and rides the remaining steps (no batch-close barrier).

The engine rows run the XLA KV-decode path; ``engine_flash_b*`` rows
rerun batch 8/16 with the flash-decode Pallas kernel, which executes in
*interpret mode* on this CPU container (grid points run sequentially, so
it is slower here; on real TPU the kernel is the roofline-floor path).
Also reports pure decode throughput (tokens/s) per batch size from timed
``llm_decode_step`` loops for both paths. Weights are freshly initialised
(cached trained checkpoints are used when present) — serving throughput
depends only on the geometry, not on the weight values.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, init_serving_system, make_engine, \
    make_executor, time_best, write_bench_json
from repro.configs.lisa_mini import CONFIG as PCFG
from repro.core import vlm
from repro.core.intent import Intent
from repro.data import floodseg

N_REQUESTS = 32
ANSWER_TOKENS = 4
BATCHES = (1, 4, 8, 16)
# repeat-prefix per-UAV workload (paged shared-prefix KV cache mode)
N_UAVS = 4
FRAMES_PER_UAV = 6
# speculative mode: longer answers amortise the per-admission draft
# prefill over more verify rounds (the Insight-path regime spec targets)
SPEC_ANSWER_TOKENS = 8
# chaos storm workload: fleet burst + seeded fault schedule (blackout
# window, mid-decode stage fault, latency-spiked straggler) under a
# per-request SLO, served with retry-with-downshift + deadline cancel
CHAOS_UAVS = 3
CHAOS_FRAMES = 8
CHAOS_SLO_S = 8.0
CHAOS_BLACKOUT = (2.0, 4.0)       # swallows the t=2,3 submissions
CHAOS_SPIKE_EXTRA_S = 60.0        # straggler arrives hopelessly late


def _requests(executor, n):
    rng = np.random.RandomState(0)
    tier = executor.lut.tiers[0]
    reqs = []
    for i in range(n):
        b = floodseg.make_batch(rng, 1, "segment", augment=False)
        pkt = executor.edge_insight(jnp.asarray(b["images"]), tier, i, 0.0)
        reqs.append((pkt, b["query"]))
    return reqs


def _baseline_serve(executor, reqs, max_new, jit_reason):
    """Seed path generalised to T tokens: per request, per token, a full
    no-cache forward over the grown sequence at batch 1. ``jit_reason``
    must persist across calls so the warm-up rep absorbs its compiles —
    the engine side reuses the executor's compile cache the same way."""
    params = executor.params
    for pkt, q in reqs:
        executor.cloud_insight(pkt, q)              # mask + first token
        query = jnp.asarray(q)
        ctx = jnp.asarray(pkt.content["clip"])
        for _ in range(max_new - 1):
            logits, _ = jit_reason(params, ctx, query)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            query = jnp.concatenate([query, nxt], axis=1)
        jax.block_until_ready(query)


def _engine_serve(executor, reqs, max_batch, batching):
    engine = make_engine(executor, max_batch=max_batch, batching=batching)
    for pkt, q in reqs:
        engine.submit_packet(pkt, q, Intent.INSIGHT)
    return engine.drain()


def _decode_loop(executor, batch, steps):
    """Pure KV-decode hot loop at batch B (cache pre-filled); runs the
    flash kernel or the XLA path per the executor's ``flash_decode``."""
    pcfg = executor._gen_pcfg
    params = executor.params
    ctx = jnp.zeros((batch, pcfg.clip_tokens, pcfg.llm.d_model),
                    pcfg.llm.adtype)
    query = jnp.zeros((batch, 8), jnp.int32)
    S = pcfg.clip_tokens + 8
    _, _, cache = jax.jit(lambda p, c, q: vlm.llm_prefill(
        p, pcfg, c, q, width=S + steps))(params, ctx, query)
    step = jax.jit(lambda p, ca, t, pos: vlm.llm_decode_step(
        p, pcfg, ca, t, pos))
    tok = jnp.zeros((batch, 1), jnp.int32)

    def run():
        c = cache
        for i in range(steps):
            logits, _, c = step(params, c, tok, jnp.int32(S + i))
        jax.block_until_ready(logits)
    return run


def _uav_stream(executor, n_uavs, frames, kind):
    """N UAVs x M frames; each UAV re-sends its frame under a standing
    query, so the cloud-side [ctx; query] prefix repeats per UAV."""
    rng = np.random.RandomState(7)
    tier = executor.lut.tiers[0]
    reqs = []
    for u in range(n_uavs):
        b = floodseg.make_batch(rng, 1,
                                "segment" if kind == "insight" else "any",
                                augment=False)
        img = jnp.asarray(b["images"])
        for f in range(frames):
            sid = u * frames + f
            if kind == "insight":
                pkt = executor.edge_insight(img, tier, sid, 0.0)
            else:
                pkt, _ = executor.edge_context(img, sid, 0.0)
            reqs.append((f"uav-{u}", pkt, b["query"]))
    return reqs


def paged_prefix_rows(executor, n_uavs=N_UAVS, frames=FRAMES_PER_UAV,
                      emit_row=None):
    """Paged shared-prefix mode: admission throughput on repeat-prefix
    per-UAV traffic, with and without the prefix store. Admission is the
    per-request serving cost that prefix reuse removes (prefill FLOPs +
    prefix KV pages); the decode steps are identical either way, so the
    measured loop is N ``InflightDecoder.submit`` calls (prefix
    lookup/prefill + page-table setup), not the shared decode."""
    from repro.core.paging import PagePool, pages_for
    from repro.engine.inflight import InflightDecoder
    from repro.network.energy import encoder_flops

    emit_row = emit_row or emit
    rows = []
    for kind in ("context", "insight"):
        reqs = _uav_stream(executor, n_uavs, frames, kind)
        intent = Intent.CONTEXT if kind == "context" else Intent.INSIGHT
        times, pools = {}, {}

        def admit_all(share):
            pool = PagePool(page_size=executor.page_size,
                            share_prefixes=share)
            dec = InflightDecoder(executor, slots=len(reqs), pool=pool)
            for i, (op, pkt, q) in enumerate(reqs):
                dec.submit(i, intent, pkt, q, lambda out: None,
                           operator_id=op)
            pools[share] = pool

        for share in (False, True):
            times[share] = time_best(lambda: admit_all(share))
        pool = pools[True]
        qlen = np.asarray(reqs[0][2]).shape[-1]
        prefix_len = executor.pcfg.clip_tokens + qlen
        n_prefix = pages_for(prefix_len, pool.page_size)
        # per run: one prefix prefill per UAV instead of one per frame
        hits = n_uavs * (frames - 1)
        saved_flops = hits * encoder_flops(executor.pcfg.llm, prefix_len)
        saved_bytes = hits * n_prefix * pool.page_bytes
        rows.append(emit_row(
            f"serving/paged_admit_{kind}", times[True] * 1e6,
            f"admit_req_s={len(reqs) / times[True]:.1f};"
            f"speedup_vs_no_prefix_reuse={times[False] / times[True]:.2f}x;"
            f"prefix_hit_rate={pool.prefix_hit_rate:.2f};"
            f"prefill_flops_saved={saved_flops:.3g};"
            f"kv_bytes_saved={saved_bytes};"
            f"uavs={n_uavs};frames_per_uav={frames}"))
    return rows


def spec_rows(executor, n_uavs=N_UAVS, frames=FRAMES_PER_UAV,
              draft_tokens=3, emit_row=None, spec_cfg=None,
              row_name="serving/spec_insight",
              note="draft_shares_target_geometry_on_cpu"):
    """Speculative decoding mode: repeat-prefix per-UAV Insight traffic
    served end to end (admission + decode) through the in-flight batch,
    with the draft model proposing ``draft_tokens`` per verify step vs.
    the non-speculative paged baseline. Tokens/step > 1 is the direct
    measure of serving-model passes saved; greedy output is token-exact
    either way (pinned in tests), so the speedup is free of quality
    cost. ``spec_cfg`` overrides the whole ``SpeculativeConfig`` (the
    nano-draft row passes the truncated-trunk config)."""
    from repro.core.paging import PagePool
    from repro.engine.inflight import InflightDecoder
    from repro.engine.speculative import SpeculativeConfig

    emit_row = emit_row or emit
    rows = []
    reqs = _uav_stream(executor, n_uavs, frames, "insight")
    times, stats = {}, {}

    def serve_all(spec):
        pool = PagePool(page_size=executor.page_size)
        dec = InflightDecoder(executor, slots=8, pool=pool, spec=spec)
        for i, (op, pkt, q) in enumerate(reqs):
            dec.submit(i, Intent.INSIGHT, pkt, q, lambda out: None,
                       operator_id=op)
        dec.drain()
        stats[spec is not None] = (
            dec.spec_stats, dec.n_steps,
            (dec.draft.n_steps, dec.draft.n_prefills)
            if dec.draft is not None else (0, 0),
            pool.stats())

    cfg = spec_cfg or SpeculativeConfig(draft_tokens=draft_tokens)
    for spec in (None, cfg):
        times[spec is not None] = time_best(lambda: serve_all(spec))
    st, n_steps, draft_steps, pool_stats = stats[True]
    base_steps = stats[False][1]
    # the CPU-container caveat: the default Context-stream draft shares
    # the target's lisa_mini geometry, so each draft step costs ~a
    # target step and wall-clock sits near parity; the hardware-relevant
    # signal is tokens/step (serving-model passes saved) — with the
    # lisa7b target the same draft is ~50x cheaper per step, and the
    # nano row runs a truncated trunk that is cheap on any host
    draft_layers = (cfg.draft_pcfg or executor.pcfg).llm.num_layers
    rows.append(emit_row(
        row_name, times[True] * 1e6,
        f"req_s={len(reqs) / times[True]:.1f};"
        f"speedup_vs_paged={times[False] / times[True]:.2f}x;"
        f"tokens_per_step={st.tokens_per_step:.2f};"
        f"acceptance_rate={st.acceptance_rate:.2f};"
        f"verify_steps={n_steps};baseline_decode_steps={base_steps};"
        f"draft_steps={draft_steps[0]};draft_prefills={draft_steps[1]};"
        f"draft_layers={draft_layers};"
        f"kv_pages_peak={pool_stats['kv_pages_peak']};"
        f"k={cfg.draft_tokens};uavs={n_uavs};frames_per_uav={frames};"
        f"note={note}"))
    return rows


def spec_nano_rows(executor, emit_row=None, **kw):
    """The truly-small draft row: lisa_nano (the target's truncated
    trunk — 1 of 4 LLM layers, shared embed/head) drafting against the
    full target. Draft steps are ~4x cheaper than the shared-geometry
    draft; acceptance depends on how often the early-exit argmax agrees
    with the full trunk's (weight-dependent — reported, not assumed),
    and greedy verify keeps the output token-exact regardless."""
    from repro.configs import lisa_nano
    from repro.engine.speculative import SpeculativeConfig

    cfg = SpeculativeConfig(
        draft_tokens=3, draft_pcfg=lisa_nano.CONFIG,
        draft_params=lisa_nano.nano_draft_params(executor.params))
    return spec_rows(executor, emit_row=emit_row, spec_cfg=cfg,
                     row_name="serving/spec_insight_nano",
                     note="nano_truncated_trunk_draft", **kw)


def sharded_rows(executor, n_uavs=N_UAVS, frames=FRAMES_PER_UAV,
                 draft_tokens=3, emit_row=None):
    """Sharded paged serving mode: the same repeat-prefix per-UAV
    Insight traffic served through a ``ShardedServingContext`` on the
    local mesh — params model-sharded, KV pool kv-heads over "model",
    page tables replicated — in plain paged and speculative-verify
    disciplines, pinned token-exact against the unsharded
    ``llm_generate`` path. Run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a real
    8-device host mesh (ci_fast does); wall-clock vs unsharded is
    *expected* < 1x there — eight fake devices share one CPU and pay
    real collectives — the row's signal is exactness + per-shard pool
    residency; on real multi-chip hardware the same partitioning is the
    scaling path."""
    from repro.core.paging import PagePool
    from repro.engine.inflight import InflightDecoder
    from repro.engine.speculative import SpeculativeConfig
    from repro.launch.mesh import make_local_mesh
    from repro.sharding.serving import ShardedServingContext

    emit_row = emit_row or emit
    n_dev = jax.device_count()
    model = max(m for m in (4, 2, 1) if n_dev % m == 0)
    mesh = make_local_mesh(model=model)
    ctx = ShardedServingContext(executor, mesh)
    reqs = _uav_stream(executor, n_uavs, frames, "insight")
    T = executor.max_new_tokens

    def serve_all(ex, spec, out):
        if hasattr(ex, "place_pool"):
            pool = PagePool(page_size=ex.page_size, placement=ex.place_pool,
                            shards=ex.model_shards)
        else:
            pool = PagePool(page_size=ex.page_size)
        dec = InflightDecoder(ex, slots=8, pool=pool, spec=spec)
        done = {}
        for i, (op, pkt, q) in enumerate(reqs):
            dec.submit(i, Intent.INSIGHT, pkt, q,
                       lambda o: done.setdefault(o["seq_id"], o),
                       operator_id=op)
        dec.drain()
        out["done"], out["pool"], out["dec"] = done, pool, dec

    base, shard, shsp = {}, {}, {}
    t_base = time_best(lambda: serve_all(executor, None, base))
    t_shard = time_best(lambda: serve_all(ctx, None, shard))
    spec_cfg = SpeculativeConfig(draft_tokens=draft_tokens)
    t_spec = time_best(lambda: serve_all(ctx, spec_cfg, shsp))

    # exactness pin: both sharded disciplines vs the unsharded one-shot
    # (the measured flag goes into the artifact; a mismatch also fails
    # the run loudly so CI can't record a stale green claim)
    exact_paged = exact_spec = True
    for i, (op, pkt, q) in enumerate(reqs):
        ref = executor.cloud_generate_batch([pkt], [q])[0][-1]
        exact_paged &= bool(np.array_equal(shard["done"][i]["tokens"], ref))
        exact_spec &= bool(np.array_equal(shsp["done"][i]["tokens"], ref))
    if not (exact_paged and exact_spec):
        raise AssertionError(
            f"sharded serving diverged from unsharded llm_generate "
            f"(paged exact={exact_paged}, spec exact={exact_spec})")

    n = len(reqs)
    st = shard["pool"].stats()
    rows = [emit_row(
        "serving/sharded_paged", t_shard * 1e6,
        f"req_s={n / t_shard:.1f};tok_s={n * T / t_shard:.1f};"
        f"vs_unsharded={t_base / t_shard:.2f}x;devices={n_dev};"
        f"model_shards={model};token_exact={int(exact_paged)};"
        f"kv_pool_bytes_per_shard={st['kv_pool_bytes_per_shard']};"
        f"uavs={n_uavs};frames_per_uav={frames};"
        f"note=host_platform_shards_share_one_cpu")]
    sst = shsp["dec"].spec_stats
    rows.append(emit_row(
        "serving/sharded_spec", t_spec * 1e6,
        f"req_s={n / t_spec:.1f};"
        f"tokens_per_step={sst.tokens_per_step:.2f};"
        f"acceptance_rate={sst.acceptance_rate:.2f};"
        f"model_shards={model};token_exact={int(exact_spec)};"
        f"k={draft_tokens};"
        f"uavs={n_uavs};frames_per_uav={frames}"))
    return rows


def chaos_rows(executor, n_uavs=CHAOS_UAVS, frames=CHAOS_FRAMES,
               emit_row=None, seed=0):
    """Chaos storm mode: a repeat-prefix fleet burst (one Insight frame
    per mission second, UAVs round-robin) served through the in-flight
    engine under a seeded fault schedule — an uplink blackout window
    that swallows two submissions, a ``cloud_decode_rows`` fault that
    kills the whole running batch mid-decode, and a latency spike that
    blows the final straggler frame past its SLO — with a
    ``RetryPolicy`` (backoff + tier downshift), per-request deadlines
    (``max_latency_s``), and ``debug_invariants`` page audits on.

    The row reports the delivered-under-SLO rate and the retry/
    downshift/cancel telemetry; the run *asserts* the fault-tolerance
    contract (every future resolves, at least one successful
    downshifted retry, at least one deadline cancellation, zero leaked
    KV pages) so CI cannot record a green row for a broken engine."""
    import dataclasses

    from repro.core.intent import DEFAULT_REQUIREMENTS
    from repro.engine import (FaultInjector, FaultyExecutor,
                              LoopbackTransport, RetryPolicy)

    emit_row = emit_row or emit
    n = n_uavs * frames
    rng = np.random.RandomState(seed)
    fleet = []
    for u in range(n_uavs):
        b = floodseg.make_batch(rng, 1, "segment", augment=False)
        fleet.append((f"uav-{u}", jnp.asarray(b["images"]), b["query"]))
    reqs = dict(DEFAULT_REQUIREMENTS)
    reqs[Intent.INSIGHT] = dataclasses.replace(
        reqs[Intent.INSIGHT], max_latency_s=CHAOS_SLO_S)
    out = {}

    # the straggler flies long after the burst (and its retry tail) has
    # drained, so the spiked delivery's watermark jump can only sweep
    # the straggler itself, not still-decoding burst requests
    t_straggler = float(n + 30)

    def serve():
        # fresh faults + engine per rep: the schedule (call indices, RNG
        # stream, mission clock) must replay identically every run
        faults = FaultInjector(
            LoopbackTransport(), seed=seed, blackouts=[CHAOS_BLACKOUT],
            spikes=[(t_straggler, t_straggler + 1.0, CHAOS_SPIKE_EXTRA_S)])
        chaotic = FaultyExecutor(executor,
                                 fail_at={"cloud_decode_rows": [2]})
        engine = make_engine(
            chaotic, transport=faults, batching="inflight", max_batch=8,
            retry=RetryPolicy(max_attempts=3, backoff_base_s=0.25),
            debug_invariants=True)
        sessions = {op: engine.session(op, requirements=dict(reqs))
                    for op, _, _ in fleet}
        futs = []
        for i in range(n - 1):           # the storm burst
            op, img, q = fleet[i % n_uavs]
            futs.append(sessions[op].submit(
                prompt="segment the stranded person", images=img, query=q,
                time_s=float(i), intent=Intent.INSIGHT))
        engine.drain()
        # the straggler: its delivery is spiked past the SLO, so the
        # deadline sweep must cancel it (slot + pages released) instead
        # of letting its future hang
        op, img, q = fleet[(n - 1) % n_uavs]
        futs.append(sessions[op].submit(
            prompt="segment the stranded person", images=img, query=q,
            time_s=t_straggler, intent=Intent.INSIGHT))
        engine.drain()
        for s in sessions.values():
            s.close()
        out["futs"], out["engine"] = futs, engine

    chaos_s = time_best(serve)
    futs, engine = out["futs"], out["engine"]
    resps = [f.result() for f in futs]   # must all resolve, never hang
    st = engine.stats
    leaks = engine.kv_pool.pages_in_use
    engine.kv_pool.check_invariants()
    served_retried = [r for r in resps
                      if r.failure is None and r.attempts > 1]
    if not served_retried or st["downshifts"] < 1:
        raise AssertionError(
            f"chaos storm produced no successful downshifted retry "
            f"(retried-and-served={len(served_retried)}, "
            f"downshifts={st['downshifts']})")
    if st["deadline_cancelled"] < 1:
        raise AssertionError("spiked straggler was not deadline-cancelled")
    if leaks != 0:
        raise AssertionError(f"chaos run leaked {leaks} KV pages")
    slo = sum(1 for r in resps if r.failure is None) / len(resps)
    return [emit_row(
        "serving/chaos", chaos_s * 1e6,
        f"req_s={n / chaos_s:.1f};delivered_under_slo={slo:.2f};"
        f"retries={int(st['retries'])};downshifts={int(st['downshifts'])};"
        f"deadline_cancelled={int(st['deadline_cancelled'])};"
        f"inflight_cancelled={int(st['inflight_cancelled'])};"
        f"stage_faults={int(st['stage_faults'])};"
        f"blackouts_terminal={int(st['blackouts'])};"
        f"cloud_errors_terminal={int(st['cloud_errors'])};"
        f"page_leaks={leaks};slo_s={CHAOS_SLO_S};seed={seed};"
        f"uavs={n_uavs};frames_per_uav={frames}")]


def run(log=print):
    rows = []
    params, bns, lut = init_serving_system(PCFG)
    # XLA KV-decode engine (the CPU-appropriate config; flash-decode
    # interpret mode is measured separately below)
    executor = make_executor(PCFG, params, bns, lut,
                             max_new_tokens=ANSWER_TOKENS, flash_decode=False)
    flash_exec = make_executor(PCFG, params, bns, lut,
                               max_new_tokens=ANSWER_TOKENS,
                               flash_decode=True)
    reqs = _requests(executor, N_REQUESTS)

    pcfg = executor.pcfg
    jit_reason = jax.jit(lambda p, c, q: vlm.llm_reason(p, pcfg, c, q))
    base_s = time_best(lambda: _baseline_serve(executor, reqs, ANSWER_TOKENS,
                                               jit_reason))
    base_rps = N_REQUESTS / base_s
    rows.append(emit("serving/baseline_full_forward", base_s * 1e6,
                     f"req_s={base_rps:.1f};"
                     f"tok_s={N_REQUESTS * ANSWER_TOKENS / base_s:.1f};"
                     f"T={ANSWER_TOKENS};N={N_REQUESTS}"))

    for b in BATCHES:
        eng_s = time_best(lambda: _engine_serve(executor, reqs, b,
                                                "generate"))
        rps = N_REQUESTS / eng_s
        rows.append(emit(
            f"serving/engine_b{b}", eng_s * 1e6,
            f"req_s={rps:.1f};speedup_vs_full_forward={rps / base_rps:.2f}x;"
            f"tok_s={N_REQUESTS * ANSWER_TOKENS / eng_s:.1f}"))

    for b in (8, 16):
        eng_s = time_best(lambda: _engine_serve(executor, reqs, b,
                                                "inflight"))
        rps = N_REQUESTS / eng_s
        rows.append(emit(
            f"serving/inflight_b{b}", eng_s * 1e6,
            f"req_s={rps:.1f};speedup_vs_full_forward={rps / base_rps:.2f}x;"
            "note=token_level_continuous_batching"))

    for b in (8, 16):
        eng_s = time_best(lambda: _engine_serve(flash_exec, reqs, b,
                                                "generate"))
        rps = N_REQUESTS / eng_s
        rows.append(emit(
            f"serving/engine_flash_b{b}", eng_s * 1e6,
            f"req_s={rps:.1f};speedup_vs_full_forward={rps / base_rps:.2f}x;"
            "note=pallas_interpret_on_cpu"))

    # paged shared-prefix KV cache: repeat-prefix per-UAV admission
    rows += paged_prefix_rows(executor)

    # speculative decoding off the Context-stream model (its own
    # executor: the longer-answer regime speculation targets)
    spec_exec = make_executor(PCFG, params, bns, lut,
                              max_new_tokens=SPEC_ANSWER_TOKENS,
                              flash_decode=False)
    rows += spec_rows(spec_exec)
    rows += spec_nano_rows(spec_exec)

    # sharded paged serving (degenerates to 1 shard on a 1-device host;
    # ci_fast forces an 8-device host platform for the real mesh)
    rows += sharded_rows(executor)

    # chaos storm: the fault-tolerance contract under a seeded schedule
    rows += chaos_rows(executor)

    steps = 32
    for b in BATCHES:
        dec_s = time_best(_decode_loop(executor, b, steps))
        rows.append(emit(
            f"serving/decode_b{b}", dec_s * 1e6,
            f"decode_tok_s={b * steps / dec_s:.1f};steps={steps}"))
    for b in (8, 16):
        dec_s = time_best(_decode_loop(flash_exec, b, steps))
        rows.append(emit(
            f"serving/decode_flash_b{b}", dec_s * 1e6,
            f"decode_tok_s={b * steps / dec_s:.1f};steps={steps};"
            "note=pallas_interpret_on_cpu"))
    write_bench_json(rows)
    return rows


def _smoke_executor(max_new_tokens=ANSWER_TOKENS):
    params, bns, lut = init_serving_system(PCFG)
    return make_executor(PCFG, params, bns, lut,
                         max_new_tokens=max_new_tokens, flash_decode=False)


def _smoke_emit(name, us, derived):
    """Smoke rows carry their own names in the JSON artifact so the
    reduced-size numbers never overwrite the full-run perf records."""
    return emit(name + "_smoke", us, derived)


def run_paged_smoke():
    """CI smoke: only the paged shared-prefix mode, at a reduced size
    (2 UAVs x 4 frames, XLA decode path) — exercises prefix store,
    allocator, and page-table admission end to end in seconds."""
    rows = paged_prefix_rows(_smoke_executor(), n_uavs=2, frames=4,
                             emit_row=_smoke_emit)
    write_bench_json(rows)
    return rows


def run_spec():
    """Full speculative mode on its own (the rest of the serving suite
    untouched): Context-stream drafts + paged multi-token verify vs the
    non-speculative paged baseline, plus the truly-small lisa_nano
    truncated-trunk draft row."""
    executor = _smoke_executor(SPEC_ANSWER_TOKENS)
    rows = spec_rows(executor)
    rows += spec_nano_rows(executor)
    write_bench_json(rows)
    return rows


def run_sharded():
    """Sharded paged serving mode on its own: tensor-parallel paged
    decode + speculative verify on the local mesh, token-exact vs the
    unsharded path. Force a multi-device host platform first:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    rows = sharded_rows(_smoke_executor())
    write_bench_json(rows)
    return rows


def run_sharded_smoke():
    """CI smoke: the sharded mode at a reduced size (2 UAVs x 3 frames)
    — mesh construction, sharded param/pool placement, sharded decode +
    verify exactness, and the per-shard residency stats in minutes."""
    rows = sharded_rows(_smoke_executor(), n_uavs=2, frames=3,
                        emit_row=_smoke_emit)
    write_bench_json(rows)
    return rows


def run_chaos():
    """Chaos storm mode on its own: the full-size seeded fault schedule
    (3 UAVs x 8 frames) against the in-flight engine with retries,
    downshifts, deadlines, and page audits — asserting the
    fault-tolerance contract, not just timing it."""
    rows = chaos_rows(_smoke_executor())
    write_bench_json(rows)
    return rows


def run_chaos_smoke():
    """CI smoke: the chaos storm at a reduced size (2 UAVs x 3 frames)
    — blackout retry-with-downshift, batch-wide stage-fault recovery,
    and the spiked straggler's deadline cancellation in seconds, with
    the same hard asserts (>=1 successful downshifted retry, >=1
    deadline cancel, zero leaked pages) as the full run."""
    rows = chaos_rows(_smoke_executor(), n_uavs=2, frames=3,
                      emit_row=_smoke_emit)
    write_bench_json(rows)
    return rows


def run_spec_smoke():
    """CI smoke: speculative decoding end to end at a reduced size
    (2 UAVs x 3 frames) — draft model, verify kernel path, greedy
    acceptance, rollback, and the tokens/step accounting in seconds."""
    rows = spec_rows(_smoke_executor(SPEC_ANSWER_TOKENS), n_uavs=2,
                     frames=3, emit_row=_smoke_emit)
    write_bench_json(rows)
    return rows


if __name__ == "__main__":
    import sys
    if "--paged-smoke" in sys.argv:
        run_paged_smoke()
    elif "--spec-smoke" in sys.argv:
        run_spec_smoke()
    elif "--spec" in sys.argv:
        run_spec()
    elif "--sharded-smoke" in sys.argv:
        run_sharded_smoke()
    elif "--sharded" in sys.argv:
        run_sharded()
    elif "--chaos-smoke" in sys.argv:
        run_chaos_smoke()
    elif "--chaos" in sys.argv:
        run_chaos()
    else:
        run()
