"""Benchmark: batched KV-cache serving engine vs the seed per-request path.

Serves N Insight requests that each need a T-token answer, two ways:

  baseline — the seed serving loop: one jitted call per request at batch 1,
             and every answer token re-runs the full [ctx; query; generated]
             forward (no KV cache);
  engine   — the continuous-batching scheduler: tier-bucketed microbatches
             through ``cloud_generate_batch`` (one batched prefill + decode
             steps against the KV cache) at batch {1,4,8,16}.

The engine rows run the XLA KV-decode path; ``engine_flash_b*`` rows rerun
batch 8/16 with the flash-decode Pallas kernel, which executes in
*interpret mode* on this CPU container (grid points run sequentially, so
it is slower here; on real TPU the kernel is the roofline-floor path).
Also reports pure decode throughput (tokens/s) per batch size from timed
``llm_decode_step`` loops for both paths. Weights are freshly initialised
(cached trained checkpoints are used when present) — serving throughput
depends only on the geometry, not on the weight values.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CKPT, emit
from repro.configs.lisa_mini import CONFIG as PCFG
from repro.core import DualStreamExecutor, bottleneck as bn, paper_lut, vlm
from repro.core.intent import Intent
from repro.data import floodseg
from repro.runtime.scheduler import MicrobatchScheduler, ServeRequest

N_REQUESTS = 32
ANSWER_TOKENS = 4
BATCHES = (1, 4, 8, 16)


def _system():
    lut = paper_lut()
    path = os.path.join(CKPT, "lisa_mini_original", "arrays.npz")
    if os.path.exists(path):
        from repro.checkpoint import load_pytree
        params = load_pytree(os.path.dirname(path))
    else:
        params = vlm.init_lisa(PCFG, jax.random.PRNGKey(0))
    d = PCFG.sam.d_model
    bns = {t.name: bn.init_bottleneck(
        jax.random.PRNGKey(i), bn.BottleneckSpec(d, bn.rank_for_ratio(
            d, t.ratio, 4), 4)) for i, t in enumerate(lut.tiers)}
    return params, bns, lut


def _requests(executor, n):
    rng = np.random.RandomState(0)
    tier = executor.lut.tiers[0]
    reqs = []
    for i in range(n):
        b = floodseg.make_batch(rng, 1, "segment", augment=False)
        pkt = executor.edge_insight(jnp.asarray(b["images"]), tier, i, 0.0)
        reqs.append(ServeRequest(seq_id=i, intent=Intent.INSIGHT, packet=pkt,
                                 query=b["query"]))
    return reqs


def _time(fn, reps=2):
    fn()                                    # warm-up (compiles)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _baseline_serve(executor, reqs, max_new, jit_reason):
    """Seed path generalised to T tokens: per request, per token, a full
    no-cache forward over the grown sequence at batch 1. ``jit_reason``
    must persist across calls so the warm-up rep absorbs its compiles —
    the engine side reuses the executor's compile cache the same way."""
    params = executor.params
    for r in reqs:
        executor.cloud_insight(r.packet, r.query)   # mask + first token
        query = jnp.asarray(r.query)
        ctx = jnp.asarray(r.packet.content["clip"])
        for _ in range(max_new - 1):
            logits, _ = jit_reason(params, ctx, query)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            query = jnp.concatenate([query, nxt], axis=1)
        jax.block_until_ready(query)


def _engine_serve(executor, reqs, max_batch):
    sched = MicrobatchScheduler(executor=executor, max_batch=max_batch,
                                generate=True)
    return sched.serve_all(reqs)


def _decode_loop(executor, batch, steps):
    """Pure KV-decode hot loop at batch B (cache pre-filled); runs the
    flash kernel or the XLA path per the executor's ``flash_decode``."""
    pcfg = executor._gen_pcfg
    params = executor.params
    ctx = jnp.zeros((batch, pcfg.clip_tokens, pcfg.llm.d_model),
                    pcfg.llm.adtype)
    query = jnp.zeros((batch, 8), jnp.int32)
    S = pcfg.clip_tokens + 8
    _, _, cache = jax.jit(lambda p, c, q: vlm.llm_prefill(
        p, pcfg, c, q, width=S + steps))(params, ctx, query)
    step = jax.jit(lambda p, ca, t, pos: vlm.llm_decode_step(
        p, pcfg, ca, t, pos))
    tok = jnp.zeros((batch, 1), jnp.int32)

    def run():
        c = cache
        for i in range(steps):
            logits, _, c = step(params, c, tok, jnp.int32(S + i))
        jax.block_until_ready(logits)
    return run


def run(log=print):
    rows = []
    params, bns, lut = _system()
    # XLA KV-decode engine (the CPU-appropriate config; flash-decode
    # interpret mode is measured separately below)
    executor = DualStreamExecutor(pcfg=PCFG, params=params, bottlenecks=bns,
                                  lut=lut, max_new_tokens=ANSWER_TOKENS,
                                  flash_decode=False)
    flash_exec = DualStreamExecutor(pcfg=PCFG, params=params,
                                    bottlenecks=bns, lut=lut,
                                    max_new_tokens=ANSWER_TOKENS,
                                    flash_decode=True)
    reqs = _requests(executor, N_REQUESTS)

    pcfg = executor.pcfg
    jit_reason = jax.jit(lambda p, c, q: vlm.llm_reason(p, pcfg, c, q))
    base_s = _time(lambda: _baseline_serve(executor, reqs, ANSWER_TOKENS,
                                           jit_reason))
    base_rps = N_REQUESTS / base_s
    rows.append(emit("serving/baseline_full_forward", base_s * 1e6,
                     f"req_s={base_rps:.1f};"
                     f"tok_s={N_REQUESTS * ANSWER_TOKENS / base_s:.1f};"
                     f"T={ANSWER_TOKENS};N={N_REQUESTS}"))

    for b in BATCHES:
        eng_s = _time(lambda: _engine_serve(executor, reqs, b))
        rps = N_REQUESTS / eng_s
        rows.append(emit(
            f"serving/engine_b{b}", eng_s * 1e6,
            f"req_s={rps:.1f};speedup_vs_full_forward={rps / base_rps:.2f}x;"
            f"tok_s={N_REQUESTS * ANSWER_TOKENS / eng_s:.1f}"))

    for b in (8, 16):
        eng_s = _time(lambda: _engine_serve(flash_exec, reqs, b))
        rps = N_REQUESTS / eng_s
        rows.append(emit(
            f"serving/engine_flash_b{b}", eng_s * 1e6,
            f"req_s={rps:.1f};speedup_vs_full_forward={rps / base_rps:.2f}x;"
            "note=pallas_interpret_on_cpu"))

    steps = 32
    for b in BATCHES:
        dec_s = _time(_decode_loop(executor, b, steps))
        rows.append(emit(
            f"serving/decode_b{b}", dec_s * 1e6,
            f"decode_tok_s={b * steps / dec_s:.1f};steps={steps}"))
    for b in (8, 16):
        dec_s = _time(_decode_loop(flash_exec, b, steps))
        rows.append(emit(
            f"serving/decode_flash_b{b}", dec_s * 1e6,
            f"decode_tok_s={b * steps / dec_s:.1f};steps={steps};"
            "note=pallas_interpret_on_cpu"))
    return rows


if __name__ == "__main__":
    run()
