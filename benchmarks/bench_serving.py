"""Benchmark: the engine's batched serving paths vs the seed per-request
path.

Serves N Insight requests that each need a T-token answer, three ways:

  baseline — the seed serving loop: one jitted call per request at batch
             1, and every answer token re-runs the full [ctx; query;
             generated] forward (no KV cache);
  engine   — ``AveryEngine`` with closed tier-bucketed microbatches
             through ``cloud_generate_batch`` (one batched prefill +
             decode steps against the KV cache) at batch {1,4,8,16};
  inflight — ``AveryEngine`` with token-level in-flight batching: each
             request prefills into a slot of the running decode batch
             and rides the remaining steps (no batch-close barrier).

The engine rows run the XLA KV-decode path; ``engine_flash_b*`` rows
rerun batch 8/16 with the flash-decode Pallas kernel, which executes in
*interpret mode* on this CPU container (grid points run sequentially, so
it is slower here; on real TPU the kernel is the roofline-floor path).
Also reports pure decode throughput (tokens/s) per batch size from timed
``llm_decode_step`` loops for both paths. Weights are freshly initialised
(cached trained checkpoints are used when present) — serving throughput
depends only on the geometry, not on the weight values.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ART, emit, init_serving_system, make_engine, \
    make_executor, time_best, write_bench_json
from repro.configs.lisa_mini import CONFIG as PCFG
from repro.core import vlm
from repro.core.intent import Intent
from repro.data import floodseg

N_REQUESTS = 32
ANSWER_TOKENS = 4
BATCHES = (1, 4, 8, 16)
# repeat-prefix per-UAV workload (paged shared-prefix KV cache mode)
N_UAVS = 4
FRAMES_PER_UAV = 6
# speculative mode: longer answers amortise the per-admission draft
# prefill over more verify rounds (the Insight-path regime spec targets)
SPEC_ANSWER_TOKENS = 8
# chaos storm workload: fleet burst + seeded fault schedule (blackout
# window, mid-decode stage fault, latency-spiked straggler) under a
# per-request SLO, served with retry-with-downshift + deadline cancel
CHAOS_UAVS = 3
CHAOS_FRAMES = 8
CHAOS_SLO_S = 8.0
CHAOS_BLACKOUT = (2.0, 4.0)       # swallows the t=2,3 submissions
CHAOS_SPIKE_EXTRA_S = 60.0        # straggler arrives hopelessly late
CHAOS_BW_MBPS = 20.0              # constant uplink under the fault layer:
                                  # a ~12 KB Insight packet takes ~5 ms,
                                  # so TTFT is a real positive transmit +
                                  # queue time, not loopback-instant 0.0
# fleet storm workload (multi-tenant scheduling): many operators across
# both QoS classes, heavy-tailed arrivals, operator churn, a mid-storm
# blackout, and one spamming operator — the same seeded trace served
# under FifoScheduler vs QoSScheduler
STORM_SEED = 0
STORM_DURATION_S = 40.0
STORM_SLOTS = 4                   # decode slots (scarce on purpose)
STORM_TOKENS = 6                  # answer length (queueing pressure)
STORM_PUMP_DT = 0.5               # mission seconds per decode pump
STORM_SPAM_RATE = (0.8, 2.0)      # spammer's token bucket (rate, burst)


def _requests(executor, n):
    rng = np.random.RandomState(0)
    tier = executor.lut.tiers[0]
    reqs = []
    for i in range(n):
        b = floodseg.make_batch(rng, 1, "segment", augment=False)
        pkt = executor.edge_insight(jnp.asarray(b["images"]), tier, i, 0.0)
        reqs.append((pkt, b["query"]))
    return reqs


def _baseline_serve(executor, reqs, max_new, jit_reason):
    """Seed path generalised to T tokens: per request, per token, a full
    no-cache forward over the grown sequence at batch 1. ``jit_reason``
    must persist across calls so the warm-up rep absorbs its compiles —
    the engine side reuses the executor's compile cache the same way."""
    params = executor.params
    for pkt, q in reqs:
        executor.cloud_insight(pkt, q)              # mask + first token
        query = jnp.asarray(q)
        ctx = jnp.asarray(pkt.content["clip"])
        for _ in range(max_new - 1):
            logits, _ = jit_reason(params, ctx, query)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            query = jnp.concatenate([query, nxt], axis=1)
        jax.block_until_ready(query)


def _engine_serve(executor, reqs, max_batch, batching):
    engine = make_engine(executor, max_batch=max_batch, batching=batching)
    for pkt, q in reqs:
        engine.submit_packet(pkt, q, Intent.INSIGHT)
    return engine.drain()


def _decode_loop(executor, batch, steps):
    """Pure KV-decode hot loop at batch B (cache pre-filled); runs the
    flash kernel or the XLA path per the executor's ``flash_decode``."""
    pcfg = executor._gen_pcfg
    params = executor.params
    ctx = jnp.zeros((batch, pcfg.clip_tokens, pcfg.llm.d_model),
                    pcfg.llm.adtype)
    query = jnp.zeros((batch, 8), jnp.int32)
    S = pcfg.clip_tokens + 8
    _, _, cache = jax.jit(lambda p, c, q: vlm.llm_prefill(
        p, pcfg, c, q, width=S + steps))(params, ctx, query)
    step = jax.jit(lambda p, ca, t, pos: vlm.llm_decode_step(
        p, pcfg, ca, t, pos))
    tok = jnp.zeros((batch, 1), jnp.int32)

    def run():
        c = cache
        for i in range(steps):
            logits, _, c = step(params, c, tok, jnp.int32(S + i))
        jax.block_until_ready(logits)
    return run


def _uav_stream(executor, n_uavs, frames, kind):
    """N UAVs x M frames; each UAV re-sends its frame under a standing
    query, so the cloud-side [ctx; query] prefix repeats per UAV."""
    rng = np.random.RandomState(7)
    tier = executor.lut.tiers[0]
    reqs = []
    for u in range(n_uavs):
        b = floodseg.make_batch(rng, 1,
                                "segment" if kind == "insight" else "any",
                                augment=False)
        img = jnp.asarray(b["images"])
        for f in range(frames):
            sid = u * frames + f
            if kind == "insight":
                pkt = executor.edge_insight(img, tier, sid, 0.0)
            else:
                pkt, _ = executor.edge_context(img, sid, 0.0)
            reqs.append((f"uav-{u}", pkt, b["query"]))
    return reqs


def paged_prefix_rows(executor, n_uavs=N_UAVS, frames=FRAMES_PER_UAV,
                      emit_row=None):
    """Paged shared-prefix mode: admission throughput on repeat-prefix
    per-UAV traffic, with and without the prefix store. Admission is the
    per-request serving cost that prefix reuse removes (prefill FLOPs +
    prefix KV pages); the decode steps are identical either way, so the
    measured loop is N ``InflightDecoder.submit`` calls (prefix
    lookup/prefill + page-table setup), not the shared decode."""
    from repro.core.paging import PagePool, pages_for
    from repro.engine.inflight import InflightDecoder
    from repro.network.energy import encoder_flops

    emit_row = emit_row or emit
    rows = []
    for kind in ("context", "insight"):
        reqs = _uav_stream(executor, n_uavs, frames, kind)
        intent = Intent.CONTEXT if kind == "context" else Intent.INSIGHT
        times, pools = {}, {}

        def admit_all(share):
            pool = PagePool(page_size=executor.page_size,
                            share_prefixes=share)
            dec = InflightDecoder(executor, slots=len(reqs), pool=pool)
            for i, (op, pkt, q) in enumerate(reqs):
                dec.submit(i, intent, pkt, q, lambda out: None,
                           operator_id=op)
            pools[share] = pool

        for share in (False, True):
            times[share] = time_best(lambda: admit_all(share))
        pool = pools[True]
        qlen = np.asarray(reqs[0][2]).shape[-1]
        prefix_len = executor.pcfg.clip_tokens + qlen
        n_prefix = pages_for(prefix_len, pool.page_size)
        # per run: one prefix prefill per UAV instead of one per frame
        hits = n_uavs * (frames - 1)
        saved_flops = hits * encoder_flops(executor.pcfg.llm, prefix_len)
        saved_bytes = hits * n_prefix * pool.page_bytes
        rows.append(emit_row(
            f"serving/paged_admit_{kind}", times[True] * 1e6,
            f"admit_req_s={len(reqs) / times[True]:.1f};"
            f"speedup_vs_no_prefix_reuse={times[False] / times[True]:.2f}x;"
            f"prefix_hit_rate={pool.prefix_hit_rate:.2f};"
            f"prefill_flops_saved={saved_flops:.3g};"
            f"kv_bytes_saved={saved_bytes};"
            f"uavs={n_uavs};frames_per_uav={frames}"))
    return rows


def spec_rows(executor, n_uavs=N_UAVS, frames=FRAMES_PER_UAV,
              draft_tokens=3, emit_row=None, spec_cfg=None,
              row_name="serving/spec_insight",
              note="draft_shares_target_geometry_on_cpu"):
    """Speculative decoding mode: repeat-prefix per-UAV Insight traffic
    served end to end (admission + decode) through the in-flight batch,
    with the draft model proposing ``draft_tokens`` per verify step vs.
    the non-speculative paged baseline. Tokens/step > 1 is the direct
    measure of serving-model passes saved; greedy output is token-exact
    either way (pinned in tests), so the speedup is free of quality
    cost. ``spec_cfg`` overrides the whole ``SpeculativeConfig`` (the
    nano-draft row passes the truncated-trunk config)."""
    from repro.core.paging import PagePool
    from repro.engine.inflight import InflightDecoder
    from repro.engine.speculative import SpeculativeConfig

    emit_row = emit_row or emit
    rows = []
    reqs = _uav_stream(executor, n_uavs, frames, "insight")
    times, stats = {}, {}

    def serve_all(spec):
        pool = PagePool(page_size=executor.page_size)
        dec = InflightDecoder(executor, slots=8, pool=pool, spec=spec)
        for i, (op, pkt, q) in enumerate(reqs):
            dec.submit(i, Intent.INSIGHT, pkt, q, lambda out: None,
                       operator_id=op)
        dec.drain()
        stats[spec is not None] = (
            dec.spec_stats, dec.n_steps,
            (dec.draft.n_steps, dec.draft.n_prefills)
            if dec.draft is not None else (0, 0),
            pool.stats())

    cfg = spec_cfg or SpeculativeConfig(draft_tokens=draft_tokens)
    for spec in (None, cfg):
        times[spec is not None] = time_best(lambda: serve_all(spec))
    st, n_steps, draft_steps, pool_stats = stats[True]
    base_steps = stats[False][1]
    # the CPU-container caveat: the default Context-stream draft shares
    # the target's lisa_mini geometry, so each draft step costs ~a
    # target step and wall-clock sits near parity; the hardware-relevant
    # signal is tokens/step (serving-model passes saved) — with the
    # lisa7b target the same draft is ~50x cheaper per step, and the
    # nano row runs a truncated trunk that is cheap on any host
    draft_layers = (cfg.draft_pcfg or executor.pcfg).llm.num_layers
    rows.append(emit_row(
        row_name, times[True] * 1e6,
        f"req_s={len(reqs) / times[True]:.1f};"
        f"speedup_vs_paged={times[False] / times[True]:.2f}x;"
        f"tokens_per_step={st.tokens_per_step:.2f};"
        f"acceptance_rate={st.acceptance_rate:.2f};"
        f"verify_steps={n_steps};baseline_decode_steps={base_steps};"
        f"draft_steps={draft_steps[0]};draft_prefills={draft_steps[1]};"
        f"draft_layers={draft_layers};"
        f"kv_pages_peak={pool_stats['kv_pages_peak']};"
        f"k={cfg.draft_tokens};uavs={n_uavs};frames_per_uav={frames};"
        f"note={note}"))
    return rows


def spec_nano_rows(executor, emit_row=None, **kw):
    """The truly-small draft row: lisa_nano (the target's truncated
    trunk — 1 of 4 LLM layers, shared embed/head) drafting against the
    full target. Draft steps are ~4x cheaper than the shared-geometry
    draft; acceptance depends on how often the early-exit argmax agrees
    with the full trunk's (weight-dependent — reported, not assumed),
    and greedy verify keeps the output token-exact regardless."""
    from repro.configs import lisa_nano
    from repro.engine.speculative import SpeculativeConfig

    cfg = SpeculativeConfig(
        draft_tokens=3, draft_pcfg=lisa_nano.CONFIG,
        draft_params=lisa_nano.nano_draft_params(executor.params))
    return spec_rows(executor, emit_row=emit_row, spec_cfg=cfg,
                     row_name="serving/spec_insight_nano",
                     note="nano_truncated_trunk_draft", **kw)


def sharded_rows(executor, n_uavs=N_UAVS, frames=FRAMES_PER_UAV,
                 draft_tokens=3, emit_row=None):
    """Sharded paged serving mode: the same repeat-prefix per-UAV
    Insight traffic served through a ``ShardedServingContext`` on the
    local mesh — params model-sharded, KV pool kv-heads over "model",
    page tables replicated — in plain paged and speculative-verify
    disciplines, pinned token-exact against the unsharded
    ``llm_generate`` path. Run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a real
    8-device host mesh (ci_fast does); wall-clock vs unsharded is
    *expected* < 1x there — eight fake devices share one CPU and pay
    real collectives — the row's signal is exactness + per-shard pool
    residency; on real multi-chip hardware the same partitioning is the
    scaling path."""
    from repro.core.paging import PagePool
    from repro.engine.inflight import InflightDecoder
    from repro.engine.speculative import SpeculativeConfig
    from repro.launch.mesh import make_local_mesh
    from repro.sharding.serving import ShardedServingContext

    emit_row = emit_row or emit
    n_dev = jax.device_count()
    model = max(m for m in (4, 2, 1) if n_dev % m == 0)
    mesh = make_local_mesh(model=model)
    ctx = ShardedServingContext(executor, mesh)
    reqs = _uav_stream(executor, n_uavs, frames, "insight")
    T = executor.max_new_tokens

    def serve_all(ex, spec, out):
        if hasattr(ex, "place_pool"):
            pool = PagePool(page_size=ex.page_size, placement=ex.place_pool,
                            shards=ex.model_shards)
        else:
            pool = PagePool(page_size=ex.page_size)
        dec = InflightDecoder(ex, slots=8, pool=pool, spec=spec)
        done = {}
        for i, (op, pkt, q) in enumerate(reqs):
            dec.submit(i, Intent.INSIGHT, pkt, q,
                       lambda o: done.setdefault(o["seq_id"], o),
                       operator_id=op)
        dec.drain()
        out["done"], out["pool"], out["dec"] = done, pool, dec

    base, shard, shsp = {}, {}, {}
    t_base = time_best(lambda: serve_all(executor, None, base))
    t_shard = time_best(lambda: serve_all(ctx, None, shard))
    spec_cfg = SpeculativeConfig(draft_tokens=draft_tokens)
    t_spec = time_best(lambda: serve_all(ctx, spec_cfg, shsp))

    # exactness pin: both sharded disciplines vs the unsharded one-shot
    # (the measured flag goes into the artifact; a mismatch also fails
    # the run loudly so CI can't record a stale green claim)
    exact_paged = exact_spec = True
    for i, (op, pkt, q) in enumerate(reqs):
        ref = executor.cloud_generate_batch([pkt], [q])[0][-1]
        exact_paged &= bool(np.array_equal(shard["done"][i]["tokens"], ref))
        exact_spec &= bool(np.array_equal(shsp["done"][i]["tokens"], ref))
    if not (exact_paged and exact_spec):
        raise AssertionError(
            f"sharded serving diverged from unsharded llm_generate "
            f"(paged exact={exact_paged}, spec exact={exact_spec})")

    n = len(reqs)
    st = shard["pool"].stats()
    rows = [emit_row(
        "serving/sharded_paged", t_shard * 1e6,
        f"req_s={n / t_shard:.1f};tok_s={n * T / t_shard:.1f};"
        f"vs_unsharded={t_base / t_shard:.2f}x;devices={n_dev};"
        f"model_shards={model};token_exact={int(exact_paged)};"
        f"kv_pool_bytes_per_shard={st['kv_pool_bytes_per_shard']};"
        f"uavs={n_uavs};frames_per_uav={frames};"
        f"note=host_platform_shards_share_one_cpu")]
    sst = shsp["dec"].spec_stats
    rows.append(emit_row(
        "serving/sharded_spec", t_spec * 1e6,
        f"req_s={n / t_spec:.1f};"
        f"tokens_per_step={sst.tokens_per_step:.2f};"
        f"acceptance_rate={sst.acceptance_rate:.2f};"
        f"model_shards={model};token_exact={int(exact_spec)};"
        f"k={draft_tokens};"
        f"uavs={n_uavs};frames_per_uav={frames}"))
    return rows


def _dump_trace_artifact(engine, tag):
    """Write the run's Perfetto trace under ``benchmarks/artifacts/`` and
    hard-fail the bench if the export violates the trace schema — an
    artifact nobody can open is worse than no artifact."""
    from repro.engine.observability import validate_chrome_trace

    path = engine.dump_trace(os.path.join(ART, f"trace_{tag}.json"))
    with open(path) as f:
        problems = validate_chrome_trace(json.load(f))
    if problems:
        raise AssertionError(
            f"trace artifact {path} failed validation: {problems[:3]}")
    return path


def chaos_rows(executor, n_uavs=CHAOS_UAVS, frames=CHAOS_FRAMES,
               emit_row=None, seed=0, artifact_tag="chaos"):
    """Chaos storm mode: a repeat-prefix fleet burst (one Insight frame
    per mission second, UAVs round-robin) served through the in-flight
    engine under a seeded fault schedule — an uplink blackout window
    that swallows two submissions, a ``cloud_decode_rows`` fault that
    kills the whole running batch mid-decode, and a latency spike that
    blows the final straggler frame past its SLO — with a
    ``RetryPolicy`` (backoff + tier downshift), per-request deadlines
    (``max_latency_s``), and ``debug_invariants`` page audits on.

    The row reports the delivered-under-SLO rate and the retry/
    downshift/cancel telemetry; the run *asserts* the fault-tolerance
    contract (every future resolves, at least one successful
    downshifted retry, at least one deadline cancellation, zero leaked
    KV pages) so CI cannot record a green row for a broken engine."""
    import dataclasses

    from repro.core.intent import DEFAULT_REQUIREMENTS
    from repro.engine import (ChannelTransport, FaultInjector,
                              FaultyExecutor, RetryPolicy)
    from repro.network.traces import constant_trace

    emit_row = emit_row or emit
    n = n_uavs * frames
    rng = np.random.RandomState(seed)
    fleet = []
    for u in range(n_uavs):
        b = floodseg.make_batch(rng, 1, "segment", augment=False)
        fleet.append((f"uav-{u}", jnp.asarray(b["images"]), b["query"]))
    reqs = dict(DEFAULT_REQUIREMENTS)
    reqs[Intent.INSIGHT] = dataclasses.replace(
        reqs[Intent.INSIGHT], max_latency_s=CHAOS_SLO_S)
    out = {}

    # the straggler flies long after the burst (and its retry tail) has
    # drained, so the spiked delivery's watermark jump can only sweep
    # the straggler itself, not still-decoding burst requests
    t_straggler = float(n + 30)

    def serve():
        # fresh faults + engine per rep: the schedule (call indices, RNG
        # stream, mission clock) must replay identically every run
        # a real (finite-bandwidth) channel under the fault layer: the
        # loopback transport's instant delivery stamped every burst
        # request's first token at its own submission time, collapsing
        # the TTFT histogram's p50 to the underflow bucket (the old
        # ttft_p50_s=0.0 anomaly in BENCH_serving.json)
        faults = FaultInjector(
            ChannelTransport.from_trace(
                constant_trace(CHAOS_BW_MBPS, duration_s=300)),
            seed=seed, blackouts=[CHAOS_BLACKOUT],
            spikes=[(t_straggler, t_straggler + 1.0, CHAOS_SPIKE_EXTRA_S)])
        chaotic = FaultyExecutor(executor,
                                 fail_at={"cloud_decode_rows": [2]})
        engine = make_engine(
            chaotic, transport=faults, batching="inflight", max_batch=8,
            retry=RetryPolicy(max_attempts=3, backoff_base_s=0.25),
            debug_invariants=True, trace=True,
            flight_dir=os.path.join(ART, f"flight_{artifact_tag}"))
        sessions = {op: engine.session(op, requirements=dict(reqs))
                    for op, _, _ in fleet}
        futs = []
        for i in range(n - 1):           # the storm burst
            op, img, q = fleet[i % n_uavs]
            futs.append(sessions[op].submit(
                prompt="segment the stranded person", images=img, query=q,
                time_s=float(i), intent=Intent.INSIGHT))
        engine.drain()
        # the straggler: its delivery is spiked past the SLO, so the
        # deadline sweep must cancel it (slot + pages released) instead
        # of letting its future hang
        op, img, q = fleet[(n - 1) % n_uavs]
        futs.append(sessions[op].submit(
            prompt="segment the stranded person", images=img, query=q,
            time_s=t_straggler, intent=Intent.INSIGHT))
        engine.drain()
        for s in sessions.values():
            s.close()
        out["futs"], out["engine"] = futs, engine

    chaos_s = time_best(serve)
    futs, engine = out["futs"], out["engine"]
    resps = [f.result() for f in futs]   # must all resolve, never hang
    st = engine.stats
    leaks = engine.kv_pool.pages_in_use
    engine.kv_pool.check_invariants()
    served_retried = [r for r in resps
                      if r.failure is None and r.attempts > 1]
    if not served_retried or st["downshifts"] < 1:
        raise AssertionError(
            f"chaos storm produced no successful downshifted retry "
            f"(retried-and-served={len(served_retried)}, "
            f"downshifts={st['downshifts']})")
    if st["deadline_cancelled"] < 1:
        raise AssertionError("spiked straggler was not deadline-cancelled")
    if leaks != 0:
        raise AssertionError(f"chaos run leaked {leaks} KV pages")
    # observability contract: the run leaves a valid Perfetto trace and
    # the injected faults left a flight-recorder dump on disk
    _dump_trace_artifact(engine, artifact_tag)
    if st["flight_dumps"] < 1 or engine.flight.last_dump is None:
        raise AssertionError(
            "chaos faults produced no flight-recorder autodump")
    slo = sum(1 for r in resps if r.failure is None) / len(resps)
    return [emit_row(
        "serving/chaos", chaos_s * 1e6,
        f"req_s={n / chaos_s:.1f};delivered_under_slo={slo:.2f};"
        f"ttft_p50_s={st['ttft_throughput_p50_s']:.3f};"
        f"ttft_p99_s={st['ttft_throughput_p99_s']:.3f};"
        f"retries={int(st['retries'])};downshifts={int(st['downshifts'])};"
        f"deadline_cancelled={int(st['deadline_cancelled'])};"
        f"inflight_cancelled={int(st['inflight_cancelled'])};"
        f"stage_faults={int(st['stage_faults'])};"
        f"blackouts_terminal={int(st['blackouts'])};"
        f"cloud_errors_terminal={int(st['cloud_errors'])};"
        f"flight_dumps={int(st['flight_dumps'])};"
        f"page_leaks={leaks};slo_s={CHAOS_SLO_S};seed={seed};"
        f"uavs={n_uavs};frames_per_uav={frames}")]


def profiled_rows(executor, n_uavs=2, frames=3, emit_row=None,
                  artifact_tag="profiled"):
    """Device-level observability mode (docs/observability.md
    §Profiler): the repeat-prefix fleet burst served through the
    in-flight engine bare and again with the ``StageProfiler`` on.
    Reports the profiler's measured overhead against its <5% budget,
    the compile observatory's event count, and the cost/energy ledger
    totals. The run *asserts* the observability contract — profiling
    changes no served token, the Perfetto artifact gains a validating
    device track, and every served response carries a positive FLOPs/
    energy ledger — so CI cannot record a green row for a profiler
    that perturbs or under-reports the engine."""
    import time as _time

    from repro.engine.observability import DEVICE_TRACK_PID

    emit_row = emit_row or emit
    reqs = _uav_stream(executor, n_uavs, frames, "insight")
    out = {}

    def serve(profile):
        engine = make_engine(
            executor, batching="inflight", max_batch=8, trace=profile,
            profile=profile,
            wallclock=_time.perf_counter if profile else None)
        futs = [engine.submit_packet(pkt, q, Intent.INSIGHT,
                                     time_s=float(i))
                for i, (_, pkt, q) in enumerate(reqs)]
        engine.drain()
        out[profile] = (engine, [f.result() for f in futs])

    t_bare = time_best(lambda: serve(False))
    t_prof = time_best(lambda: serve(True))
    engine, resps = out[True]
    bare_resps = out[False][1]
    for a, b in zip(bare_resps, resps):
        if not np.array_equal(a.tokens, b.tokens):
            raise AssertionError(
                f"profiling changed request {b.request_id}'s tokens")
    for r in resps:
        if r.failure is None and not (r.cloud_flops and r.cloud_flops > 0
                                      and r.cloud_energy_j
                                      and r.cloud_energy_j > 0):
            raise AssertionError(
                f"served request {r.request_id} has an empty cost "
                f"ledger (flops={r.cloud_flops})")
    path = _dump_trace_artifact(engine, artifact_tag)
    with open(path) as f:
        doc = json.load(f)
    dev = [e for e in doc["traceEvents"]
           if e.get("pid") == DEVICE_TRACK_PID and e.get("ph") == "X"]
    if not dev:
        raise AssertionError(
            f"profiled trace artifact {path} has no device track "
            f"(pid {DEVICE_TRACK_PID})")
    st = engine.stats
    if st["profiled_stage_calls"] <= 0:
        raise AssertionError("profiler recorded no stage calls")
    return [emit_row(
        "serving/profiled", t_prof * 1e6,
        f"req_s={len(reqs) / t_prof:.1f};"
        f"profile_overhead={t_prof / t_bare:.3f}x;"
        f"profiled_stage_calls={int(st['profiled_stage_calls'])};"
        f"compile_events={int(st['compile_events'])};"
        f"ledger_flops_total={st['ledger_flops_total']:.3g};"
        f"ledger_energy_j_total={st['ledger_energy_j_total']:.3g};"
        f"decode_roofline_frac={st['decode_roofline_frac']:.3g};"
        f"device_events={len(dev)};"
        f"uavs={n_uavs};frames_per_uav={frames}")]


def _storm_ops(duration_s):
    """The storm's operator roster: (op, kind, priority, t_start, t_end,
    mean-gap scale). Two recon streams are the latency class, the
    command post is a priority-1 Insight operator, three bulk mappers
    are the throughput class — ``bulk-0`` spams at ~3x the others and
    ``bulk-2`` churns out at 40% (its session closes); ``late-0`` joins
    at 60% (operator churn in both directions)."""
    return [
        ("recon-0", "context", 0, 0.0, duration_s, 0.55),
        ("recon-1", "context", 0, 0.0, duration_s, 0.55),
        ("cmdpost", "insight", 1, 0.0, duration_s, 0.6),
        ("bulk-0", "insight", 0, 0.0, duration_s, 0.3),
        ("bulk-1", "insight", 0, 0.0, duration_s, 0.55),
        ("bulk-2", "insight", 0, 0.0, 0.55 * duration_s, 0.45),
        ("late-0", "insight", 0, 0.45 * duration_s, duration_s, 0.45),
    ]


def _storm_trace(executor, duration_s, seed):
    """Seeded storm trace: one packet per operator (repeat-prefix, like
    a standing query over a hovering UAV's feed) plus a heavy-tailed
    (Pareto inter-arrival) submission schedule, merged in arrival
    order. Returns (ops, packets, events)."""
    ops = _storm_ops(duration_s)
    rng = np.random.RandomState(seed)
    tier = executor.lut.tiers[0]
    packets = {}
    events = []
    for i, (op, kind, _prio, t0, t1, scale) in enumerate(ops):
        b = floodseg.make_batch(
            rng, 1, "segment" if kind == "insight" else "any",
            augment=False)
        img = jnp.asarray(b["images"])
        if kind == "insight":
            pkt = executor.edge_insight(img, tier, i, 0.0)
        else:
            pkt, _ = executor.edge_context(img, i, 0.0)
        packets[op] = (pkt, b["query"])
        t = t0
        while True:
            t += scale * (0.4 + rng.pareto(1.8))
            if t >= t1:
                break
            events.append((round(t, 3), op))
    events.sort()
    return ops, packets, events


def fleet_storm_rows(executor, duration_s=STORM_DURATION_S, emit_row=None,
                     seed=STORM_SEED, artifact_tag="fleet_storm"):
    """Fleet storm mode: the multi-tenant scheduling contract, measured.

    The same seeded trace — 7 operators, both QoS classes, Pareto
    bursts, churn, a spammer, and a blackout window mid-storm — is
    served twice through the in-flight engine: once under the default
    ``FifoScheduler`` and once under a ``QoSScheduler`` (weighted-fair
    classes, strict priority, per-operator rate limit on the spammer,
    page-rollback preemption). Mission time advances with the trace and
    decode pumps are metered per mission second, so per-class latency
    (``t_finished - t_submit``) measures queueing on the mission clock,
    not wall-clock.

    The run *asserts* the scheduling contract on the QoS pass — Context
    p99 strictly better than FIFO on the same trace, Jain's index over
    per-operator served counts >= 0.9, at least one preemption with a
    preempted-then-resumed request finishing token-exact vs the
    uninterrupted ``cloud_generate_batch`` path, at least one rate-limit
    rejection, and zero leaked KV pages — so CI cannot record a green
    row for a scheduler that starves, leaks, or corrupts decodes."""
    import dataclasses
    import time as _time

    from repro.core.intent import DEFAULT_REQUIREMENTS
    from repro.engine import (FaultInjector, FifoScheduler,
                              LoopbackTransport, QoSScheduler, RetryPolicy,
                              jain_index, qos_class)

    emit_row = emit_row or emit
    ops, packets, events = _storm_trace(executor, duration_s, seed)
    blackout = (0.7 * duration_s, 0.7 * duration_s + 1.0)
    close_t = 0.55 * duration_s          # bulk-2's churn-out time
    # no per-request SLO: the storm measures queueing latency, and a
    # deadline sweep would censor exactly the tail the rows report
    reqs = {i: dataclasses.replace(r, max_latency_s=None)
            for i, r in DEFAULT_REQUIREMENTS.items()}
    kinds = {op: kind for op, kind, *_ in ops}
    prios = {op: prio for op, _, prio, *_ in ops}

    def serve(make_sched):
        faults = FaultInjector(LoopbackTransport(), seed=seed,
                               blackouts=[blackout])
        engine = make_engine(
            executor, transport=faults, batching="inflight",
            max_batch=STORM_SLOTS, scheduler=make_sched(),
            retry=RetryPolicy(max_attempts=3, backoff_base_s=0.25),
            debug_invariants=True, trace=True)
        sessions, futs, closed = {}, [], False
        t_pump = 0.0
        for t, op in events:
            while t_pump + STORM_PUMP_DT <= t:   # metered decode service
                t_pump += STORM_PUMP_DT
                engine.pump()
            if not closed and t >= close_t and "bulk-2" in sessions:
                sessions["bulk-2"].close()       # churn: operator leaves
                closed = True
            sess = sessions.get(op)
            if sess is None:                     # churn: operator joins
                sess = sessions[op] = engine.session(
                    op, requirements=dict(reqs), priority=prios[op])
            pkt, q = packets[op]
            futs.append(engine.submit_packet(
                pkt, q,
                Intent.CONTEXT if kinds[op] == "context"
                else Intent.INSIGHT, time_s=t, session=sess))
        engine.drain()
        resps = [f.result() for f in futs]       # every future resolves
        for s in sessions.values():
            s.close()
        return engine, resps

    out = {}
    for name, make_sched in (
            ("fifo", FifoScheduler),
            ("qos", lambda: QoSScheduler(
                rate_overrides={"bulk-0": STORM_SPAM_RATE},
                # patience below the typical slot turnover (~0.2 mission
                # seconds at this load), so urgent latency-class arrivals
                # preempt instead of waiting out a full bulk decode
                max_queue=64, latency_patience_s=0.15, max_resumes=2))):
        t0 = _time.perf_counter()
        engine, resps = serve(make_sched)
        out[name] = (_time.perf_counter() - t0, engine, resps)

    def lat_percentiles(resps, cls):
        xs = [r.t_finished - r.t_submit for r in resps
              if r.failure is None and qos_class(r.intent) == cls]
        if not xs:
            return 0.0, 0.0
        return (float(np.percentile(xs, 50)), float(np.percentile(xs, 99)))

    # the scheduling contract, asserted on the QoS pass
    _, eng_q, resps_q = out["qos"]
    _, eng_f, resps_f = out["fifo"]
    st_q, st_f = eng_q.stats, eng_f.stats
    ctx_fifo = lat_percentiles(resps_f, "latency")
    ctx_qos = lat_percentiles(resps_q, "latency")
    if not ctx_qos[1] < ctx_fifo[1]:
        raise AssertionError(
            f"QoS did not beat FIFO on Context p99 "
            f"({ctx_qos[1]:.2f}s vs {ctx_fifo[1]:.2f}s)")
    jain = jain_index(eng_q.served_by_operator.values())
    if jain < 0.9:
        raise AssertionError(
            f"per-operator service too uneven (jain={jain:.3f}, "
            f"served={eng_q.served_by_operator})")
    if st_q["sched_preemptions"] < 1:
        raise AssertionError("storm produced no preemption")
    if st_q["sched_rejected_rate_limit"] < 1:
        raise AssertionError("spammer was never rate-limited")
    resumed = [r for r in resps_q
               if r.failure is None and r.preemptions > 0
               and r.intent is Intent.INSIGHT]
    if not resumed:
        raise AssertionError("no preempted-then-resumed request served")
    for r in resumed:                        # token-exactness guarantee
        pkt, q = packets[r.operator_id]
        ref = executor.cloud_generate_batch([pkt], [q])[0][-1]
        if not np.array_equal(r.tokens, ref):
            raise AssertionError(
                f"resumed request {r.request_id} diverged from the "
                f"uninterrupted decode (op={r.operator_id})")
    for eng in (eng_q, eng_f):
        if eng.kv_pool.pages_in_use != 0:
            raise AssertionError(
                f"storm leaked {eng.kv_pool.pages_in_use} KV pages")
        eng.kv_pool.check_invariants()
    _dump_trace_artifact(eng_q, artifact_tag)

    rows = []
    for name, st, ctx, resps, eng in (
            ("fifo", st_f, ctx_fifo, resps_f, eng_f),
            ("qos", st_q, ctx_qos, resps_q, eng_q)):
        thr = lat_percentiles(resps, "throughput")
        n_served = sum(1 for r in resps if r.failure is None)
        rows.append(emit_row(
            f"serving/fleet_storm_{name}", out[name][0] * 1e6,
            f"served={n_served};offered={len(resps)};"
            f"ctx_p50_s={ctx[0]:.2f};ctx_p99_s={ctx[1]:.2f};"
            f"thr_p50_s={thr[0]:.2f};thr_p99_s={thr[1]:.2f};"
            f"ttft_latency_p50_s={st['ttft_latency_p50_s']:.3f};"
            f"ttft_latency_p99_s={st['ttft_latency_p99_s']:.3f};"
            f"ttft_throughput_p50_s={st['ttft_throughput_p50_s']:.3f};"
            f"ttft_throughput_p99_s={st['ttft_throughput_p99_s']:.3f};"
            f"jain={jain_index(eng.served_by_operator.values()):.3f};"
            f"preemptions={int(st['sched_preemptions'])};"
            f"resumed_served={int(st['sched_resumed_served'])};"
            f"tokens_replayed={int(st['sched_tokens_replayed'])};"
            f"rejected_rate_limit={int(st['sched_rejected_rate_limit'])};"
            f"rejected_queue_full={int(st['sched_rejected_queue_full'])};"
            f"wait_latency_p95_s={st['sched_wait_latency_p95_s']:.2f};"
            f"wait_throughput_p95_s="
            f"{st['sched_wait_throughput_p95_s']:.2f};"
            f"page_leaks=0;ops=7;duration_s={duration_s};seed={seed}"))
    return rows


def run(log=print):
    rows = []
    params, bns, lut = init_serving_system(PCFG)
    # XLA KV-decode engine (the CPU-appropriate config; flash-decode
    # interpret mode is measured separately below)
    executor = make_executor(PCFG, params, bns, lut,
                             max_new_tokens=ANSWER_TOKENS, flash_decode=False)
    flash_exec = make_executor(PCFG, params, bns, lut,
                               max_new_tokens=ANSWER_TOKENS,
                               flash_decode=True)
    reqs = _requests(executor, N_REQUESTS)

    pcfg = executor.pcfg
    jit_reason = jax.jit(lambda p, c, q: vlm.llm_reason(p, pcfg, c, q))
    base_s = time_best(lambda: _baseline_serve(executor, reqs, ANSWER_TOKENS,
                                               jit_reason))
    base_rps = N_REQUESTS / base_s
    rows.append(emit("serving/baseline_full_forward", base_s * 1e6,
                     f"req_s={base_rps:.1f};"
                     f"tok_s={N_REQUESTS * ANSWER_TOKENS / base_s:.1f};"
                     f"T={ANSWER_TOKENS};N={N_REQUESTS}"))

    for b in BATCHES:
        eng_s = time_best(lambda: _engine_serve(executor, reqs, b,
                                                "generate"))
        rps = N_REQUESTS / eng_s
        rows.append(emit(
            f"serving/engine_b{b}", eng_s * 1e6,
            f"req_s={rps:.1f};speedup_vs_full_forward={rps / base_rps:.2f}x;"
            f"tok_s={N_REQUESTS * ANSWER_TOKENS / eng_s:.1f}"))

    for b in (8, 16):
        eng_s = time_best(lambda: _engine_serve(executor, reqs, b,
                                                "inflight"))
        rps = N_REQUESTS / eng_s
        rows.append(emit(
            f"serving/inflight_b{b}", eng_s * 1e6,
            f"req_s={rps:.1f};speedup_vs_full_forward={rps / base_rps:.2f}x;"
            "note=token_level_continuous_batching"))

    for b in (8, 16):
        eng_s = time_best(lambda: _engine_serve(flash_exec, reqs, b,
                                                "generate"))
        rps = N_REQUESTS / eng_s
        rows.append(emit(
            f"serving/engine_flash_b{b}", eng_s * 1e6,
            f"req_s={rps:.1f};speedup_vs_full_forward={rps / base_rps:.2f}x;"
            "note=pallas_interpret_on_cpu"))

    # paged shared-prefix KV cache: repeat-prefix per-UAV admission
    rows += paged_prefix_rows(executor)

    # speculative decoding off the Context-stream model (its own
    # executor: the longer-answer regime speculation targets)
    spec_exec = make_executor(PCFG, params, bns, lut,
                              max_new_tokens=SPEC_ANSWER_TOKENS,
                              flash_decode=False)
    rows += spec_rows(spec_exec)
    rows += spec_nano_rows(spec_exec)

    # sharded paged serving (degenerates to 1 shard on a 1-device host;
    # ci_fast forces an 8-device host platform for the real mesh)
    rows += sharded_rows(executor)

    # chaos storm: the fault-tolerance contract under a seeded schedule
    rows += chaos_rows(executor)

    # fleet storm: the multi-tenant scheduling contract (FIFO vs QoS on
    # the same seeded heavy-tailed trace); its own executor — longer
    # answers keep the decode slots contended
    rows += fleet_storm_rows(make_executor(
        PCFG, params, bns, lut, max_new_tokens=STORM_TOKENS,
        flash_decode=False))

    steps = 32
    for b in BATCHES:
        dec_s = time_best(_decode_loop(executor, b, steps))
        rows.append(emit(
            f"serving/decode_b{b}", dec_s * 1e6,
            f"decode_tok_s={b * steps / dec_s:.1f};steps={steps}"))
    for b in (8, 16):
        dec_s = time_best(_decode_loop(flash_exec, b, steps))
        rows.append(emit(
            f"serving/decode_flash_b{b}", dec_s * 1e6,
            f"decode_tok_s={b * steps / dec_s:.1f};steps={steps};"
            "note=pallas_interpret_on_cpu"))
    write_bench_json(rows)
    return rows


def _smoke_executor(max_new_tokens=ANSWER_TOKENS):
    params, bns, lut = init_serving_system(PCFG)
    return make_executor(PCFG, params, bns, lut,
                         max_new_tokens=max_new_tokens, flash_decode=False)


def _smoke_emit(name, us, derived):
    """Smoke rows carry their own names in the JSON artifact so the
    reduced-size numbers never overwrite the full-run perf records."""
    return emit(name + "_smoke", us, derived)


def run_paged_smoke():
    """CI smoke: only the paged shared-prefix mode, at a reduced size
    (2 UAVs x 4 frames, XLA decode path) — exercises prefix store,
    allocator, and page-table admission end to end in seconds."""
    rows = paged_prefix_rows(_smoke_executor(), n_uavs=2, frames=4,
                             emit_row=_smoke_emit)
    write_bench_json(rows)
    return rows


def run_spec():
    """Full speculative mode on its own (the rest of the serving suite
    untouched): Context-stream drafts + paged multi-token verify vs the
    non-speculative paged baseline, plus the truly-small lisa_nano
    truncated-trunk draft row."""
    executor = _smoke_executor(SPEC_ANSWER_TOKENS)
    rows = spec_rows(executor)
    rows += spec_nano_rows(executor)
    write_bench_json(rows)
    return rows


def run_sharded():
    """Sharded paged serving mode on its own: tensor-parallel paged
    decode + speculative verify on the local mesh, token-exact vs the
    unsharded path. Force a multi-device host platform first:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    rows = sharded_rows(_smoke_executor())
    write_bench_json(rows)
    return rows


def run_sharded_smoke():
    """CI smoke: the sharded mode at a reduced size (2 UAVs x 3 frames)
    — mesh construction, sharded param/pool placement, sharded decode +
    verify exactness, and the per-shard residency stats in minutes."""
    rows = sharded_rows(_smoke_executor(), n_uavs=2, frames=3,
                        emit_row=_smoke_emit)
    write_bench_json(rows)
    return rows


def run_chaos():
    """Chaos storm mode on its own: the full-size seeded fault schedule
    (3 UAVs x 8 frames) against the in-flight engine with retries,
    downshifts, deadlines, and page audits — asserting the
    fault-tolerance contract, not just timing it."""
    rows = chaos_rows(_smoke_executor())
    write_bench_json(rows)
    return rows


def run_chaos_smoke():
    """CI smoke: the chaos storm at a reduced size (2 UAVs x 3 frames)
    — blackout retry-with-downshift, batch-wide stage-fault recovery,
    and the spiked straggler's deadline cancellation in seconds, with
    the same hard asserts (>=1 successful downshifted retry, >=1
    deadline cancel, zero leaked pages) as the full run."""
    rows = chaos_rows(_smoke_executor(), n_uavs=2, frames=3,
                      emit_row=_smoke_emit, artifact_tag="chaos_smoke")
    write_bench_json(rows)
    return rows


def run_profiled_smoke():
    """CI smoke: the device-level observability mode at a reduced size
    (2 UAVs x 3 frames) — StageProfiler wrap, compile observatory,
    cost/energy ledger, and the Perfetto device track end to end in
    seconds, with the same hard asserts (token-exact under profiling,
    validating device track, positive per-request ledger) as the full
    run."""
    rows = profiled_rows(_smoke_executor(), n_uavs=2, frames=3,
                         emit_row=_smoke_emit,
                         artifact_tag="profiled_smoke")
    write_bench_json(rows)
    return rows


def run_fleet_storm():
    """Fleet storm mode on its own: the full-size multi-tenant trace
    (7 operators, 40 mission seconds) under FIFO vs QoS scheduling,
    asserting the scheduling contract (Context p99 win, Jain >= 0.9,
    token-exact preemption resume, rate-limit shed, zero page leaks)."""
    rows = fleet_storm_rows(_smoke_executor(STORM_TOKENS))
    write_bench_json(rows)
    return rows


def run_fleet_storm_smoke():
    """CI smoke: the fleet storm at a reduced size (16 mission seconds,
    same 7-operator roster) — weighted-fair admission, strict priority,
    rate limiting, and page-rollback preemption end to end in minutes,
    with the same hard asserts as the full run."""
    rows = fleet_storm_rows(_smoke_executor(STORM_TOKENS),
                            duration_s=16.0, emit_row=_smoke_emit,
                            artifact_tag="fleet_storm_smoke")
    write_bench_json(rows)
    return rows


def run_spec_smoke():
    """CI smoke: speculative decoding end to end at a reduced size
    (2 UAVs x 3 frames) — draft model, verify kernel path, greedy
    acceptance, rollback, and the tokens/step accounting in seconds."""
    rows = spec_rows(_smoke_executor(SPEC_ANSWER_TOKENS), n_uavs=2,
                     frames=3, emit_row=_smoke_emit)
    write_bench_json(rows)
    return rows


if __name__ == "__main__":
    import sys
    if "--paged-smoke" in sys.argv:
        run_paged_smoke()
    elif "--spec-smoke" in sys.argv:
        run_spec_smoke()
    elif "--spec" in sys.argv:
        run_spec()
    elif "--sharded-smoke" in sys.argv:
        run_sharded_smoke()
    elif "--sharded" in sys.argv:
        run_sharded()
    elif "--profiled-smoke" in sys.argv:
        run_profiled_smoke()
    elif "--chaos-smoke" in sys.argv:
        run_chaos_smoke()
    elif "--chaos" in sys.argv:
        run_chaos()
    elif "--fleet-storm-smoke" in sys.argv:
        run_fleet_storm_smoke()
    elif "--fleet-storm" in sys.argv:
        run_fleet_storm()
    else:
        run()
