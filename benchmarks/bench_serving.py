"""Benchmark: the engine's batched serving paths vs the seed per-request
path.

Serves N Insight requests that each need a T-token answer, three ways:

  baseline — the seed serving loop: one jitted call per request at batch
             1, and every answer token re-runs the full [ctx; query;
             generated] forward (no KV cache);
  engine   — ``AveryEngine`` with closed tier-bucketed microbatches
             through ``cloud_generate_batch`` (one batched prefill +
             decode steps against the KV cache) at batch {1,4,8,16};
  inflight — ``AveryEngine`` with token-level in-flight batching: each
             request prefills into a slot of the running decode batch
             and rides the remaining steps (no batch-close barrier).

The engine rows run the XLA KV-decode path; ``engine_flash_b*`` rows
rerun batch 8/16 with the flash-decode Pallas kernel, which executes in
*interpret mode* on this CPU container (grid points run sequentially, so
it is slower here; on real TPU the kernel is the roofline-floor path).
Also reports pure decode throughput (tokens/s) per batch size from timed
``llm_decode_step`` loops for both paths. Weights are freshly initialised
(cached trained checkpoints are used when present) — serving throughput
depends only on the geometry, not on the weight values.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, init_serving_system, make_engine, \
    make_executor, time_best, write_bench_json
from repro.configs.lisa_mini import CONFIG as PCFG
from repro.core import vlm
from repro.core.intent import Intent
from repro.data import floodseg

N_REQUESTS = 32
ANSWER_TOKENS = 4
BATCHES = (1, 4, 8, 16)
# repeat-prefix per-UAV workload (paged shared-prefix KV cache mode)
N_UAVS = 4
FRAMES_PER_UAV = 6
# speculative mode: longer answers amortise the per-admission draft
# prefill over more verify rounds (the Insight-path regime spec targets)
SPEC_ANSWER_TOKENS = 8


def _requests(executor, n):
    rng = np.random.RandomState(0)
    tier = executor.lut.tiers[0]
    reqs = []
    for i in range(n):
        b = floodseg.make_batch(rng, 1, "segment", augment=False)
        pkt = executor.edge_insight(jnp.asarray(b["images"]), tier, i, 0.0)
        reqs.append((pkt, b["query"]))
    return reqs


def _baseline_serve(executor, reqs, max_new, jit_reason):
    """Seed path generalised to T tokens: per request, per token, a full
    no-cache forward over the grown sequence at batch 1. ``jit_reason``
    must persist across calls so the warm-up rep absorbs its compiles —
    the engine side reuses the executor's compile cache the same way."""
    params = executor.params
    for pkt, q in reqs:
        executor.cloud_insight(pkt, q)              # mask + first token
        query = jnp.asarray(q)
        ctx = jnp.asarray(pkt.content["clip"])
        for _ in range(max_new - 1):
            logits, _ = jit_reason(params, ctx, query)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            query = jnp.concatenate([query, nxt], axis=1)
        jax.block_until_ready(query)


def _engine_serve(executor, reqs, max_batch, batching):
    engine = make_engine(executor, max_batch=max_batch, batching=batching)
    for pkt, q in reqs:
        engine.submit_packet(pkt, q, Intent.INSIGHT)
    return engine.drain()


def _decode_loop(executor, batch, steps):
    """Pure KV-decode hot loop at batch B (cache pre-filled); runs the
    flash kernel or the XLA path per the executor's ``flash_decode``."""
    pcfg = executor._gen_pcfg
    params = executor.params
    ctx = jnp.zeros((batch, pcfg.clip_tokens, pcfg.llm.d_model),
                    pcfg.llm.adtype)
    query = jnp.zeros((batch, 8), jnp.int32)
    S = pcfg.clip_tokens + 8
    _, _, cache = jax.jit(lambda p, c, q: vlm.llm_prefill(
        p, pcfg, c, q, width=S + steps))(params, ctx, query)
    step = jax.jit(lambda p, ca, t, pos: vlm.llm_decode_step(
        p, pcfg, ca, t, pos))
    tok = jnp.zeros((batch, 1), jnp.int32)

    def run():
        c = cache
        for i in range(steps):
            logits, _, c = step(params, c, tok, jnp.int32(S + i))
        jax.block_until_ready(logits)
    return run


def _uav_stream(executor, n_uavs, frames, kind):
    """N UAVs x M frames; each UAV re-sends its frame under a standing
    query, so the cloud-side [ctx; query] prefix repeats per UAV."""
    rng = np.random.RandomState(7)
    tier = executor.lut.tiers[0]
    reqs = []
    for u in range(n_uavs):
        b = floodseg.make_batch(rng, 1,
                                "segment" if kind == "insight" else "any",
                                augment=False)
        img = jnp.asarray(b["images"])
        for f in range(frames):
            sid = u * frames + f
            if kind == "insight":
                pkt = executor.edge_insight(img, tier, sid, 0.0)
            else:
                pkt, _ = executor.edge_context(img, sid, 0.0)
            reqs.append((f"uav-{u}", pkt, b["query"]))
    return reqs


def paged_prefix_rows(executor, n_uavs=N_UAVS, frames=FRAMES_PER_UAV,
                      emit_row=None):
    """Paged shared-prefix mode: admission throughput on repeat-prefix
    per-UAV traffic, with and without the prefix store. Admission is the
    per-request serving cost that prefix reuse removes (prefill FLOPs +
    prefix KV pages); the decode steps are identical either way, so the
    measured loop is N ``InflightDecoder.submit`` calls (prefix
    lookup/prefill + page-table setup), not the shared decode."""
    from repro.core.paging import PagePool, pages_for
    from repro.engine.inflight import InflightDecoder
    from repro.network.energy import encoder_flops

    emit_row = emit_row or emit
    rows = []
    for kind in ("context", "insight"):
        reqs = _uav_stream(executor, n_uavs, frames, kind)
        intent = Intent.CONTEXT if kind == "context" else Intent.INSIGHT
        times, pools = {}, {}

        def admit_all(share):
            pool = PagePool(page_size=executor.page_size,
                            share_prefixes=share)
            dec = InflightDecoder(executor, slots=len(reqs), pool=pool)
            for i, (op, pkt, q) in enumerate(reqs):
                dec.submit(i, intent, pkt, q, lambda out: None,
                           operator_id=op)
            pools[share] = pool

        for share in (False, True):
            times[share] = time_best(lambda: admit_all(share))
        pool = pools[True]
        qlen = np.asarray(reqs[0][2]).shape[-1]
        prefix_len = executor.pcfg.clip_tokens + qlen
        n_prefix = pages_for(prefix_len, pool.page_size)
        # per run: one prefix prefill per UAV instead of one per frame
        hits = n_uavs * (frames - 1)
        saved_flops = hits * encoder_flops(executor.pcfg.llm, prefix_len)
        saved_bytes = hits * n_prefix * pool.page_bytes
        rows.append(emit_row(
            f"serving/paged_admit_{kind}", times[True] * 1e6,
            f"admit_req_s={len(reqs) / times[True]:.1f};"
            f"speedup_vs_no_prefix_reuse={times[False] / times[True]:.2f}x;"
            f"prefix_hit_rate={pool.prefix_hit_rate:.2f};"
            f"prefill_flops_saved={saved_flops:.3g};"
            f"kv_bytes_saved={saved_bytes};"
            f"uavs={n_uavs};frames_per_uav={frames}"))
    return rows


def spec_rows(executor, n_uavs=N_UAVS, frames=FRAMES_PER_UAV,
              draft_tokens=3, emit_row=None):
    """Speculative decoding mode: repeat-prefix per-UAV Insight traffic
    served end to end (admission + decode) through the in-flight batch,
    with the Context-stream model drafting ``draft_tokens`` per verify
    step vs. the non-speculative paged baseline. Tokens/step > 1 is the
    direct measure of serving-model passes saved; greedy output is
    token-exact either way (pinned in tests), so the speedup is free of
    quality cost."""
    from repro.core.paging import PagePool
    from repro.engine.inflight import InflightDecoder
    from repro.engine.speculative import SpeculativeConfig

    emit_row = emit_row or emit
    rows = []
    reqs = _uav_stream(executor, n_uavs, frames, "insight")
    times, stats = {}, {}

    def serve_all(spec):
        pool = PagePool(page_size=executor.page_size)
        dec = InflightDecoder(executor, slots=8, pool=pool, spec=spec)
        for i, (op, pkt, q) in enumerate(reqs):
            dec.submit(i, Intent.INSIGHT, pkt, q, lambda out: None,
                       operator_id=op)
        dec.drain()
        stats[spec is not None] = (
            dec.spec_stats, dec.n_steps,
            (dec.draft.n_steps, dec.draft.n_prefills)
            if dec.draft is not None else (0, 0),
            pool.stats())

    cfg = SpeculativeConfig(draft_tokens=draft_tokens)
    for spec in (None, cfg):
        times[spec is not None] = time_best(lambda: serve_all(spec))
    st, n_steps, draft_steps, pool_stats = stats[True]
    base_steps = stats[False][1]
    # the CPU-container caveat: the Context-stream draft here shares the
    # target's lisa_mini geometry, so each draft step costs ~a target
    # step and wall-clock sits near parity; the hardware-relevant signal
    # is tokens/step (serving-model passes saved) — with the lisa7b
    # target the same draft is ~50x cheaper per step
    rows.append(emit_row(
        "serving/spec_insight", times[True] * 1e6,
        f"req_s={len(reqs) / times[True]:.1f};"
        f"speedup_vs_paged={times[False] / times[True]:.2f}x;"
        f"tokens_per_step={st.tokens_per_step:.2f};"
        f"acceptance_rate={st.acceptance_rate:.2f};"
        f"verify_steps={n_steps};baseline_decode_steps={base_steps};"
        f"draft_steps={draft_steps[0]};draft_prefills={draft_steps[1]};"
        f"kv_pages_peak={pool_stats['kv_pages_peak']};"
        f"k={draft_tokens};uavs={n_uavs};frames_per_uav={frames};"
        f"note=draft_shares_target_geometry_on_cpu"))
    return rows


def run(log=print):
    rows = []
    params, bns, lut = init_serving_system(PCFG)
    # XLA KV-decode engine (the CPU-appropriate config; flash-decode
    # interpret mode is measured separately below)
    executor = make_executor(PCFG, params, bns, lut,
                             max_new_tokens=ANSWER_TOKENS, flash_decode=False)
    flash_exec = make_executor(PCFG, params, bns, lut,
                               max_new_tokens=ANSWER_TOKENS,
                               flash_decode=True)
    reqs = _requests(executor, N_REQUESTS)

    pcfg = executor.pcfg
    jit_reason = jax.jit(lambda p, c, q: vlm.llm_reason(p, pcfg, c, q))
    base_s = time_best(lambda: _baseline_serve(executor, reqs, ANSWER_TOKENS,
                                               jit_reason))
    base_rps = N_REQUESTS / base_s
    rows.append(emit("serving/baseline_full_forward", base_s * 1e6,
                     f"req_s={base_rps:.1f};"
                     f"tok_s={N_REQUESTS * ANSWER_TOKENS / base_s:.1f};"
                     f"T={ANSWER_TOKENS};N={N_REQUESTS}"))

    for b in BATCHES:
        eng_s = time_best(lambda: _engine_serve(executor, reqs, b,
                                                "generate"))
        rps = N_REQUESTS / eng_s
        rows.append(emit(
            f"serving/engine_b{b}", eng_s * 1e6,
            f"req_s={rps:.1f};speedup_vs_full_forward={rps / base_rps:.2f}x;"
            f"tok_s={N_REQUESTS * ANSWER_TOKENS / eng_s:.1f}"))

    for b in (8, 16):
        eng_s = time_best(lambda: _engine_serve(executor, reqs, b,
                                                "inflight"))
        rps = N_REQUESTS / eng_s
        rows.append(emit(
            f"serving/inflight_b{b}", eng_s * 1e6,
            f"req_s={rps:.1f};speedup_vs_full_forward={rps / base_rps:.2f}x;"
            "note=token_level_continuous_batching"))

    for b in (8, 16):
        eng_s = time_best(lambda: _engine_serve(flash_exec, reqs, b,
                                                "generate"))
        rps = N_REQUESTS / eng_s
        rows.append(emit(
            f"serving/engine_flash_b{b}", eng_s * 1e6,
            f"req_s={rps:.1f};speedup_vs_full_forward={rps / base_rps:.2f}x;"
            "note=pallas_interpret_on_cpu"))

    # paged shared-prefix KV cache: repeat-prefix per-UAV admission
    rows += paged_prefix_rows(executor)

    # speculative decoding off the Context-stream model (its own
    # executor: the longer-answer regime speculation targets)
    spec_exec = make_executor(PCFG, params, bns, lut,
                              max_new_tokens=SPEC_ANSWER_TOKENS,
                              flash_decode=False)
    rows += spec_rows(spec_exec)

    steps = 32
    for b in BATCHES:
        dec_s = time_best(_decode_loop(executor, b, steps))
        rows.append(emit(
            f"serving/decode_b{b}", dec_s * 1e6,
            f"decode_tok_s={b * steps / dec_s:.1f};steps={steps}"))
    for b in (8, 16):
        dec_s = time_best(_decode_loop(flash_exec, b, steps))
        rows.append(emit(
            f"serving/decode_flash_b{b}", dec_s * 1e6,
            f"decode_tok_s={b * steps / dec_s:.1f};steps={steps};"
            "note=pallas_interpret_on_cpu"))
    write_bench_json(rows)
    return rows


def _smoke_executor(max_new_tokens=ANSWER_TOKENS):
    params, bns, lut = init_serving_system(PCFG)
    return make_executor(PCFG, params, bns, lut,
                         max_new_tokens=max_new_tokens, flash_decode=False)


def _smoke_emit(name, us, derived):
    """Smoke rows carry their own names in the JSON artifact so the
    reduced-size numbers never overwrite the full-run perf records."""
    return emit(name + "_smoke", us, derived)


def run_paged_smoke():
    """CI smoke: only the paged shared-prefix mode, at a reduced size
    (2 UAVs x 4 frames, XLA decode path) — exercises prefix store,
    allocator, and page-table admission end to end in seconds."""
    rows = paged_prefix_rows(_smoke_executor(), n_uavs=2, frames=4,
                             emit_row=_smoke_emit)
    write_bench_json(rows)
    return rows


def run_spec():
    """Full speculative mode on its own (the rest of the serving suite
    untouched): Context-stream drafts + paged multi-token verify vs the
    non-speculative paged baseline."""
    rows = spec_rows(_smoke_executor(SPEC_ANSWER_TOKENS))
    write_bench_json(rows)
    return rows


def run_spec_smoke():
    """CI smoke: speculative decoding end to end at a reduced size
    (2 UAVs x 3 frames) — draft model, verify kernel path, greedy
    acceptance, rollback, and the tokens/step accounting in seconds."""
    rows = spec_rows(_smoke_executor(SPEC_ANSWER_TOKENS), n_uavs=2,
                     frames=3, emit_row=_smoke_emit)
    write_bench_json(rows)
    return rows


if __name__ == "__main__":
    import sys
    if "--paged-smoke" in sys.argv:
        run_paged_smoke()
    elif "--spec-smoke" in sys.argv:
        run_spec_smoke()
    elif "--spec" in sys.argv:
        run_spec()
    else:
        run()
