"""Benchmark (beyond-paper, §6 future work): multi-UAV fleet scaling.

Sweeps fleet size N ∈ {1, 2, 4, 6} on the paper trace with equal
bandwidth shares. Expected shape: static High-Accuracy hits its 11.68
Mbps feasibility cliff already at N=2 (share ≤ 10 Mbps), while AVERY
keeps every UAV above the 0.5 PPS floor by sliding down the tier list,
trading fidelity for fleet-wide liveness."""
from __future__ import annotations

from benchmarks.common import Timer, emit, ensure_lut
from repro.engine import (AdaptivePolicy, BestEffortPolicy, StaticTierPolicy)
from repro.network import paper_trace
from repro.runtime.fleet import run_fleet
from repro.runtime.mission import MissionSpec


def run(log=print):
    lut = ensure_lut(log)
    trace = paper_trace(seed=0)
    rows = []
    results = []
    # every fleet variant is the same engine with a different ControlPolicy
    with Timer() as t:
        for n in (1, 2, 4, 6):
            fleet_av = run_fleet(lut, trace, n,
                                 MissionSpec(policy=AdaptivePolicy()))
            fleet_fb = run_fleet(lut, trace, n,
                                 MissionSpec(policy=BestEffortPolicy()))
            fleet_ha = run_fleet(lut, trace, n, MissionSpec(
                policy=StaticTierPolicy("High Accuracy")))
            results.append((n, fleet_av, fleet_fb, fleet_ha))
    for n, fleet_av, fleet_fb, fleet_ha in results:
        rows.append(emit(
            f"fleet/N{n}", t.us,
            f"avery_agg_pps={fleet_av.aggregate_pps:.2f};"
            f"avery_iou={fleet_av.mean_iou:.4f};"
            f"avery_starved_frac={fleet_av.infeasible_frac:.3f};"
            f"avery_fb_agg_pps={fleet_fb.aggregate_pps:.2f};"
            f"avery_fb_iou={fleet_fb.mean_iou:.4f};"
            f"staticHA_agg_pps={fleet_ha.aggregate_pps:.2f};"
            f"staticHA_iou={fleet_ha.mean_iou:.4f}"))
    return rows


if __name__ == "__main__":
    run()
