"""Benchmark (beyond-paper, §6 future work): multi-UAV fleet scaling.

Sweeps fleet size N ∈ {1, 2, 4, 6} on the paper trace with equal
bandwidth shares. Expected shape: static High-Accuracy hits its 11.68
Mbps feasibility cliff already at N=2 (share ≤ 10 Mbps), while AVERY
keeps every UAV above the 0.5 PPS floor by sliding down the tier list,
trading fidelity for fleet-wide liveness.

The fleet loop drives the engine's real admission path (arrival-ordered
merge across UAVs — see ``runtime/fleet.py``); the final row additionally
puts N=4 behind a ``QoSScheduler`` with a per-operator rate limit, so the
shed fraction under admission control is measured on the same trace. That
run also records per-frame lifecycle spans (``engine_trace=True``) and
leaves a validated Perfetto trace under ``benchmarks/artifacts/``."""
from __future__ import annotations

import json
import os

from benchmarks.common import ART, Timer, emit, ensure_lut
from repro.engine import (AdaptivePolicy, BestEffortPolicy, QoSScheduler,
                          StaticTierPolicy)
from repro.engine.observability import validate_chrome_trace
from repro.network import paper_trace
from repro.runtime.fleet import run_fleet
from repro.runtime.mission import MissionSpec


def run(log=print):
    lut = ensure_lut(log)
    trace = paper_trace(seed=0)
    rows = []
    results = []
    # every fleet variant is the same engine with a different ControlPolicy
    with Timer() as t:
        for n in (1, 2, 4, 6):
            fleet_av = run_fleet(lut, trace, n,
                                 MissionSpec(policy=AdaptivePolicy()))
            fleet_fb = run_fleet(lut, trace, n,
                                 MissionSpec(policy=BestEffortPolicy()))
            fleet_ha = run_fleet(lut, trace, n, MissionSpec(
                policy=StaticTierPolicy("High Accuracy")))
            results.append((n, fleet_av, fleet_fb, fleet_ha))
    for n, fleet_av, fleet_fb, fleet_ha in results:
        rows.append(emit(
            f"fleet/N{n}", t.us,
            f"avery_agg_pps={fleet_av.aggregate_pps:.2f};"
            f"avery_iou={fleet_av.mean_iou:.4f};"
            f"avery_starved_frac={fleet_av.infeasible_frac:.3f};"
            f"avery_fb_agg_pps={fleet_fb.aggregate_pps:.2f};"
            f"avery_fb_iou={fleet_fb.mean_iou:.4f};"
            f"staticHA_agg_pps={fleet_ha.aggregate_pps:.2f};"
            f"staticHA_iou={fleet_ha.mean_iou:.4f}"))
    # admission control at fleet scale: cap each UAV at 0.4 frames/s
    # (below AVERY's 0.5 PPS floor) and measure the shed fraction
    with Timer() as t_rl:
        fleet_rl = run_fleet(
            lut, trace, 4, MissionSpec(policy=AdaptivePolicy()),
            scheduler=QoSScheduler(rate_per_s=0.4, burst=2.0),
            engine_trace=True)
    rejected = int(fleet_rl.stats.get("rejected", 0))
    served = sum(len(l.frames) for l in fleet_rl.logs)
    # the traced pass leaves a Perfetto artifact; an export that fails
    # schema validation fails the bench
    path = fleet_rl.tracer.dump(os.path.join(ART, "trace_fleet.json"))
    with open(path) as f:
        problems = validate_chrome_trace(json.load(f))
    if problems:
        raise AssertionError(
            f"fleet trace artifact failed validation: {problems[:3]}")
    rows.append(emit(
        "fleet/N4_ratelimited", t_rl.us,
        f"agg_pps={fleet_rl.aggregate_pps:.2f};"
        f"rejected={rejected};served={served};"
        f"shed_frac={rejected / max(1, rejected + served):.3f};"
        f"traced_frames={len(fleet_rl.tracer)};"
        f"trace_evicted={fleet_rl.tracer.n_evicted}"))
    return rows


if __name__ == "__main__":
    run()
