"""Benchmark harness — one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. The offline phase (training
lisa-mini + bottleneck tiers) runs once and is cached on disk, so the
first invocation is the slow one.

  PYTHONPATH=src python -m benchmarks.run              # everything
  PYTHONPATH=src python -m benchmarks.run --only fig9  # substring filter
  PYTHONPATH=src python -m benchmarks.run --fast       # skip fig7 sweep
"""
from __future__ import annotations

import argparse
import sys
import traceback

BENCHES = [
    ("table3", "benchmarks.bench_lut"),                 # Table 3
    ("fig7", "benchmarks.bench_split_points"),          # Fig 7
    ("fig8", "benchmarks.bench_energy"),                # Fig 8
    ("raw", "benchmarks.bench_raw_compression"),        # §5.2.1 11.2% claim
    ("streams", "benchmarks.bench_streams"),            # §5.2.2 6.4x claim
    ("fig9", "benchmarks.bench_dynamic"),               # Fig 9
    ("fig10", "benchmarks.bench_tradeoff"),             # Fig 10
    ("fine_tiers", "benchmarks.bench_fine_tiers"),      # beyond-paper (§6 fw)
    ("fleet", "benchmarks.bench_fleet"),                # beyond-paper (§6 fw)
    ("serving", "benchmarks.bench_serving"),            # KV-cache engine

    ("roofline", "benchmarks.bench_roofline"),          # deliverable (g)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="skip the fig7 bottleneck-per-split retrain sweep")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = []
    for key, module_name in BENCHES:
        if args.only and args.only not in key:
            continue
        if args.fast and key == "fig7":
            continue
        try:
            import importlib
            mod = importlib.import_module(module_name)
            mod.run(log=lambda s: print(f"# {s}", flush=True))
        except Exception:                                  # noqa: BLE001
            failures.append(key)
            print(f"# BENCH {key} FAILED:", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(f"failed benches: {failures}")


if __name__ == "__main__":
    main()
