"""Benchmark: Fig. 10 — accuracy vs throughput trade-off envelope.

AVERY in both mission modes against the static tiers (original model
accuracies, as in the paper's figure)."""
from __future__ import annotations

from benchmarks.common import Timer, emit, ensure_lut
from repro.core.controller import MissionGoal
from repro.network import paper_trace
from repro.runtime import MissionSpec, run_mission


def run(log=print):
    lut = ensure_lut(log)
    trace = paper_trace(seed=0)
    rows = []
    with Timer() as t:
        pts = {}
        pts["AVERY_acc_mode"] = run_mission(lut, trace,
                                            MissionSpec(mode="avery"))
        pts["AVERY_tput_mode"] = run_mission(
            lut, trace,
            MissionSpec(mode="avery",
                        goal=MissionGoal.PRIORITIZE_THROUGHPUT))
        for tier in ("High Accuracy", "Balanced", "High Throughput"):
            pts[tier] = run_mission(
                lut, trace, MissionSpec(mode="static", static_tier=tier))
    for name, lg in pts.items():
        rows.append(emit(f"fig10/{name.replace(' ', '_')}", t.us,
                         f"avg_pps={lg.mean_pps:.3f};"
                         f"avg_iou={lg.mean_iou:.4f}"))
    # blended-profile claim: AVERY(acc) strictly dominates Balanced
    bal, av = pts["Balanced"], pts["AVERY_acc_mode"]
    rows.append(emit(
        "fig10/claims", t.us,
        f"avery_beats_balanced_iou={av.mean_iou > bal.mean_iou};"
        f"tput_mode_pps={pts['AVERY_tput_mode'].mean_pps:.2f};"
        f"paper_tput_pps=1.85"))
    return rows


if __name__ == "__main__":
    run()
