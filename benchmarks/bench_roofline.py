"""Benchmark: roofline table (ours — deliverable g).

Reads the dry-run artifacts produced by ``python -m repro.launch.dryrun``
and emits the per-(arch x shape x mesh) roofline terms. Run the dry-run
first; this bench degrades gracefully (reports what exists)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import DRYRUN_DIR, Timer, emit


def load_records(mesh: str = "16x16", tag: str = ""):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh and r.get("tag", "") == tag:
            recs.append(r)
    return recs


def run(log=print):
    rows = []
    with Timer() as t:
        recs = load_records()
    if not recs:
        rows.append(emit("roofline/missing", t.us,
                         "run `python -m repro.launch.dryrun --all` first"))
        return rows
    for r in recs:
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r.get("skipped"):
            rows.append(emit(name, t.us, f"skipped={r['skipped']}"))
            continue
        if r.get("error"):
            rows.append(emit(name, t.us, f"error={r['error'][:80]}"))
            continue
        rows.append(emit(
            name, t.us,
            f"compute_s={r['compute_term_s']:.4g};"
            f"memory_s={r['memory_term_s']:.4g};"
            f"collective_s={r['collective_term_s']:.4g};"
            f"bottleneck={r['bottleneck']};"
            f"useful_flops_ratio={r['useful_flops_ratio']:.3f}"))
    return rows


if __name__ == "__main__":
    run()
