"""End-to-end driver: a 20-minute disaster-response mission (paper §5.3).

Serves the trained lisa-mini system with batched operator requests over
the scripted 8-20 Mbps bandwidth trace, comparing AVERY's adaptive
controller against the three static tiers — the reproduction of Fig. 9
and Fig. 10. Uses cached offline-phase checkpoints when present.

Run:  PYTHONPATH=src python examples/disaster_mission.py [--minutes 20]
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import ensure_lut, ensure_trained_system  # noqa: E402
from repro.configs.lisa_mini import CONFIG as pcfg
from repro.core import DualStreamExecutor, MissionGoal
from repro.engine import AdaptivePolicy, StaticTierPolicy
from repro.network import paper_trace
from repro.runtime import MissionSpec, run_mission


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=20.0)
    ap.add_argument("--real-inference", action="store_true",
                    help="score every frame with actual lisa-mini inference "
                         "(slower) instead of the profiled LUT oracle")
    args = ap.parse_args()
    duration = args.minutes * 60.0

    lut = ensure_lut()
    executor = None
    if args.real_inference:
        params, _, bns = ensure_trained_system()
        executor = DualStreamExecutor(
            pcfg=pcfg, params=params,
            bottlenecks={t.name: bns[t.ratio] for t in lut.tiers}, lut=lut)

    trace = paper_trace(seed=0, duration_s=int(duration))
    print(f"== {args.minutes:.0f}-minute mission on the paper trace "
          f"(mean bw {trace.mean():.1f} Mbps) ==")
    print(f"{'config':22s} {'PPS':>6s} {'AvgIoU':>7s} {'gap(pp)':>8s} "
          f"{'energy(J)':>10s} {'switches':>8s}")

    # the §5.3 adaptive-vs-static comparison is a one-line policy swap
    logs = {}
    logs["AVERY (accuracy)"] = run_mission(
        lut, trace, MissionSpec(duration_s=duration, policy=AdaptivePolicy()),
        executor=executor, pcfg=pcfg)
    logs["AVERY (throughput)"] = run_mission(
        lut, trace, MissionSpec(duration_s=duration, policy=AdaptivePolicy(),
                                goal=MissionGoal.PRIORITIZE_THROUGHPUT),
        executor=executor, pcfg=pcfg)
    for tier in ("High Accuracy", "Balanced", "High Throughput"):
        logs[f"static {tier}"] = run_mission(
            lut, trace, MissionSpec(duration_s=duration,
                                    policy=StaticTierPolicy(tier)),
            executor=executor, pcfg=pcfg)

    ha = logs["static High Accuracy"].mean_iou
    for name, lg in logs.items():
        switches = sum(1 for a, b in zip(lg.frames, lg.frames[1:])
                       if a.tier != b.tier)
        print(f"{name:22s} {lg.mean_pps:6.3f} {lg.mean_iou:7.4f} "
              f"{100 * (ha - lg.mean_iou):8.2f} "
              f"{lg.total_edge_energy_j:10.0f} {switches:8d}")

    av = logs["AVERY (accuracy)"]
    print(f"\npaper claims -> ours: IoU gap 0.75pp -> "
          f"{100 * (ha - av.mean_iou):.2f}pp; PPS 0.74 -> {av.mean_pps:.2f}")
    print("minute-by-minute tier (AVERY):",
          " ".join(t[:4] for t in av.tier_timeline(60.0)[:20]))


if __name__ == "__main__":
    main()
