"""Quickstart: the AVERY public API in ~60 lines.

1. Train a tiny LISA proxy + one bottleneck tier (offline phase).
2. Classify operator intent, let Algorithm 1 pick the operating point.
3. Run one Context query and one Insight query through the dual-stream
   split executor over a simulated channel.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.configs.lisa_mini import CONFIG as pcfg
from repro.core import (DualStreamExecutor, Intent, MissionGoal, PowerConfig,
                        classify_intent, select_configuration)
from repro.core import profile as prof
from repro.core import training
from repro.core.intent import DEFAULT_REQUIREMENTS
from repro.core.vlm import iou_metrics
from repro.data import floodseg
from repro.network import Channel, paper_trace

# ---- 1. offline phase (tiny budget so this finishes in ~2 minutes) ----
print("== offline phase: training lisa-mini + bottleneck ==")
params = training.train_lisa(pcfg, steps=250, batch_size=16, log_every=80)
bn = training.train_bottleneck(pcfg, params, ratio=0.25, steps=80,
                               batch_size=8, log_every=40)
lut = prof.build_lut(pcfg, params, params, {0.25: bn}, eval_batches=2)
print("LUT:", [(t.name, round(t.acc_base, 3), f"{t.payload_mb:.2f}MB")
               for t in lut.tiers])

executor = DualStreamExecutor(pcfg=pcfg, params=params,
                              bottlenecks={"High Accuracy": bn}, lut=lut)
channel = Channel(paper_trace(seed=0))

# ---- 2. operator asks a triage question -> Context stream ----
prompt = "Are there any persons in this sector?"
intent = classify_intent(prompt)
print(f"\noperator: {prompt!r} -> intent={intent.value}")
rng = np.random.RandomState(0)
batch = floodseg.make_batch(rng, 1, "any", augment=False, cls="person")
pkt, _ = executor.edge_context(jnp.asarray(batch["images"]), 0, 0.0)
rec = channel.transmit(pkt, 0.0)
logits = executor.cloud_context(pkt, jnp.asarray(batch["query"]))
ans = "yes" if logits[0].argmax() == floodseg.ANS_YES else "no"
print(f"context answer: {ans!r} (gt: "
      f"{'yes' if batch['answer'][0] == floodseg.ANS_YES else 'no'}) "
      f"[{pkt.payload_bytes}B, {rec.latency_s * 1000:.1f}ms on the link]")

# ---- 3. operator escalates -> Insight stream via Algorithm 1 ----
prompt = "Highlight the stranded persons who may need rescue."
intent = classify_intent(prompt)
bw = channel.measure_bandwidth(5.0)
sel = select_configuration(bw, PowerConfig(),
                           MissionGoal.PRIORITIZE_ACCURACY, intent,
                           DEFAULT_REQUIREMENTS[Intent.INSIGHT], lut)
print(f"\noperator: {prompt!r} -> intent={intent.value}; "
      f"controller picked tier={sel.tier.name!r} at {bw:.1f} Mbps "
      f"({sel.throughput_pps:.2f} PPS)")
batch = floodseg.make_batch(rng, 1, "segment", augment=False, cls="person")
pkt = executor.edge_insight(jnp.asarray(batch["images"]), sel.tier, 1, 5.0)
rec = channel.transmit(pkt, 5.0)
mask_logits, _ = executor.cloud_insight(pkt, jnp.asarray(batch["query"]))
m = iou_metrics(jnp.asarray(mask_logits), jnp.asarray(batch["mask"]))
print(f"insight mask IoU: {float(m['avg_iou']):.3f} "
      f"[{pkt.payload_bytes}B, {rec.latency_s * 1000:.1f}ms on the link]")
print("\nquickstart OK")
