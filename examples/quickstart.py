"""Quickstart: the AVERY public API in ~60 lines.

1. Train a tiny LISA proxy + one bottleneck tier (offline phase).
2. Build the ``AveryEngine`` front door: executor + LUT + a simulated
   channel transport + the Algorithm-1 adaptive policy.
3. Run one Context query and one Insight query through an operator
   session — the engine classifies intent, picks the operating point,
   runs the edge encode, transmits, and serves the cloud batch.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.configs.lisa_mini import CONFIG as pcfg
from repro.core import DualStreamExecutor
from repro.core import profile as prof
from repro.core import training
from repro.core.vlm import iou_metrics
from repro.data import floodseg
from repro.engine import AdaptivePolicy, AveryEngine, ChannelTransport
from repro.network import paper_trace

# ---- 1. offline phase (tiny budget so this finishes in ~2 minutes) ----
print("== offline phase: training lisa-mini + bottleneck ==")
params = training.train_lisa(pcfg, steps=250, batch_size=16, log_every=80)
bn = training.train_bottleneck(pcfg, params, ratio=0.25, steps=80,
                               batch_size=8, log_every=40)
lut = prof.build_lut(pcfg, params, params, {0.25: bn}, eval_batches=2)
print("LUT:", [(t.name, round(t.acc_base, 3), f"{t.payload_mb:.2f}MB")
               for t in lut.tiers])

# ---- 2. one engine, one operator session ----
executor = DualStreamExecutor(pcfg=pcfg, params=params,
                              bottlenecks={"High Accuracy": bn}, lut=lut)
engine = AveryEngine(lut=lut, executor=executor,
                     transport=ChannelTransport.from_trace(paper_trace(seed=0)),
                     policy=AdaptivePolicy())
session = engine.session("operator-0")
rng = np.random.RandomState(0)

# ---- operator asks a triage question -> Context stream ----
prompt = "Are there any persons in this sector?"
batch = floodseg.make_batch(rng, 1, "any", augment=False, cls="person")
fut = session.submit(prompt=prompt, images=jnp.asarray(batch["images"]),
                     query=batch["query"], time_s=0.0)
res = fut.result()
ans = "yes" if res.answer_logits[0].argmax() == floodseg.ANS_YES else "no"
print(f"\noperator: {prompt!r} -> intent={res.intent.value}")
print(f"context answer: {ans!r} (gt: "
      f"{'yes' if batch['answer'][0] == floodseg.ANS_YES else 'no'}) "
      f"[{res.latency_s * 1000:.1f}ms on the link]")

# ---- 3. operator escalates -> Insight stream via Algorithm 1 ----
prompt = "Highlight the stranded persons who may need rescue."
batch = floodseg.make_batch(rng, 1, "segment", augment=False, cls="person")
fut = session.submit(prompt=prompt, images=jnp.asarray(batch["images"]),
                     query=batch["query"], time_s=5.0)
res = fut.result()
sel = res.events[0].data          # the engine's tier_selected event
print(f"\noperator: {prompt!r} -> intent={res.intent.value}; "
      f"controller picked tier={res.tier_name!r} at "
      f"{sel['bandwidth_mbps']:.1f} Mbps")
m = iou_metrics(jnp.asarray(res.mask_logits), jnp.asarray(batch["mask"]))
print(f"insight mask IoU: {float(m['avg_iou']):.3f} "
      f"[{res.latency_s * 1000:.1f}ms on the link]")
print("\nquickstart OK")
