"""Example: distillation-train bottleneck tiers at a chosen split point and
inspect the accuracy-vs-ratio curve (paper Fig. 5 / Table 3 workflow).

Also demonstrates the *generic* SplitPlan API (DESIGN.md §3): the same
depth-wise split + bottleneck machinery applied to one of the assigned
text architectures (phi4-mini reduced), not just the VLM — the beyond-
paper generalisation of the technique.

Run:  PYTHONPATH=src python examples/train_bottleneck.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.configs.lisa_mini import CONFIG as pcfg
from repro.core import BottleneckSpec, SplitPlan, init_bottleneck
from repro.core import bottleneck as bn
from repro.core import training
from repro.models import forward, init_params
from repro.models.common import causal_mask

# ---- 1. the paper's workflow: tiers on the VLM split ----
print("== training lisa-mini, then one bottleneck per ratio ==")
params = training.train_lisa(pcfg, steps=120, batch_size=8, log_every=60)
print(f"{'ratio':>6s} {'avg_iou':>8s} {'recon':>8s}")
for ratio in (0.25, 0.10, 0.05):
    bp = training.train_bottleneck(pcfg, params, ratio, steps=80,
                                   batch_size=8, log_every=0,
                                   log=lambda s: None)
    acc = training.evaluate_insight(pcfg, params, bn_params=bp, batches=3)
    from repro.core import vlm
    from repro.data import floodseg
    rng = np.random.RandomState(0)
    b = floodseg.make_batch(rng, 16, "segment")
    a = vlm.sam_head(params, pcfg, jnp.asarray(b["images"]))
    recon = float(bn.recon_loss(bp, a))
    print(f"{ratio:6.2f} {acc['avg_iou']:8.4f} {recon:8.4f}")

# ---- 2. beyond the paper: split + bottleneck on a text arch ----
print("\n== SplitPlan on phi4-mini (reduced): split@1, r=0.25 ==")
cfg = get_reduced("phi4-mini-3.8b")
tparams = init_params(cfg, jax.random.PRNGKey(0))
plan = SplitPlan(cfg, split_layer=1)
edge, cloud = plan.split_params(tparams)

tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                            cfg.vocab_size)
B, S = tokens.shape
positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
mask = causal_mask(S)[None]

x = jnp.take(tparams["embed"], tokens, axis=0)
boundary = plan.head_apply(edge, x, positions, mask)        # edge side
spec = BottleneckSpec(cfg.d_model,
                      bn.rank_for_ratio(cfg.d_model, 0.25, 4), 4)
bp = init_bottleneck(jax.random.PRNGKey(2), spec)
codes, scales = bn.encode(bp, boundary)                     # the link
restored = bn.decode(bp, codes, scales)
h = plan.tail_apply(cloud, restored, positions, mask)       # cloud side

_, _, _, h_full = forward(tparams, cfg, {"tokens": tokens})
rel = float(jnp.linalg.norm(h - h_full) / jnp.linalg.norm(h_full))
raw_mb = boundary.size * 4 / 1e6
comp_mb = (codes.size + scales.size * 2) / 1e6
print(f"boundary {raw_mb:.3f}MB -> {comp_mb:.3f}MB "
      f"({raw_mb / comp_mb:.1f}x); untrained-bottleneck rel err {rel:.3f}")
print("(train the pair with repro.core.training.train_bottleneck to "
      "recover task fidelity — see part 1)")
