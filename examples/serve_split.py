"""Example: batched-request split serving with intent gating.

Drives the serving runtime with a Poisson stream of mixed operator
requests (context triage + insight escalations), exercising the full
edge/channel/cloud path with real model inference — the "serve a small
model with batched requests" end-to-end driver.

Run:  PYTHONPATH=src python examples/serve_split.py [--duration 90]

For the pod-disaggregated (2x16x16 mesh) lowering of the same split —
the TPU mapping of the edge/cloud boundary — run:
      PYTHONPATH=src python -m repro.launch.serve --dryrun
"""
import argparse
import subprocess
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=90.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    # launch/serve.py is the canonical implementation; this example is the
    # documented entry point for it.
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve",
         "--duration", str(args.duration), "--seed", str(args.seed)]))
