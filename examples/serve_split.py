"""Example: batched-request split serving through the ``AveryEngine``.

Drives the engine with a Poisson stream of mixed operator requests
(context triage + insight escalations), exercising the full
edge/channel/cloud path with real model inference — the "serve a small
model with batched requests" end-to-end driver. The engine owns the
wiring (intent gate -> ControlPolicy -> edge encode -> Transport ->
batched cloud serving); this example owns only the request stream.

Run:  PYTHONPATH=src python examples/serve_split.py [--duration 90]
      PYTHONPATH=src python examples/serve_split.py --batching inflight
      PYTHONPATH=src python examples/serve_split.py --smoke   # no training

For the pod-disaggregated (2x16x16 mesh) lowering of the same split —
the TPU mapping of the edge/cloud boundary — run:
      PYTHONPATH=src python -m repro.launch.serve --dryrun
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.launch.serve import serve_local  # noqa: E402

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=90.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="random-init weights instead of the offline phase")
    ap.add_argument("--batching", choices=("microbatch", "inflight"),
                    default="microbatch")
    args = ap.parse_args()
    # serve_local is the canonical engine-driven loop; this example is the
    # documented entry point for it.
    serve_local(args.duration, args.seed, args.max_batch, smoke=args.smoke,
                batching=args.batching)
