import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: run tagged dry-run variants of one
(arch x shape) pair and print the roofline-term deltas.

  PYTHONPATH=src python scripts/hillclimb.py deepseek-v3-671b train_4k \
      scatter fsdp scatter+fsdp
"""
import dataclasses
import json
import sys

from repro.configs import get_config
from repro.launch import dryrun


def variant_cfg(arch: str, name: str):
    """Named config transforms (the §Perf levers)."""
    cfg = get_config(arch)
    fsdp = False
    for part in name.split("+"):
        if part == "base":
            pass
        elif part in ("scatter", "grouped"):
            cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                      dispatch=part))
        elif part == "fsdp":
            fsdp = True
        elif part.startswith("chunk"):
            cfg = cfg.replace(attn_chunk=int(part[len("chunk"):]))
        elif part == "remat":
            cfg = cfg.replace(remat=True)
        elif part == "kvhd":
            cfg = cfg.replace(shard_cache_hd=True)
        elif part == "skipscores":
            cfg = cfg.replace(attn_scores_stub=True)
        elif part == "seqshard":
            cfg = cfg.replace(seq_shard=True)
        elif part.startswith("window"):
            cfg = cfg.with_sliding_window(int(part[len("window"):]))
        else:
            raise ValueError(f"unknown variant part {part!r}")
    return cfg, fsdp


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    variants = sys.argv[3:] or ["base"]
    print(f"{'variant':>18s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'coll_s':>10s} {'bottleneck':>11s} {'useful':>7s} "
          f"{'temp_GB':>8s}")
    for v in ["base"] + [x for x in variants if x != "base"]:
        cfg, fsdp = variant_cfg(arch, v)
        rec = dryrun.run_combo(arch, shape, multi_pod=False,
                               cfg_override=cfg, tag=v.replace("+", "_"),
                               fsdp=fsdp)
        if rec.get("error"):
            print(f"{v:>18s} ERROR {rec['error'][:90]}")
            continue
        print(f"{v:>18s} {rec['compute_term_s']:10.3f} "
              f"{rec['memory_term_s']:10.3f} "
              f"{rec['collective_term_s']:10.3f} {rec['bottleneck']:>11s} "
              f"{rec['useful_flops_ratio']:7.3f} "
              f"{rec['temp_size_in_bytes'] / 1e9:8.1f}")


if __name__ == "__main__":
    main()
