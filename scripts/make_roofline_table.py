"""Generate the EXPERIMENTS.md §Roofline markdown table + §Dry-run summary
from benchmarks/artifacts/dryrun/*.json."""
import glob
import json
import os
import sys

ARCH_ORDER = ["falcon-mamba-7b", "nemotron-4-340b", "qwen1.5-32b",
              "phi4-mini-3.8b", "zamba2-7b", "hubert-xlarge",
              "granite-moe-3b-a800m", "deepseek-v3-671b", "minicpm3-4b",
              "qwen2-vl-2b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x):
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}m"
    return f"{x * 1e6:.1f}u"


def main(dirpath="benchmarks/artifacts/dryrun", mesh="16x16"):
    recs = {}
    for p in glob.glob(os.path.join(dirpath, "*.json")):
        r = json.load(open(p))
        if r.get("mesh") == mesh and not r.get("tag"):
            recs[(r["arch"], r["shape"])] = r
    print("| arch | shape | compute s | memory s | collective s | "
          "bottleneck | useful | temp GB/dev | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                print(f"| {a} | {s} | - | - | - | - | - | - | MISSING |")
                continue
            if r.get("skipped"):
                print(f"| {a} | {s} | - | - | - | - | - | - | "
                      f"skipped: {r['skipped']} |")
                continue
            if r.get("error"):
                print(f"| {a} | {s} | - | - | - | - | - | - | "
                      f"ERROR: {r['error'][:60]} |")
                continue
            note = "sliding-window 8192" if (
                s == "long_500k" and a not in ("falcon-mamba-7b",)) else ""
            print(f"| {a} | {s} | {fmt_s(r['compute_term_s'])} | "
                  f"{fmt_s(r['memory_term_s'])} | "
                  f"{fmt_s(r['collective_term_s'])} | {r['bottleneck']} | "
                  f"{r['useful_flops_ratio']:.3f} | "
                  f"{r.get('temp_size_in_bytes', 0) / 1e9:.1f} | {note} |")


if __name__ == "__main__":
    main(*sys.argv[1:])
