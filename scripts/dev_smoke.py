"""Dev-only quick smoke of the model substrate (not part of the test suite)."""
import sys

import jax
import jax.numpy as jnp

from repro.models import (HybridConfig, MLAConfig, MoEConfig, ModelConfig,
                          SSMConfig, decode_step, forward, init_cache,
                          init_params, loss_fn, make_train_step, prefill_step)
from repro import optim

CFGS = [
    ModelConfig(name="t-dense", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97),
    ModelConfig(name="t-bias-relu2", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=97,
                qkv_bias=True, mlp_act="relu2", gated_mlp=False),
    ModelConfig(name="t-sw", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                sliding_window=8),
    ModelConfig(name="t-mla", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=97,
                attn_type="mla",
                mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)),
    ModelConfig(name="t-moe", arch_type="moe", num_layers=3, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                              num_shared_experts=1, d_ff_shared=32,
                              first_k_dense=1, d_ff_dense=128)),
    ModelConfig(name="t-moe-scatter", arch_type="moe", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                              dispatch="scatter")),
    ModelConfig(name="t-mamba1", arch_type="ssm", num_layers=2, d_model=64,
                num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=97,
                attn_type="none", rope_style="none",
                ssm=SSMConfig(version=1, state_size=4)),
    ModelConfig(name="t-mamba2", arch_type="ssm", num_layers=2, d_model=64,
                num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=97,
                attn_type="none", rope_style="none",
                ssm=SSMConfig(version=2, state_size=8, head_dim=16)),
    ModelConfig(name="t-hybrid", arch_type="hybrid", num_layers=4, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=97,
                ssm=SSMConfig(version=2, state_size=8, head_dim=16),
                hybrid=HybridConfig(attn_every=2)),
    ModelConfig(name="t-audio", arch_type="audio", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=31,
                causal=False, rope_style="none", modality="audio",
                frontend_dim=24),
    ModelConfig(name="t-vlm", arch_type="vlm", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                rope_style="mrope", mrope_sections=(4, 2, 2), modality="vlm",
                frontend_dim=24, num_vision_tokens=4),
    ModelConfig(name="t-mtp", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97, mtp=True),
]

B, S = 2, 16


def make_batch(cfg, rng):
    if cfg.modality == "audio":
        return {
            "frames": jax.random.normal(rng, (B, S, cfg.frontend_dim)),
            "targets": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
            "mask_positions": jax.random.bernoulli(rng, 0.3, (B, S)),
        }
    if cfg.modality == "vlm":
        t = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S))
        return {"tokens": t,
                "vision_embeds": jax.random.normal(
                    rng, (B, cfg.num_vision_tokens, cfg.frontend_dim)),
                "positions": pos}
    return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}


def main():
    failures = []
    for cfg in CFGS:
        try:
            rng = jax.random.PRNGKey(0)
            params = init_params(cfg, rng)
            batch = make_batch(cfg, jax.random.PRNGKey(1))
            logits, aux, _, _ = jax.jit(
                lambda p, b: forward(p, cfg, b))(params, batch)
            assert logits.shape == (B, S, cfg.vocab_size), logits.shape
            assert bool(jnp.all(jnp.isfinite(logits))), "NaN in logits"
            # one train step
            opt = optim.adamw(1e-3)
            st = opt.init(params)
            ts = jax.jit(make_train_step(cfg, opt))
            params2, st2, metrics = ts(params, st, batch)
            assert bool(jnp.isfinite(metrics["total_loss"])), metrics
            # decode
            if cfg.supports_decode and cfg.modality == "text":
                cache = init_cache(cfg, B, S)
                lg, cache = jax.jit(
                    lambda p, c, t, pos: decode_step(p, cfg, c, t, pos)
                )(params, cache, batch["tokens"][:, :1], jnp.int32(0))
                assert lg.shape == (B, 1, cfg.vocab_size)
                assert bool(jnp.all(jnp.isfinite(lg))), "NaN in decode"
            print(f"OK   {cfg.name}  loss={float(metrics['loss']):.3f}")
        except Exception as e:  # noqa: BLE001
            failures.append((cfg.name, repr(e)[:300]))
            print(f"FAIL {cfg.name}: {repr(e)[:300]}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
