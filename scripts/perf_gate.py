#!/usr/bin/env python
"""Continuous perf-regression gate over ``BENCH_serving.json``.

The serving bench writes one JSON artifact per run (merged rows, root
mirror — see ``benchmarks/common.write_bench_json``); this script diffs
it against the committed ``BENCH_baseline.json`` with per-metric
direction + tolerance budgets and exits 1 on any regression, so the
repo's perf trajectory is *gated*, not write-only. Every future perf
item on the ROADMAP (shard_map kernels, quantized KV, disaggregated
prefill/decode) lands against this gate.

Metric classification (``classify``):

  * **lower-better** — wall timings: ``us`` and any ``*_s``/``*_us``
    metric, plus ``profile_overhead``. Regression when the new value
    exceeds baseline by more than ``--tolerance`` (relative).
  * **higher-better** — quality/throughput: ``req_s``-family rates,
    SLO/hit/acceptance rates, Jain fairness, tokens/step, saved
    FLOPs/bytes. Regression when the new value falls below baseline by
    more than ``--quality-tolerance`` (relative); ``speedup_*`` ratios
    are timing-derived, so they use the (looser) time tolerance on the
    same lower bound — BUT a speedup is self-normalized (numerator and
    denominator are measured in the same run, so machine load largely
    cancels), so any speedup whose baseline claims a material win
    (>= ``SPEEDUP_PARITY_MARGIN``) additionally gates hard at the
    parity floor: a recorded value below 1.0 means the accelerated
    path measured *slower* than its own in-run baseline, which no
    tolerance excuses. Near-parity baselines (e.g. the CPU-container
    spec-decode row, whose draft shares the target's geometry) stay
    on the relative budget only, so they cannot flap CI.
  * **zero-tolerance** — ``page_leaks``: any nonzero value is a
    regression regardless of baseline or tolerance.
  * **ignored** — run geometry (seeds, sizes, SLOs), fault-schedule
    telemetry pinned by the benches' own asserts, and informational
    counters. Non-numeric values are never compared.

A baseline row missing from the bench is a regression (a mode silently
stopped running); a baseline metric missing from its row likewise. New
rows/metrics are informational until ``--update-baseline`` admits them.

``--append-history FILE`` appends one JSONL entry — git sha, UTC
timestamp, and the full record set (each row carries its seed) — so
``BENCH_history.jsonl`` accumulates the cross-PR trajectory.

Usage (the CI step, scripts/ci_fast.sh):

    python scripts/perf_gate.py --bench BENCH_serving.json \
        --baseline BENCH_baseline.json --smoke \
        --append-history BENCH_history.jsonl
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Any, Dict, List, Tuple

# default (full-run) budgets; --smoke loosens the timing side for the
# reduced-size CI rows, where constant costs dominate and wall noise on
# a shared container is large
DEFAULT_TOLERANCE = 0.50          # lower-better metrics may grow 50%
DEFAULT_QUALITY_TOLERANCE = 0.05  # higher-better metrics may drop 5%
SMOKE_TOLERANCE = 1.50
SMOKE_QUALITY_TOLERANCE = 0.30

# speedup ratios cancel machine noise; a baseline at/above the margin
# claims a real win, and such a row dropping below the floor means the
# fast path measured slower than its own in-run baseline — gated in
# every mode, independent of the relative budgets above
SPEEDUP_PARITY_MARGIN = 1.10
SPEEDUP_PARITY_FLOOR = 1.0

HIGHER_BETTER = {
    "req_s", "admit_req_s", "decode_tok_s", "delivered_under_slo",
    "prefix_hit_rate", "jain", "served", "acceptance_rate",
    "tokens_per_step", "kv_bytes_saved", "prefill_flops_saved",
}
LOWER_BETTER = {"profile_overhead"}
ZERO_TOLERANCE = {"page_leaks"}
IGNORED = {
    "seed", "uavs", "frames_per_uav", "slo_s", "duration_s", "offered",
    "ops", "k", "draft_layers", "steps", "note", "model_shards",
    "token_exact", "baseline_decode_steps", "draft_prefills",
    "draft_steps", "verify_steps", "retries", "preemptions",
    "rejected_rate_limit", "rejected_queue_full", "resumed_served",
    "tokens_replayed", "downshifts", "flight_dumps",
    "deadline_cancelled", "inflight_cancelled", "stage_faults",
    "blackouts_terminal", "cloud_errors_terminal", "kv_pages_peak",
    "compile_events", "device_events", "profiled_stage_calls",
    "ledger_flops_total", "ledger_energy_j_total",
    "decode_roofline_frac", "shard_imbalance",
}


def classify(metric: str) -> str:
    """'higher' | 'lower' | 'zero' | 'ignore' for one metric name."""
    if metric in ZERO_TOLERANCE:
        return "zero"
    if metric in IGNORED:
        return "ignore"
    if metric in HIGHER_BETTER or metric.startswith("speedup_"):
        return "higher"
    if metric in LOWER_BETTER or metric == "us" \
            or metric.endswith("_s") or metric.endswith("_us"):
        return "lower"
    return "ignore"


def load_bench(path: str) -> Dict[str, Dict[str, Any]]:
    with open(path) as f:
        doc = json.load(f)
    records = doc.get("records")
    if not isinstance(records, dict):
        raise ValueError(f"{path}: no 'records' object")
    return records


def compare(bench: Dict[str, Dict[str, Any]],
            baseline: Dict[str, Dict[str, Any]],
            tolerance: float, quality_tolerance: float
            ) -> Tuple[List[str], List[str]]:
    """Diff ``bench`` against ``baseline``; returns (regressions,
    infos). Deterministic order: rows and metrics sorted by name."""
    regressions: List[str] = []
    infos: List[str] = []
    for name in sorted(baseline):
        base_row = baseline[name]
        row = bench.get(name)
        if row is None:
            regressions.append(
                f"{name}: row missing from bench (mode stopped running)")
            continue
        for metric in sorted(base_row):
            old = base_row[metric]
            if not isinstance(old, (int, float)):
                continue
            kind = classify(metric)
            if kind == "ignore":
                continue
            new = row.get(metric)
            if not isinstance(new, (int, float)):
                regressions.append(
                    f"{name}.{metric}: metric missing from bench row")
                continue
            if kind == "zero":
                if new != 0:
                    regressions.append(
                        f"{name}.{metric}: {new:g} != 0 (zero-tolerance)")
                continue
            if kind == "lower":
                limit = old * (1.0 + tolerance)
                if new > limit:
                    regressions.append(
                        f"{name}.{metric}: {new:g} > {old:g} "
                        f"(+{tolerance:.0%} budget -> {limit:g})")
            else:   # higher-better; speedups ride the time tolerance
                is_speedup = metric.startswith("speedup_")
                tol = tolerance if is_speedup else quality_tolerance
                limit = old * (1.0 - tol)
                if new < limit:
                    regressions.append(
                        f"{name}.{metric}: {new:g} < {old:g} "
                        f"(-{tol:.0%} budget -> {limit:g})")
                elif is_speedup and old >= SPEEDUP_PARITY_MARGIN \
                        and new < SPEEDUP_PARITY_FLOOR:
                    regressions.append(
                        f"{name}.{metric}: {new:g} fell below parity "
                        f"(baseline {old:g} claimed a >="
                        f"{SPEEDUP_PARITY_MARGIN:g}x win; the "
                        f"accelerated path now measures slower than "
                        f"its in-run baseline)")
        for metric in sorted(set(row) - set(base_row)):
            if isinstance(row[metric], (int, float)) \
                    and classify(metric) != "ignore":
                infos.append(f"{name}.{metric}: new metric "
                             f"({row[metric]:g}), not yet gated")
    for name in sorted(set(bench) - set(baseline)):
        infos.append(f"{name}: new row, not yet gated")
    return regressions, infos


def git_sha(repo_dir: str) -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=repo_dir,
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def append_history(path: str, bench: Dict[str, Dict[str, Any]],
                   sha: str) -> None:
    entry = {
        "sha": sha,
        "time_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "records": bench,
    }
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")


def main(argv=None) -> int:
    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(here)
    ap = argparse.ArgumentParser(
        prog="python scripts/perf_gate.py",
        description="diff BENCH_serving.json against the committed "
                    "baseline; exit 1 on regression")
    ap.add_argument("--bench",
                    default=os.path.join(repo, "BENCH_serving.json"))
    ap.add_argument("--baseline",
                    default=os.path.join(repo, "BENCH_baseline.json"))
    ap.add_argument("--tolerance", type=float, default=None,
                    help="relative budget for lower-better (timing) "
                         f"metrics (default {DEFAULT_TOLERANCE})")
    ap.add_argument("--quality-tolerance", type=float, default=None,
                    help="relative budget for higher-better metrics "
                         f"(default {DEFAULT_QUALITY_TOLERANCE})")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke budgets: looser timing tolerance "
                         "for reduced-size rows on shared runners")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report on stdout")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current bench "
                         "(run after an intentional perf change)")
    ap.add_argument("--append-history", metavar="FILE", default=None,
                    help="append a sha-stamped JSONL entry with the "
                         "full record set")
    args = ap.parse_args(argv)

    tolerance = args.tolerance if args.tolerance is not None else (
        SMOKE_TOLERANCE if args.smoke else DEFAULT_TOLERANCE)
    quality = args.quality_tolerance \
        if args.quality_tolerance is not None else (
            SMOKE_QUALITY_TOLERANCE if args.smoke
            else DEFAULT_QUALITY_TOLERANCE)

    try:
        bench = load_bench(args.bench)
    except (OSError, ValueError) as e:
        print(f"perf_gate: cannot load bench: {e}", file=sys.stderr)
        return 2

    if args.update_baseline:
        with open(args.baseline, "w") as f:
            json.dump({"benchmark": "BENCH_baseline",
                       "records": bench}, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"perf_gate: baseline updated from {args.bench} "
              f"({len(bench)} rows)")
        if args.append_history:
            append_history(args.append_history, bench, git_sha(repo))
        return 0

    try:
        baseline = load_bench(args.baseline)
    except (OSError, ValueError) as e:
        print(f"perf_gate: cannot load baseline: {e}", file=sys.stderr)
        return 2

    regressions, infos = compare(bench, baseline, tolerance, quality)
    if args.append_history:
        append_history(args.append_history, bench, git_sha(repo))

    if args.json:
        print(json.dumps({
            "ok": not regressions,
            "tolerance": tolerance,
            "quality_tolerance": quality,
            "regressions": regressions,
            "infos": infos,
        }, indent=2, sort_keys=True))
    else:
        for line in infos:
            print(f"perf_gate [info] {line}")
        for line in regressions:
            print(f"perf_gate [REGRESSION] {line}")
        n_rows = sum(1 for r in baseline if r in bench)
        if regressions:
            print(f"perf_gate: {len(regressions)} regression(s) across "
                  f"{len(baseline)} baselined rows")
        else:
            print(f"perf_gate: clean ({n_rows}/{len(baseline)} "
                  f"baselined rows checked, +{tolerance:.0%} time / "
                  f"-{quality:.0%} quality budgets)")
    return 1 if regressions else 0


if __name__ == "__main__":
    raise SystemExit(main())
