#!/usr/bin/env bash
# Fast tier-1 selection: everything except the @pytest.mark.slow
# end-to-end tests (offline-phase training + long missions), so CI gets a
# signal in minutes. The full suite remains the default `pytest` run.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -q -m "not slow" "$@"
