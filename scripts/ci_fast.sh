#!/usr/bin/env bash
# Fast tier-1 selection: everything except the @pytest.mark.slow
# end-to-end tests (offline-phase training + long missions), so CI gets a
# signal in minutes. The full suite remains the default `pytest` run.
# Finishes with an engine smoke: a short serve through the AveryEngine
# front door (random-init weights) so the fast path exercises prompt
# gating -> policy -> channel -> batched cloud serving end to end.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
echo "[ci_fast] averylint (static invariants + runtime sanitizer smoke)"
# repo-aware lints first: recompile/host-sync/future/refcount/determinism
# findings fail fast before the test suite spends minutes compiling, then
# a short serve under the recompile + transfer sanitizers proves the
# steady-state decode pump stays churn-free (see docs/analysis.md)
python -m repro.analysis.lint src/
python -m repro.analysis.sanitizers --smoke
python -m pytest -q -m "not slow" "$@"
echo "[ci_fast] engine smoke (microbatch + inflight)"
python -m repro.launch.serve --duration 2 --smoke --max-batch 4
python -m repro.launch.serve --duration 2 --smoke --max-batch 4 --batching inflight
echo "[ci_fast] paged shared-prefix serving smoke"
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving --paged-smoke
echo "[ci_fast] speculative decoding smoke"
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving --spec-smoke
echo "[ci_fast] sharded serving smoke (8-device host-platform mesh)"
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving --sharded-smoke
echo "[ci_fast] chaos storm smoke (retry/downshift/deadline, zero leaks)"
# chaos_rows asserts the fault-tolerance contract itself: every future
# resolves, >=1 successful downshifted retry, >=1 deadline cancel, and
# zero leaked KV pages — a broken engine fails this step, not just a row
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving --chaos-smoke
echo "[ci_fast] fleet storm smoke (QoS scheduling vs FIFO)"
# fleet_storm_rows asserts the multi-tenant scheduling contract: Context
# p99 strictly beats FIFO on the same trace, Jain >= 0.9, >=1 preemption
# with token-exact resume, >=1 rate-limit rejection, zero leaked pages
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving --fleet-storm-smoke
echo "[ci_fast] trace smoke (span tracer + flight recorder)"
# a traced profiled-path serve through a blackout window: the retry spans
# must pass the lifecycle validator, the Perfetto export must round-trip,
# and the flight-recorder ring must dump the journey — all on the
# LUT-profiled engine, no executor/model (observability itself stays
# jax-free: averylint AV201 + test_host_only_modules_have_no_jax_imports)
python - <<'EOF'
import glob, json, os
from repro.core.lut import paper_lut
from repro.engine import (AveryEngine, FaultInjector, LoopbackTransport,
                          RetryPolicy)
from repro.engine.observability import validate_chrome_trace, validate_traces
art = os.path.join("benchmarks", "artifacts")
engine = AveryEngine(
    lut=paper_lut(), trace=True,
    flight_dir=os.path.join(art, "flight_ci_smoke"),
    transport=FaultInjector(LoopbackTransport(20.0), blackouts=[(0.0, 30.0)]),
    retry=RetryPolicy(max_attempts=3, backoff_base_s=1.0))
sess = engine.session("uav-ci")
res = sess.submit_frame(0.0)
assert res.feasible and res.attempts == 2, res
problems = validate_traces(engine.tracer)
assert not problems, problems
path = engine.dump_trace(os.path.join(art, "trace_ci_smoke.json"))
problems = validate_chrome_trace(json.load(open(path)))
assert not problems, problems
dump = engine.dump_flight(os.path.join(art, "flight_ci_smoke", "manual.json"))
assert dump and json.load(open(dump))["events"], dump
for f in glob.glob(os.path.join(art, "flight_ci_smoke", "*.json")):
    os.remove(f)
os.rmdir(os.path.join(art, "flight_ci_smoke"))
print("trace smoke ok:", path)
EOF
echo "[ci_fast] profiled frame smoke (stage profiler + device track)"
# profiled_rows asserts the device-observability contract: the profiled
# serve is token-exact with the bare one, every served response carries
# a positive FLOPs/energy ledger, and the validated Perfetto artifact
# contains the pid-3 device track (docs/observability.md section Profiler)
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving --profiled-smoke
echo "[ci_fast] perf gate (bench rows vs committed baseline)"
# the smoke budgets tolerate shared-runner wall noise; quality metrics,
# zero-tolerance page leaks, and missing rows still gate hard
python scripts/perf_gate.py --smoke --append-history BENCH_history.jsonl
