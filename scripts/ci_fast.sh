#!/usr/bin/env bash
# Fast tier-1 selection: everything except the @pytest.mark.slow
# end-to-end tests (offline-phase training + long missions), so CI gets a
# signal in minutes. The full suite remains the default `pytest` run.
# Finishes with an engine smoke: a short serve through the AveryEngine
# front door (random-init weights) so the fast path exercises prompt
# gating -> policy -> channel -> batched cloud serving end to end.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
echo "[ci_fast] averylint (static invariants + runtime sanitizer smoke)"
# repo-aware lints first: recompile/host-sync/future/refcount/determinism
# findings fail fast before the test suite spends minutes compiling, then
# a short serve under the recompile + transfer sanitizers proves the
# steady-state decode pump stays churn-free (see docs/analysis.md)
python -m repro.analysis.lint src/
python -m repro.analysis.sanitizers --smoke
python -m pytest -q -m "not slow" "$@"
echo "[ci_fast] engine smoke (microbatch + inflight)"
python -m repro.launch.serve --duration 2 --smoke --max-batch 4
python -m repro.launch.serve --duration 2 --smoke --max-batch 4 --batching inflight
echo "[ci_fast] paged shared-prefix serving smoke"
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving --paged-smoke
echo "[ci_fast] speculative decoding smoke"
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving --spec-smoke
echo "[ci_fast] sharded serving smoke (8-device host-platform mesh)"
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving --sharded-smoke
echo "[ci_fast] chaos storm smoke (retry/downshift/deadline, zero leaks)"
# chaos_rows asserts the fault-tolerance contract itself: every future
# resolves, >=1 successful downshifted retry, >=1 deadline cancel, and
# zero leaked KV pages — a broken engine fails this step, not just a row
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving --chaos-smoke
echo "[ci_fast] fleet storm smoke (QoS scheduling vs FIFO)"
# fleet_storm_rows asserts the multi-tenant scheduling contract: Context
# p99 strictly beats FIFO on the same trace, Jain >= 0.9, >=1 preemption
# with token-exact resume, >=1 rate-limit rejection, zero leaked pages
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serving --fleet-storm-smoke
