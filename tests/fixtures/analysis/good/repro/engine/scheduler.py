"""averylint fixture: host-only module staying pure Python (no AV201)."""
import numpy as np


def pick(scores):
    return int(np.argmax(np.asarray(scores)))
