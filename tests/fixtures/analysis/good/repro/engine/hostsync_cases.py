"""averylint fixture: host-sync negatives — static-shape reads and
host-side sync are all fine."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def shape_math(x):
    b, t, pp = x.shape
    s = int(round(pp ** 0.5))            # shape-derived: static, fine
    n = int(x.shape[0])
    return x.reshape(b, t * s, s // s)[:n]


@jax.jit
def device_branchless(x):
    return jnp.where(x > 0, x, -x)       # branchless: fine


def host_side(x):
    arr = np.asarray(x)                  # outside tracing: fine
    if float(arr[0]) > 0:
        return int(arr.sum())
    return arr.item()
