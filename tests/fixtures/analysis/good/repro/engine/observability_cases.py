"""AV6xx negatives: every sanctioned bounding idiom, exercised."""
from collections import deque

MAX_EVENTS = 16


class RingDecoder:
    """deque(maxlen=...) is the sanctioned ring idiom."""

    def __init__(self):
        self.events = deque(maxlen=MAX_EVENTS)

    def on_event(self, ev):
        self.events.append(ev)          # bounded by the ring


class GuardedFuture:
    """The cap-and-count idiom (RequestFuture.emit)."""

    def __init__(self):
        self.events = []
        self.dropped = 0

    def emit(self, ev):
        if len(self.events) < MAX_EVENTS:
            self.events.append(ev)
        else:
            self.dropped += 1


class DrainingEngine:
    """Reassignment outside __init__ is a drain path (engine._order)."""

    def __init__(self):
        self.order = []
        self.records = []

    def submit(self, rid):
        self.order.append(rid)

    def drain(self):
        done, remaining = [], []
        for rid in self.order:
            (done if rid < 0 else remaining).append(rid)
        self.order = remaining
        return done

    def send(self, rec):
        self.records.append(rec)
        del self.records[:-MAX_EVENTS]   # del-slice bound (transport)


class SessionIndex:
    """The appended value escapes: an index of caller-owned objects
    (engine.session), not an event log."""

    def __init__(self):
        self.sessions = []

    def session(self, operator_id):
        sess = {"operator_id": operator_id}
        self.sessions.append(sess)
        return sess


class PoppingQueue:
    """A shrinking method anywhere in the class counts as a bound."""

    def __init__(self):
        self.queue = []

    def push(self, item):
        self.queue.append(item)

    def pop_next(self):
        return self.queue.pop(0) if self.queue else None


class WallclockTimer:
    """The sanctioned wall-time idiom (AV603 negative): the clock is
    injected once at construction — engine code only ever calls the
    hook, never the stdlib directly."""

    def __init__(self, wallclock=None):
        self._wallclock = wallclock

    def measure(self, fn):
        wc = self._wallclock
        w0 = wc() if wc is not None else 0.0
        out = fn()
        return out, (wc() - w0 if wc is not None else 0.0)


def perf_counter():
    """A local name shadowing the stdlib clock: AV603 resolves calls
    through the module's import maps, so this is not a clock read."""
    return 0.0


def step_budget():
    return perf_counter()
