"""averylint fixture: recompile checker negatives — every sanctioned
jit placement in the tree, none should be flagged."""
import functools

import jax
import jax.numpy as jnp

MODULE_JIT = jax.jit(lambda v: v * 2)          # module level: built once


@jax.jit
def decorated(v):                              # decorator: built once
    return v + 1


@functools.lru_cache(maxsize=None)
def memoised_factory(width):                   # keyed by lru_cache
    return jax.jit(lambda v: v[:width])


class Executor:
    def __init__(self):
        self._compiled = {}
        self._fixed = jax.jit(lambda v: v - 1)  # constructor: per object

    def _stage_fn(self, width):
        def fn(v):
            return v[:width]
        return fn

    def jitted(self, stage, width):
        key = (stage, width)
        if key not in self._compiled:          # the executor's keyed cache
            fn = jax.jit(self._stage_fn(width))
            self._compiled[key] = fn
        return self._compiled[key]


def training_driver(steps, batches):
    step = jax.jit(lambda v: jnp.tanh(v))      # bound once, amortized
    out = []
    for b in batches:
        out.append(step(b))
    return out


def factory(width):
    return jax.jit(lambda v: v[:width])        # caller owns the cache


def aot_compile(fn, args):
    return jax.jit(fn).lower(*args).compile()  # deliberate AOT
