"""averylint fixture: refcount-discipline negatives — the decoder's
actual idioms, none should be flagged."""


class SlotState:
    def __init__(self, private_ids):
        self.private_ids = private_ids


class CarefulDecoder:
    def __init__(self, pool):
        self.pool = pool
        self.active = {}

    def admit_guarded(self, n, entry, slot):
        ids = self.pool.alloc(n)             # released on the unwind
        try:
            self._prefill(entry, ids)
        except RuntimeError:
            self.pool.release(ids)
            raise
        self.pool.retain(entry.page_ids)     # same guard discipline
        try:
            private = self.pool.alloc(2)     # escapes into the slot owner
            self.active[slot] = SlotState(private_ids=private)
        except RuntimeError:
            self.pool.release(entry.page_ids)
            raise

    def _park_slot(self, slot):
        st = self.active.pop(slot)
        self.pool.release(st.private_ids)    # unwind helper: exempt
        self.pool.retain(st.private_ids)

    def _finally_guarded(self, n):
        ids = self.pool.alloc(n)
        try:
            return self._prefill(None, ids)
        finally:
            self.pool.release(ids)

    def _prefill(self, entry, ids):
        raise RuntimeError("stage fault")


class PagePool:
    """The pool's own bookkeeping is exempt wholesale."""

    def put_prefix(self, key, entry):
        self.retain(entry.page_ids)

    def retain(self, ids):
        pass

    def release(self, ids):
        pass
