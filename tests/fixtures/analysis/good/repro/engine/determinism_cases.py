"""averylint fixture: determinism negatives — seeded and ordered, none
should be flagged."""
import numpy as np


def seeded_draw(seed):
    rng = np.random.RandomState(seed)            # mission-seeded: fine
    return rng.rand()


def mission_stamp(request):
    return request.time_s                        # mission clock: fine


def pick_slot(slots, active):
    return min(set(slots) - set(active))         # order-free reduce: fine


def walk_sorted(slots):
    out = []
    for s in sorted(set(slots)):                 # sorted first: fine
        out.append(s)
    return out
