"""averylint fixture: future-resolution negatives — the engine's
actual discipline, none should be flagged."""
from repro.engine.api import RequestFuture, Response


class CarefulEngine:
    def __init__(self):
        self._futures = {}

    def register(self, request):
        fut = RequestFuture(request, self)   # stored + returned: fine
        self._futures[request.request_id] = fut
        return fut

    def resolve_inline(self, request):
        fut = RequestFuture(request, self)   # resolved locally: fine
        fut.set_result(Response(request_id=0, operator_id="", intent=None))

    def pump_resolves_on_error(self, rid):
        fut = self._futures[rid]
        try:
            self._serve(fut)
        except RuntimeError:                 # resolves on the unwind
            fut.set_result(Response(request_id=rid, operator_id="",
                                    intent=None))

    def pump_delegates(self, rid):
        fut = self._futures[rid]
        try:
            self._serve(fut)
        except RuntimeError as err:          # fail helper owns the unwind
            self._fail_request(fut, err)

    def pump_reraises(self, rid):
        fut = self._futures[rid]
        try:
            self._serve(fut)
        except RuntimeError:                 # caller owns the unwind
            raise

    def _serve(self, fut):
        fut.set_result(Response(request_id=0, operator_id="", intent=None))

    def _fail_request(self, fut, err):
        fut.set_result(Response(request_id=0, operator_id="", intent=None,
                                failure=str(err)))
