"""averylint fixture: future-resolution positives (AV301/AV302)."""
from repro.engine.api import RequestFuture, Response


class LeakyEngine:
    def __init__(self):
        self._futures = {}

    def submit_dropped(self, request):
        fut = RequestFuture(request, self)   # AV301: never stored,
        fut.emit("queued")                   # returned, or resolved
        return request.request_id

    def pump_swallows(self, rid):
        fut = self._futures[rid]
        try:
            fut.emit("serving")
            self._serve(fut)
        except RuntimeError:                 # AV302: swallowed — the
            pass                             # request leaks unresolved

    def _serve(self, fut):
        fut.set_result(Response(request_id=0, operator_id="", intent=None))
