"""averylint fixture: recompile checker positives (AV101/AV102)."""
import jax
import jax.numpy as jnp


def per_request_jit(x):              # AV101: fresh traced wrapper per call
    fn = jax.jit(lambda v: v * 2)
    return fn(x)


def immediate_invoke_in_loop(xs):    # AV101: new lambda identity per iter
    out = []
    for x in xs:
        out.append(jax.jit(lambda v: jnp.tanh(v))(x))
    return out


def bare_expression(x):              # AV101: result not even bound
    jax.jit(lambda v: v + 1)
    return x


class Churner:
    def pump(self, qlen):            # AV102: captures per-call qlen in an
        self._fn = jax.jit(lambda v: v[:qlen])   # unkeyed attribute slot
        return self._fn
