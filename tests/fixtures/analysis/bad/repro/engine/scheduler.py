"""averylint fixture: host-only module importing jax (AV201)."""
import jax.numpy as jnp
from jax import jit


def pick(scores):
    return jnp.argmax(jnp.asarray(scores))
