"""AV6xx positives: prints on the serving path, unbounded event lists."""


def debug_print(response):
    # AV601: stdout is the bench report, not a log sink
    print("served", response.request_id)


class LeakyDecoder:
    """Accumulates per-event state forever: a mission-lifetime decoder
    whose lists nothing bounds."""

    def __init__(self):
        self.events = []
        self.step_log = []

    def on_event(self, ev):
        # AV602: plain list, no deque, no len() guard, no drain path
        self.events.append(ev)

    def step(self, result):
        # AV602: same shape, second attribute
        self.step_log.append(result)
        print("step", result)               # AV601 inside a class too
