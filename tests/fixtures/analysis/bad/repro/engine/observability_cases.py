"""AV6xx positives: prints on the serving path, unbounded event lists,
direct host-clock reads."""
import time as _t
from time import perf_counter


def debug_print(response):
    # AV601: stdout is the bench report, not a log sink
    print("served", response.request_id)


def stamp_response(response):
    # AV603: aliased-module attribute call reads the host clock
    response.t_wall = _t.time()


def measure_step(step):
    # AV603: from-imported clock, both float and _ns spellings
    w0 = perf_counter()
    step()
    return _t.monotonic_ns() - int(w0 * 1e9)


class LeakyDecoder:
    """Accumulates per-event state forever: a mission-lifetime decoder
    whose lists nothing bounds."""

    def __init__(self):
        self.events = []
        self.step_log = []

    def on_event(self, ev):
        # AV602: plain list, no deque, no len() guard, no drain path
        self.events.append(ev)

    def step(self, result):
        # AV602: same shape, second attribute
        self.step_log.append(result)
        print("step", result)               # AV601 inside a class too
