"""averylint fixture: determinism positives (AV501-AV504)."""
import os
import random
import time
import uuid

import numpy as np


def jitter():
    return np.random.rand() * random.random()    # AV501 x2: global RNGs


def unseeded():
    rng = np.random.RandomState()                # AV501: entropy-seeded
    return rng.rand()


def stamp():
    return time.time()                           # AV502: wall clock


def walk_slots(slots):
    out = []
    for s in set(slots):                         # AV503: hash order
        out.append(s)
    return out


def fresh_id():
    return uuid.uuid4().hex + os.urandom(4).hex()  # AV504 x2: entropy
