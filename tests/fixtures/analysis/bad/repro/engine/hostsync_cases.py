"""averylint fixture: host-sync positives inside traced code
(AV202/AV203)."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def readback(x):
    return x * x.item()                  # AV202: .item() under tracing


@jax.jit
def concretise(x):
    return x * float(x[0])               # AV202: float() on a tracer


@jax.jit
def host_copy(x):
    return jnp.sum(np.asarray(x))        # AV202: np.asarray on a tracer


@jax.jit
def tracer_branch(x):
    if jnp.any(x > 0):                   # AV203: control flow on device
        return x
    return -x


def helper(x):
    return bool(x.sum())                 # AV202 via the traced closure


@jax.jit
def calls_helper(x):
    return helper(x)
