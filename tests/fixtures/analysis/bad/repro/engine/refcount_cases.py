"""averylint fixture: refcount-discipline positives (AV401)."""


class LeakyDecoder:
    def __init__(self, pool):
        self.pool = pool

    def admit_bare_alloc(self, n, entry):
        ids = self.pool.alloc(n)             # AV401: an exception in
        self._prefill(entry, ids)            # _prefill leaks the pages

    def hit_bare_retain(self, entry):
        self.pool.retain(entry.page_ids)     # AV401: no unwind release
        self._prefill(entry, entry.page_ids)

    def _prefill(self, entry, ids):
        raise RuntimeError("stage fault")
