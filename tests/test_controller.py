"""Algorithm-1 controller: unit tests against the paper's published
operating points + hypothesis property tests on the selection invariants."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # minimal envs: seeded-sampling fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import (Intent, IntentRequirements, MissionGoal,
                        NoFeasibleInsightTier, PowerConfig, paper_lut,
                        select_configuration)
from repro.core.controller import min_bandwidth_for_tier
from repro.core.intent import classify_intent
from repro.core.lut import SystemLUT, Tier

LUT = paper_lut()
REQ = IntentRequirements(min_update_pps=0.5)
PC = PowerConfig()


def sel(bw, goal=MissionGoal.PRIORITIZE_ACCURACY, intent=Intent.INSIGHT,
        req=REQ, lut=LUT):
    return select_configuration(bw, PC, goal, intent, req, lut)


# ------------------------------ unit --------------------------------------


def test_paper_thresholds():
    """§3.3: High-Accuracy needs >= 11.68 Mbps at 0.5 PPS."""
    assert min_bandwidth_for_tier(LUT.by_name("High Accuracy"), 0.5) == \
        pytest.approx(11.68)


def test_accuracy_mode_picks_high_accuracy_when_feasible():
    out = sel(15.0)
    assert out.tier.name == "High Accuracy"


def test_accuracy_mode_degrades_to_balanced_below_threshold():
    out = sel(10.0)   # 10 < 11.68, Balanced needs 5.4
    assert out.tier.name == "Balanced"


def test_throughput_mode_picks_smallest_payload():
    out = sel(15.0, goal=MissionGoal.PRIORITIZE_THROUGHPUT)
    assert out.tier.name == "High Throughput"


def test_context_intent_early_return():
    out = sel(15.0, intent=Intent.CONTEXT)
    assert out.stream == "context" and out.tier is None


def test_no_feasible_tier_raises():
    with pytest.raises(NoFeasibleInsightTier):
        sel(1.0)      # High Throughput needs 3.32 Mbps


def test_fidelity_floor_filters_tiers():
    """Q_I (paper §3.3 formal model): a high fidelity floor excludes the
    low-accuracy tiers even when they satisfy timeliness."""
    req = IntentRequirements(min_update_pps=0.5, min_fidelity=0.83)
    out = select_configuration(20.0, PC, MissionGoal.PRIORITIZE_THROUGHPUT,
                               Intent.INSIGHT, req, LUT)
    assert out.tier.name == "High Accuracy"   # only tier with acc >= 0.83
    with pytest.raises(NoFeasibleInsightTier):
        # HA needs 11.68 Mbps: at 8 Mbps nothing satisfies both floors
        select_configuration(8.0, PC, MissionGoal.PRIORITIZE_ACCURACY,
                             Intent.INSIGHT, req, LUT)


def test_intent_classifier():
    assert classify_intent(
        "Highlight the living beings on that roof") is Intent.INSIGHT
    assert classify_intent(
        "What is happening in this sector?") is Intent.CONTEXT
    assert classify_intent(
        "Are there any persons near the submerged car?") is Intent.CONTEXT
    assert classify_intent(
        "Segment the vehicles stranded by floodwater") is Intent.INSIGHT


# --------------------------- properties ------------------------------------

tiers_strategy = st.lists(
    st.builds(
        Tier,
        name=st.sampled_from(["A", "B", "C", "D"]),
        ratio=st.floats(0.01, 0.5),
        acc_base=st.floats(0.3, 0.95),
        acc_finetuned=st.floats(0.3, 0.95),
        payload_mb=st.floats(0.05, 10.0),
    ),
    min_size=1, max_size=4, unique_by=lambda t: t.name)


@given(bw=st.floats(0.1, 100.0), tiers=tiers_strategy,
       fi=st.floats(0.05, 5.0),
       goal=st.sampled_from(list(MissionGoal)))
@settings(max_examples=200, deadline=None)
def test_selection_always_feasible(bw, tiers, fi, goal):
    """Whatever is selected satisfies the F_I timeliness floor; if nothing
    can, NoFeasibleInsightTier is raised (Algorithm 1 lines 22-28)."""
    lut = SystemLUT(tiers=tiers)
    req = IntentRequirements(min_update_pps=fi)
    try:
        out = select_configuration(bw, PC, goal, Intent.INSIGHT, req, lut)
    except NoFeasibleInsightTier:
        assert all(t.max_pps(bw) < fi for t in tiers)
        return
    assert out.throughput_pps >= fi
    assert out.tier.max_pps(bw) == pytest.approx(out.throughput_pps)
    feas = [t for t in tiers if t.max_pps(bw) >= fi]
    if goal is MissionGoal.PRIORITIZE_ACCURACY:
        assert out.tier.acc_base == max(t.acc_base for t in feas)
    else:
        assert out.tier.payload_mb == min(t.payload_mb for t in feas)


@given(bw_lo=st.floats(1.0, 50.0), delta=st.floats(0.1, 50.0))
@settings(max_examples=100, deadline=None)
def test_accuracy_monotone_in_bandwidth(bw_lo, delta):
    """More bandwidth never selects a lower-fidelity tier in accuracy mode
    (paper Fig. 9b's switching behaviour)."""
    def acc_at(bw):
        try:
            return sel(bw).tier.acc_base
        except NoFeasibleInsightTier:
            return -1.0
    assert acc_at(bw_lo + delta) >= acc_at(bw_lo)


@given(bw=st.floats(3.4, 100.0))
@settings(max_examples=100, deadline=None)
def test_throughput_goal_maximises_pps(bw):
    out_t = sel(bw, goal=MissionGoal.PRIORITIZE_THROUGHPUT)
    out_a = sel(bw, goal=MissionGoal.PRIORITIZE_ACCURACY)
    assert out_t.throughput_pps >= out_a.throughput_pps
    assert out_a.tier.acc_base >= out_t.tier.acc_base
