"""Engine observability: the span tracer (lifecycle invariants,
Perfetto export, zero-residue when disabled), the metrics registry
(log-bucket histograms, get-or-create instruments), the flight
recorder (bounded ring, autodump on hard failures), the ``stats()``
key-stability snapshot, and the <5% tracing-overhead budget."""
import json
import time

import dataclasses
import numpy as np
import pytest

from repro.core.intent import Intent
from repro.engine import (AveryEngine, FaultyExecutor, QoSScheduler,
                          RetryPolicy)
from repro.engine.observability import (FlightRecorder, Histogram,
                                        MetricsRegistry, RequestTrace,
                                        Span, Tracer, validate_chrome_trace,
                                        validate_trace, validate_traces)

from test_engine import LUT, StubExecutor, _edge_requests, _insight_images

REQUIRED_SNAPSHOT = "tests/fixtures/engine_stats_keys.json"


# ---- Histogram: log buckets, percentiles, O(1) memory ----


def test_histogram_empty_and_single_value():
    h = Histogram("ttft_s")
    assert h.p50 == 0.0 and h.mean == 0.0
    assert h.as_dict()["count"] == 0 and h.as_dict()["min"] == 0.0
    for _ in range(5):
        h.observe(0.5)
    # vmin == vmax clamps every percentile to the exact value
    assert h.count == 5 and h.mean == 0.5
    assert h.p50 == 0.5 and h.p95 == 0.5 and h.p99 == 0.5


def test_histogram_percentiles_ordered_and_bounded():
    h = Histogram("queue_wait_s", lo=1e-3, hi=1e3, per_decade=8)
    rng = np.random.RandomState(0)
    vals = rng.lognormal(mean=0.0, sigma=1.5, size=500)
    for v in vals:
        h.observe(float(v))
    assert h.count == 500
    assert h.vmin <= h.p50 <= h.p95 <= h.p99 <= h.vmax
    # one-bucket resolution: the p50 estimate brackets the true median
    true = float(np.median(vals))
    assert h.p50 <= true * 10 ** (1 / 8) + 1e-12
    assert h.p50 >= true * 10 ** (-1 / 8) - 1e-12


def test_histogram_underflow_overflow_and_validation():
    h = Histogram("x", lo=0.1, hi=10.0, per_decade=4)
    h.observe(0.001)                      # underflow bucket
    h.observe(1e5)                        # overflow bucket
    assert h.counts[0] == 1 and h.counts[-1] == 1
    assert h.p99 == 1e5                   # overflow reads the true max
    assert h.vmin == 0.001
    with pytest.raises(ValueError):
        Histogram("bad", lo=0.0, hi=1.0)
    with pytest.raises(ValueError):
        Histogram("bad", lo=1.0, hi=1.0)


def test_histogram_one_and_two_sample_percentiles():
    h = Histogram("x")
    h.observe(0.2)
    # a single sample IS every percentile (vmin == vmax clamp)
    assert h.p50 == h.p95 == h.p99 == 0.2
    h2 = Histogram("y")
    h2.observe(0.1)
    h2.observe(10.0)
    assert h2.count == 2 and h2.vmin == 0.1 and h2.vmax == 10.0
    # two samples: p50 resolves to the low bucket, p99 to the high one,
    # and the monotone-in-q contract holds
    assert 0.1 <= h2.p50 <= h2.p99 <= 10.0
    assert h2.p50 < 1.0 < h2.p99


def test_histogram_underflow_only_and_overflow_only():
    under = Histogram("u", lo=0.1, hi=10.0, per_decade=4)
    for _ in range(3):
        under.observe(1e-3)
    assert under.counts[0] == 3 and sum(under.counts[1:]) == 0
    # percentiles clamp to the true observed range, never to bucket lo
    assert under.p50 == under.p99 == 1e-3
    over = Histogram("o", lo=0.1, hi=10.0, per_decade=4)
    for _ in range(3):
        over.observe(1e4)
    assert over.counts[-1] == 3 and sum(over.counts[:-1]) == 0
    assert over.p50 == over.p99 == 1e4


def test_histogram_merge_adds_and_checks_geometry():
    a = Histogram("ttft_s")
    b = Histogram("ttft_s")
    for v in (0.001, 0.5, 2.0):
        a.observe(v)
    for v in (0.25, 1e5):
        b.observe(v)                       # 1e5 lands in b's overflow
    out = a.merge(b)
    assert out is a                        # merge-in-place, chainable
    assert a.count == 5
    assert a.counts[-1] == 1               # overflow carried across
    assert a.vmin == 0.001 and a.vmax == 1e5
    assert a.total == pytest.approx(0.001 + 0.5 + 2.0 + 0.25 + 1e5)
    assert a.vmin <= a.p50 <= a.p99 <= a.vmax
    # geometry must match exactly: different lo, hi, or resolution all
    # refuse rather than silently mis-bucket
    for other in (Histogram("g", lo=1e-3, hi=1e4),
                  Histogram("g", lo=1e-4, hi=1e3),
                  Histogram("g", lo=1e-4, hi=1e4, per_decade=4)):
        with pytest.raises(ValueError):
            a.merge(other)


def test_histogram_memory_is_fixed():
    h = Histogram("x", lo=1e-4, hi=1e4, per_decade=8)
    n_buckets = len(h.counts)
    for i in range(10_000):
        h.observe(1e-5 + i)
    assert len(h.counts) == n_buckets     # no unbounded sample list
    assert h.count == 10_000


def test_metrics_registry_get_or_create():
    r = MetricsRegistry()
    assert r.counter("served") is r.counter("served")
    r.counter("served").inc(3)
    assert r.counter("served").value == 3
    r.gauge("depth").set(7)
    assert r.gauge("depth").value == 7.0
    # histogram params bind on first touch only
    h = r.histogram("tok_s", hi=1e6)
    assert r.histogram("tok_s") is h
    h.observe(2.0)
    flat = r.as_dict()
    assert flat["served"] == 3 and flat["depth"] == 7.0
    assert flat["tok_s/count"] == 1 and flat["tok_s/p50"] == 2.0


# ---- Tracer: caps, disabled residue, Chrome export ----


def test_tracer_disabled_records_nothing():
    t = Tracer()                          # disabled by default
    t.begin(1, "op", intent="INSIGHT", t=0.0)
    t.span(1, "transmit", 0.0, 1.0)
    t.point(1, "retry", 2.0)
    assert len(t) == 0 and t.trace(1) is None
    assert t.to_chrome()["traceEvents"][0]["ph"] == "M"   # meta only


def test_tracer_event_and_request_caps():
    t = Tracer(enabled=True, max_requests=2, max_events=4)
    for i in range(6):
        t.span(7, "decode", float(i), float(i) + 0.5)
    tr = t.trace(7)
    assert len(tr.spans) == 4 and tr.dropped == 2
    t.begin(8, "a")
    t.begin(9, "b")                       # rid 7 evicted (oldest)
    assert len(t) == 2 and t.trace(7) is None and t.n_evicted == 1
    t.clear()
    assert len(t) == 0 and t.n_evicted == 0


def test_validate_trace_catches_each_violation():
    def one(spans=(), points=()):
        tr = RequestTrace(request_id=1)
        tr.spans = list(spans)
        tr.points = list(points)
        return validate_trace(tr)

    assert one() == []
    assert "unknown phase" in one([Span("bogus", 0, 0)])[0]
    assert "ends before" in one([Span("decode", 2.0, 1.0)])[0]
    assert "overlaps" in one([Span("transmit", 0, 1),
                              Span("queue", 0.5, 2)])[0]
    assert "resumes" in one(points=[Span("resume", 1, 1)])[0]
    assert "served with" in one(points=[Span("park", 1, 1),
                                        Span("served", 2, 2)])[0]
    assert "after the cancel" in one(points=[Span("cancelled", 1, 1),
                                             Span("retry", 2, 2)])[0]
    # the paired forms pass
    assert one(points=[Span("park", 1, 1), Span("resume", 2, 2),
                       Span("served", 3, 3)]) == []


def test_chrome_export_tracks_and_validation(tmp_path):
    t = Tracer(enabled=True)
    t.begin(0, "uav-A", intent="INSIGHT", t=0.0)
    t.span(0, "transmit", 0.0, 1.0)
    t.span(0, "decode", 1.0, 2.0, slot=3)
    t.point(0, "served", 2.0)
    doc = t.to_chrome()
    evs = doc["traceEvents"]
    names = {(e["ph"], e.get("pid")) for e in evs}
    assert ("X", 1) in names and ("X", 2) in names    # both track families
    slot_meta = [e for e in evs if e["ph"] == "M"
                 and e["args"].get("name") == "slot 3"]
    assert slot_meta and slot_meta[0]["pid"] == 2
    span = next(e for e in evs if e["ph"] == "X" and e["pid"] == 1
                and e["name"] == "transmit")
    assert span["ts"] == 0.0 and span["dur"] == pytest.approx(1e6)
    assert validate_chrome_trace(doc) == []
    path = t.dump(str(tmp_path / "sub" / "trace.json"))
    assert validate_chrome_trace(json.loads(
        (tmp_path / "sub" / "trace.json").read_text())) == []
    assert path.endswith("trace.json")
    assert validate_chrome_trace({"nope": 1}) != []
    assert validate_chrome_trace({"traceEvents": []}) != []


# ---- FlightRecorder: bounded ring, dumps ----


def test_flight_recorder_ring_and_dump(tmp_path):
    fr = FlightRecorder(capacity=4)
    for i in range(10):
        fr.record("tick", float(i), request_id=i)
    assert len(fr) == 4 and fr.n_recorded == 10
    assert [e["rid"] for e in fr.snapshot()] == [6, 7, 8, 9]
    # no autodump dir, no explicit path: a no-op
    assert fr.dump("oops") is None and fr.n_dumps == 0
    p = fr.dump("oops", path=str(tmp_path / "f.json"),
                stats={"completed": 2, "pool": object()})
    doc = json.loads((tmp_path / "f.json").read_text())
    assert doc["reason"] == "oops" and doc["n_recorded"] == 10
    assert len(doc["events"]) == 4
    assert doc["stats"]["completed"] == 2
    assert isinstance(doc["stats"]["pool"], str)     # stringified, not lost
    assert fr.n_dumps == 1 and fr.last_dump == p


def test_flight_recorder_autodump_naming(tmp_path):
    fr = FlightRecorder(capacity=2, autodump_dir=str(tmp_path))
    fr.record("boom", 1.0)
    a = fr.dump("pool_invariant")
    b = fr.dump("pool_invariant")
    assert a.endswith("flight_000_pool_invariant.json")
    assert b.endswith("flight_001_pool_invariant.json")
    assert (tmp_path / "flight_000_pool_invariant.json").is_file()


# ---- engine integration: the microbatch path (host-only) ----


def _stub_serve(trace):
    engine = AveryEngine(lut=LUT, executor=StubExecutor(), trace=trace)
    sess = engine.session("uav-0")
    rng = np.random.RandomState(0)
    q = np.zeros((1, 4), np.int32)
    sess.submit(prompt="is there anyone in the sector?",
                images=_insight_images(rng), query=q, time_s=0.0)
    sess.submit(prompt="segment the stranded person",
                images=_insight_images(rng), query=q, time_s=1.0)
    engine.drain()
    return engine


def test_traced_microbatch_serve_validates(tmp_path):
    engine = _stub_serve(trace=True)
    assert len(engine.tracer) == 2
    for tr in engine.tracer.traces():
        assert tr.operator_id == "uav-0"
        names = [sp.name for sp in tr.spans]
        assert "edge_encode" in names and "transmit" in names
        kinds = [pt.name for pt in tr.points]
        assert "tier_selected" in kinds and "served" in kinds
    assert validate_traces(engine.tracer) == []
    path = engine.dump_trace(str(tmp_path / "trace.json"))
    assert validate_chrome_trace(json.loads(open(path).read())) == []


def test_disabled_tracer_zero_residue_and_identical_stats():
    traced = _stub_serve(trace=True)
    plain = _stub_serve(trace=False)
    assert len(plain.tracer) == 0
    assert plain.stats == traced.stats    # tracing never skews telemetry


def test_engine_accepts_configured_tracer_instance():
    t = Tracer(enabled=True, max_events=8)
    engine = AveryEngine(lut=LUT, executor=StubExecutor(), trace=t)
    assert engine.tracer is t


def test_profiled_frame_tracing_validates(tmp_path):
    """submit_frame (the LUT-profiled mission path run_fleet drives)
    records the same lifecycle spans as submit(): edge_encode + transmit
    per attempt, retry/blackout points across a fault window, a
    zero-length transmit-less record for Context frames."""
    from repro.engine import FaultInjector, LoopbackTransport
    engine = AveryEngine(
        lut=LUT, trace=True,
        transport=FaultInjector(LoopbackTransport(20.0),
                                blackouts=[(0.0, 30.0)]),
        retry=RetryPolicy(max_attempts=3, backoff_base_s=1.0))
    sess = engine.session("uav-7")
    ins = sess.submit_frame(0.0)
    ctx = sess.submit_frame(40.0, intent=Intent.CONTEXT)
    assert ins.feasible and ins.attempts == 2 and ctx.feasible
    assert len(engine.tracer) == 2
    assert validate_traces(engine.tracer) == []
    ins_tr, ctx_tr = engine.tracer.traces()
    # blackout attempt: edge_encode + blackout point, then retry,
    # then a full edge_encode + transmit + served
    names = [sp.name for sp in ins_tr.spans]
    assert names.count("edge_encode") == 2
    assert names.count("transmit") == 1
    kinds = [pt.name for pt in ins_tr.points]
    assert "blackout" in kinds and "retry" in kinds
    assert ins_tr.points[-1].name == "served"
    assert [sp.name for sp in ctx_tr.spans] == ["edge_encode", "transmit"]
    assert ctx_tr.points[-1].name == "served"
    path = engine.dump_trace(str(tmp_path / "fleet_trace.json"))
    assert validate_chrome_trace(json.loads(open(path).read())) == []


# ---- engine integration: the in-flight path (real executor) ----


@pytest.fixture(scope="module")
def executor():
    from repro.configs.lisa_mini import CONFIG as PCFG
    from repro.core import DualStreamExecutor, profile as prof
    params, bns, _ = prof.random_init_system(PCFG, lut=LUT)
    return DualStreamExecutor(pcfg=PCFG, params=params, bottlenecks=bns,
                              lut=LUT, max_new_tokens=3, flash_decode=False)


def test_inflight_trace_full_lifecycle(executor, tmp_path):
    reqs = _edge_requests(executor, 3, seed=11)
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=2, trace=True)
    futs = [engine.submit_packet(p, q, it, time_s=float(i))
            for i, (p, q, it) in enumerate(reqs)]
    engine.drain()
    assert validate_traces(engine.tracer) == []
    for fut in futs:
        res = fut.result()
        assert res.failure is None
        assert res.ttft_s is not None and res.ttft_s >= 0.0
        tr = engine.tracer.trace(res.request_id)
        names = [sp.name for sp in tr.spans]
        assert "transmit" in names and "queue" in names
        assert "decode" in names
        assert ("prefill" in names) or ("prefix_hit" in names)
        assert any(pt.name == "decode_step" for pt in tr.points)
        assert tr.points[-1].name == "served" or "served" in \
            [pt.name for pt in tr.points]
    st = engine.stats
    # i%3==2 is the CONTEXT request -> latency class; the rest throughput
    assert st["ttft_latency_n"] == 1 and st["ttft_throughput_n"] == 2
    assert st["ttft_throughput_p50_s"] >= 0.0
    assert st["transmit_p50_s"] >= 0.0
    path = engine.dump_trace(str(tmp_path / "inflight.json"))
    doc = json.loads(open(path).read())
    assert validate_chrome_trace(doc) == []
    # decode-slot tracks really exist in the export
    assert any(e.get("pid") == 2 and e.get("ph") == "X"
               for e in doc["traceEvents"])


def test_preempted_trace_parks_and_resumes(executor):
    reqs = _edge_requests(executor, 3, seed=61)
    bulk, _, urgent = reqs               # i%3==2 is the CONTEXT request
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=1, debug_invariants=True, trace=True,
                         scheduler=QoSScheduler(latency_patience_s=0.0))
    f_a = engine.submit_packet(*bulk, time_s=0.0)
    f_c = engine.submit_packet(*urgent, time_s=1.0)
    engine.drain()
    assert f_a.result().preemptions == 1
    assert validate_traces(engine.tracer) == []
    tr = engine.tracer.trace(f_a.result().request_id)
    kinds = [pt.name for pt in tr.points]
    assert kinds.count("park") == 1 and kinds.count("resume") == 1
    # one decode span per residency segment, two queue waits
    names = [sp.name for sp in tr.spans]
    assert names.count("decode") == 2 and names.count("queue") == 2
    # the urgent request never parked
    tr_c = engine.tracer.trace(f_c.result().request_id)
    assert "park" not in [pt.name for pt in tr_c.points]


def test_deadline_cancel_trace_and_flight_dump(executor, tmp_path):
    reqs = _edge_requests(executor, 2, seed=17)
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=2, debug_invariants=True, trace=True,
                         flight_dir=str(tmp_path))
    sess = engine.session("op")
    sess.requirements[Intent.INSIGHT] = dataclasses.replace(
        sess.requirements[Intent.INSIGHT], max_latency_s=5.0)
    (p1, q1, _), (p2, q2, i2) = reqs
    late = engine.submit_packet(p1, q1, Intent.INSIGHT, time_s=0.0,
                                session=sess)
    # decoding has started (one pump per submit); the second submission
    # moves the mission clock past the deadline -> mid-decode cancel
    ok = engine.submit_packet(p2, q2, i2, time_s=12.0, session=sess)
    engine.drain()
    assert late.result().failure == "deadline"
    assert ok.result().failure is None
    tr = engine.tracer.trace(late.result().request_id)
    assert tr.points[-1].name == "cancelled"          # terminal event
    assert validate_traces(engine.tracer) == []
    dump = tmp_path / "flight_000_deadline_cancel.json"
    assert dump.is_file()
    doc = json.loads(dump.read_text())
    assert doc["reason"] == "deadline_cancel"
    assert any(e["kind"] == "cancelled" for e in doc["events"])
    assert doc["stats"]["deadline_cancelled"] == 1
    assert engine.stats["flight_dumps"] == 1


def test_terminal_cloud_error_autodumps_flight(executor, tmp_path):
    reqs = _edge_requests(executor, 1, seed=37)
    pkt, q, it = reqs[0]
    faulty = FaultyExecutor(executor,
                            fail_at={"cloud_decode_rows": range(32)})
    engine = AveryEngine(lut=LUT, executor=faulty, batching="inflight",
                         max_batch=2, debug_invariants=True,
                         flight_dir=str(tmp_path),
                         retry=RetryPolicy(max_attempts=2,
                                           backoff_base_s=0.1))
    fut = engine.submit_packet(pkt, q, it, time_s=0.0)
    engine.drain()
    assert fut.result().failure == "cloud_error"
    dump = tmp_path / "flight_000_cloud_error.json"
    assert dump.is_file()
    doc = json.loads(dump.read_text())
    assert doc["reason"] == "cloud_error"
    kinds = {e["kind"] for e in doc["events"]}
    assert "cloud_error" in kinds and "retry" in kinds
    assert doc["stats"]["cloud_errors"] == 1


def test_ttft_percentiles_positive_and_ordered(executor):
    """Regression for the serving/chaos ``ttft_p50_s=0.0`` anomaly:
    over a real (finite-bandwidth) channel every served request's first
    token strictly follows its submission, so whenever anything was
    served the TTFT histogram reports 0 < p50 <= p99. (The anomaly was
    the loopback transport's instant delivery stamping t_first_token at
    submission time — a transport bug surfaced as a percentile bug.)"""
    from repro.engine import ChannelTransport
    from repro.network.traces import constant_trace
    reqs = _edge_requests(executor, 4, seed=23)
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=2,
                         transport=ChannelTransport.from_trace(
                             constant_trace(20.0, duration_s=60)))
    futs = [engine.submit_packet(p, q, it, time_s=float(i))
            for i, (p, q, it) in enumerate(reqs)]
    engine.drain()
    served = [f.result() for f in futs if f.result().failure is None]
    assert served, "channel serve delivered nothing"
    for r in served:
        assert r.ttft_s is not None and r.ttft_s > 0.0
    st = engine.stats
    seen = 0
    for cls in ("latency", "throughput"):
        if st[f"ttft_{cls}_n"] > 0:
            seen += 1
            assert 0.0 < st[f"ttft_{cls}_p50_s"] \
                <= st[f"ttft_{cls}_p99_s"]
    assert seen > 0


# ---- stats() key stability ----


def test_stats_key_snapshot(executor):
    """The engine's stats() surface is load-bearing (benchmarks, fleet
    reports, the serving docs): its key set for the canonical in-flight
    scenario is pinned to a checked-in list. A diff here must be a
    deliberate choice — update tests/fixtures/engine_stats_keys.json in
    the same change that alters the surface."""
    from pathlib import Path
    reqs = _edge_requests(executor, 3, seed=11)
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=2)
    for i, (p, q, it) in enumerate(reqs):
        engine.submit_packet(p, q, it, time_s=float(i))
    engine.drain()
    keys = sorted(engine.stats)
    fixture = Path(__file__).resolve().parent / "fixtures" / \
        "engine_stats_keys.json"
    expected = json.loads(fixture.read_text())
    assert keys == expected, (
        "engine.stats() keys changed; if intentional, update "
        f"{REQUIRED_SNAPSHOT} in the same commit")


# ---- tracing overhead budget ----


def test_tracing_overhead_under_five_percent(executor):
    """The tracer must be cheap enough to leave on in benchmarks: a
    traced serve of the canonical burst stays within 5% of untraced
    wall time (plus a small absolute epsilon against timer noise)."""
    reqs = _edge_requests(executor, 4, seed=5)

    def run(trace):
        t0 = time.perf_counter()
        engine = AveryEngine(lut=LUT, executor=executor,
                             batching="inflight", max_batch=4, trace=trace)
        for i, (p, q, it) in enumerate(reqs):
            engine.submit_packet(p, q, it, time_s=float(i))
        engine.drain()
        return time.perf_counter() - t0

    run(False)                            # warm the compiled stages
    untraced = min(run(False) for _ in range(3))
    traced = min(run(True) for _ in range(3))
    assert traced <= untraced * 1.05 + 0.02, (
        f"tracing overhead too high: {traced:.4f}s traced vs "
        f"{untraced:.4f}s untraced")
