"""Batched KV-cache serving engine: (a) prefill + flash-decode matches the
full-forward ``llm_reason`` fast path, (b) batched cloud stages match
per-packet calls, (c) the continuous-batching scheduler preserves
per-request results and ordering under mixed intents/tiers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.lisa_mini import CONFIG as PCFG
from repro.core import DualStreamExecutor, bottleneck as bn, paper_lut, vlm
from repro.core.intent import Intent
from repro.data import floodseg
from repro.runtime.scheduler import MicrobatchScheduler, ServeRequest

# flash-decode kernel on the decode attention hot loop
FLASH_PCFG = dataclasses.replace(
    PCFG, llm=PCFG.llm.replace(use_flash_decode=True))


@pytest.fixture(scope="module")
def params():
    return vlm.init_lisa(PCFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def executor(params):
    lut = paper_lut()
    d = PCFG.sam.d_model
    bns = {t.name: bn.init_bottleneck(
        jax.random.PRNGKey(i), bn.BottleneckSpec(
            d, bn.rank_for_ratio(d, t.ratio, 4), 4))
        for i, t in enumerate(lut.tiers)}
    return DualStreamExecutor(pcfg=PCFG, params=params, bottlenecks=bns,
                              lut=lut)


def _ctx_query(params, batch=3, qlen=8, seed=1):
    ctx = jax.random.normal(
        jax.random.PRNGKey(seed), (batch, PCFG.clip_tokens, PCFG.llm.d_model))
    query = jax.random.randint(jax.random.PRNGKey(seed + 1), (batch, qlen), 0,
                               PCFG.llm.vocab_size)
    return ctx, query


# ---- (a) prefill + decode vs llm_reason ----


def test_prefill_plus_decode_matches_reason(params):
    """Prefill over [ctx; query[:-1]] + one flash-decode step of the final
    query token reproduces the single-shot llm_reason logits."""
    ctx, query = _ctx_query(params)
    ref_logits, ref_seg = vlm.llm_reason(params, FLASH_PCFG, ctx, query)
    _, _, cache = vlm.llm_prefill(params, FLASH_PCFG, ctx, query[:, :-1],
                                  width=PCFG.clip_tokens + query.shape[1])
    pos = jnp.int32(PCFG.clip_tokens + query.shape[1] - 1)
    logits, seg, _ = vlm.llm_decode_step(params, FLASH_PCFG, cache,
                                         query[:, -1:], pos)
    scale = float(jnp.max(jnp.abs(ref_logits))) + 1.0
    assert float(jnp.max(jnp.abs(ref_logits - logits))) < 2e-3 * scale
    seg_scale = float(jnp.max(jnp.abs(ref_seg))) + 1.0
    assert float(jnp.max(jnp.abs(ref_seg - seg))) < 2e-3 * seg_scale


def test_prefill_only_matches_reason(params):
    ctx, query = _ctx_query(params, seed=5)
    ref_logits, ref_seg = vlm.llm_reason(params, PCFG, ctx, query)
    logits, seg, cache = vlm.llm_prefill(params, PCFG, ctx, query)
    np.testing.assert_allclose(np.asarray(ref_logits), np.asarray(logits),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ref_seg), np.asarray(seg),
                               atol=1e-5)
    assert cache["positions"].shape[1] == PCFG.clip_tokens + query.shape[1]


def test_generate_matches_naive_full_forward(params):
    """Greedy KV-cache generation emits the same tokens as re-running the
    full no-cache forward per new token (the seed serving semantics)."""
    ctx, query = _ctx_query(params, seed=9)
    T = 4
    tokens, logits0, seg = vlm.llm_generate(params, FLASH_PCFG, ctx, query, T)
    cur = query
    for t in range(T):
        logits, _ = vlm.llm_reason(params, PCFG, ctx, cur)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if t == 0:
            np.testing.assert_allclose(np.asarray(logits0),
                                       np.asarray(logits), atol=1e-5)
        assert bool(jnp.all(tokens[:, t] == nxt)), t
        cur = jnp.concatenate([cur, nxt[:, None]], axis=1)
    # <SEG> convention: generate's seg is the hidden state of the final
    # generated token == llm_reason's seg over [ctx; query; answer]
    _, ref_seg = vlm.llm_reason(params, PCFG, ctx, cur)
    scale = float(jnp.max(jnp.abs(ref_seg))) + 1.0
    assert float(jnp.max(jnp.abs(ref_seg - seg))) < 2e-3 * scale


def test_generate_seg_convention_consistent_at_t1(params):
    """T == 1 uses the same final-generated-token seg convention as T > 1."""
    ctx, query = _ctx_query(params, seed=13)
    tokens, _, seg = vlm.llm_generate(params, FLASH_PCFG, ctx, query, 1)
    full = jnp.concatenate([query, tokens], axis=1)
    _, ref_seg = vlm.llm_reason(params, PCFG, ctx, full)
    scale = float(jnp.max(jnp.abs(ref_seg))) + 1.0
    assert float(jnp.max(jnp.abs(ref_seg - seg))) < 2e-3 * scale


# ---- (b) batched cloud stages vs single-packet calls ----


def _make_requests(executor, n, seed=0):
    rng = np.random.RandomState(seed)
    lut = executor.lut
    reqs = []
    for i in range(n):
        kind = ("any" if i % 3 == 0 else "segment")
        b = floodseg.make_batch(rng, 1, kind, augment=False)
        images = jnp.asarray(b["images"])
        if kind == "any":
            pkt, _ = executor.edge_context(images, i, 0.0)
            intent = Intent.CONTEXT
        else:
            pkt = executor.edge_insight(images, lut.tiers[i % 2], i, 0.0)
            intent = Intent.INSIGHT
        reqs.append(ServeRequest(seq_id=i, intent=intent, packet=pkt,
                                 query=b["query"]))
    return reqs


def test_batched_insight_matches_single_calls(executor):
    reqs = [r for r in _make_requests(executor, 12)
            if r.intent is Intent.INSIGHT
            and r.packet.tier_name == executor.lut.tiers[0].name]
    assert len(reqs) >= 3
    packets = [r.packet for r in reqs[:3]]
    queries = [r.query for r in reqs[:3]]
    batched = executor.cloud_insight_batch(packets, queries)  # bucket 4: pads
    for (mask_b, logits_b), pkt, q in zip(batched, packets, queries):
        mask_1, logits_1 = executor.cloud_insight(pkt, q)
        np.testing.assert_allclose(mask_b, mask_1, atol=2e-4)
        np.testing.assert_allclose(logits_b, logits_1, atol=2e-4)


def test_batched_context_matches_single_calls(executor):
    reqs = [r for r in _make_requests(executor, 9)
            if r.intent is Intent.CONTEXT]
    packets = [r.packet for r in reqs]
    queries = [r.query for r in reqs]
    batched = executor.cloud_context_batch(packets, queries)
    for logits_b, pkt, q in zip(batched, packets, queries):
        np.testing.assert_allclose(logits_b, executor.cloud_context(pkt, q),
                                   atol=2e-4)


def test_bucket_compile_cache_reuse(executor):
    """Varying request counts within one bucket hit the same compiled
    stage — no new cache entries."""
    reqs = [r for r in _make_requests(executor, 16, seed=3)
            if r.packet.tier_name == executor.lut.tiers[0].name]
    assert len(reqs) >= 4
    executor.cloud_insight_batch([r.packet for r in reqs[:3]],
                                 [r.query for r in reqs[:3]])
    n0 = executor.num_compiled_stages
    executor.cloud_insight_batch([r.packet for r in reqs[:4]],
                                 [r.query for r in reqs[:4]])
    assert executor.num_compiled_stages == n0      # same (stage, tier, 4) key
    assert executor.bucket_for(3) == 4 and executor.bucket_for(5) == 8


# ---- (c) scheduler: ordering + per-request results under mixed intents ----


def test_scheduler_preserves_results_and_order(executor):
    reqs = _make_requests(executor, 10, seed=7)
    sched = MicrobatchScheduler(executor=executor, max_batch=4)
    results = sched.serve_all(reqs)
    assert [r.seq_id for r in results] == [r.seq_id for r in reqs]
    assert sched.n_requests == len(reqs)
    assert sched.n_microbatches < len(reqs)        # batching actually happened
    for req, res in zip(reqs, results):
        assert res.intent is req.intent
        if req.intent is Intent.INSIGHT:
            mask_1, logits_1 = executor.cloud_insight(req.packet, req.query)
            np.testing.assert_allclose(res.mask_logits, mask_1, atol=2e-4)
            np.testing.assert_allclose(res.answer_logits, logits_1, atol=2e-4)
        else:
            np.testing.assert_allclose(
                res.answer_logits, executor.cloud_context(req.packet,
                                                          req.query),
                atol=2e-4)


def test_scheduler_respects_row_cap_for_multirow_packets(executor):
    """Edge calls may pack several frames into one packet; the scheduler
    must cap microbatches by stacked content rows, not request count."""
    rng = np.random.RandomState(21)
    tier = executor.lut.tiers[0]
    reqs = []
    for i in range(6):
        b = floodseg.make_batch(rng, 4, "segment", augment=False)  # 4 rows
        pkt = executor.edge_insight(jnp.asarray(b["images"]), tier, i, 0.0)
        reqs.append(ServeRequest(seq_id=i, intent=Intent.INSIGHT, packet=pkt,
                                 query=b["query"]))
    sched = MicrobatchScheduler(executor=executor, max_batch=16)
    results = sched.serve_all(reqs)               # 24 rows > bucket cap 16
    assert [r.seq_id for r in results] == [r.seq_id for r in reqs]
    assert sched.n_microbatches >= 2              # row cap forced a split
    for res in results:
        assert res.mask_logits.shape[0] == 4


def test_scheduler_separates_mixed_query_lengths(executor):
    """Queries of different lengths can't stack; they must land in
    separate microbatches, not crash the concatenate."""
    rng = np.random.RandomState(31)
    packets, queries = [], []
    for i in range(4):
        b = floodseg.make_batch(rng, 1, "any", augment=False)
        pkt, _ = executor.edge_context(jnp.asarray(b["images"]), i, 0.0)
        packets.append(pkt)
        q = b["query"] if i % 2 == 0 else b["query"][:, :6]
        queries.append(q)
    reqs = [ServeRequest(seq_id=i, intent=Intent.CONTEXT, packet=p, query=q)
            for i, (p, q) in enumerate(zip(packets, queries))]
    sched = MicrobatchScheduler(executor=executor, max_batch=4)
    results = sched.serve_all(reqs)
    assert [r.seq_id for r in results] == [0, 1, 2, 3]
    assert sched.n_microbatches == 2          # one per query length


def test_oversized_direct_call_rounds_up(executor):
    """Per-packet callers may exceed the largest bucket (seed allowed any
    batch); the executor rounds up instead of failing."""
    rng = np.random.RandomState(41)
    b = floodseg.make_batch(rng, 17, "any", augment=False)
    pkt, _ = executor.edge_context(jnp.asarray(b["images"]), 0, 0.0)
    logits = executor.cloud_context(pkt, b["query"])
    assert logits.shape == (17, PCFG.llm.vocab_size)
    assert executor.bucket_for(17) == 32


def test_mixed_tier_batch_rejected(executor):
    reqs = [r for r in _make_requests(executor, 6, seed=23)
            if r.intent is Intent.INSIGHT]
    assert len({r.packet.tier_name for r in reqs}) == 2
    with pytest.raises(ValueError, match="mixed tiers"):
        executor.cloud_insight_batch([r.packet for r in reqs],
                                     [r.query for r in reqs])


def test_scheduler_generate_mode(executor):
    reqs = _make_requests(executor, 6, seed=11)
    sched = MicrobatchScheduler(executor=executor, max_batch=4, generate=True)
    results = sched.serve_all(reqs)
    assert [r.seq_id for r in results] == [r.seq_id for r in reqs]
    for req, res in zip(reqs, results):
        assert res.tokens is not None
        assert res.tokens.shape == (1, executor.max_new_tokens)
        if req.intent is Intent.INSIGHT:
            assert res.mask_logits is not None


# ---- paged shared-prefix serving bench mode (slow) ----


@pytest.mark.slow
def test_bench_serving_paged_mode_reports_prefix_reuse():
    """The bench's paged mode must report a prefix-cache hit rate and an
    admission-throughput speedup from prefix reuse on the repeat-prefix
    per-UAV workload (>= 2x on the Context stream, whose admission cost
    is the prefix prefill the store removes)."""
    import subprocess
    import sys

    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_serving", "--paged-smoke"],
        capture_output=True, text=True, timeout=560,
        env={**__import__("os").environ, "PYTHONPATH": "src:."})
    assert out.returncode == 0, out.stderr[-2000:]
    rows = [l for l in out.stdout.splitlines()
            if l.startswith("serving/paged_admit_")]
    assert len(rows) == 2
    ctx_row = next(r for r in rows if "context" in r)
    fields = dict(f.split("=") for f in ctx_row.split(",")[2].split(";"))
    assert float(fields["speedup_vs_no_prefix_reuse"].rstrip("x")) >= 2.0
    assert 0.0 < float(fields["prefix_hit_rate"]) <= 1.0
    assert float(fields["kv_bytes_saved"]) > 0


@pytest.mark.slow
def test_bench_serving_spec_mode_reports_tokens_per_step():
    """The bench's speculative mode must report >= 1.5 tokens per
    verify step on repeat-prefix Context-drafted traffic (the warm
    Context weights draft for themselves, so acceptance is near-total)
    and refresh the machine-readable BENCH_serving.json artifact."""
    import json
    import os
    import subprocess
    import sys

    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_serving", "--spec-smoke"],
        capture_output=True, text=True, timeout=560,
        env={**os.environ, "PYTHONPATH": "src:."})
    assert out.returncode == 0, out.stderr[-2000:]
    rows = [l for l in out.stdout.splitlines()
            if l.startswith("serving/spec_insight")]
    assert len(rows) == 1
    fields = dict(f.split("=") for f in rows[0].split(",")[2].split(";"))
    assert float(fields["tokens_per_step"]) >= 1.5
    assert 0.0 < float(fields["acceptance_rate"]) <= 1.0
    assert int(fields["verify_steps"]) < int(fields["baseline_decode_steps"])
    art = os.path.join("benchmarks", "artifacts", "BENCH_serving.json")
    with open(art) as f:
        records = json.load(f)["records"]
    # smoke rows carry their own key so they never clobber full-run rows
    assert records["serving/spec_insight_smoke"]["tokens_per_step"] >= 1.5
