"""Network simulator, energy model, data pipelines, checkpoint, optimizer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # minimal envs: seeded-sampling fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro import optim
from repro.checkpoint import load_pytree, save_pytree
from repro.core.packets import Packet
from repro.data import floodseg, lm
from repro.network import (Channel, EdgeDevice, constant_trace, paper_trace,
                           random_trace)


# ------------------------------ traces -------------------------------------


def test_paper_trace_bounds_and_duration():
    tr = paper_trace(seed=0)
    assert tr.duration_s == 1200
    assert tr.samples.min() >= 8.0 and tr.samples.max() <= 20.0
    # must contain both a high-bandwidth regime and a sustained drop
    assert (tr.samples > 15).mean() > 0.2
    assert (tr.samples < 10).mean() > 0.1


@given(seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_random_trace_bounds(seed):
    tr = random_trace(seed, duration_s=100)
    assert tr.samples.min() >= 8.0 and tr.samples.max() <= 20.0


# ------------------------------ channel -------------------------------------


def test_channel_constant_bw_latency():
    """1 MB at a constant 8 Mbps must take exactly 1 second."""
    ch = Channel(constant_trace(8.0))
    pkt = Packet(kind="insight", tier_name="t", seq_id=0, created_at=0.0,
                 payload_bytes=1_000_000)
    rec = ch.transmit(pkt, 0.0)
    assert rec.latency_s == pytest.approx(1.0, rel=1e-6)


def test_channel_fifo_serialisation():
    ch = Channel(constant_trace(8.0))
    p = lambda i: Packet("insight", "t", i, 0.0, 500_000)  # noqa: E731
    r1 = ch.transmit(p(0), 0.0)
    r2 = ch.transmit(p(1), 0.0)
    assert r2.start_s == pytest.approx(r1.end_s)
    assert r2.end_s == pytest.approx(1.0, rel=1e-6)


@given(bw=st.floats(8.0, 20.0), nbytes=st.integers(1_000, 5_000_000))
@settings(max_examples=50, deadline=None)
def test_channel_conserves_bytes(bw, nbytes):
    """Transmission time integrates to exactly bytes*8/bw on a flat trace."""
    ch = Channel(constant_trace(bw, duration_s=3600))
    rec = ch.transmit(Packet("insight", "t", 0, 0.0, nbytes), 0.0)
    assert rec.latency_s == pytest.approx(nbytes * 8 / (bw * 1e6), rel=1e-5)


# ------------------------------ energy --------------------------------------


def test_energy_model_paper_calibration():
    """split@1 edge latency/energy must stay near the paper's Fig. 8
    measurements (0.2318 s, 3.12 J) — the model is calibrated, so drift
    here means someone broke the constants."""
    from repro.configs.lisa7b import CONFIG as deploy
    from repro.runtime import edge_insight_flops, full_edge_flops
    dev = EdgeDevice()
    lat = dev.latency_s(edge_insight_flops(deploy, 0.25))
    energy = dev.compute_energy_j(edge_insight_flops(deploy, 0.25))
    assert 0.15 < lat < 0.35
    assert 2.0 < energy < 5.0
    reduction = 1 - energy / dev.compute_energy_j(full_edge_flops(deploy))
    assert 0.90 < reduction < 0.97        # paper: 93.98%


# ------------------------------ data ----------------------------------------


def test_floodseg_masks_consistent():
    rng = np.random.RandomState(0)
    for _ in range(20):
        scene = floodseg.generate_scene(rng)
        for cls in ("person", "vehicle"):
            assert scene.masks[cls].any() == (scene.counts[cls] > 0)
        assert scene.image.shape == (32, 32, 3)
        assert scene.image.min() >= 0 and scene.image.max() <= 1


def test_floodseg_batch_contract():
    rng = np.random.RandomState(0)
    b = floodseg.make_batch(rng, 8, "segment")
    assert b["images"].shape == (8, 32, 32, 3)
    assert b["query"].shape == (8, floodseg.QUERY_LEN)
    assert b["mask"].shape == (8, 32, 32)
    assert b["answer"].shape == (8,)
    assert b["query"].max() < floodseg.VOCAB


@given(seed=st.integers(0, 30))
@settings(max_examples=15, deadline=None)
def test_photometric_augment_stays_valid(seed):
    rng = np.random.RandomState(seed)
    scene = floodseg.generate_scene(rng)
    img = floodseg.photometric_augment(rng, scene.image)
    assert img.min() >= 0.0 and img.max() <= 1.0 and img.dtype == np.float32


def test_lm_batches_match_modality_contract():
    from repro.configs import get_reduced
    for arch in ("phi4-mini-3.8b", "hubert-xlarge", "qwen2-vl-2b"):
        cfg = get_reduced(arch)
        rng = np.random.RandomState(0)
        b = lm.lm_batch(rng, cfg, 4, 32)
        if cfg.modality == "audio":
            assert b["frames"].shape == (4, 32, cfg.frontend_dim)
            assert b["mask_positions"].any()
        elif cfg.modality == "vlm":
            assert b["positions"].shape == (3, 4, 32)
            assert b["vision_embeds"].shape[1] == cfg.num_vision_tokens
        else:
            assert b["tokens"].shape == (4, 32)


# --------------------------- checkpoint -------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": [jnp.ones((4,)), {"c": jnp.zeros((2, 2), jnp.int32)}],
            "d": (jnp.full((3,), 2.5),)}
    save_pytree(str(tmp_path / "ck"), tree)
    back = load_pytree(str(tmp_path / "ck"))
    assert jax.tree.structure(jax.tree.map(lambda x: 0, tree)) == \
        jax.tree.structure(jax.tree.map(lambda x: 0, back))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------- optimizer ------------------------------------


def test_adamw_optimises_quadratic():
    opt = optim.adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(jnp.square(p["w"])))(params)
        params, state = opt.apply(params, state, grads)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_grad_clipping_bounds_update():
    opt = optim.adamw(1.0, clip_norm=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    huge = {"w": jnp.full((4,), 1e9)}
    p2, _ = opt.apply(params, state, huge)
    assert float(jnp.max(jnp.abs(p2["w"]))) < 10.0


def test_channel_all_zero_trace_blackout_terminates():
    """An all-zero trace used to divide by zero; now the transmission
    fails deterministically at the blackout timeout."""
    from repro.network.traces import BandwidthTrace
    ch = Channel(BandwidthTrace(np.zeros(300), name="dead"),
                 blackout_timeout_s=30.0)
    rec = ch.transmit(Packet("insight", "t", 0, 0.0, 1_000_000), 0.0)
    assert not rec.delivered
    assert rec.end_s == pytest.approx(30.0)
    assert ch.busy_until == pytest.approx(30.0)    # airtime was spent


def test_channel_zero_tail_trace_terminates():
    """``at()`` clamps past the end of the trace, so a trailing-zero
    trace used to spin forever advancing 1 s per iteration; now the
    transmission fails as soon as the dead tail is reached."""
    from repro.network.traces import BandwidthTrace
    tr = BandwidthTrace(np.array([8.0, 8.0, 0.0]), name="zero-tail")
    # 3 MB needs 3 s at 8 Mbps but only 2 s of live trace exist
    ch = Channel(tr, blackout_timeout_s=1e9)       # timeout alone won't save us
    rec = ch.transmit(Packet("insight", "t", 0, 0.0, 3_000_000), 0.0)
    assert not rec.delivered
    assert rec.end_s == pytest.approx(3.0)         # gave up at the trace end
    # a packet that fits in the live prefix still delivers normally
    ch2 = Channel(tr)
    rec2 = ch2.transmit(Packet("insight", "t", 1, 0.0, 1_000_000), 0.0)
    assert rec2.delivered and rec2.end_s == pytest.approx(1.0)


def _trace_integral_bits(trace, start, end):
    """∫ bw dt over [start, end] against the piecewise-per-second trace."""
    total, t = 0.0, start
    while t < end - 1e-12:
        boundary = min(float(int(t) + 1), end)
        total += trace.at(t) * 1e6 * (boundary - t)
        t = boundary
    return total


@given(seed=st.integers(0, 40), sizes=st.lists(
    st.integers(10_000, 4_000_000), min_size=1, max_size=6))
@settings(max_examples=30, deadline=None)
def test_channel_work_conserving_and_fifo(seed, sizes):
    """Over random traces (including near-zero bandwidth), the channel is
    work-conserving and FIFO: each delivery starts the instant the link
    frees (or the packet arrives), ``end_s`` is monotone in submission
    order, and the transferred bits equal the trace integral over the
    occupied interval."""
    lo = 0.2 if seed % 3 == 0 else 8.0     # a third of cases: near-blackout
    tr = random_trace(seed, duration_s=3600, lo=lo, hi=20.0)
    ch = Channel(tr)
    rng = np.random.RandomState(seed)
    t_submit = np.cumsum(rng.uniform(0.0, 2.0, size=len(sizes)))
    recs = []
    for i, (nbytes, ts) in enumerate(zip(sizes, t_submit)):
        recs.append(ch.transmit(Packet("insight", "t", i, float(ts),
                                       int(nbytes)), float(ts)))
    prev_end = 0.0
    for rec, ts in zip(recs, t_submit):
        assert rec.delivered                      # lo > blackout floor
        # work conservation: no idle gap between queued transmissions
        assert rec.start_s == pytest.approx(max(float(ts), prev_end))
        # FIFO: completion order follows submission order
        assert rec.end_s >= prev_end
        # conservation of bits: the occupied interval integrates to the
        # payload exactly
        bits = _trace_integral_bits(tr, rec.start_s, rec.end_s)
        assert bits == pytest.approx(rec.packet.payload_bytes * 8.0,
                                     rel=1e-6)
        prev_end = rec.end_s
