"""Fault-tolerant serving core: chaos injection (FaultInjector /
FaultyExecutor), retry-with-downshift (RetryPolicy), per-request
deadlines with in-flight cancellation, and the bounded transmit logs.

The contract under test: every submitted request resolves exactly once
with an accurate ``failure``/``attempts``; retries re-run Select and
transmit a strictly cheaper tier; cancellations and stage faults release
pages refcount-safely (``PagePool.check_invariants`` passes, zero
leaks); and retries never corrupt the prefix store — a retried request
serves token-exact results."""
import dataclasses

import numpy as np
import pytest

from repro.core import paper_lut
from repro.core.intent import DEFAULT_REQUIREMENTS, Intent
from repro.engine import (AdaptivePolicy, AveryEngine, CloudStageError,
                          FaultInjector, FaultyExecutor, LoopbackTransport,
                          RetryPolicy, StaticTierPolicy)
from repro.core.packets import Packet
from repro.network.channel import Channel
from repro.network.traces import BandwidthTrace

from test_engine import LUT, StubExecutor, _edge_requests, _insight_images


def _packet(seq_id=0, t=0.0, mb=1.0):
    return Packet(kind="insight", tier_name="Balanced", seq_id=seq_id,
                  created_at=t, payload_bytes=int(mb * 1e6))


# ---- FaultInjector: deterministic transport chaos ----


def test_fault_injector_blackout_window():
    inj = FaultInjector(LoopbackTransport(12.0), blackouts=[(2.0, 6.0)])
    ok = inj.send(_packet(0, 1.0), 1.0)
    assert ok.delivered
    dead = inj.send(_packet(1, 3.0), 3.0)
    assert not dead.delivered
    assert dead.end_s == 6.0          # the window's end: retry resume point
    after = inj.send(_packet(2, 6.0), 6.0)   # half-open: end excluded
    assert after.delivered
    assert inj.n_blackout_failures == 1 and inj.n_sends == 3


def test_fault_injector_drop_determinism_and_delegation():
    inner1, inner2 = LoopbackTransport(12.0), LoopbackTransport(12.0)
    a = FaultInjector(inner1, seed=7, drop_rate=0.5)
    b = FaultInjector(inner2, seed=7, drop_rate=0.5)
    pat_a = [a.send(_packet(i, float(i)), float(i)).delivered
             for i in range(32)]
    pat_b = [b.send(_packet(i, float(i)), float(i)).delivered
             for i in range(32)]
    assert pat_a == pat_b             # same seed, same fault stream
    assert 0 < sum(pat_a) < 32        # both outcomes occur
    assert a.n_drops == 32 - sum(pat_a)
    # delivered packets reached the wrapped transport; drops did not
    assert len(inner1.records) == sum(pat_a)
    assert a.records is inner1.records


def test_fault_injector_spikes_and_sense_lies():
    inj = FaultInjector(LoopbackTransport(12.0),
                        spikes=[(0.0, 1.0, 9.0)],
                        sense_lies=[(5.0, 6.0, 99.0)])
    spiked = inj.send(_packet(0, 0.5), 0.5)
    assert spiked.delivered and spiked.end_s == 0.5 + 9.0
    clean = inj.send(_packet(1, 2.0), 2.0)
    assert clean.end_s == 2.0
    assert inj.bandwidth(5.5) == 99.0        # the Sense stage is lied to
    assert inj.bandwidth(7.0) == 12.0
    assert inj.n_spiked == 1 and inj.n_sense_lies == 1
    assert set(inj.stats()) == {"fault_sends", "fault_blackout_failures",
                                "fault_drops", "fault_spiked",
                                "fault_sense_lies"}


# ---- FaultyExecutor ----


def test_faulty_executor_schedule_and_validation():
    with pytest.raises(ValueError, match="unknown faultable"):
        FaultyExecutor(StubExecutor(), fail_at={"edge_context": [0]})
    fx = FaultyExecutor(StubExecutor(),
                        fail_at={"cloud_decode_rows": [1]})
    assert fx.max_new_tokens == 2            # plain attrs delegate
    fx._gate("cloud_decode_rows")            # call 0: clean
    with pytest.raises(CloudStageError, match="cloud_decode_rows call 1"):
        fx._gate("cloud_decode_rows")
    assert fx.calls["cloud_decode_rows"] == 2 and fx.n_faults == 1


# ---- RetryPolicy math ----


def test_retry_policy_backoff_and_downshift():
    pol = RetryPolicy(backoff_base_s=0.5, backoff_factor=2.0)
    assert pol.backoff_s(1) == 0.5 and pol.backoff_s(3) == 2.0
    lut = paper_lut()
    ha, bal, ht = lut.tiers          # heaviest -> lightest
    assert ha.payload_mb > bal.payload_mb > ht.payload_mb
    adaptive = AdaptivePolicy()
    reqs = DEFAULT_REQUIREMENTS[Intent.INSIGHT]
    rich = adaptive.select(20.0, Intent.INSIGHT, reqs, lut)
    assert rich.tier is ha
    # re-Select still picks the tier that just failed -> force cheaper
    down = pol.downshifted(rich, ha, lut, 20.0)
    assert down.tier is bal
    # failure at the bottom: stay on the lightest (degrade, don't idle)
    floor = pol.downshifted(rich, ht, lut, 20.0)
    assert floor.tier is ht
    # a fresh decision already cheaper than the failed tier is kept
    poor = adaptive.select(9.0, Intent.INSIGHT, reqs, lut)
    assert pol.downshifted(poor, ha, lut, 9.0) is poor
    # context stream / downshift disabled: untouched
    ctx = adaptive.select(20.0, Intent.CONTEXT, reqs, lut)
    assert pol.downshifted(ctx, ha, lut, 20.0) is ctx
    off = RetryPolicy(downshift=False)
    assert off.downshifted(rich, ha, lut, 20.0) is rich


# ---- engine: blackout retry with tier downshift ----


def test_blackout_retry_downshifts_and_succeeds():
    """A blackout-windowed first attempt retries after backoff on a
    strictly cheaper tier and serves; telemetry reports the journey."""
    engine = AveryEngine(
        lut=LUT, executor=StubExecutor(),
        transport=FaultInjector(LoopbackTransport(20.0),
                                blackouts=[(0.0, 5.0)]),
        retry=RetryPolicy(max_attempts=3, backoff_base_s=3.0))
    fut = engine.session("op").submit(
        prompt="segment the person",
        images=_insight_images(np.random.RandomState(0)),
        query=np.zeros((1, 4), np.int32), time_s=0.0)
    engine.drain()
    res = fut.result()
    assert res.failure is None and res.feasible
    assert res.attempts == 2
    assert res.tier_name == "Balanced"       # downshifted from High Accuracy
    assert res.answer_logits is not None
    kinds = [e.kind for e in res.events]
    assert "blackout" in kinds and "retry" in kinds
    stats = engine.stats
    assert stats["retries"] == 1 and stats["downshifts"] == 1
    assert stats["blackouts"] == 0           # not a terminal blackout
    assert stats["completed"] == 1


def test_blackout_exhausts_attempts_then_terminal():
    # drop_rate=1.0: every attempt dies on the wire (a blackout window
    # can't exhaust retries — its end_s is the retry resume point)
    engine = AveryEngine(
        lut=LUT, executor=StubExecutor(),
        transport=FaultInjector(LoopbackTransport(20.0), drop_rate=1.0),
        retry=RetryPolicy(max_attempts=2, backoff_base_s=0.1))
    fut = engine.session("op").submit(
        prompt="segment the person",
        images=_insight_images(np.random.RandomState(0)),
        query=np.zeros((1, 4), np.int32), time_s=0.0)
    engine.drain()
    res = fut.result()
    assert res.failure == "blackout" and not res.feasible
    assert res.attempts == 2 and res.answer_logits is None
    stats = engine.stats
    assert stats["blackouts"] == 1 and stats["retries"] == 1
    assert stats["completed"] == 0


def test_infeasible_failure_taxonomy_and_single_count():
    engine = AveryEngine(lut=LUT, executor=StubExecutor(),
                         transport=LoopbackTransport(1.0))
    fut = engine.session("op").submit(
        prompt="segment the person",
        images=_insight_images(np.random.RandomState(0)),
        query=np.zeros((1, 4), np.int32))
    engine.drain()
    res = fut.result()
    assert res.failure == "infeasible" and not res.feasible
    stats = engine.stats
    assert stats["infeasible"] == 1 and stats["blackouts"] == 0
    assert stats["completed"] == 0


def test_best_effort_starved_is_served_not_infeasible():
    """Exactly-once classification: a served best-effort frame counts as
    completed + starved, never as infeasible (the old double-count)."""
    from repro.engine import BestEffortPolicy
    engine = AveryEngine(lut=LUT, executor=StubExecutor(),
                         transport=LoopbackTransport(1.0),
                         policy=BestEffortPolicy())
    fut = engine.session("op").submit(
        prompt="segment the person",
        images=_insight_images(np.random.RandomState(0)),
        query=np.zeros((1, 4), np.int32))
    engine.drain()
    res = fut.result()
    assert res.failure is None and not res.feasible   # served, F_I unmet
    stats = engine.stats
    assert stats["completed"] == 1 and stats["starved"] == 1
    assert stats["infeasible"] == 0 and stats["blackouts"] == 0


def test_chaos_determinism_same_seed_same_outcomes():
    """The chaos-determinism contract: an identical seeded schedule
    yields an identical per-request (failure, attempts) sequence."""
    def run(seed):
        engine = AveryEngine(
            lut=LUT, executor=StubExecutor(),
            transport=FaultInjector(LoopbackTransport(20.0), seed=seed,
                                    drop_rate=0.6),
            retry=RetryPolicy(max_attempts=3, backoff_base_s=0.1))
        sess = engine.session("op")
        rng = np.random.RandomState(0)
        futs = [sess.submit(prompt="segment the person",
                            images=_insight_images(rng),
                            query=np.zeros((1, 4), np.int32),
                            time_s=float(i)) for i in range(8)]
        engine.drain()
        return ([(f.result().failure, f.result().attempts) for f in futs],
                engine.stats["retries"])

    first, retries = run(seed=3)
    again, _ = run(seed=3)
    assert first == again
    assert retries >= 1                      # the schedule really bites
    assert any(f is None for f, _ in first)  # and some requests survive


def test_submit_frame_retries_with_downshift():
    """The profiled mission path rides the same retry loop: blackout,
    backoff past the window, re-Select downshifted, serve."""
    engine = AveryEngine(
        lut=LUT,
        transport=FaultInjector(LoopbackTransport(20.0),
                                blackouts=[(0.0, 30.0)]),
        retry=RetryPolicy(max_attempts=3, backoff_base_s=1.0))
    res = engine.session("op").submit_frame(0.0)
    assert res.failure is None and res.feasible
    assert res.attempts == 2
    assert res.tier_name == "Balanced"
    assert engine.stats["downshifts"] == 1
    # energy telemetry accumulates across attempts
    one = AveryEngine(lut=LUT).session("op").submit_frame(0.0)
    assert res.edge_energy_j > one.edge_energy_j


# ---- transmit log caps ----


def test_loopback_transmit_log_bounded():
    tr = LoopbackTransport(12.0, max_records=5)
    for i in range(12):
        tr.send(_packet(i, float(i)), float(i))
    assert len(tr.records) == 5 and tr.n_sent == 12
    assert tr.records_dropped == 7
    assert tr.records[0].packet.seq_id == 7      # newest records kept


def test_channel_transmit_log_bounded():
    ch = Channel(BandwidthTrace(np.full(600, 12.0), name="flat"),
                 max_log=3)
    for i in range(5):
        ch.transmit(_packet(i, mb=0.1), float(i))
    assert len(ch.log) == 3 and ch.n_logged == 5
    assert ch.records_dropped == 2
    assert ch.log[0].packet.seq_id == 2


# ---- real executor: cancellation, deadlines, cloud-stage faults ----


@pytest.fixture(scope="module")
def executor():
    from repro.configs.lisa_mini import CONFIG as PCFG
    from repro.core import DualStreamExecutor, profile as prof
    params, bns, _ = prof.random_init_system(PCFG, lut=LUT)
    return DualStreamExecutor(pcfg=PCFG, params=params, bottlenecks=bns,
                              lut=LUT, max_new_tokens=3, flash_decode=False)


def test_decoder_cancel_pending_and_active(executor):
    """InflightDecoder.cancel removes a request from either queue state,
    releasing its slot and pages refcount-safely."""
    from repro.engine.inflight import InflightDecoder
    reqs = _edge_requests(executor, 2, seed=7)
    dec = InflightDecoder(executor, slots=1)
    done = []
    for sid, (pkt, q, it) in enumerate(reqs):
        dec.submit(sid, it, pkt, q, done.append)
    assert len(dec.active) == 1 and len(dec.pending) == 1
    assert dec.cancel(1)                     # still pending: dequeued
    assert not dec.pending
    assert dec.cancel(0)                     # mid-decode: slot released
    assert not dec.active and not done
    assert not dec.cancel(99)                # unknown seq: a no-op
    assert dec.n_cancelled == 2
    dec.pool.check_invariants()
    # only the store's prefix pins survive; private pages all returned
    dec.pool.release_operator("")
    assert dec.pool.pages_in_use == 0


def test_deadline_cancels_inflight_request(executor):
    """A latency spike blows the request past max_latency_s: the
    decoder's pre-admission deadline sweep resolves it with a
    ``deadline`` failure *before* it pays a cloud prefill (it arrives
    at the cloud already expired), pages stay balanced, and the future
    never hangs."""
    reqs = _edge_requests(executor, 2, seed=17)
    engine = AveryEngine(
        lut=LUT, executor=executor, batching="inflight", max_batch=2,
        transport=FaultInjector(LoopbackTransport(1000.0),
                                spikes=[(0.0, 1.0, 10.0)]),
        debug_invariants=True)
    sess = engine.session("op")
    sess.requirements[Intent.INSIGHT] = dataclasses.replace(
        sess.requirements[Intent.INSIGHT], max_latency_s=5.0)
    (p1, q1, i1), (p2, q2, i2) = reqs
    late = engine.submit_packet(p1, q1, Intent.INSIGHT, time_s=0.0,
                                session=sess)
    # the spiked delivery moved the mission clock to t=10; the second
    # request arrives after that, with deadline headroom
    ok = engine.submit_packet(p2, q2, i2, time_s=12.0, session=sess)
    engine.drain()
    res = late.result()
    assert res.failure == "deadline" and not res.feasible
    assert res.tokens is None
    assert any(e.kind == "cancelled" for e in res.events)
    assert ok.result().failure is None       # the spike missed this one
    stats = engine.stats
    assert stats["deadline_cancelled"] == 1
    # expired while pending -> swept at the admission boundary, never
    # admitted: no mid-decode cancellation, no prefill wasted on it
    assert stats["sched_expired_pending"] == 1
    assert stats["inflight_cancelled"] == 0
    assert stats["completed"] == 1
    engine.kv_pool.check_invariants()
    sess.close()
    engine.release_prefixes("_direct")
    assert engine.stats["kv_pages_in_use"] == 0   # zero leaked pages


@pytest.mark.parametrize("stage", ["cloud_prefix", "pool_write",
                                   "cloud_sam_feats", "cloud_decode_rows"])
def test_cloud_stage_fault_retries_token_exact(executor, stage):
    """A cloud-stage fault mid-serve retries through the full path and
    the retry is token-exact vs the one-shot generate reference —
    faults never corrupt the KV pool or the prefix store."""
    reqs = _edge_requests(executor, 1, seed=27)
    pkt, q, it = reqs[0]
    faulty = FaultyExecutor(executor, fail_at={stage: [0]})
    engine = AveryEngine(lut=LUT, executor=faulty, batching="inflight",
                         max_batch=2, debug_invariants=True,
                         retry=RetryPolicy(max_attempts=3,
                                           backoff_base_s=0.1))
    fut = engine.submit_packet(pkt, q, it, time_s=0.0)
    engine.drain()
    res = fut.result()
    assert res.failure is None and res.attempts == 2
    assert any(e.kind == "cloud_error" for e in res.events)
    ref = executor.cloud_generate_batch([pkt], [q])[0]
    assert np.array_equal(res.tokens, ref[-1])
    np.testing.assert_allclose(res.mask_logits, ref[0], atol=3e-4)
    stats = engine.stats
    assert stats["retries"] == 1 and stats["cloud_errors"] == 0
    assert stats["stage_faults"] == 1
    engine.kv_pool.check_invariants()
    engine.release_prefixes("_direct")
    assert engine.stats["kv_pages_in_use"] == 0


def test_cloud_fault_terminal_after_exhaustion(executor):
    reqs = _edge_requests(executor, 1, seed=37)
    pkt, q, it = reqs[0]
    faulty = FaultyExecutor(executor,
                            fail_at={"cloud_decode_rows": range(32)})
    engine = AveryEngine(lut=LUT, executor=faulty, batching="inflight",
                         max_batch=2, debug_invariants=True,
                         retry=RetryPolicy(max_attempts=2,
                                           backoff_base_s=0.1))
    fut = engine.submit_packet(pkt, q, it, time_s=0.0)
    engine.drain()
    res = fut.result()
    assert res.failure == "cloud_error" and res.attempts == 2
    assert res.tokens is None
    stats = engine.stats
    assert stats["cloud_errors"] == 1 and stats["retries"] == 1
    engine.kv_pool.check_invariants()
    engine.release_prefixes("_direct")
    assert engine.stats["kv_pages_in_use"] == 0


def test_batch_wide_fault_fails_all_then_retries(executor):
    """A decode-stage fault kills the step for every co-active slot;
    with a RetryPolicy both requests re-admit (prefix hits) and serve
    token-exact."""
    reqs = _edge_requests(executor, 2, seed=47)
    faulty = FaultyExecutor(executor, fail_at={"cloud_decode_rows": [1]})
    engine = AveryEngine(lut=LUT, executor=faulty, batching="inflight",
                         max_batch=2, debug_invariants=True,
                         retry=RetryPolicy(max_attempts=3,
                                           backoff_base_s=0.1))
    futs = [engine.submit_packet(p, q, it, time_s=float(i))
            for i, (p, q, it) in enumerate(reqs)]
    engine.drain()
    for fut, (pkt, q, it) in zip(futs, reqs):
        res = fut.result()
        assert res.failure is None and res.attempts == 2
        ref = executor.cloud_generate_batch([pkt], [q])[0]
        assert np.array_equal(res.tokens, ref[-1])
    assert engine.stats["stage_faults"] == 1     # one fault, two victims
    assert engine.stats["retries"] == 2
    engine.kv_pool.check_invariants()
