"""averylint: each checker catches its fixture positives, passes its
fixture negatives, the baseline workflow round-trips, and the tree
itself lints clean against the committed baseline."""
import json
from pathlib import Path

import pytest

from repro.analysis import baseline as baseline_mod
from repro.analysis import lint

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "analysis"
BAD = FIXTURES / "bad"
GOOD = FIXTURES / "good"


def _findings(tree, checker=None):
    only = [checker] if checker else None
    return lint.lint_paths([tree], tree, only=only)


def _codes(tree, checker=None):
    return {f.code for f in _findings(tree, checker)}


# ---- per-checker: positives caught, negatives pass ----


@pytest.mark.parametrize("checker,codes", [
    ("recompile", {"AV101", "AV102"}),
    ("hostsync", {"AV201", "AV202", "AV203"}),
    ("futures", {"AV301", "AV302"}),
    ("refcount", {"AV401"}),
    ("determinism", {"AV501", "AV502", "AV503", "AV504"}),
    ("observability", {"AV601", "AV602", "AV603"}),
])
def test_checker_catches_bad_and_passes_good(checker, codes):
    assert _codes(BAD, checker) == codes
    assert _findings(GOOD, checker) == []


def test_recompile_granularity():
    """Every distinct churn shape in the fixture is caught, and the
    keyed-cache/constructor/lru/amortized idioms are each exercised in
    the good fixture (parse sanity: the functions exist)."""
    by_symbol = {f.symbol for f in _findings(BAD, "recompile")}
    assert {"per_request_jit", "immediate_invoke_in_loop",
            "bare_expression", "Churner.pump"} <= by_symbol
    good_src = (GOOD / "repro/engine/recompile_cases.py").read_text()
    for idiom in ("lru_cache", "_compiled", "__init__", "lower"):
        assert idiom in good_src


def test_hostsync_flags_traced_callee():
    """AV202 propagates through the traced-region closure: the helper
    is flagged because a jitted function calls it."""
    hits = [f for f in _findings(BAD, "hostsync") if f.symbol == "helper"]
    assert len(hits) == 1 and hits[0].code == "AV202"


def test_refcount_flags_both_acquisitions():
    msgs = {f.message.split("(")[0] for f in _findings(BAD, "refcount")}
    assert any("pool.alloc" in m for m in msgs)
    assert any("pool.retain" in m for m in msgs)


# ---- fingerprints + baseline workflow ----


def test_fingerprint_survives_line_drift(tmp_path):
    src = (BAD / "repro/engine/determinism_cases.py").read_text()
    a = tmp_path / "a" / "repro" / "engine"
    a.mkdir(parents=True)
    (a / "determinism_cases.py").write_text(src)
    fa = lint.lint_paths([tmp_path / "a"], tmp_path / "a")
    # shift every site down ten lines; fingerprints must not move
    (a / "determinism_cases.py").write_text("\n" * 10 + src)
    fb = lint.lint_paths([tmp_path / "a"], tmp_path / "a")
    assert [f.fingerprint for f in fa] == [f.fingerprint for f in fb]
    assert [f.line + 10 for f in fa] == [f.line for f in fb]


def test_baseline_roundtrip(tmp_path):
    findings = _findings(BAD)
    path = tmp_path / baseline_mod.BASELINE_NAME
    baseline_mod.write(path, findings)
    loaded = baseline_mod.load(path)
    new, old = baseline_mod.split(findings, loaded)
    assert new == [] and len(old) == len(findings)
    # a reason survives a rewrite
    fp = findings[0].fingerprint
    loaded[fp] = "known debt"
    baseline_mod.write(path, findings, reasons=loaded)
    assert baseline_mod.load(path)[fp] == "known debt"


def test_driver_exit_codes_and_baseline(tmp_path, capsys):
    assert lint.main([str(BAD), "--no-baseline"]) == 1
    assert lint.main([str(GOOD), "--no-baseline"]) == 0
    assert lint.main([str(tmp_path / "missing")]) == 2
    capsys.readouterr()
    # grandfather everything -> clean; then a fresh finding is new again
    bl = tmp_path / baseline_mod.BASELINE_NAME
    assert lint.main([str(BAD), "--baseline", str(bl),
                      "--write-baseline"]) == 0
    capsys.readouterr()
    assert lint.main([str(BAD), "--baseline", str(bl)]) == 0
    out = capsys.readouterr().out
    assert "baselined" in out and "clean" in out


def test_json_output(capsys):
    lint.main([str(BAD), "--no-baseline", "--json",
               "--checker", "futures"])
    data = json.loads(capsys.readouterr().out)
    assert data["counts"]["new"] == 2
    codes = {f["code"] for f in data["new"]}
    assert codes == {"AV301", "AV302"}
    assert all("fingerprint" in f for f in data["new"])


# ---- the tree itself ----


def test_src_lints_clean_against_committed_baseline(capsys):
    """`python -m repro.analysis.lint src/` — the CI gate itself."""
    assert (REPO / baseline_mod.BASELINE_NAME).is_file()
    rc = lint.main([str(REPO / "src"),
                    "--baseline", str(REPO / baseline_mod.BASELINE_NAME)])
    out = capsys.readouterr().out
    assert rc == 0, f"averylint found new issues in src/:\n{out}"


def test_committed_baseline_is_near_empty():
    """The grandfather list must not silently grow into a dumping
    ground: every entry needs a justification, and there should be at
    most a handful."""
    data = json.loads((REPO / baseline_mod.BASELINE_NAME).read_text())
    assert len(data["entries"]) <= 5
    for entry in data["entries"]:
        assert entry.get("reason", "").strip() not in ("", "TODO: justify")


def test_host_only_modules_have_no_jax_imports():
    """Belt and braces for AV201: the host-only modules really import
    no jax today (the checker test proves detection; this pins the
    current tree)."""
    for rel in ("engine/scheduler.py", "engine/policy.py",
                "engine/faults.py", "engine/observability.py"):
        text = (REPO / "src" / "repro" / rel).read_text()
        assert "import jax" not in text, rel


def test_observability_checker_granularity():
    """Both AV602 idioms in the bad fixture are caught per attribute,
    and every sanctioned bounding idiom appears in the good fixture."""
    hits = [f for f in _findings(BAD, "observability")
            if f.code == "AV602"]
    assert {f.symbol for f in hits} == {"LeakyDecoder.on_event",
                                        "LeakyDecoder.step"}
    good_src = (GOOD / "repro/engine/observability_cases.py").read_text()
    for idiom in ("deque(maxlen", "len(self.events)", "del self.records",
                  "self.order = remaining", "return sess",
                  "self.queue.pop"):
        assert idiom in good_src


def test_av603_catches_both_import_spellings():
    """AV603 resolves clock calls through the import maps: the aliased
    ``import time as _t`` attribute spelling and the ``from time
    import perf_counter`` name spelling are both caught (exactly the
    AV502 loopholes), while the good fixture's injected-wallclock hook
    and a shadowing local ``perf_counter`` stay clean."""
    hits = [f for f in _findings(BAD, "observability")
            if f.code == "AV603"
            and f.path.endswith("observability_cases.py")]
    assert {f.symbol for f in hits} == {"stamp_response", "measure_step"}
    assert len(hits) == 3          # _t.time, perf_counter, _t.monotonic_ns
    msgs = " ".join(f.message for f in hits)
    for name in ("time.time", "time.perf_counter", "time.monotonic_ns"):
        assert name in msgs
    good_src = (GOOD / "repro/engine/observability_cases.py").read_text()
    assert "wallclock" in good_src and "def perf_counter" in good_src
