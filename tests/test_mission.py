"""Dynamic-adaptation behaviour (paper §5.3): the mission simulator must
reproduce the qualitative claims — AVERY switches tiers, never violates
the timeliness floor when feasible, and blends accuracy/throughput better
than any static tier."""
import numpy as np
import pytest

from repro.core import MissionGoal, paper_lut
from repro.network import constant_trace, paper_trace
from repro.runtime import MissionSpec, run_mission

LUT = paper_lut()
TRACE = paper_trace(seed=0)


@pytest.fixture(scope="module")
def logs():
    out = {}
    out["avery"] = run_mission(LUT, TRACE, MissionSpec(mode="avery"))
    for tier in ("High Accuracy", "Balanced", "High Throughput"):
        out[tier] = run_mission(LUT, TRACE,
                                MissionSpec(mode="static", static_tier=tier))
    return out


def test_avery_switches_tiers(logs):
    used = {f.tier for f in logs["avery"].frames}
    assert "High Accuracy" in used and "Balanced" in used  # Fig. 9b


def test_avery_beats_static_high_accuracy_throughput(logs):
    assert logs["avery"].mean_pps > logs["High Accuracy"].mean_pps  # Fig. 9d


def test_avery_iou_within_paper_band(logs):
    """Average IoU within 0.75% (abs) of the static High-Accuracy baseline
    — the paper's headline adaptation claim."""
    gap = logs["High Accuracy"].mean_iou - logs["avery"].mean_iou
    assert gap < 0.0075 * 1.5     # small slack over the paper's 0.75%


def test_avery_dominates_balanced_accuracy(logs):
    assert logs["avery"].mean_iou > logs["Balanced"].mean_iou
    assert logs["avery"].mean_iou > logs["High Throughput"].mean_iou


def test_static_high_accuracy_collapses_under_drop(logs):
    """During the sustained-drop phase the High-Accuracy tier cannot meet
    0.5 PPS (needs 11.68 Mbps), while AVERY keeps delivering (Fig. 9d)."""
    pps_ha = logs["High Accuracy"].pps_timeline(60.0)
    pps_av = logs["avery"].pps_timeline(60.0)
    drop_windows = [i for i in range(len(pps_ha))
                    if np.mean(TRACE.samples[i * 60:(i + 1) * 60]) < 10.0]
    assert drop_windows, "trace must contain a sustained drop"
    assert all(pps_av[i] >= 0.5 - 1e-6 for i in drop_windows)
    assert any(pps_ha[i] < 0.5 for i in drop_windows)


def test_timeliness_floor_met_when_feasible():
    """On a flat 12 Mbps link every delivered AVERY frame rate stays >= F_I."""
    log = run_mission(LUT, constant_trace(12.0, 600),
                      MissionSpec(mode="avery", duration_s=600))
    assert log.infeasible_s == 0
    pps = log.pps_timeline(60.0)
    assert all(p >= 0.5 - 1e-6 for p in pps[:-1])


def test_throughput_goal_yields_more_pps():
    a = run_mission(LUT, TRACE, MissionSpec(mode="avery"))
    t = run_mission(LUT, TRACE, MissionSpec(
        mode="avery", goal=MissionGoal.PRIORITIZE_THROUGHPUT))
    assert t.mean_pps > a.mean_pps
    assert a.mean_iou > t.mean_iou


def test_energy_scales_with_frames(logs):
    for log in logs.values():
        per_frame = log.total_edge_energy_j / max(1, len(log.frames))
        assert 2.0 < per_frame < 8.0     # J/frame at split@1 (Fig. 8 band)
