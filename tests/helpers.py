"""Shared test utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 1):
    rng = jax.random.PRNGKey(seed)
    if cfg.modality == "audio":
        return {
            "frames": jax.random.normal(rng, (batch, seq, cfg.frontend_dim)),
            "targets": jax.random.randint(rng, (batch, seq), 0,
                                          cfg.vocab_size),
            "mask_positions": jax.random.bernoulli(rng, 0.3, (batch, seq)),
        }
    if cfg.modality == "vlm":
        nv = cfg.num_vision_tokens
        side = max(1, int(round(nv ** 0.5)))
        pos = np.zeros((3, batch, seq), np.int32)
        pos[:, :, :] = np.arange(seq)[None, None, :]
        return {
            "tokens": jax.random.randint(rng, (batch, seq), 0, cfg.vocab_size),
            "vision_embeds": jax.random.normal(
                rng, (batch, nv, cfg.frontend_dim)),
            "positions": jnp.asarray(pos),
        }
    return {"tokens": jax.random.randint(rng, (batch, seq), 0,
                                         cfg.vocab_size)}


def finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(tree)
               if jnp.issubdtype(l.dtype, jnp.floating))
