"""LISA-mini pipeline: shapes, losses, short-training improvement, and the
bottleneck's effect on the Insight pathway (integration tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.lisa_mini import CONFIG as PCFG
from repro.core import bottleneck as bn
from repro.core import training, vlm
from repro.data import floodseg


@pytest.fixture(scope="module")
def params():
    return vlm.init_lisa(PCFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    rng = np.random.RandomState(0)
    b = floodseg.make_batch(rng, 4, "segment")
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_insight_forward_shapes(params, batch):
    mask_logits, answer_logits = vlm.insight_forward(
        params, PCFG, batch["images"], batch["query"])
    assert mask_logits.shape == (4, 32, 32)
    assert answer_logits.shape == (4, PCFG.llm.vocab_size)
    assert bool(jnp.all(jnp.isfinite(mask_logits)))


def test_context_forward_shapes(params, batch):
    logits = vlm.context_forward(params, PCFG, batch["images"],
                                 batch["query"])
    assert logits.shape == (4, PCFG.llm.vocab_size)


def test_losses_finite(params, batch):
    li, mi = vlm.insight_loss(params, PCFG, batch)
    assert bool(jnp.isfinite(li))
    rng = np.random.RandomState(1)
    ctx = {k: jnp.asarray(v)
           for k, v in floodseg.make_batch(rng, 4, "any").items()}
    lc, _ = vlm.context_loss(params, PCFG, ctx)
    assert bool(jnp.isfinite(lc))


def test_bottleneck_insertion_changes_little_at_high_rank(params, batch):
    d = PCFG.sam.d_model
    spec = bn.BottleneckSpec(d, d, 4)          # rank == d: near-lossless
    bp = bn.init_bottleneck(jax.random.PRNGKey(1), spec)
    # identity-ish bottleneck: enc/dec = I
    bp = {"enc": jnp.eye(d), "dec": jnp.eye(d)}
    m0, _ = vlm.insight_forward(params, PCFG, batch["images"], batch["query"])
    m1, _ = vlm.insight_forward(params, PCFG, batch["images"], batch["query"],
                                bn_params=bp)
    # identity projection + int8 quantisation: small perturbation only
    assert float(jnp.mean(jnp.abs(m0 - m1))) < 0.15 * float(
        jnp.mean(jnp.abs(m0)) + 1e-3)


@pytest.mark.slow
def test_short_training_improves_iou():
    """A short real training run must lift Average IoU well above the
    untrained baseline — the e2e learning path works."""
    params0 = vlm.init_lisa(PCFG, jax.random.PRNGKey(0))
    before = training.evaluate_insight(PCFG, params0, batches=2,
                                       batch_size=16)
    params = training.train_lisa(PCFG, steps=250, batch_size=16,
                                 log_every=0, log=lambda s: None)
    after = training.evaluate_insight(PCFG, params, batches=2, batch_size=16)
    assert after["avg_iou"] > before["avg_iou"] + 0.05
    assert after["avg_iou"] > 0.15


def test_iou_metrics_definition():
    logits = jnp.array([[[10.0, -10.0], [10.0, 10.0]]])   # pred 3 of 4
    gt = jnp.array([[[1.0, 0.0], [1.0, 1.0]]])
    m = vlm.iou_metrics(logits, gt)
    assert m["giou"] == pytest.approx(1.0)
    assert m["ciou"] == pytest.approx(1.0)
    # pred {(0,0),(0,1)} vs gt {(0,0),(1,0),(1,1)}: inter 1, union 4
    m2 = vlm.iou_metrics(jnp.array([[[10.0, 10.0], [-10.0, -10.0]]]), gt)
    assert m2["avg_iou"] == pytest.approx(0.25, abs=1e-5)
