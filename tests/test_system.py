"""End-to-end system behaviour: offline phase (train -> LUT) feeding the
online phase (dual-stream executor + Algorithm-1 control over a channel).
This is the paper's full workflow at proxy scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.lisa_mini import CONFIG as PCFG
from repro.core import (DualStreamExecutor, Intent, MissionGoal,
                        classify_intent, paper_lut)
from repro.core import profile as prof
from repro.core import training, vlm
from repro.data import floodseg, requests
from repro.network import Channel, paper_trace
from repro.runtime import MissionSpec, run_mission


@pytest.fixture(scope="module")
def system():
    """Small-budget offline phase: original + finetuned + one bottleneck."""
    params = training.train_lisa(PCFG, steps=300, batch_size=16,
                                 log_every=0, log=lambda s: None)
    bns = {0.25: training.train_bottleneck(PCFG, params, 0.25, steps=80,
                                           batch_size=16, log_every=0,
                                           log=lambda s: None)}
    return params, bns


@pytest.mark.slow
def test_build_lut_from_trained_system(system):
    params, bns = system
    lut = prof.build_lut(PCFG, params, params, bns, eval_batches=2)
    assert len(lut.tiers) == 1
    t = lut.tiers[0]
    assert t.name == "High Accuracy"
    assert 0.15 < t.acc_base <= 1.0
    # deployment payload must be in the paper's band (Table 3: 2.92 MB)
    assert 2.0 < t.payload_mb < 4.0
    assert lut.context.payload_mb < 3.0


@pytest.mark.slow
def test_dual_stream_executor_roundtrip(system):
    params, bns = system
    lut = prof.build_lut(PCFG, params, params, bns, eval_batches=1)
    execu = DualStreamExecutor(pcfg=PCFG, params=params,
                               bottlenecks={"High Accuracy": bns[0.25]},
                               lut=lut)
    rng = np.random.RandomState(0)
    b = floodseg.make_batch(rng, 2, "segment", augment=False)
    images, query = jnp.asarray(b["images"]), jnp.asarray(b["query"])

    pkt = execu.edge_insight(images, lut.tiers[0], 0, 0.0)
    assert pkt.payload_bytes > 0 and pkt.kind == "insight"
    mask_logits, answer_logits = execu.cloud_insight(pkt, query)
    assert mask_logits.shape == (2, 32, 32)

    cpkt, _ = execu.edge_context(images, 0, 0.0)
    assert cpkt.payload_bytes < pkt.payload_bytes   # context is lightweight
    logits = execu.cloud_context(cpkt, query)
    assert logits.shape == (2, PCFG.llm.vocab_size)

    # the compressed Insight packet must match the mini-scale payload model
    from repro.core import bottleneck as bn
    d = PCFG.sam.d_model
    rank = bn.rank_for_ratio(d, 0.25, 4)
    expected = 64 * rank  # 64 SAM-mini tokens of int8 codes dominate
    assert pkt.payload_bytes >= expected


@pytest.mark.slow
def test_mission_with_real_inference(system):
    """Closed-loop mission with real model inference in the fidelity oracle
    (executor mode) — short horizon."""
    params, bns = system
    lut = prof.build_lut(PCFG, params, params, bns, eval_batches=1)
    execu = DualStreamExecutor(pcfg=PCFG, params=params,
                               bottlenecks={"High Accuracy": bns[0.25]},
                               lut=lut)
    log = run_mission(lut, paper_trace(seed=3, duration_s=60),
                      MissionSpec(duration_s=60.0, mode="avery"),
                      executor=execu, pcfg=PCFG)
    assert len(log.frames) >= 20
    assert 0.0 < log.mean_iou <= 1.0


def test_intent_gate_routes_mission_requests():
    ctx = ins = 0
    for req in requests.mission_requests(0, 300.0):
        intent = classify_intent(req.prompt)
        if req.kind == "segment":
            assert intent is Intent.INSIGHT, req.prompt
            ins += 1
        else:
            assert intent is Intent.CONTEXT, req.prompt
            ctx += 1
    assert ctx > 10 and ins > 10
