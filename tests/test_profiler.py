"""Device-level observability: the stage profiler (opt-in knob, stats
surface, Perfetto device track, <5% overhead + token-exactness), the
cost/energy ledger against the analytic ``network/energy.py`` model,
and the compile observatory's pool-growth visibility."""
import json
import time

import numpy as np
import pytest

from repro.core.intent import Intent
from repro.engine import AveryEngine, StageProfiler
from repro.engine.observability import (DEVICE_TRACK_PID,
                                        validate_chrome_trace)
from repro.engine.profiler import PROFILED_STAGES

from test_engine import LUT, StubExecutor, _edge_requests

BASE_SNAPSHOT = "tests/fixtures/engine_stats_keys.json"
PROFILED_SNAPSHOT = "tests/fixtures/engine_stats_keys_profiled.json"


@pytest.fixture(scope="module")
def executor():
    from repro.configs.lisa_mini import CONFIG as PCFG
    from repro.core import DualStreamExecutor, profile as prof
    params, bns, _ = prof.random_init_system(PCFG, lut=LUT)
    return DualStreamExecutor(pcfg=PCFG, params=params, bottlenecks=bns,
                              lut=LUT, max_new_tokens=3, flash_decode=False)


def _profiled_engine(executor, **kw):
    kw.setdefault("wallclock", time.perf_counter)
    return AveryEngine(lut=LUT, executor=executor, batching="inflight",
                       profile=True, **kw)


# ---- the opt-in knob ----


def test_profile_requires_wallclock():
    """Engine code never reads the wall clock itself (AV502/AV603):
    ``profile=True`` without an injected wallclock must refuse."""
    with pytest.raises(ValueError, match="wallclock"):
        AveryEngine(lut=LUT, executor=StubExecutor(), profile=True)
    with pytest.raises(ValueError, match="wallclock"):
        StageProfiler(wallclock=None)


# ---- stats() surface: off-path byte-identical, on-path pinned ----


def test_profiled_stats_key_snapshot(executor):
    """With the profiler on, stats() grows exactly the pinned profiler
    key block — and nothing else. Together with PR 9's base snapshot
    test (which runs the same scenario with the profiler off against
    the unchanged base fixture), this proves the off-by-default path
    leaves the stats surface byte-identical."""
    from pathlib import Path
    reqs = _edge_requests(executor, 3, seed=11)
    engine = _profiled_engine(executor, max_batch=2)
    for i, (p, q, it) in enumerate(reqs):
        engine.submit_packet(p, q, it, time_s=float(i))
    engine.drain()
    keys = sorted(engine.stats)
    fixtures = Path(__file__).resolve().parent / "fixtures"
    expected = json.loads((fixtures /
                           "engine_stats_keys_profiled.json").read_text())
    assert keys == expected, (
        "profiled engine.stats() keys changed; if intentional, update "
        f"{PROFILED_SNAPSHOT} in the same commit")
    base = json.loads((fixtures / "engine_stats_keys.json").read_text())
    extra = sorted(set(keys) - set(base))
    per_stage = [k for s in PROFILED_STAGES
                 for k in (f"stage_{s}_calls", f"stage_{s}_p50_s")]
    assert extra == sorted(per_stage + [
        "profiled_stage_calls", "profiled_wall_s", "compile_events",
        "compile_wall_s", "compiled_roots", "ledger_flops_total",
        "ledger_hbm_bytes_total", "ledger_energy_j_total",
        "decode_roofline_frac"])
    assert set(base) <= set(keys)          # profiler only adds
    st = engine.stats
    assert st["profiled_stage_calls"] > 0
    assert st["profiled_wall_s"] > 0.0
    assert st["stage_cloud_decode_rows_calls"] > 0
    assert st["stage_draft_calls"] == 0    # no speculative decode ran


# ---- Perfetto device track ----


def test_device_track_in_chrome_export(executor, tmp_path):
    """A profiled + traced serve exports the device stages as their own
    Perfetto process (pid 3) alongside the operator/slot tracks, and
    the merged document still validates."""
    reqs = _edge_requests(executor, 3, seed=11)
    engine = _profiled_engine(executor, max_batch=2, trace=True)
    for i, (p, q, it) in enumerate(reqs):
        engine.submit_packet(p, q, it, time_s=float(i))
    engine.drain()
    path = engine.dump_trace(str(tmp_path / "profiled.json"))
    doc = json.loads(open(path).read())
    assert validate_chrome_trace(doc) == []
    device = [e for e in doc["traceEvents"]
              if e.get("pid") == DEVICE_TRACK_PID and e.get("ph") == "X"]
    assert device, "no device spans in the export"
    stages = {e["name"] for e in device}
    assert stages <= set(PROFILED_STAGES)
    assert "cloud_decode_rows" in stages and "cloud_prefix" in stages
    # the track is labelled for the Perfetto UI
    names = [e for e in doc["traceEvents"]
             if e.get("ph") == "M" and e.get("pid") == DEVICE_TRACK_PID]
    assert any(e["name"] == "process_name" for e in names)
    labelled = {e["args"]["name"] for e in names
                if e["name"] == "thread_name"}
    assert stages <= labelled
    # operator (pid 1) and slot (pid 2) tracks survive the merge
    pids = {e.get("pid") for e in doc["traceEvents"]}
    assert {1, 2, DEVICE_TRACK_PID} <= pids


# ---- cost/energy ledger vs the analytic model ----


def test_ledger_matches_analytic_model(executor):
    """On a pinned config, a single prefix-miss request's ledger equals
    the closed-form ``network/energy.py`` cost: one full-sequence
    prefill plus one decode token per step at its attended context
    length (T = max_new_tokens steps, the last one scoring <SEG>)."""
    from repro.network.energy import (CloudDevice, decode_token_flops,
                                      decode_token_hbm_bytes,
                                      encoder_flops)
    pkt, q, it = _edge_requests(executor, 1, seed=7)[0]
    engine = _profiled_engine(executor, max_batch=1)
    fut = engine.submit_packet(pkt, q, it, time_s=0.0)
    engine.drain()
    r = fut.result()
    assert r.failure is None

    pcfg = executor.pcfg
    prefix_len = pcfg.clip_tokens + int(np.asarray(q).shape[-1])
    T = executor.max_new_tokens
    flops = encoder_flops(pcfg.llm, prefix_len) + sum(
        decode_token_flops(pcfg.llm, prefix_len + i)
        for i in range(1, T + 1))
    hbm = sum(decode_token_hbm_bytes(pcfg.llm, prefix_len + i)
              for i in range(1, T + 1))
    assert r.cloud_flops == pytest.approx(flops, rel=1e-9)
    assert r.cloud_hbm_bytes == pytest.approx(hbm, rel=1e-9)
    assert r.cloud_energy_j == pytest.approx(
        CloudDevice().compute_energy_j(flops), rel=1e-9)
    # the engine-level ledger is the sum over responses (here: one)
    st = engine.stats
    assert st["ledger_flops_total"] == pytest.approx(r.cloud_flops)
    assert st["ledger_hbm_bytes_total"] == pytest.approx(
        r.cloud_hbm_bytes)
    assert st["ledger_energy_j_total"] == pytest.approx(r.cloud_energy_j)
    # achieved vs roofline: a fraction, strictly positive on a real run
    assert 0.0 < st["decode_roofline_frac"] < 1.0


def test_ledger_absent_without_profiler(executor):
    """The ledger rides the profiler knob: an unprofiled response keeps
    the cost fields at None (no silent zero-cost claims)."""
    pkt, q, it = _edge_requests(executor, 1, seed=7)[0]
    engine = AveryEngine(lut=LUT, executor=executor, batching="inflight",
                         max_batch=1)
    fut = engine.submit_packet(pkt, q, it, time_s=0.0)
    engine.drain()
    r = fut.result()
    assert r.failure is None
    assert r.cloud_flops is None and r.cloud_hbm_bytes is None
    assert r.cloud_energy_j is None


# ---- compile observatory: pool growth is a spike, not an exception ----


def test_pool_growth_compile_spike_is_visible(executor):
    """PR 8's ``debug_recompiles`` turns pool-growth churn into a hard
    error; the observatory (no debug knob) turns it into telemetry: a
    tiny pool served distinct-prefix requests, the forced growth
    recompiled the decode stages, the counter rose, serving continued,
    and the flight recorder kept the events."""
    import jax.numpy as jnp

    from repro.data import floodseg
    rng = np.random.RandomState(311)

    def submit(engine, i):
        b = floodseg.make_batch(rng, 1, "segment", augment=False)
        pkt = executor.edge_insight(jnp.asarray(b["images"]),
                                    LUT.tiers[0], i, 0.0)
        return engine.submit_packet(pkt, b["query"], Intent.INSIGHT,
                                    time_s=float(i),
                                    session=engine.session(f"uav-{i}"))

    engine = _profiled_engine(executor, max_batch=4, kv_pages=2)
    futs = [submit(engine, 0)]
    engine.drain()
    warm = engine.stats["compile_events"]       # cold-cache compiles
    pages0 = engine.stats["kv_pages_total"]
    # enough distinct prefixes to outgrow the first prefill's capacity
    # hint: the pool doubles mid-flight, the decode shapes change, and
    # the paged stages recompile
    futs += [submit(engine, i) for i in range(1, 8)]
    engine.drain()
    st = engine.stats
    assert st["compile_events"] > warm, (
        "pool growth recompiled nothing visible")
    assert st["kv_pages_total"] > pages0    # the pool really grew
    assert st["compile_wall_s"] > 0.0 and st["compiled_roots"] > 0
    assert all(f.result().failure is None for f in futs)
    compiles = [e for e in engine.flight.snapshot()
                if e["kind"] == "compile"]
    assert compiles
    assert all(e["data"]["delta"] >= 1 and e["data"]["root"]
               for e in compiles)


# ---- overhead budget + token-exactness ----


def test_profiler_overhead_and_token_exactness(executor):
    """Profiling must be cheap enough to leave on for benches (<5% of
    bare wall time, plus a small epsilon against timer noise) and must
    not perturb the serve: profiled responses are token-exact with
    bare ones."""
    reqs = _edge_requests(executor, 4, seed=5)

    def run(profile):
        t0 = time.perf_counter()
        engine = AveryEngine(
            lut=LUT, executor=executor, batching="inflight", max_batch=4,
            profile=profile,
            wallclock=time.perf_counter if profile else None)
        futs = [engine.submit_packet(p, q, it, time_s=float(i))
                for i, (p, q, it) in enumerate(reqs)]
        engine.drain()
        toks = [np.asarray(f.result().tokens).tolist() for f in futs]
        return time.perf_counter() - t0, toks

    run(False)                            # warm the compiled stages
    run(True)                             # ...and the profiled wrappers
    bare = min(run(False)[0] for _ in range(3))
    t_prof, toks_prof = min((run(True) for _ in range(3)),
                            key=lambda r: r[0])
    assert toks_prof == run(False)[1]     # profiling never changes tokens
    assert t_prof <= bare * 1.05 + 0.02, (
        f"profiler overhead too high: {t_prof:.4f}s profiled vs "
        f"{bare:.4f}s bare")
