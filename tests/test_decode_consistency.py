"""Decode path must reproduce full-sequence forward logits step by step —
validates cache bookkeeping, rotary offsets, ring buffers, SSM recurrence
and MLA absorbed-matmul decode across every attention/mixer family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.models import (HybridConfig, MLAConfig, MoEConfig, ModelConfig,
                          SSMConfig, decode_step, forward, init_cache,
                          init_params)

B, S = 2, 16

CASES = [
    ModelConfig(name="gqa", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97),
    ModelConfig(name="sw", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                sliding_window=8),
    ModelConfig(name="mla", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=97,
                attn_type="mla",
                mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)),
    ModelConfig(name="moe", arch_type="moe", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                              capacity_factor=8.0)),
    ModelConfig(name="mamba1", arch_type="ssm", num_layers=2, d_model=64,
                num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=97,
                attn_type="none", rope_style="none",
                ssm=SSMConfig(version=1, state_size=4)),
    ModelConfig(name="mamba2", arch_type="ssm", num_layers=2, d_model=64,
                num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=97,
                attn_type="none", rope_style="none",
                ssm=SSMConfig(version=2, state_size=8, head_dim=16)),
    ModelConfig(name="hybrid", arch_type="hybrid", num_layers=4, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=97,
                ssm=SSMConfig(version=2, state_size=8, head_dim=16),
                hybrid=HybridConfig(attn_every=2)),
]


@pytest.mark.parametrize("cfg", CASES, ids=lambda c: c.name)
def test_decode_matches_forward(cfg):
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    logits_full, *_ = forward(params, cfg, {"tokens": tokens})
    if cfg.sliding_window:
        # full forward masks by window; decode must agree within the window
        pass
    cache = init_cache(cfg, B, S if not cfg.sliding_window
                       else cfg.sliding_window)
    dec = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
    outs = []
    for t in range(S):
        lg, cache = dec(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(logits_full - logits_dec)))
    scale = float(jnp.max(jnp.abs(logits_full))) + 1.0
    assert err < 2e-3 * scale, f"{cfg.name}: decode mismatch {err}"


# ---- paged serving cache vs the contiguous generate path ----


@pytest.mark.parametrize("flash", [False, True], ids=["xla", "flash"])
def test_paged_decode_matches_llm_generate(flash):
    """The paged KV cache (page pool + page tables, the serving engine's
    layout) is token-exact against the contiguous ``llm_generate``: same
    greedy tokens, same first-token logits, same <SEG> embedding. Pages
    are laid out non-contiguously and a second batch row shares the
    prefix pages read-only — the multi-UAV serving configuration."""
    import numpy as np

    from repro.configs.lisa_mini import CONFIG as PCFG
    from repro.core import vlm
    from repro.core.paging import pages_for, prefix_positions

    pcfg = dataclasses.replace(
        PCFG, llm=PCFG.llm.replace(use_flash_decode=flash))
    params = vlm.init_lisa(pcfg, jax.random.PRNGKey(0))
    qlen, T, page = 8, 4, 16
    ctx = jax.random.normal(jax.random.PRNGKey(1),
                            (1, pcfg.clip_tokens, pcfg.llm.d_model))
    query = jax.random.randint(jax.random.PRNGKey(2), (1, qlen), 0,
                               pcfg.llm.vocab_size)
    tokens_ref, logits0_ref, seg_ref = vlm.llm_generate(params, pcfg, ctx,
                                                        query, T)

    S = pcfg.clip_tokens + qlen
    n_prefix, n_private = pages_for(S, page), pages_for(T, page)
    logits0, _, paged = vlm.llm_prefill_paged(params, pcfg, ctx, query, page)
    np.testing.assert_allclose(np.asarray(logits0), np.asarray(logits0_ref),
                               atol=1e-5)

    # pool: trash page 0, then scattered prefix/private pages; two rows
    # share the prefix read-only, each with its own private decode pages
    B = 2
    P = 1 + n_prefix + B * n_private
    prefix_ids = np.arange(1, 1 + n_prefix)
    pool = {"groups": [jax.tree.map(
        lambda a: jnp.zeros((a.shape[0], P) + a.shape[3:], a.dtype)
        .at[:, prefix_ids].set(a[:, 0]), paged["groups"][0])]}
    pt = np.zeros((B, n_prefix + n_private), np.int32)
    positions = np.full((B, (n_prefix + n_private) * page), -1, np.int32)
    for b in range(B):
        priv = 1 + n_prefix + b * n_private
        pt[b] = list(prefix_ids) + list(range(priv, priv + n_private))
        positions[b, :n_prefix * page] = prefix_positions(S, n_prefix, page)

    toks = [int(jnp.argmax(logits0[0]))]
    base = n_prefix * page
    seg = None
    for t in range(T):
        tk = np.full((B, 1), toks[-1], np.int32)
        pos = np.full((B,), S + t, np.int32)
        ws = np.full((B,), base + t, np.int32)
        logits, seg, pool = vlm.llm_decode_step_paged(
            params, pcfg, pool, pt, positions, tk, pos, ws)
        positions[:, base + t] = S + t
        if t < T - 1:
            toks.append(int(jnp.argmax(logits[0])))
    assert np.array_equal(np.asarray(tokens_ref)[0], np.asarray(toks))
    # both rows decoded the same sequence; row 1 through shared prefix
    # pages — identical hidden states prove the pages were untouched
    seg = np.asarray(seg)
    scale = float(jnp.max(jnp.abs(seg_ref))) + 1.0
    assert float(np.max(np.abs(seg[0] - np.asarray(seg_ref)[0]))) \
        < 2e-3 * scale
    np.testing.assert_allclose(seg[0], seg[1], atol=1e-6)
